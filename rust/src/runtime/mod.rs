//! PJRT runtime: load AOT-compiled HLO artifacts (emitted by
//! `python/compile/aot.py`) and execute them from Rust on the request
//! path. Python never runs at execution time — the interchange format is
//! HLO *text* (the bundled xla_extension 0.5.1 rejects jax >= 0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! The PJRT client itself comes from the `xla` crate, which is not
//! vendored in the offline build environment; it is gated behind the
//! non-default `pjrt` cargo feature (see `Cargo.toml`). Without the
//! feature, [`PjrtRuntime`] keeps the same API but `open` fails with a
//! clear error and the FFT app stays on its naive Rust backend.

pub mod manifest;

use std::path::Path;

pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "pjrt")]
mod client {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::Manifest;
    use crate::error::{Result, TunaError};

    /// A compiled-executable cache over a PJRT CPU client.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        artifacts_dir: PathBuf,
        manifest: Manifest,
    }

    impl PjrtRuntime {
        /// Open the runtime against an artifacts directory containing
        /// `manifest.tsv` plus `*.hlo.txt` files.
        pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
            let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&artifacts_dir.join("manifest.tsv"))?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| TunaError::runtime(format!("PJRT CPU client: {e}")))?;
            Ok(PjrtRuntime {
                client,
                executables: HashMap::new(),
                artifacts_dir,
                manifest,
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// True if the manifest advertises `name`.
        pub fn has(&self, name: &str) -> bool {
            self.manifest.get(name).is_some()
        }

        fn ensure_compiled(&mut self, name: &str) -> Result<()> {
            if self.executables.contains_key(name) {
                return Ok(());
            }
            let entry = self
                .manifest
                .get(name)
                .ok_or_else(|| TunaError::runtime(format!("artifact `{name}` not in manifest")))?;
            let path = self.artifacts_dir.join(&entry.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| TunaError::runtime("non-utf8 artifact path"))?,
            )
            .map_err(|e| TunaError::runtime(format!("parse {path:?}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| TunaError::runtime(format!("compile `{name}`: {e}")))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute artifact `name` on f32 tensors `(data, dims)`; returns the
        /// flattened f32 contents of each tuple element (artifacts are lowered
        /// with `return_tuple=True`).
        pub fn execute_f32(
            &mut self,
            name: &str,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            self.ensure_compiled(name)?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let n: i64 = dims.iter().product();
                if n as usize != data.len() {
                    return Err(TunaError::runtime(format!(
                        "artifact `{name}`: input has {} elements but dims {:?}",
                        data.len(),
                        dims
                    )));
                }
                let lit = xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| TunaError::runtime(format!("reshape: {e}")))?;
                literals.push(lit);
            }
            let exe = self.executables.get(name).expect("just compiled");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| TunaError::runtime(format!("execute `{name}`: {e}")))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| TunaError::runtime(format!("fetch result: {e}")))?;
            let parts = out
                .to_tuple()
                .map_err(|e| TunaError::runtime(format!("untuple: {e}")))?;
            parts
                .into_iter()
                .map(|lit| {
                    lit.to_vec::<f32>()
                        .map_err(|e| TunaError::runtime(format!("to_vec: {e}")))
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod client {
    use std::path::Path;

    use super::Manifest;
    use crate::error::{Result, TunaError};

    /// API-compatible stub used without the `pjrt` feature. The manifest
    /// is still checked first so a missing `make artifacts` run produces
    /// the same actionable error as the real client.
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
            let dir = artifacts_dir.as_ref();
            let _ = Manifest::load(&dir.join("manifest.tsv"))?;
            Err(TunaError::runtime(
                "PJRT runtime unavailable: tuna was built without the `pjrt` \
                 cargo feature (see rust/Cargo.toml)",
            ))
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "unavailable (pjrt feature disabled)".to_string()
        }

        pub fn has(&self, name: &str) -> bool {
            self.manifest.get(name).is_some()
        }

        pub fn execute_f32(
            &mut self,
            name: &str,
            _inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            Err(TunaError::runtime(format!(
                "cannot execute artifact `{name}`: built without the `pjrt` feature"
            )))
        }
    }
}

pub use client::PjrtRuntime;

/// True when this build can actually execute PJRT artifacts.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// True when `dir` looks like an artifacts directory (has a manifest).
pub fn artifacts_present(dir: impl AsRef<Path>) -> bool {
    dir.as_ref().join("manifest.tsv").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_fails_without_manifest() {
        match PjrtRuntime::open("/nonexistent-dir") {
            Ok(_) => panic!("open must fail without a manifest"),
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("manifest") || msg.contains("I/O"), "{msg}");
            }
        }
    }

    #[test]
    fn availability_matches_feature() {
        assert_eq!(pjrt_available(), cfg!(feature = "pjrt"));
        assert!(!artifacts_present("/nonexistent-dir"));
    }

    // Execution against real artifacts is covered by
    // `tests/runtime_pjrt.rs` (requires the `pjrt` feature and skips
    // gracefully when `make artifacts` has not run) and the fft_e2e
    // example.
}
