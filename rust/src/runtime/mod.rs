//! PJRT runtime: load AOT-compiled HLO artifacts (emitted by
//! `python/compile/aot.py`) and execute them from Rust on the request
//! path. Python never runs at execution time — the interchange format is
//! HLO *text* (the bundled xla_extension 0.5.1 rejects jax >= 0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, TunaError};

pub use manifest::{Manifest, ManifestEntry};

/// A compiled-executable cache over a PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    artifacts_dir: PathBuf,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Open the runtime against an artifacts directory containing
    /// `manifest.tsv` plus `*.hlo.txt` files.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&artifacts_dir.join("manifest.tsv"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| TunaError::runtime(format!("PJRT CPU client: {e}")))?;
        Ok(PjrtRuntime {
            client,
            executables: HashMap::new(),
            artifacts_dir,
            manifest,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// True if the manifest advertises `name`.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .get(name)
            .ok_or_else(|| TunaError::runtime(format!("artifact `{name}` not in manifest")))?;
        let path = self.artifacts_dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| TunaError::runtime("non-utf8 artifact path"))?,
        )
        .map_err(|e| TunaError::runtime(format!("parse {path:?}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| TunaError::runtime(format!("compile `{name}`: {e}")))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on f32 tensors `(data, dims)`; returns the
    /// flattened f32 contents of each tuple element (artifacts are lowered
    /// with `return_tuple=True`).
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let n: i64 = dims.iter().product();
            if n as usize != data.len() {
                return Err(TunaError::runtime(format!(
                    "artifact `{name}`: input has {} elements but dims {:?}",
                    data.len(),
                    dims
                )));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| TunaError::runtime(format!("reshape: {e}")))?;
            literals.push(lit);
        }
        let exe = self.executables.get(name).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| TunaError::runtime(format!("execute `{name}`: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| TunaError::runtime(format!("fetch result: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| TunaError::runtime(format!("untuple: {e}")))?;
        parts
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .map_err(|e| TunaError::runtime(format!("to_vec: {e}")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_fails_without_manifest() {
        match PjrtRuntime::open("/nonexistent-dir") {
            Ok(_) => panic!("open must fail without a manifest"),
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("manifest") || msg.contains("I/O"), "{msg}");
            }
        }
    }

    // Execution against real artifacts is covered by
    // `tests/runtime_pjrt.rs` (skips gracefully when `make artifacts` has
    // not run) and the fft_e2e example.
}
