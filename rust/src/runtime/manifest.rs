//! Artifact manifest: a deliberately trivial TSV (`name\tpath\tinfo`)
//! written by `python/compile/aot.py`, so the Rust side needs no JSON
//! dependency offline.

use std::path::Path;

use crate::error::{Result, TunaError};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    /// Path relative to the artifacts directory.
    pub path: String,
    /// Free-form description (shapes, dtypes).
    pub info: String,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            TunaError::runtime(format!(
                "manifest {path:?}: {e} (run `make artifacts` first)"
            ))
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, '\t');
            let name = parts.next().unwrap_or("").to_string();
            let path = parts.next().unwrap_or("").to_string();
            let info = parts.next().unwrap_or("").to_string();
            if name.is_empty() || path.is_empty() {
                return Err(TunaError::runtime(format!(
                    "manifest line {}: expected name\\tpath[\\tinfo]",
                    lineno + 1
                )));
            }
            entries.push(ManifestEntry { name, path, info });
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tsv_with_comments() {
        let m = Manifest::parse(
            "# artifacts\nstage1_8x64\tstage1_8x64.hlo.txt\tf32[8,64] x6 -> (re, im)\n\nstage2_64x8\tstage2_64x8.hlo.txt\t\n",
        )
        .unwrap();
        assert_eq!(m.entries().len(), 2);
        assert_eq!(m.get("stage1_8x64").unwrap().path, "stage1_8x64.hlo.txt");
        assert!(m.get("stage1_8x64").unwrap().info.contains("f32"));
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(Manifest::parse("just-a-name\n").is_err());
    }
}
