//! Parameter selection: the paper's radix heuristic (§V-A), a
//! measurement-driven autotuner (what Fig. 9's "ideal r" annotations come
//! from), and persisted, versioned *tuning tables* so repeat runs (and
//! the figure harnesses) can look an answer up instead of re-sweeping.
//!
//! Observed trends (§V-A, Fig. 7):
//! * small S (latency-bound) → small radix (few rounds ⇒ r≈2 minimizes
//!   per-round latency only when rounds dominate — empirically the ideal
//!   *rises* as S shrinks only on the far-small end; the paper reports
//!   ideal r ≈ 2 for S ≤ 512 B);
//! * medium S → r ≈ √P balances rounds against duplicate data;
//! * large S (bandwidth-bound) → r ≈ P minimizes total transmitted bytes.

use std::path::{Path, PathBuf};

use super::AlgoKind;
use crate::comm::Engine;
use crate::error::TunaError;
use crate::workload::BlockSizes;

/// The §V-A rule of thumb: pick a radix from the average block size.
/// Thresholds follow the paper's Polaris observations (small: ≤512 B,
/// medium: ≤8 KiB, large: above).
pub fn heuristic_radix(p: usize, mean_block_size: f64) -> usize {
    let r = if mean_block_size <= 256.0 {
        // S/2 <= 256 <=> S <= 512: latency-dominated.
        2
    } else if mean_block_size <= 4096.0 {
        // Medium: sqrt(P) balances K against D.
        (p as f64).sqrt().round() as usize
    } else {
        // Bandwidth-dominated: minimize duplicate transfers.
        p
    };
    r.clamp(2, p.max(2))
}

/// Candidate radices for sweeps: powers of two, √P, and P itself —
/// the grid used for the box plots (Fig. 8) and heatmaps (Fig. 9).
pub fn radix_candidates(p: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut r = 2usize;
    while r < p {
        out.push(r);
        r *= 2;
    }
    let sqrt = (p as f64).sqrt().round() as usize;
    if sqrt >= 2 {
        out.push(sqrt);
    }
    out.push(p.max(2));
    out.sort_unstable();
    out.dedup();
    out
}

/// Candidate block_counts: powers of two up to `max`, plus `max`.
pub fn block_count_candidates(max: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < max {
        out.push(b);
        b *= 2;
    }
    out.push(max.max(1));
    out.sort_unstable();
    out.dedup();
    out
}

/// Result of an autotuning sweep.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: AlgoKind,
    pub best_time: f64,
    /// Every candidate with its simulated time.
    pub sweep: Vec<(AlgoKind, f64)>,
}

/// Pick the best TuNA radix for a workload by simulated measurement.
pub fn autotune_tuna(engine: &Engine, sizes: &BlockSizes) -> crate::Result<TuneResult> {
    let candidates: Vec<AlgoKind> = radix_candidates(engine.topo.p())
        .into_iter()
        .map(|radix| AlgoKind::Tuna { radix })
        .collect();
    sweep(engine, sizes, &candidates)
}

/// Pick the best (local radix, block_count) for the paper's TuNA-local
/// hierarchy pairings (coalesced = Alg. 3, staggered = Alg. 2).
pub fn autotune_hier(
    engine: &Engine,
    sizes: &BlockSizes,
    coalesced: bool,
) -> crate::Result<TuneResult> {
    let q = engine.topo.q();
    let n = engine.topo.nodes();
    let bc_max = if coalesced { (n - 1).max(1) } else { ((n - 1) * q).max(1) };
    let mut candidates = Vec::new();
    for radix in radix_candidates(q).into_iter().filter(|&r| r <= q) {
        for bc in block_count_candidates(bc_max) {
            candidates.push(if coalesced {
                AlgoKind::hier_coalesced(radix, bc)
            } else {
                AlgoKind::hier_staggered(radix, bc)
            });
        }
    }
    sweep(engine, sizes, &candidates)
}

/// Evaluate a candidate list and return the argmin by simulated makespan.
pub fn sweep(
    engine: &Engine,
    sizes: &BlockSizes,
    candidates: &[AlgoKind],
) -> crate::Result<TuneResult> {
    assert!(!candidates.is_empty());
    let mut sweep = Vec::with_capacity(candidates.len());
    for kind in candidates {
        let rep = super::run_alltoallv(engine, kind, sizes, false)?;
        sweep.push((*kind, rep.makespan));
    }
    let (best, best_time) = sweep
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .copied()
        .unwrap();
    Ok(TuneResult {
        best,
        best_time,
        sweep,
    })
}

// ---- persisted tuning tables ---------------------------------------------

/// Default on-disk location for tuning tables, relative to the working
/// directory (next to the PJRT artifacts, which share their lifecycle).
pub const DEFAULT_TABLE_DIR: &str = "artifacts/tuning";

/// Path of `machine`'s table inside a tuning-table directory.
pub fn table_path(dir: &Path, machine: &str) -> PathBuf {
    dir.join(format!("{machine}.tsv"))
}

/// One row of a persisted tuning table: a candidate's position in the
/// selector's ranking for one (machine, P, Q, workload) scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct TuningEntry {
    pub machine: String,
    pub p: usize,
    pub q: usize,
    /// Distribution short name (`Dist::name`).
    pub dist: String,
    /// Mean block size of the scenario's workload, bytes.
    pub mean_block: f64,
    /// 1-based rank; 1 is the selected algorithm.
    pub rank: usize,
    pub algo: AlgoKind,
    /// Analytic-model makespan estimate, seconds.
    pub model_time: f64,
    /// Engine-measured median, seconds, when the selector refined this
    /// candidate.
    pub measured_time: Option<f64>,
}

/// A versioned, mergeable TSV tuning table (`artifacts/tuning/*.tsv`).
/// The format is line-oriented so tables diff cleanly in review:
///
/// ```text
/// # tuna-tuning-table v1
/// # machine  p  q  dist  mean_block  rank  algo  model_time  measured_time
/// fugaku  256  32  uniform  2.56e2  1  hier:l=tuna:r=2,g=coalesced:b=1  1.1e-4  1.2e-4
/// ```
#[derive(Clone, Debug, Default)]
pub struct TuningTable {
    pub entries: Vec<TuningEntry>,
}

fn scenario_key(e: &TuningEntry) -> (String, usize, usize, String, String) {
    // The mean is keyed via a fixed text rendering so float noise cannot
    // split one scenario into two.
    (
        e.machine.clone(),
        e.p,
        e.q,
        e.dist.clone(),
        format!("{:.6e}", e.mean_block),
    )
}

impl TuningTable {
    pub const VERSION_HEADER: &'static str = "# tuna-tuning-table v1";
    const COLUMNS: &'static str =
        "# machine\tp\tq\tdist\tmean_block\trank\talgo\tmodel_time\tmeasured_time";

    pub fn to_tsv(&self) -> String {
        let mut out = format!("{}\n{}\n", Self::VERSION_HEADER, Self::COLUMNS);
        for e in &self.entries {
            let measured = match e.measured_time {
                Some(t) => format!("{t:.9e}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\t{:.6e}\t{}\t{}\t{:.9e}\t{}\n",
                e.machine,
                e.p,
                e.q,
                e.dist,
                e.mean_block,
                e.rank,
                e.algo.spec(),
                e.model_time,
                measured,
            ));
        }
        out
    }

    /// Parse a table, rejecting unknown versions (the format is the
    /// contract between tuning runs and later lookups).
    pub fn parse(text: &str) -> crate::Result<TuningTable> {
        let mut lines = text.lines();
        match lines.next().map(str::trim) {
            Some(first) if first == Self::VERSION_HEADER => {}
            other => {
                return Err(TunaError::config(format!(
                    "tuning table: expected `{}`, found {:?}",
                    Self::VERSION_HEADER,
                    other
                )))
            }
        }
        let mut entries = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let bad = |what: &str| {
                TunaError::config(format!("tuning table line {}: {what}", lineno + 2))
            };
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 9 {
                return Err(bad(&format!("expected 9 columns, got {}", cols.len())));
            }
            entries.push(TuningEntry {
                machine: cols[0].to_string(),
                p: cols[1].parse().map_err(|_| bad("bad p"))?,
                q: cols[2].parse().map_err(|_| bad("bad q"))?,
                dist: cols[3].to_string(),
                mean_block: cols[4].parse().map_err(|_| bad("bad mean_block"))?,
                rank: cols[5].parse().map_err(|_| bad("bad rank"))?,
                algo: AlgoKind::parse(cols[6])?,
                model_time: cols[7].parse().map_err(|_| bad("bad model_time"))?,
                measured_time: match cols[8] {
                    "-" => None,
                    v => Some(v.parse().map_err(|_| bad("bad measured_time"))?),
                },
            });
        }
        Ok(TuningTable { entries })
    }

    pub fn load(path: &Path) -> crate::Result<TuningTable> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Merge `incoming`: every scenario it covers replaces the stored
    /// rows for that scenario wholesale (rankings are atomic).
    pub fn merge_from(&mut self, incoming: TuningTable) {
        let keys: std::collections::HashSet<_> =
            incoming.entries.iter().map(scenario_key).collect();
        self.entries.retain(|e| !keys.contains(&scenario_key(e)));
        self.entries.extend(incoming.entries);
    }

    /// Write this table to `path`, merging into whatever is already
    /// stored there (so one file accumulates many scenarios). Tables are
    /// regenerable caches, not sources of truth: an existing file that
    /// fails to parse (corrupt, or a future version) is replaced rather
    /// than propagating an error.
    pub fn save_merged(&self, path: &Path) -> crate::Result<()> {
        let mut on_disk = if path.exists() {
            Self::load(path).unwrap_or_default()
        } else {
            TuningTable::default()
        };
        on_disk.merge_from(self.clone());
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, on_disk.to_tsv())?;
        Ok(())
    }

    /// The selected (rank-1) algorithm for a machine/topology, matched on
    /// the nearest stored mean block size. Returns `None` unless a
    /// snapshot within 2x of `mean_block` exists — extrapolating further
    /// is worse than falling back to the heuristic or re-selecting.
    pub fn lookup(
        &self,
        machine: &str,
        p: usize,
        q: usize,
        mean_block: f64,
    ) -> Option<&TuningEntry> {
        let mut best: Option<(&TuningEntry, f64)> = None;
        for e in &self.entries {
            if e.rank != 1 || e.machine != machine || e.p != p || e.q != q {
                continue;
            }
            let d = (e.mean_block.max(1.0) / mean_block.max(1.0)).ln().abs();
            if best.as_ref().map(|b| d < b.1).unwrap_or(true) {
                best = Some((e, d));
            }
        }
        best.and_then(|(e, d)| (d <= std::f64::consts::LN_2 + 1e-12).then_some(e))
    }

    /// The stored best as a flat-TuNA radix for `tuna:auto` dispatch:
    /// `Some(r)` when this scenario's rank-1 entry is a TuNA configuration
    /// runnable at P (Bruck2 counts as radix 2), `None` otherwise — a
    /// table whose winner is a different family cannot override the
    /// caller's choice to run TuNA, so dispatch falls back to the §V-A
    /// heuristic.
    pub fn lookup_radix(
        &self,
        machine: &str,
        p: usize,
        q: usize,
        mean_block: f64,
    ) -> Option<usize> {
        match self.lookup(machine, p, q, mean_block)?.algo {
            AlgoKind::Tuna { radix } if (2..=p.max(2)).contains(&radix) => Some(radix),
            AlgoKind::Bruck2 => Some(2),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::model::MachineProfile;
    use crate::workload::Dist;

    #[test]
    fn heuristic_follows_paper_trends() {
        // Small messages -> r = 2; medium -> sqrt(P); large -> P.
        assert_eq!(heuristic_radix(1024, 8.0), 2);
        assert_eq!(heuristic_radix(1024, 1024.0), 32);
        assert_eq!(heuristic_radix(1024, 16384.0), 1024);
        // Monotone non-decreasing in S.
        let mut last = 0;
        for s in [8.0, 64.0, 512.0, 2048.0, 8192.0, 65536.0] {
            let r = heuristic_radix(256, s);
            assert!(r >= last, "ideal radix must grow with S");
            last = r;
        }
    }

    #[test]
    fn candidates_cover_extremes() {
        let c = radix_candidates(64);
        assert!(c.contains(&2));
        assert!(c.contains(&8)); // sqrt(64)
        assert!(c.contains(&64));
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(radix_candidates(2), vec![2]);
    }

    #[test]
    fn block_count_candidates_bounded() {
        let c = block_count_candidates(12);
        assert_eq!(c, vec![1, 2, 4, 8, 12]);
        assert_eq!(block_count_candidates(1), vec![1]);
    }

    #[test]
    fn autotune_picks_argmin() {
        let e = Engine::new(MachineProfile::fugaku(), Topology::new(16, 4));
        let sizes = BlockSizes::generate(16, Dist::Uniform { max: 256 }, 1);
        let res = autotune_tuna(&e, &sizes).unwrap();
        // Best time must be the minimum of the sweep.
        let min = res.sweep.iter().map(|s| s.1).fold(f64::INFINITY, f64::min);
        assert_eq!(res.best_time, min);
        assert!(res.sweep.len() >= 3);
    }

    #[test]
    fn autotune_hier_respects_q_bound() {
        let e = Engine::new(MachineProfile::fugaku(), Topology::new(16, 4));
        let sizes = BlockSizes::generate(16, Dist::Uniform { max: 256 }, 1);
        let res = autotune_hier(&e, &sizes, true).unwrap();
        for (kind, _) in &res.sweep {
            if let AlgoKind::Hier {
                local: crate::algos::LocalAlgo::Tuna { radix },
                global: crate::algos::GlobalAlgo::Coalesced { block_count },
            } = kind
            {
                assert!(*radix <= 4);
                assert!(*block_count <= 3); // N-1 = 3
            } else {
                panic!("unexpected kind in hier sweep");
            }
        }
    }

    fn entry(machine: &str, p: usize, mean: f64, rank: usize, algo: AlgoKind) -> TuningEntry {
        TuningEntry {
            machine: machine.to_string(),
            p,
            q: 8,
            dist: "uniform".to_string(),
            mean_block: mean,
            rank,
            algo,
            model_time: 1e-3 * rank as f64,
            measured_time: if rank == 1 { Some(1.1e-3) } else { None },
        }
    }

    #[test]
    fn table_roundtrips_through_tsv() {
        let hier = AlgoKind::hier_coalesced(2, 1);
        let t = TuningTable {
            entries: vec![
                entry("fugaku", 256, 256.0, 1, hier),
                entry("fugaku", 256, 256.0, 2, AlgoKind::Tuna { radix: 2 }),
                entry("polaris", 64, 8192.0, 1, AlgoKind::Vendor),
            ],
        };
        let text = t.to_tsv();
        assert!(text.starts_with(TuningTable::VERSION_HEADER));
        let back = TuningTable::parse(&text).unwrap();
        assert_eq!(back.entries, t.entries);
    }

    #[test]
    fn table_rejects_wrong_version() {
        assert!(TuningTable::parse("# tuna-tuning-table v99\n").is_err());
        assert!(TuningTable::parse("").is_err());
    }

    #[test]
    fn table_lookup_matches_nearest_mean_within_2x() {
        let t = TuningTable {
            entries: vec![
                entry("fugaku", 256, 128.0, 1, AlgoKind::Tuna { radix: 2 }),
                entry("fugaku", 256, 8192.0, 1, AlgoKind::Tuna { radix: 256 }),
                entry("fugaku", 256, 8192.0, 2, AlgoKind::Vendor),
            ],
        };
        // Nearest snapshot within 2x wins; rank-2 rows never surface.
        assert_eq!(
            t.lookup("fugaku", 256, 8, 200.0).unwrap().algo,
            AlgoKind::Tuna { radix: 2 }
        );
        assert_eq!(
            t.lookup("fugaku", 256, 8, 10000.0).unwrap().algo,
            AlgoKind::Tuna { radix: 256 }
        );
        // Too far from any snapshot (128 * 2 < 1000 < 8192 / 2): no hit.
        assert!(t.lookup("fugaku", 256, 8, 1000.0).is_none());
        // Other keys must match exactly.
        assert!(t.lookup("polaris", 256, 8, 200.0).is_none());
        assert!(t.lookup("fugaku", 128, 8, 200.0).is_none());
    }

    #[test]
    fn lookup_radix_only_surfaces_runnable_tuna_bests() {
        let t = TuningTable {
            entries: vec![
                entry("fugaku", 64, 128.0, 1, AlgoKind::Tuna { radix: 8 }),
                entry("fugaku", 64, 8192.0, 1, AlgoKind::Vendor),
                entry("fugaku", 32, 128.0, 1, AlgoKind::Bruck2),
                entry("fugaku", 16, 128.0, 1, AlgoKind::Tuna { radix: 999 }),
            ],
        };
        assert_eq!(t.lookup_radix("fugaku", 64, 8, 150.0), Some(8));
        // Non-TuNA winner: no override.
        assert_eq!(t.lookup_radix("fugaku", 64, 8, 8192.0), None);
        // Bruck2 is TuNA at radix 2.
        assert_eq!(t.lookup_radix("fugaku", 32, 8, 128.0), Some(2));
        // A stored radix that exceeds P must not surface.
        assert_eq!(t.lookup_radix("fugaku", 16, 8, 128.0), None);
        // No scenario match at all.
        assert_eq!(t.lookup_radix("polaris", 64, 8, 150.0), None);
    }

    #[test]
    fn table_merge_replaces_scenarios_wholesale() {
        let mut base = TuningTable {
            entries: vec![
                entry("fugaku", 256, 256.0, 1, AlgoKind::Tuna { radix: 2 }),
                entry("fugaku", 256, 256.0, 2, AlgoKind::Vendor),
                entry("fugaku", 64, 256.0, 1, AlgoKind::Tuna { radix: 8 }),
            ],
        };
        base.merge_from(TuningTable {
            entries: vec![entry("fugaku", 256, 256.0, 1, AlgoKind::TunaAuto)],
        });
        // The P=256 scenario is replaced (both rows gone), P=64 survives.
        assert_eq!(base.entries.len(), 2);
        assert!(base
            .entries
            .iter()
            .any(|e| e.p == 256 && e.algo == AlgoKind::TunaAuto));
        assert!(base.entries.iter().any(|e| e.p == 64));
    }

    #[test]
    fn table_save_merged_accumulates_on_disk() {
        let dir = std::env::temp_dir().join("tuna_tuning_table_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = table_path(&dir, "fugaku");
        let a = TuningTable {
            entries: vec![entry("fugaku", 64, 256.0, 1, AlgoKind::Tuna { radix: 8 })],
        };
        a.save_merged(&path).unwrap();
        let b = TuningTable {
            entries: vec![entry("fugaku", 256, 256.0, 1, AlgoKind::TunaAuto)],
        };
        b.save_merged(&path).unwrap();
        let merged = TuningTable::load(&path).unwrap();
        assert_eq!(merged.entries.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
