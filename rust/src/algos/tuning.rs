//! Parameter selection: the paper's radix heuristic (§V-A) and a
//! measurement-driven autotuner (what Fig. 9's "ideal r" annotations come
//! from).
//!
//! Observed trends (§V-A, Fig. 7):
//! * small S (latency-bound) → small radix (few rounds ⇒ r≈2 minimizes
//!   per-round latency only when rounds dominate — empirically the ideal
//!   *rises* as S shrinks only on the far-small end; the paper reports
//!   ideal r ≈ 2 for S ≤ 512 B);
//! * medium S → r ≈ √P balances rounds against duplicate data;
//! * large S (bandwidth-bound) → r ≈ P minimizes total transmitted bytes.

use super::AlgoKind;
use crate::comm::Engine;
use crate::workload::BlockSizes;

/// The §V-A rule of thumb: pick a radix from the average block size.
/// Thresholds follow the paper's Polaris observations (small: ≤512 B,
/// medium: ≤8 KiB, large: above).
pub fn heuristic_radix(p: usize, mean_block_size: f64) -> usize {
    let r = if mean_block_size <= 256.0 {
        // S/2 <= 256 <=> S <= 512: latency-dominated.
        2
    } else if mean_block_size <= 4096.0 {
        // Medium: sqrt(P) balances K against D.
        (p as f64).sqrt().round() as usize
    } else {
        // Bandwidth-dominated: minimize duplicate transfers.
        p
    };
    r.clamp(2, p.max(2))
}

/// Candidate radices for sweeps: powers of two, √P, and P itself —
/// the grid used for the box plots (Fig. 8) and heatmaps (Fig. 9).
pub fn radix_candidates(p: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut r = 2usize;
    while r < p {
        out.push(r);
        r *= 2;
    }
    let sqrt = (p as f64).sqrt().round() as usize;
    if sqrt >= 2 {
        out.push(sqrt);
    }
    out.push(p.max(2));
    out.sort_unstable();
    out.dedup();
    out
}

/// Candidate block_counts: powers of two up to `max`, plus `max`.
pub fn block_count_candidates(max: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut b = 1usize;
    while b < max {
        out.push(b);
        b *= 2;
    }
    out.push(max.max(1));
    out.sort_unstable();
    out.dedup();
    out
}

/// Result of an autotuning sweep.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: AlgoKind,
    pub best_time: f64,
    /// Every candidate with its simulated time.
    pub sweep: Vec<(AlgoKind, f64)>,
}

/// Pick the best TuNA radix for a workload by simulated measurement.
pub fn autotune_tuna(engine: &Engine, sizes: &BlockSizes) -> crate::Result<TuneResult> {
    let candidates: Vec<AlgoKind> = radix_candidates(engine.topo.p())
        .into_iter()
        .map(|radix| AlgoKind::Tuna { radix })
        .collect();
    sweep(engine, sizes, &candidates)
}

/// Pick the best (radix, block_count) for hierarchical TuNA.
pub fn autotune_hier(
    engine: &Engine,
    sizes: &BlockSizes,
    coalesced: bool,
) -> crate::Result<TuneResult> {
    let q = engine.topo.q();
    let n = engine.topo.nodes();
    let bc_max = if coalesced { (n - 1).max(1) } else { ((n - 1) * q).max(1) };
    let mut candidates = Vec::new();
    for radix in radix_candidates(q).into_iter().filter(|&r| r <= q) {
        for bc in block_count_candidates(bc_max) {
            candidates.push(if coalesced {
                AlgoKind::TunaHierCoalesced { radix, block_count: bc }
            } else {
                AlgoKind::TunaHierStaggered { radix, block_count: bc }
            });
        }
    }
    sweep(engine, sizes, &candidates)
}

/// Evaluate a candidate list and return the argmin by simulated makespan.
pub fn sweep(
    engine: &Engine,
    sizes: &BlockSizes,
    candidates: &[AlgoKind],
) -> crate::Result<TuneResult> {
    assert!(!candidates.is_empty());
    let mut sweep = Vec::with_capacity(candidates.len());
    for kind in candidates {
        let rep = super::run_alltoallv(engine, kind, sizes, false)?;
        sweep.push((*kind, rep.makespan));
    }
    let (best, best_time) = sweep
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .copied()
        .unwrap();
    Ok(TuneResult {
        best,
        best_time,
        sweep,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::model::MachineProfile;
    use crate::workload::Dist;

    #[test]
    fn heuristic_follows_paper_trends() {
        // Small messages -> r = 2; medium -> sqrt(P); large -> P.
        assert_eq!(heuristic_radix(1024, 8.0), 2);
        assert_eq!(heuristic_radix(1024, 1024.0), 32);
        assert_eq!(heuristic_radix(1024, 16384.0), 1024);
        // Monotone non-decreasing in S.
        let mut last = 0;
        for s in [8.0, 64.0, 512.0, 2048.0, 8192.0, 65536.0] {
            let r = heuristic_radix(256, s);
            assert!(r >= last, "ideal radix must grow with S");
            last = r;
        }
    }

    #[test]
    fn candidates_cover_extremes() {
        let c = radix_candidates(64);
        assert!(c.contains(&2));
        assert!(c.contains(&8)); // sqrt(64)
        assert!(c.contains(&64));
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(radix_candidates(2), vec![2]);
    }

    #[test]
    fn block_count_candidates_bounded() {
        let c = block_count_candidates(12);
        assert_eq!(c, vec![1, 2, 4, 8, 12]);
        assert_eq!(block_count_candidates(1), vec![1]);
    }

    #[test]
    fn autotune_picks_argmin() {
        let e = Engine::new(MachineProfile::fugaku(), Topology::new(16, 4));
        let sizes = BlockSizes::generate(16, Dist::Uniform { max: 256 }, 1);
        let res = autotune_tuna(&e, &sizes).unwrap();
        // Best time must be the minimum of the sweep.
        let min = res.sweep.iter().map(|s| s.1).fold(f64::INFINITY, f64::min);
        assert_eq!(res.best_time, min);
        assert!(res.sweep.len() >= 3);
    }

    #[test]
    fn autotune_hier_respects_q_bound() {
        let e = Engine::new(MachineProfile::fugaku(), Topology::new(16, 4));
        let sizes = BlockSizes::generate(16, Dist::Uniform { max: 256 }, 1);
        let res = autotune_hier(&e, &sizes, true).unwrap();
        for (kind, _) in &res.sweep {
            if let AlgoKind::TunaHierCoalesced { radix, block_count } = kind {
                assert!(*radix <= 4);
                assert!(*block_count <= 3); // N-1 = 3
            } else {
                panic!("unexpected kind in hier sweep");
            }
        }
    }
}
