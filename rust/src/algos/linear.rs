//! Linear-time non-uniform all-to-all algorithms (§II(d)).
//!
//! These are the four standard implementations found in MPICH and OpenMPI
//! which the paper benchmarks in Fig. 12 and builds on (the scattered
//! algorithm is the inter-node component of TuNA_l^g):
//!
//! * **spread-out** (MPICH): post every isend/irecv in round-robin order
//!   (`dst = me + i`, `src = me − i`) and wait once — each round targets a
//!   unique destination, spreading load across endpoints.
//! * **OpenMPI linear**: same non-blocking pattern but in *ascending
//!   absolute rank order* — every rank hits rank 0 first, producing the
//!   incast bursts that make it the worst performer at scale.
//! * **pairwise** (OpenMPI): P−1 synchronized rounds of blocking
//!   sendrecv; xor partners when P is a power of two, shifted ring
//!   otherwise.
//! * **scattered** (MPICH): spread-out in batches of `block_count`
//!   requests with a waitall between batches — the tunable congestion
//!   throttle.
//!
//! All four ship each block directly: payloads enter the engine as rope
//! views and reach the destination without any host-side byte movement
//! (the only modeled copy is the self-block delivery memcpy).

use crate::comm::engine::{RecvReq, SendReq};
use crate::comm::{Block, Payload, Phase, PlanBuilder, RankCtx};
use crate::workload::BlockSizes;

/// Tag used by every linear algorithm (one message per (src,dst) pair;
/// FIFO per channel keeps this unambiguous).
const TAG: u32 = 1;

fn take_self_block(ctx: &mut RankCtx, blocks: &mut Vec<Block>) -> Block {
    let me = ctx.rank();
    let b = blocks.swap_remove(
        blocks
            .iter()
            .position(|b| b.dest as usize == me)
            .expect("missing self block"),
    );
    // Local delivery is a plain memcpy.
    ctx.copy(b.len());
    b
}

/// MPICH spread-out: all requests posted round-robin, one waitall.
pub fn spread_out(ctx: &mut RankCtx, mut blocks: Vec<Block>) -> Vec<Block> {
    let p = ctx.size();
    let me = ctx.rank();
    ctx.phase_mark();
    let self_block = take_self_block(ctx, &mut blocks);
    blocks.sort_by_key(|b| (b.dest as usize + p - me) % p);

    let mut sends: Vec<SendReq> = Vec::with_capacity(p - 1);
    let mut recvs: Vec<RecvReq> = Vec::with_capacity(p - 1);
    for (i, block) in blocks.into_iter().enumerate() {
        debug_assert_eq!(block.dest as usize, (me + i + 1) % p);
        let src = (me + p - i - 1) % p;
        recvs.push(ctx.irecv(src, TAG));
        sends.push(ctx.isend(block.dest as usize, TAG, Payload::Blocks(vec![block])));
    }
    let mut out: Vec<Block> = ctx
        .waitall(&sends, &recvs)
        .into_iter()
        .flat_map(|pl| pl.into_blocks())
        .collect();
    out.push(self_block);
    ctx.phase_lap(Phase::Data);
    out
}

/// OpenMPI basic linear: non-blocking, but in ascending rank order.
pub fn ompi_linear(ctx: &mut RankCtx, mut blocks: Vec<Block>) -> Vec<Block> {
    let p = ctx.size();
    let me = ctx.rank();
    ctx.phase_mark();
    let self_block = take_self_block(ctx, &mut blocks);
    blocks.sort_by_key(|b| b.dest);

    let mut sends: Vec<SendReq> = Vec::with_capacity(p - 1);
    let mut recvs: Vec<RecvReq> = Vec::with_capacity(p - 1);
    for block in blocks {
        let dst = block.dest as usize;
        debug_assert_ne!(dst, me);
        recvs.push(ctx.irecv(dst, TAG)); // symmetric: recv from the same peer
        sends.push(ctx.isend(dst, TAG, Payload::Blocks(vec![block])));
    }
    let mut out: Vec<Block> = ctx
        .waitall(&sends, &recvs)
        .into_iter()
        .flat_map(|pl| pl.into_blocks())
        .collect();
    out.push(self_block);
    ctx.phase_lap(Phase::Data);
    out
}

/// OpenMPI pairwise: P−1 rounds of blocking sendrecv. With P a power of
/// two, partners are `me ^ i` (perfect matching per round); otherwise the
/// shifted ring `send to me+i, recv from me−i`.
pub fn pairwise(ctx: &mut RankCtx, mut blocks: Vec<Block>) -> Vec<Block> {
    let p = ctx.size();
    let me = ctx.rank();
    ctx.phase_mark();
    let self_block = take_self_block(ctx, &mut blocks);
    let pow2 = p.is_power_of_two();

    // Index blocks by destination for O(1) lookup per round.
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        let d = b.dest as usize;
        by_dest[d] = Some(b);
    }

    let mut out = Vec::with_capacity(p);
    for i in 1..p {
        let (dst, src) = if pow2 {
            (me ^ i, me ^ i)
        } else {
            ((me + i) % p, (me + p - i) % p)
        };
        let block = by_dest[dst].take().expect("pairwise visits each dest once");
        let got = ctx.sendrecv(dst, TAG, Payload::Blocks(vec![block]), src, TAG);
        out.extend(got.into_blocks());
    }
    out.push(self_block);
    ctx.phase_lap(Phase::Data);
    out
}

/// MPICH scattered: spread-out batched by `block_count` with a waitall
/// between batches — the congestion throttle the paper tunes (and reuses
/// for the inter-node phase of TuNA_l^g).
pub fn scattered(ctx: &mut RankCtx, mut blocks: Vec<Block>, block_count: usize) -> Vec<Block> {
    assert!(block_count >= 1, "block_count must be >= 1");
    let p = ctx.size();
    let me = ctx.rank();
    ctx.phase_mark();
    let self_block = take_self_block(ctx, &mut blocks);
    blocks.sort_by_key(|b| (b.dest as usize + p - me) % p);

    let mut out = Vec::with_capacity(p);
    let mut iter = blocks.into_iter();
    let mut i = 0usize;
    while i < p - 1 {
        let batch = block_count.min(p - 1 - i);
        let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
        let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
        for j in 0..batch {
            let off = i + j + 1;
            let src = (me + p - off) % p;
            let block = iter.next().expect("block per offset");
            debug_assert_eq!(block.dest as usize, (me + off) % p);
            recvs.push(ctx.irecv(src, TAG));
            sends.push(ctx.isend(block.dest as usize, TAG, Payload::Blocks(vec![block])));
        }
        out.extend(
            ctx.waitall(&sends, &recvs)
                .into_iter()
                .flat_map(|pl| pl.into_blocks()),
        );
        i += batch;
    }
    out.push(self_block);
    ctx.phase_lap(Phase::Data);
    out
}

// ---- structural-sparse variants -------------------------------------------
//
// On a sparse workload a rank exchanges with its *structural* peers only:
// sends follow its row's nonzeros, receives follow the workload
// transpose (`Counts::senders`). Both the threaded runners below and the
// sparse plan compilers derive their schedules from the single
// [`sparse_linear_events`] function, so the two execution modes cannot
// drift — `tests/replay_equivalence.rs` pins them bit-identical.

/// One merged step of a sparse linear schedule: at most one send and one
/// receive aimed at (possibly different) peers that share a step key.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct SparseLinearEvent {
    /// `(dst, bytes)` of the block sent this step.
    pub send: Option<(usize, u64)>,
    /// Source of the block received this step.
    pub recv: Option<usize>,
}

/// Step-key order of a sparse linear schedule — each mirrors its dense
/// family's partner structure.
#[derive(Clone, Copy, Debug)]
pub(crate) enum SparseOrder {
    /// Round-robin offsets: send to `me + i`, receive from `me − i`
    /// share step `i` (spread-out / scattered).
    RoundRobin,
    /// Absolute peer rank (the OpenMPI-linear order).
    Ascending,
    /// Pairwise partners: xor partner `me ^ i` keys step `i` when P is a
    /// power of two (send and receive face the same peer per step, like
    /// the dense blocking sendrecv), shifted ring otherwise.
    Pairwise,
}

/// The merged per-peer schedule of a sparse linear algorithm for rank
/// `me`, steps ascending by key. Within a step the receive is posted
/// before the send.
pub(crate) fn sparse_linear_events(
    sizes: &BlockSizes,
    me: usize,
    order: SparseOrder,
) -> Vec<SparseLinearEvent> {
    let p = sizes.p();
    let pow2 = p.is_power_of_two();
    let send_key = |dst: usize| match order {
        SparseOrder::Ascending => dst,
        SparseOrder::Pairwise if pow2 => me ^ dst,
        _ => (dst + p - me) % p,
    };
    let recv_key = |src: usize| match order {
        SparseOrder::Ascending => src,
        SparseOrder::Pairwise if pow2 => me ^ src,
        _ => (me + p - src) % p,
    };
    let mut map: std::collections::BTreeMap<usize, SparseLinearEvent> =
        std::collections::BTreeMap::new();
    for (dst, bytes) in sizes.row_view(me).entries() {
        if dst == me {
            continue;
        }
        map.entry(send_key(dst)).or_default().send = Some((dst, bytes));
    }
    for &src in sizes.senders()[me].iter() {
        let src = src as usize;
        if src == me {
            continue;
        }
        map.entry(recv_key(src)).or_default().recv = Some(src);
    }
    map.into_values().collect()
}

/// How a sparse linear schedule groups its steps between waits.
#[derive(Clone, Copy, Debug)]
pub(crate) enum SparseBatching {
    /// Every step posted, one wait (spread-out / OpenMPI linear).
    SingleWait,
    /// One wait per step (pairwise).
    PerStep,
    /// One wait per `block_count` steps (scattered / vendor).
    Chunk(usize),
}

/// Shared sparse runner for all four linear families.
fn run_linear_sparse(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    sizes: &BlockSizes,
    order: SparseOrder,
    batching: SparseBatching,
) -> Vec<Block> {
    let p = ctx.size();
    let me = ctx.rank();
    ctx.phase_mark();
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        by_dest[b.dest as usize] = Some(b);
    }
    let mut out: Vec<Block> = Vec::new();
    // Local delivery of the self block (0-byte charge when absent) —
    // mirrored unconditionally by the plan compiler.
    let self_block = by_dest[me].take();
    ctx.copy(self_block.as_ref().map(|b| b.len()).unwrap_or(0));
    out.extend(self_block);

    let events = sparse_linear_events(sizes, me, order);
    let chunk = match batching {
        SparseBatching::SingleWait => events.len().max(1),
        SparseBatching::PerStep => 1,
        SparseBatching::Chunk(bc) => bc.max(1),
    };
    let mut i = 0usize;
    while i < events.len() {
        let batch = chunk.min(events.len() - i);
        let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
        let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
        for ev in &events[i..i + batch] {
            if let Some(src) = ev.recv {
                recvs.push(ctx.irecv(src, TAG));
            }
            if let Some((dst, _)) = ev.send {
                let block = by_dest[dst].take().expect("structural send without block");
                sends.push(ctx.isend(dst, TAG, Payload::Blocks(vec![block])));
            }
        }
        out.extend(
            ctx.waitall(&sends, &recvs)
                .into_iter()
                .flat_map(|pl| pl.into_blocks()),
        );
        i += batch;
    }
    if events.is_empty() {
        // Keep the (no-op) wait boundary of the dense schedule shape.
        ctx.waitall(&[], &[]);
    }
    ctx.phase_lap(Phase::Data);
    out
}

/// Sparse spread-out: round-robin order over structural peers, one wait.
pub fn spread_out_sparse(ctx: &mut RankCtx, blocks: Vec<Block>, sizes: &BlockSizes) -> Vec<Block> {
    run_linear_sparse(ctx, blocks, sizes, SparseOrder::RoundRobin, SparseBatching::SingleWait)
}

/// Sparse OpenMPI linear: ascending peer order, one wait.
pub fn ompi_linear_sparse(ctx: &mut RankCtx, blocks: Vec<Block>, sizes: &BlockSizes) -> Vec<Block> {
    run_linear_sparse(ctx, blocks, sizes, SparseOrder::Ascending, SparseBatching::SingleWait)
}

/// Sparse pairwise: one synchronized step per structural peer offset.
pub fn pairwise_sparse(ctx: &mut RankCtx, blocks: Vec<Block>, sizes: &BlockSizes) -> Vec<Block> {
    run_linear_sparse(ctx, blocks, sizes, SparseOrder::Pairwise, SparseBatching::PerStep)
}

/// Sparse scattered: round-robin steps batched by `block_count`.
pub fn scattered_sparse(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    sizes: &BlockSizes,
    block_count: usize,
) -> Vec<Block> {
    assert!(block_count >= 1, "block_count must be >= 1");
    run_linear_sparse(ctx, blocks, sizes, SparseOrder::RoundRobin, SparseBatching::Chunk(block_count))
}

/// Shared sparse plan compiler — emits exactly the ops
/// [`run_linear_sparse`] charges, per rank, from the same event
/// schedule. O(nnz) ops per rank instead of O(P).
fn plan_linear_sparse(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    order: SparseOrder,
    batching: SparseBatching,
) {
    for (me, b) in builders.iter_mut().enumerate() {
        plan_sparse_rank(b, sizes, me, order, batching);
    }
}

/// Emit rank `me`'s sparse ops alone — the unit `algos::patch_plan`
/// recompiles when a row diff touches only a few ranks. Rank `me`'s
/// schedule depends on row `me` (sends) and on `senders()[me]` (the
/// structural transpose column), so a patch is sound only while the
/// changed rows' destination *sets* are unchanged.
pub(crate) fn plan_sparse_rank(
    b: &mut PlanBuilder,
    sizes: &BlockSizes,
    me: usize,
    order: SparseOrder,
    batching: SparseBatching,
) {
    b.mark();
    b.copy(sizes.row_view(me).get(me));
    let events = sparse_linear_events(sizes, me, order);
    let chunk = match batching {
        SparseBatching::SingleWait => events.len().max(1),
        SparseBatching::PerStep => 1,
        SparseBatching::Chunk(bc) => bc.max(1),
    };
    let mut i = 0usize;
    while i < events.len() {
        let batch = chunk.min(events.len() - i);
        for ev in &events[i..i + batch] {
            if let Some(src) = ev.recv {
                b.recv(src, TAG);
            }
            if let Some((dst, bytes)) = ev.send {
                b.send(dst, TAG, bytes);
            }
        }
        b.wait();
        i += batch;
    }
    if events.is_empty() {
        b.wait();
    }
    b.lap(Phase::Data);
}

/// Compile [`spread_out_sparse`] for every rank.
pub(crate) fn plan_spread_out_sparse(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    plan_linear_sparse(builders, sizes, SparseOrder::RoundRobin, SparseBatching::SingleWait);
}

/// Compile [`ompi_linear_sparse`] for every rank.
pub(crate) fn plan_ompi_linear_sparse(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    plan_linear_sparse(builders, sizes, SparseOrder::Ascending, SparseBatching::SingleWait);
}

/// Compile [`pairwise_sparse`] for every rank.
pub(crate) fn plan_pairwise_sparse(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    plan_linear_sparse(builders, sizes, SparseOrder::Pairwise, SparseBatching::PerStep);
}

/// Compile [`scattered_sparse`] for every rank.
pub(crate) fn plan_scattered_sparse(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    block_count: usize,
) {
    assert!(block_count >= 1, "block_count must be >= 1");
    plan_linear_sparse(builders, sizes, SparseOrder::RoundRobin, SparseBatching::Chunk(block_count));
}

// ---- plan compilers -------------------------------------------------------
//
// Each mirrors its run function above op-for-op (same clock charges, same
// send/recv posting order, same wait boundaries), reading block sizes from
// the counts matrix instead of moving payloads — the plan-determinism
// contract of `comm::plan`. Equivalence is asserted bitwise by
// `tests/replay_equivalence.rs`.

/// Compile [`spread_out`] for every rank.
pub(crate) fn plan_spread_out(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    for (me, b) in builders.iter_mut().enumerate() {
        plan_spread_out_rank(b, sizes, me);
    }
}

/// Emit rank `me`'s [`spread_out`] ops alone. All four dense per-rank
/// emitters read only row `me` of the counts matrix (receives carry no
/// size), which is what makes single-rank patching sound.
pub(crate) fn plan_spread_out_rank(b: &mut PlanBuilder, sizes: &BlockSizes, me: usize) {
    let p = sizes.p();
    let row = sizes.row(me);
    b.mark();
    b.copy(row[me]); // self-block delivery memcpy
    for i in 0..p - 1 {
        let dst = (me + i + 1) % p;
        let src = (me + p - i - 1) % p;
        b.recv(src, TAG);
        b.send(dst, TAG, row[dst]);
    }
    b.wait();
    b.lap(Phase::Data);
}

/// Compile [`ompi_linear`] for every rank.
pub(crate) fn plan_ompi_linear(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    for (me, b) in builders.iter_mut().enumerate() {
        plan_ompi_linear_rank(b, sizes, me);
    }
}

/// Emit rank `me`'s [`ompi_linear`] ops alone.
pub(crate) fn plan_ompi_linear_rank(b: &mut PlanBuilder, sizes: &BlockSizes, me: usize) {
    let p = sizes.p();
    let row = sizes.row(me);
    b.mark();
    b.copy(row[me]);
    for dst in (0..p).filter(|&d| d != me) {
        b.recv(dst, TAG);
        b.send(dst, TAG, row[dst]);
    }
    b.wait();
    b.lap(Phase::Data);
}

/// Compile [`pairwise`] for every rank.
pub(crate) fn plan_pairwise(builders: &mut [PlanBuilder], sizes: &BlockSizes) {
    for (me, b) in builders.iter_mut().enumerate() {
        plan_pairwise_rank(b, sizes, me);
    }
}

/// Emit rank `me`'s [`pairwise`] ops alone.
pub(crate) fn plan_pairwise_rank(b: &mut PlanBuilder, sizes: &BlockSizes, me: usize) {
    let p = sizes.p();
    let pow2 = p.is_power_of_two();
    let row = sizes.row(me);
    b.mark();
    b.copy(row[me]);
    for i in 1..p {
        let (dst, src) = if pow2 {
            (me ^ i, me ^ i)
        } else {
            ((me + i) % p, (me + p - i) % p)
        };
        b.sendrecv(dst, TAG, row[dst], src, TAG);
    }
    b.lap(Phase::Data);
}

/// Compile [`scattered`] for every rank.
pub(crate) fn plan_scattered(builders: &mut [PlanBuilder], sizes: &BlockSizes, block_count: usize) {
    assert!(block_count >= 1, "block_count must be >= 1");
    for (me, b) in builders.iter_mut().enumerate() {
        plan_scattered_rank(b, sizes, me, block_count);
    }
}

/// Emit rank `me`'s [`scattered`] ops alone.
pub(crate) fn plan_scattered_rank(
    b: &mut PlanBuilder,
    sizes: &BlockSizes,
    me: usize,
    block_count: usize,
) {
    assert!(block_count >= 1, "block_count must be >= 1");
    let p = sizes.p();
    let row = sizes.row(me);
    b.mark();
    b.copy(row[me]);
    let mut i = 0usize;
    while i < p - 1 {
        let batch = block_count.min(p - 1 - i);
        for j in 0..batch {
            let off = i + j + 1;
            let src = (me + p - off) % p;
            let dst = (me + off) % p;
            b.recv(src, TAG);
            b.send(dst, TAG, row[dst]);
        }
        b.wait();
        i += batch;
    }
    b.lap(Phase::Data);
}

#[cfg(test)]
mod tests {
    //! Algorithm-specific behaviors; full gold-correctness matrices live in
    //! `tests/algos_correctness.rs`.
    use super::*;
    use crate::comm::{DataBuf, Engine, Topology};
    use crate::model::MachineProfile;

    fn pattern_blocks(ctx: &RankCtx) -> Vec<Block> {
        let me = ctx.rank();
        (0..ctx.size())
            .map(|d| Block::new(me, d, DataBuf::pattern(me, d, (d as u64 + 1) * 16)))
            .collect()
    }

    fn check_full(me: usize, p: usize, out: &[Block]) {
        assert_eq!(out.len(), p);
        let mut seen = vec![false; p];
        for b in out {
            assert_eq!(b.dest as usize, me);
            assert!(!seen[b.origin as usize]);
            seen[b.origin as usize] = true;
            assert_eq!(b.len(), (me as u64 + 1) * 16);
            b.data.check_pattern(b.origin as usize, me).unwrap();
        }
    }

    fn run_algo(p: usize, q: usize, f: impl Fn(&mut RankCtx, Vec<Block>) -> Vec<Block> + Send + Sync) {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let res = e.run(|ctx| {
            let blocks = pattern_blocks(ctx);
            let out = f(ctx, blocks);
            check_full(ctx.rank(), ctx.size(), &out);
            true
        });
        assert!(res.ranks.iter().all(|r| r.value));
    }

    #[test]
    fn spread_out_correct() {
        run_algo(8, 2, spread_out);
        run_algo(5, 1, spread_out);
    }

    #[test]
    fn ompi_linear_correct() {
        run_algo(8, 4, ompi_linear);
        run_algo(7, 1, ompi_linear);
    }

    #[test]
    fn pairwise_correct_pow2_and_not() {
        run_algo(8, 2, pairwise);
        run_algo(6, 3, pairwise);
        run_algo(9, 3, pairwise);
    }

    #[test]
    fn scattered_correct_various_batches() {
        for bc in [1usize, 2, 3, 7, 64] {
            run_algo(8, 4, move |ctx, b| scattered(ctx, b, bc));
        }
    }

    #[test]
    fn scattered_batching_reduces_burst_under_congestion() {
        // With congestion enabled and enough concurrent flows in the
        // network (congestion scales with P), a full burst of P-1
        // outstanding sends must cost more than a moderately batched
        // scattered run — the block_count effect of §II(d) / Fig. 12.
        let p = 512;
        let mut prof = MachineProfile::fugaku();
        prof.mem_bw = 1e12; // isolate communication costs
        let e = Engine::new(prof, Topology::flat(p));
        let mk = |ctx: &RankCtx| {
            let me = ctx.rank();
            (0..p)
                .map(|d| Block::new(me, d, DataBuf::Phantom(16 * 1024)))
                .collect::<Vec<_>>()
        };
        let burst = e.run(|ctx| {
            let b = mk(ctx);
            spread_out(ctx, b);
        });
        let throttled = e.run(|ctx| {
            let b = mk(ctx);
            scattered(ctx, b, 4);
        });
        assert!(
            burst.makespan > throttled.makespan,
            "burst {} should exceed throttled {} under congestion",
            burst.makespan,
            throttled.makespan
        );
    }

    #[test]
    fn ompi_linear_slower_than_spread_out_under_incast() {
        // Ascending order concentrates early arrivals on low ranks; the
        // incast penalty should make it no faster than spread-out.
        let prof = MachineProfile::fugaku();
        let e = Engine::new(prof, Topology::flat(32));
        let mk = |ctx: &RankCtx| {
            let me = ctx.rank();
            (0..32)
                .map(|d| Block::new(me, d, DataBuf::Phantom(8192)))
                .collect::<Vec<_>>()
        };
        let asc = e.run(|ctx| {
            let b = mk(ctx);
            ompi_linear(ctx, b);
        });
        let rr = e.run(|ctx| {
            let b = mk(ctx);
            spread_out(ctx, b);
        });
        assert!(
            asc.makespan >= rr.makespan * 0.95,
            "ascending {} vs round-robin {}",
            asc.makespan,
            rr.makespan
        );
    }
}
