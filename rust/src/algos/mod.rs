//! Non-uniform all-to-all algorithms.
//!
//! Everything the paper implements or compares against, behind one
//! dispatch enum [`AlgoKind`]:
//!
//! | kind | paper §II/III/IV | complexity |
//! |---|---|---|
//! | `SpreadOut` | MPICH spread-out (round-robin linear) | P−1 rounds |
//! | `OmpiLinear` | OpenMPI basic linear (ascending order) | P−1 rounds |
//! | `Pairwise` | OpenMPI pairwise (xor / shift partners) | P−1 sync rounds |
//! | `Scattered` | MPICH scattered (batched, tunable `block_count`) | P−1, batched |
//! | `Vendor` | vendor MPI_Alltoallv proxy (scattered @ default throttle) | — |
//! | `Bruck2` | two-phase non-uniform Bruck [10] (radix fixed at 2) | log₂P rounds |
//! | `Tuna` | **TuNA** (Alg. 1): tunable radix, two-phase, tight T | ≤ w(r−1) rounds |
//! | `Hier` | **composable TuNA_l^g** (§IV): any [`LocalAlgo`] × any [`GlobalAlgo`] | local + global |
//!
//! The paper's Algorithms 2 and 3 are the compositions
//! `hier:l=tuna:r=R,g=staggered:b=B` and `hier:l=tuna:r=R,g=coalesced:b=B`
//! (their legacy `tuna-hier-staggered:*` / `tuna-hier-coalesced:*` specs
//! keep parsing as aliases); see [`hier`] for the composition contract
//! and the full local/global implementation menu.
//!
//! All algorithms move [`Block`]s (origin, dest, payload) and must deliver
//! exactly one block per source to every destination; `run_alltoallv`
//! validates that against workload fingerprints (and byte patterns when
//! payloads are real).

pub mod hier;
pub mod linear;
pub mod radix;
pub mod select;
pub mod tuna;
pub mod tuning;

pub use hier::{GlobalAlgo, LocalAlgo};

use std::collections::HashSet;
use std::sync::Arc;

use crate::comm::{
    Block, CommPlan, Counters, DataBuf, Engine, PhaseBreakdown, PlanBuilder, PlanOp, RankCtx,
    RankPlan,
};
use crate::error::{Result, TunaError};
use crate::workload::{fingerprint_one, segment_counts, BlockSizes};

/// MPICH's default throttle for its scattered alltoallv (`MPIR_CVAR_ALLTOALLV
/// _THROTTLE`-style); our vendor proxy uses the same value.
pub const VENDOR_BLOCK_COUNT: usize = 32;

/// Algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    SpreadOut,
    OmpiLinear,
    Pairwise,
    Scattered { block_count: usize },
    Vendor,
    /// Two-phase non-uniform Bruck of [10]: TuNA's ancestor, radix 2.
    Bruck2,
    Tuna { radix: usize },
    /// TuNA with an automatically chosen radix, agreed across ranks at
    /// run time from the global mean block size (one extra allreduce).
    /// A tuning table attached to the engine ([`Engine::with_tuning`]) is
    /// consulted first; the §V-A heuristic is the fallback.
    TunaAuto,
    /// Composable two-level hierarchy (TuNA_l^g, §IV): any intra-node
    /// algorithm paired with any inter-node algorithm. See [`hier`] for
    /// the composition contract and the implementation menu.
    Hier { local: LocalAlgo, global: GlobalAlgo },
}

impl AlgoKind {
    /// The paper's coalesced TuNA_l^g (Alg. 3) as a composition — the
    /// legacy `tuna-hier-coalesced:r=R,b=B` pairing.
    pub fn hier_coalesced(radix: usize, block_count: usize) -> AlgoKind {
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix },
            global: GlobalAlgo::Coalesced { block_count },
        }
    }

    /// The paper's staggered TuNA_l^g (Alg. 2) as a composition — the
    /// legacy `tuna-hier-staggered:r=R,b=B` pairing.
    pub fn hier_staggered(radix: usize, block_count: usize) -> AlgoKind {
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix },
            global: GlobalAlgo::Staggered { block_count },
        }
    }

    pub fn name(&self) -> String {
        match self {
            AlgoKind::SpreadOut => "spread-out".into(),
            AlgoKind::OmpiLinear => "ompi-linear".into(),
            AlgoKind::Pairwise => "pairwise".into(),
            AlgoKind::Scattered { block_count } => format!("scattered(b={block_count})"),
            AlgoKind::Vendor => "vendor-alltoallv".into(),
            AlgoKind::Bruck2 => "bruck2-nonuniform".into(),
            AlgoKind::Tuna { radix } => format!("tuna(r={radix})"),
            AlgoKind::TunaAuto => "tuna(r=auto)".into(),
            AlgoKind::Hier { local, global } => {
                format!("hier(l={},g={})", local.name(), global.name())
            }
        }
    }

    /// Short family name without parameters (for table columns).
    pub fn family(&self) -> &'static str {
        match self {
            AlgoKind::SpreadOut => "spread-out",
            AlgoKind::OmpiLinear => "ompi-linear",
            AlgoKind::Pairwise => "pairwise",
            AlgoKind::Scattered { .. } => "scattered",
            AlgoKind::Vendor => "vendor",
            AlgoKind::Bruck2 => "bruck2",
            AlgoKind::Tuna { .. } | AlgoKind::TunaAuto => "tuna",
            AlgoKind::Hier { global, .. } => global.family(),
        }
    }

    /// Parse `"tuna:r=4"`, `"tuna:auto"`, `"scattered:b=16"`,
    /// `"hier:l=tuna:r=4,g=coalesced:b=8"`, `"spread-out"`, ... The
    /// legacy hierarchy specs (`"tuna-hier-coalesced:r=4,b=8"`,
    /// `"tuna-hier-staggered:r=4,b=8"`) keep parsing as aliases for the
    /// equivalent composition. Errors name the missing or invalid
    /// parameter instead of failing silently.
    pub fn parse(s: &str) -> Result<AlgoKind> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, a),
            None => (s, ""),
        };
        let get = |key: &str| -> Result<usize> {
            let raw = args
                .split(',')
                .find_map(|kv| kv.strip_prefix(key).and_then(|r| r.strip_prefix('=')));
            match raw {
                None => Err(TunaError::config(format!(
                    "{head}: missing parameter `{key}` (expected `{head}:{key}=N`)"
                ))),
                Some(v) => v.parse().map_err(|_| {
                    TunaError::config(format!(
                        "{head}: invalid value `{v}` for parameter `{key}`"
                    ))
                }),
            }
        };
        match head {
            "spread-out" => Ok(AlgoKind::SpreadOut),
            "ompi-linear" => Ok(AlgoKind::OmpiLinear),
            "pairwise" => Ok(AlgoKind::Pairwise),
            "scattered" => Ok(AlgoKind::Scattered {
                block_count: get("b")?,
            }),
            "vendor" => Ok(AlgoKind::Vendor),
            "bruck2" => Ok(AlgoKind::Bruck2),
            "tuna" => match args {
                "auto" | "r=auto" => Ok(AlgoKind::TunaAuto),
                _ => Ok(AlgoKind::Tuna { radix: get("r")? }),
            },
            "hier" => {
                let (l, g) = hier::split_spec(args)?;
                Ok(AlgoKind::Hier {
                    local: LocalAlgo::parse(&l)?,
                    global: GlobalAlgo::parse(&g)?,
                })
            }
            "tuna-hier-coalesced" => Ok(AlgoKind::hier_coalesced(get("r")?, get("b")?)),
            "tuna-hier-staggered" => Ok(AlgoKind::hier_staggered(get("r")?, get("b")?)),
            other => Err(TunaError::config(format!(
                "unknown algorithm `{other}` (see `tuna list`)"
            ))),
        }
    }

    /// Parseable spec string — the inverse of [`AlgoKind::parse`]
    /// (`parse(&k.spec()) == Ok(k)`), used by the tuning tables.
    pub fn spec(&self) -> String {
        match self {
            AlgoKind::SpreadOut => "spread-out".into(),
            AlgoKind::OmpiLinear => "ompi-linear".into(),
            AlgoKind::Pairwise => "pairwise".into(),
            AlgoKind::Scattered { block_count } => format!("scattered:b={block_count}"),
            AlgoKind::Vendor => "vendor".into(),
            AlgoKind::Bruck2 => "bruck2".into(),
            AlgoKind::Tuna { radix } => format!("tuna:r={radix}"),
            AlgoKind::TunaAuto => "tuna:auto".into(),
            AlgoKind::Hier { local, global } => {
                format!("hier:l={},g={}", local.spec(), global.spec())
            }
        }
    }

    /// Is this kind only runnable through a persistent handle
    /// ([`crate::comm::persist::PersistentColl`])? True for schedules
    /// whose setup cost is per-handle (the hier `balanced` local): the
    /// one-shot entry points refuse them so the cost model's rankings
    /// and the tuning tables can never quietly pay that setup per call.
    pub fn persistent_only(&self) -> bool {
        matches!(
            self,
            AlgoKind::Hier { local: LocalAlgo::Balanced, .. }
        )
    }

    /// Validate parameters against a topology before running.
    pub fn check(&self, p: usize, q: usize) -> Result<()> {
        let bad = |m: String| Err(TunaError::Config(m));
        match *self {
            AlgoKind::Scattered { block_count } if block_count == 0 => {
                bad("scattered: block_count must be >= 1".into())
            }
            AlgoKind::Tuna { radix } if radix < 2 => {
                bad(format!("tuna: radix {radix} < 2"))
            }
            AlgoKind::Tuna { radix } if radix > p.max(2) => {
                bad(format!("tuna: radix {radix} > P={p}"))
            }
            AlgoKind::Hier { ref local, ref global } => {
                let n = if q >= 1 { p / q } else { 0 };
                hier::check(local, global, p, q, n)
            }
            _ => Ok(()),
        }
    }

    /// Run this algorithm on one rank of a structurally sparse workload:
    /// `blocks` holds only the rank's structural blocks, and every family
    /// follows its sparse schedule (structural peers only — no phantom
    /// sends). `sizes` supplies the receive-side structure (the workload
    /// transpose); any rank can reproduce any row, so consulting it is
    /// control-plane knowledge, not payload access.
    pub fn dispatch_sparse(
        &self,
        ctx: &mut RankCtx,
        blocks: Vec<Block>,
        sizes: &BlockSizes,
    ) -> (Vec<Block>, AlgoStats) {
        match *self {
            AlgoKind::SpreadOut => {
                (linear::spread_out_sparse(ctx, blocks, sizes), AlgoStats::default())
            }
            AlgoKind::OmpiLinear => {
                (linear::ompi_linear_sparse(ctx, blocks, sizes), AlgoStats::default())
            }
            AlgoKind::Pairwise => {
                (linear::pairwise_sparse(ctx, blocks, sizes), AlgoStats::default())
            }
            AlgoKind::Scattered { block_count } => (
                linear::scattered_sparse(ctx, blocks, sizes, block_count),
                AlgoStats::default(),
            ),
            AlgoKind::Vendor => (
                linear::scattered_sparse(ctx, blocks, sizes, VENDOR_BLOCK_COUNT),
                AlgoStats::default(),
            ),
            AlgoKind::Bruck2 => tuna::run_sparse(ctx, blocks, 2),
            AlgoKind::Tuna { radix } => tuna::run_sparse(ctx, blocks, radix),
            AlgoKind::TunaAuto => {
                // Same agreement preamble as the dense dispatch; the
                // structural sum is what every rank contributes.
                let mine: u64 = blocks.iter().map(|b| b.len()).sum();
                let total = ctx.allreduce_sum(mine);
                let p = ctx.size();
                let mean = total as f64 / (p as f64 * p as f64);
                let radix = ctx
                    .tuning_table()
                    .and_then(|t| {
                        t.lookup_radix(ctx.profile().name, p, ctx.topo().q(), mean)
                    })
                    .unwrap_or_else(|| tuning::heuristic_radix(p, mean));
                tuna::run_sparse(ctx, blocks, radix)
            }
            AlgoKind::Hier { local, global } => {
                hier::run_sparse(ctx, blocks, local, global, sizes)
            }
        }
    }

    /// Run this algorithm on one rank. `blocks[d]` must be the block this
    /// rank sends to destination `d`. Returns delivered blocks + stats.
    pub fn dispatch(&self, ctx: &mut RankCtx, blocks: Vec<Block>) -> (Vec<Block>, AlgoStats) {
        match *self {
            AlgoKind::SpreadOut => (linear::spread_out(ctx, blocks), AlgoStats::default()),
            AlgoKind::OmpiLinear => (linear::ompi_linear(ctx, blocks), AlgoStats::default()),
            AlgoKind::Pairwise => (linear::pairwise(ctx, blocks), AlgoStats::default()),
            AlgoKind::Scattered { block_count } => {
                (linear::scattered(ctx, blocks, block_count), AlgoStats::default())
            }
            AlgoKind::Vendor => (
                linear::scattered(ctx, blocks, VENDOR_BLOCK_COUNT),
                AlgoStats::default(),
            ),
            AlgoKind::Bruck2 => tuna::run(ctx, blocks, 2),
            AlgoKind::Tuna { radix } => tuna::run(ctx, blocks, radix),
            AlgoKind::TunaAuto => {
                // All ranks must run the same radix: agree on the global
                // mean block size first (timed like any other traffic).
                let mine: u64 = blocks.iter().map(|b| b.len()).sum();
                let total = ctx.allreduce_sum(mine);
                let p = ctx.size();
                let mean = total as f64 / (p as f64 * p as f64);
                // A persisted tuning table attached to the engine wins
                // over the §V-A heuristic. The allreduced mean is
                // bit-identical on every rank, so every rank resolves the
                // same table entry — no extra agreement round needed.
                let radix = ctx
                    .tuning_table()
                    .and_then(|t| {
                        t.lookup_radix(ctx.profile().name, p, ctx.topo().q(), mean)
                    })
                    .unwrap_or_else(|| tuning::heuristic_radix(p, mean));
                tuna::run(ctx, blocks, radix)
            }
            AlgoKind::Hier { local, global } => hier::run(ctx, blocks, local, global),
        }
    }
}

/// How an all-to-allv executes on the engine.
///
/// * [`ExecMode::Threaded`] — one OS thread per rank, real message
///   matching; the golden oracle and the only mode that moves/validates
///   real payload bytes.
/// * [`ExecMode::Replay`] — compile a [`CommPlan`] from the counts
///   matrix (cached per engine) and advance it on the single-threaded
///   discrete-event executor; phantom-only, bit-identical timing, and
///   orders of magnitude cheaper at large P.
/// * [`ExecMode::Auto`] — replay for phantom workloads, threaded for
///   real ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Auto,
    Threaded,
    Replay,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "auto" => Some(ExecMode::Auto),
            "threaded" => Some(ExecMode::Threaded),
            "replay" => Some(ExecMode::Replay),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Auto => "auto",
            ExecMode::Threaded => "threaded",
            ExecMode::Replay => "replay",
        }
    }

    /// Concrete mode for a workload: `Auto` replays phantom payloads and
    /// threads real ones.
    pub fn resolve(self, real_payloads: bool) -> ExecMode {
        match self {
            ExecMode::Auto => {
                if real_payloads {
                    ExecMode::Threaded
                } else {
                    ExecMode::Replay
                }
            }
            m => m,
        }
    }
}

/// Per-rank statistics an algorithm reports beyond timing.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlgoStats {
    /// Peak number of occupied temporary-buffer slots (TuNA's T).
    pub t_peak: usize,
    /// Communication rounds executed.
    pub rounds: usize,
}

/// Result of a full all-to-allv run on the engine.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub algo: String,
    /// Simulated completion time (max over rank clocks).
    pub makespan: f64,
    /// Per-phase critical path (element-wise max over ranks).
    pub phases: PhaseBreakdown,
    /// Aggregate message/byte counters.
    pub counters: Counters,
    /// Max observed T occupancy over all ranks.
    pub t_peak: usize,
    /// Max rounds executed by any rank.
    pub rounds: usize,
    /// All ranks received a complete, correct block set.
    pub validated: bool,
}

/// Run `kind` over the whole engine on workload `sizes`.
///
/// With `real_payloads` every block carries a deterministic byte pattern
/// that is verified at the destination; without, phantom buffers carry
/// only sizes (for large-P simulations) and validation covers block
/// identity and sizes via workload fingerprints.
pub fn run_alltoallv(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    real_payloads: bool,
) -> Result<RunReport> {
    if kind.persistent_only() {
        return Err(TunaError::config(format!(
            "{} is persistent-only: its setup is amortized per handle, not per \
             call — construct it through comm::persist::PersistentColl",
            kind.name()
        )));
    }
    let parts = PreparedParts::build(engine, sizes)?;
    run_alltoallv_prepared(engine, kind, sizes, real_payloads, &parts, None)
}

/// The per-workload one-shot setup [`run_alltoallv`] performs before any
/// rank thread starts: the structural expectation counts (the
/// `senders()` transpose for sparse workloads) and the per-rank receive
/// fingerprints. Persistent handles build this once at `init` and hand
/// it to every `start`; repeated one-shot runs rebuild it per call.
pub(crate) struct PreparedParts {
    pub expect_counts: Arc<Vec<usize>>,
    pub fingerprints: Arc<Vec<u64>>,
}

impl PreparedParts {
    pub(crate) fn build(engine: &Engine, sizes: &BlockSizes) -> Result<PreparedParts> {
        let p = engine.topo.p();
        if sizes.p() != p {
            return Err(TunaError::config(format!(
                "workload is for P={} but engine has P={p}",
                sizes.p()
            )));
        }
        // A rank expects exactly one block per structural sender (every
        // rank for dense workloads). Build the transpose once, up front,
        // so rank threads share it instead of racing to construct it.
        let expect_counts: Arc<Vec<usize>> = if sizes.is_sparse() {
            Arc::new(sizes.senders().iter().map(Vec::len).collect())
        } else {
            Arc::new(vec![p; p])
        };
        Ok(PreparedParts {
            expect_counts,
            fingerprints: Arc::new(sizes.recv_fingerprints()),
        })
    }
}

/// Prebuilt per-rank send blocks for the threaded path: pattern-row
/// payload ropes (real mode) or row entry lists (phantom), materialized
/// once and cheaply re-instantiated per call. Payload ropes are
/// Arc-backed views, so a clone shares the underlying bytes — the
/// zero-copy accounting (`copied_bytes == 2 * total_bytes`) is
/// unaffected because it counts *simulated* writes/reads, which are
/// identical whether the views were built this call or at `init`.
pub(crate) struct PayloadArena {
    /// Per-rank `(dest, len)` send entries (every dest for dense rows).
    entries: Vec<Vec<(usize, u64)>>,
    /// Per-rank pattern payloads aligned with `entries`; `None` in
    /// phantom mode.
    bufs: Option<Vec<Vec<DataBuf>>>,
}

impl PayloadArena {
    pub(crate) fn build(sizes: &BlockSizes, real_payloads: bool) -> PayloadArena {
        let p = sizes.p();
        let entries: Vec<Vec<(usize, u64)>> = if sizes.is_sparse() {
            (0..p).map(|me| sizes.row_view(me).entries().collect()).collect()
        } else {
            (0..p)
                .map(|me| sizes.row(me).into_iter().enumerate().collect())
                .collect()
        };
        let bufs = real_payloads.then(|| {
            entries
                .iter()
                .enumerate()
                .map(|(me, es)| DataBuf::pattern_row_entries(me, es))
                .collect()
        });
        PayloadArena { entries, bufs }
    }

    /// Instantiate rank `me`'s send blocks: cloned payload views (real)
    /// or fresh phantoms (free).
    pub(crate) fn blocks_for(&self, me: usize) -> Vec<Block> {
        match &self.bufs {
            Some(bufs) => bufs[me]
                .iter()
                .zip(self.entries[me].iter())
                .map(|(data, &(d, _))| Block::new(me, d, data.clone()))
                .collect(),
            None => self.entries[me]
                .iter()
                .map(|&(d, len)| Block::new(me, d, DataBuf::Phantom(len)))
                .collect(),
        }
    }
}

/// The threaded-run core shared by [`run_alltoallv`] and the persistent
/// handles: every per-workload one-shot artifact arrives prebuilt
/// (`parts`, optionally an `arena`), so this function adds no setup of
/// its own. Persistent-only kinds are admitted here — the public entry
/// points gate them; a handle *is* the authorization.
pub(crate) fn run_alltoallv_prepared(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    real_payloads: bool,
    parts: &PreparedParts,
    arena: Option<&Arc<PayloadArena>>,
) -> Result<RunReport> {
    let p = engine.topo.p();
    if sizes.p() != p {
        return Err(TunaError::config(format!(
            "workload is for P={} but engine has P={p}",
            sizes.p()
        )));
    }
    kind.check(p, engine.topo.q())?;

    let sparse = sizes.is_sparse();
    let kind_c = *kind;
    let sizes_c = sizes.clone();
    let fp = parts.fingerprints.clone();
    let expect = parts.expect_counts.clone();
    let arena_c = arena.cloned();

    let res = engine.run(move |ctx| {
        let me = ctx.rank();
        // Real payloads are written once into a per-rank arena and handed
        // to the algorithm as zero-copy views; every hop from here to the
        // destination moves views, not bytes (see comm::buffer).
        let blocks: Vec<Block> = match &arena_c {
            Some(a) => a.blocks_for(me),
            None if sparse => {
                let entries: Vec<(usize, u64)> = sizes_c.row_view(me).entries().collect();
                if real_payloads {
                    DataBuf::pattern_row_entries(me, &entries)
                        .into_iter()
                        .zip(entries.iter())
                        .map(|(data, &(d, _))| Block::new(me, d, data))
                        .collect()
                } else {
                    entries
                        .iter()
                        .map(|&(d, len)| Block::new(me, d, DataBuf::Phantom(len)))
                        .collect()
                }
            }
            None => {
                let row = sizes_c.row(me);
                if real_payloads {
                    DataBuf::pattern_row(me, &row)
                        .into_iter()
                        .enumerate()
                        .map(|(d, data)| Block::new(me, d, data))
                        .collect()
                } else {
                    row.iter()
                        .enumerate()
                        .map(|(d, &len)| Block::new(me, d, DataBuf::Phantom(len)))
                        .collect()
                }
            }
        };
        let (recv, stats) = if sparse {
            kind_c.dispatch_sparse(ctx, blocks, &sizes_c)
        } else {
            kind_c.dispatch(ctx, blocks)
        };
        let ok = validate_received(me, expect[me], &recv, fp[me], real_payloads);
        (ok, stats)
    });

    let validated = res.ranks.iter().all(|r| r.value.0);
    let t_peak = res.ranks.iter().map(|r| r.value.1.t_peak).max().unwrap_or(0);
    let rounds = res.ranks.iter().map(|r| r.value.1.rounds).max().unwrap_or(0);
    let report = RunReport {
        algo: kind.name(),
        makespan: res.makespan,
        phases: res.phase_critical_path(),
        counters: res.total_counters(),
        t_peak,
        rounds,
        validated,
    };
    if !validated {
        return Err(TunaError::validation(format!(
            "{} delivered an incorrect block set",
            report.algo
        )));
    }
    Ok(report)
}

/// Run `kind` in `mode` (resolved against `real_payloads`): the threaded
/// oracle, or plan/replay for phantom workloads. `mode=replay` with real
/// payloads is a contradiction — replay never materializes bytes — and
/// fails loudly instead of silently dropping validation.
pub fn run_alltoallv_mode(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    real_payloads: bool,
    mode: ExecMode,
) -> Result<RunReport> {
    match mode.resolve(real_payloads) {
        ExecMode::Replay => {
            if real_payloads {
                return Err(TunaError::config(
                    "mode=replay is phantom-only (real payloads need the threaded oracle); \
                     use real=false or mode=threaded",
                ));
            }
            run_alltoallv_replay(engine, kind, sizes)
        }
        _ => run_alltoallv(engine, kind, sizes, real_payloads),
    }
}

/// Replay `kind` over `sizes`: compile (or fetch the cached) plan, then
/// advance it on the discrete-event executor — sharded across
/// `engine.replay_shards` workers (auto-sized from P and the host when
/// unset), bit-identical for every shard count. The report matches a
/// threaded phantom run (`tests/replay_equivalence.rs`); `validated`
/// reflects the compile-time schedule checks — byte validation requires
/// real payloads and therefore the threaded oracle.
pub fn run_alltoallv_replay(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
) -> Result<RunReport> {
    if kind.persistent_only() {
        return Err(TunaError::config(format!(
            "{} is persistent-only: its setup is amortized per handle, not per \
             call — construct it through comm::persist::PersistentColl",
            kind.name()
        )));
    }
    let plan = plan_for(engine, kind, sizes)?;
    let shards = engine
        .replay_shards
        .unwrap_or_else(|| crate::comm::replay::auto_shards(engine.topo.p()));
    replay_plan_report(engine, kind, &plan, shards)
}

/// Advance an already-compiled plan on the sharded replay executor and
/// assemble the [`RunReport`] — the replay tail shared by
/// [`run_alltoallv_replay`] and the persistent handles (which hold their
/// plan and shard count frozen across `start` calls).
pub(crate) fn replay_plan_report(
    engine: &Engine,
    kind: &AlgoKind,
    plan: &Arc<CommPlan>,
    shards: usize,
) -> Result<RunReport> {
    let res = crate::comm::replay::execute_faulted(
        &engine.profile,
        engine.topo,
        plan,
        shards,
        engine.faults.as_deref(),
    )?;
    Ok(RunReport {
        algo: kind.name(),
        makespan: res.makespan,
        phases: res.phase_critical_path(),
        counters: res.total_counters(),
        t_peak: plan.t_peak,
        rounds: plan.rounds,
        validated: true,
    })
}

/// Per-segment user compute charged by the segmented overlap driver
/// ahead of each segment's communication.
///
/// * `None` — pure segmentation, no compute to hide (the `segments=1`
///   bit-identity baseline).
/// * `Uniform(secs)` — the same cost for every `(rank, segment)`; this
///   is what the CLI's `compute=` knob produces, and the only variant
///   the plan cache admits (its identity is one `f64`).
/// * `PerRank(f)` — app-measured costs, `f(rank, segment)` seconds;
///   closures have no content identity, so these plans bypass the
///   cache.
#[derive(Clone, Copy)]
pub enum SegmentCompute<'a> {
    None,
    Uniform(f64),
    PerRank(&'a (dyn Fn(usize, usize) -> f64 + Sync)),
}

impl<'a> SegmentCompute<'a> {
    #[inline]
    fn cost(&self, rank: usize, segment: usize) -> f64 {
        match self {
            SegmentCompute::None => 0.0,
            SegmentCompute::Uniform(secs) => *secs,
            SegmentCompute::PerRank(f) => f(rank, segment),
        }
    }

    /// Cache identity when this variant has one (see [`SegmentCompute`]).
    fn cache_id(&self) -> Option<u64> {
        match self {
            SegmentCompute::None => Some(0),
            SegmentCompute::Uniform(secs) => Some(secs.to_bits()),
            SegmentCompute::PerRank(_) => None,
        }
    }
}

/// Compile the **stitched** segmented plan for `kind` over `sizes`:
/// [`segment_counts`] partitions every block's bytes into `segments`
/// chunk workloads, each chunk compiles to a valid [`CommPlan`] through
/// the ordinary [`compile_plan`] path, and the chunks are stitched into
/// one plan per rank.
///
/// * `overlap=false` (blocking stitch): `Compute(c_i); chunk_i` in
///   sequence — segmentation overhead with nothing hidden.
/// * `overlap=true` (pipelined stitch): each chunk splits at its final
///   `Wait` ([`RankPlan::split_at_last_wait`]); segment `i`'s compute
///   runs *between* segment `i−1`'s last communication post and its
///   completion wait, so the final round of every segment flies under
///   the next segment's compute:
///   `C₀ pre₀ · C₁ suf₀ pre₁ · C₂ suf₁ pre₂ · … · suf_{K−1}`.
///
/// At most one segment's communication is in flight per rank (the next
/// prefix posts only after the previous suffix waits), so same-tag
/// messages from consecutive segments can never race: per-channel FIFO
/// delivery keeps them in segment order. With `K=1` and no compute the
/// stitched plan is op-for-op the unsegmented plan — the `segments=1`
/// bit-identity of `replay_equivalence.rs` holds by construction.
///
/// `t_peak`/`rounds` report the per-chunk maxima (the driver keeps at
/// most two segments' buffers resident).
pub fn compile_segmented_plan(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    segments: usize,
    overlap: bool,
    compute: &SegmentCompute,
) -> Result<CommPlan> {
    if segments == 0 {
        return Err(TunaError::config("segments must be >= 1 (got 0)"));
    }
    let chunks = segment_counts(sizes, segments);
    let mut plans = Vec::with_capacity(segments);
    for chunk in &chunks {
        plans.push(compile_plan(engine, kind, chunk)?);
    }
    let p = engine.topo.p();
    let k = segments;
    let push_compute = |ops: &mut Vec<PlanOp>, secs: f64| {
        if secs > 0.0 {
            ops.push(PlanOp::Compute { secs });
        }
    };
    // The stitch is per rank (decode each chunk's rank program, splice),
    // so it runs through the same parallel packer as a family compile.
    let t_peak = plans.iter().map(|pl| pl.t_peak).max().unwrap_or(0);
    let rounds = plans.iter().map(|pl| pl.rounds).max().unwrap_or(0);
    let threads = engine.compile_threads_for(p);
    Ok(CommPlan::build_parallel(
        p,
        engine.topo.q(),
        kind.name(),
        t_peak,
        rounds,
        threads,
        |r| {
            let mut ops: Vec<PlanOp> = Vec::new();
            if overlap {
                push_compute(&mut ops, compute.cost(r, 0));
                let rp0 = plans[0].rank_plan(r);
                let (pre0, _) = rp0.split_at_last_wait();
                ops.extend_from_slice(pre0);
                for i in 1..k {
                    push_compute(&mut ops, compute.cost(r, i));
                    let rp_prev = plans[i - 1].rank_plan(r);
                    let (_, suf_prev) = rp_prev.split_at_last_wait();
                    ops.extend_from_slice(suf_prev);
                    let rp_i = plans[i].rank_plan(r);
                    let (pre_i, _) = rp_i.split_at_last_wait();
                    ops.extend_from_slice(pre_i);
                }
                let rp_last = plans[k - 1].rank_plan(r);
                let (_, suf_last) = rp_last.split_at_last_wait();
                ops.extend_from_slice(suf_last);
            } else {
                for (i, plan) in plans.iter().enumerate() {
                    push_compute(&mut ops, compute.cost(r, i));
                    ops.extend(plan.rank_plan(r).ops);
                }
            }
            ops
        },
    ))
}

/// Fetch (or compile) the stitched segmented plan through the engine's
/// plan cache. Cacheable compute variants extend [`plan_key`] with
/// `(segments, overlap, compute identity)`; `PerRank` closures compile
/// fresh every call.
pub fn segmented_plan_for(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    segments: usize,
    overlap: bool,
    compute: &SegmentCompute,
) -> Result<Arc<CommPlan>> {
    match compute.cache_id() {
        None => compile_segmented_plan(engine, kind, sizes, segments, overlap, compute)
            .map(Arc::new),
        Some(cid) => {
            let (spec, mut h) = plan_key(engine, kind, sizes);
            let mut mix = |v: u64| {
                h ^= v;
                h = h.wrapping_mul(0x100_0000_01b3);
            };
            mix(segments as u64);
            mix(overlap as u64 + 1);
            mix(cid);
            let key = (format!("{spec}#segments={segments},overlap={overlap}"), h);
            engine
                .plan_cache
                .get_or_try_insert(key, engine.topo.p(), engine.topo.q(), || {
                    compile_segmented_plan(engine, kind, sizes, segments, overlap, compute)
                })
        }
    }
}

/// Run the segmented overlap driver on the **threaded** engine: the
/// stitched plan is interpreted op-for-op by every rank thread
/// ([`RankCtx::run_plan`]), so message matching is real and timing is
/// virtual, exactly like any threaded collective. Phantom-only — plans
/// model sizes, never payload bytes — and bit-identical to
/// [`run_alltoallv_segmented_replay`] (asserted by
/// `tests/replay_equivalence.rs`). `validated` reflects the compile-time
/// schedule checks, as in replay.
pub fn run_alltoallv_segmented(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    segments: usize,
    overlap: bool,
    compute: &SegmentCompute,
) -> Result<RunReport> {
    if kind.persistent_only() {
        return Err(TunaError::config(format!(
            "{} is persistent-only: its setup is amortized per handle, not per \
             call — construct it through comm::persist::PersistentColl",
            kind.name()
        )));
    }
    let plan = segmented_plan_for(engine, kind, sizes, segments, overlap, compute)?;
    let plan_ref = &plan;
    let res = engine.run(move |ctx| {
        let rp = plan_ref.rank_plan(ctx.rank());
        ctx.run_plan(&rp);
    });
    Ok(RunReport {
        algo: kind.name(),
        makespan: res.makespan,
        phases: res.phase_critical_path(),
        counters: res.total_counters(),
        t_peak: plan.t_peak,
        rounds: plan.rounds,
        validated: true,
    })
}

/// Run the segmented overlap driver on the **sharded replay** executor:
/// same stitched plan, advanced by `comm/replay.rs` under
/// `engine.replay_shards` workers (auto-sized when unset), bit-identical
/// to the threaded driver and across every shard count.
pub fn run_alltoallv_segmented_replay(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    segments: usize,
    overlap: bool,
    compute: &SegmentCompute,
) -> Result<RunReport> {
    if kind.persistent_only() {
        return Err(TunaError::config(format!(
            "{} is persistent-only: its setup is amortized per handle, not per \
             call — construct it through comm::persist::PersistentColl",
            kind.name()
        )));
    }
    let plan = segmented_plan_for(engine, kind, sizes, segments, overlap, compute)?;
    let shards = engine
        .replay_shards
        .unwrap_or_else(|| crate::comm::replay::auto_shards(engine.topo.p()));
    replay_plan_report(engine, kind, &plan, shards)
}

/// The cache key of `kind`'s plan for `sizes` on `engine`: `(resolved
/// algo spec, mixed identity hash)`. The matrix identity comes
/// incrementally through [`BlockSizes::identity_hash`] — generator-backed
/// workloads hash their `(p, dist, seed)` descriptor (rows are a pure
/// function of it, so two separately constructed handles with equal
/// contents share one cache entry), materialized workloads hash their
/// structural entries row by row, never via a dense materialization.
pub fn plan_key(engine: &Engine, kind: &AlgoKind, sizes: &BlockSizes) -> (String, u64) {
    let mut h: u64 = sizes.identity_hash();
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100_0000_01b3);
    };
    mix(engine.topo.q() as u64);
    // `tuna:auto` resolves its radix against the attached tuning table,
    // so the table's identity is part of the plan's inputs (the Arc
    // address is unique for the table's lifetime; `Engine::with_tuning`
    // additionally resets the cache when swapping tables).
    if let Some(table) = &engine.tuning {
        mix(Arc::as_ptr(table) as u64);
    }
    (kind.spec(), h)
}

/// Fetch `kind`'s compiled plan for `sizes` from the engine's cache,
/// compiling on a miss. Keyed by [`plan_key`]; the engine's `(p, q)`
/// shape is re-verified on every hit so a 64-bit hash collision can
/// never hand a wrong-shape plan to the replay executor.
pub fn plan_for(engine: &Engine, kind: &AlgoKind, sizes: &BlockSizes) -> Result<Arc<CommPlan>> {
    let key = plan_key(engine, kind, sizes);
    engine
        .plan_cache
        .get_or_try_insert(key, engine.topo.p(), engine.topo.q(), || {
            compile_plan(engine, kind, sizes)
        })
}

/// Row-diff bound for [`patch_plan`]: beyond this many changed rows a
/// full recompile is cheaper than diffing P row views.
pub const PLAN_PATCH_MAX_ROWS: usize = 64;

/// Incrementally patch `base_plan` (compiled for `base_sizes`) into the
/// plan for `new_sizes`, recompiling only the ranks whose send rows
/// changed, and cache the result under `new_sizes`' [`plan_key`].
/// Returns `None` whenever patching would not be provably equivalent to
/// a fresh compile, in which case the caller should fall back to
/// [`plan_for`]:
///
/// * non-linear families — TuNA's moving-slot metadata, `tuna:auto`'s
///   allreduced mean and the hierarchy's bucketing couple every rank's
///   schedule to the whole matrix;
/// * shape mismatches, sparsity-class changes, or more than
///   [`PLAN_PATCH_MAX_ROWS`] changed rows ([`BlockSizes::row_diff`]);
/// * sparse rows whose structural destination *set* changed — receivers'
///   recv schedules follow the transpose, so such a change reaches
///   beyond the changed rows' own plans.
///
/// For the linear families, rank `r`'s plan is a function of row `r`
/// alone (receives carry no sizes), so splicing freshly emitted rank
/// plans for the changed rows is op-for-op identical to a full
/// recompile — asserted in `tests/replay_equivalence.rs`.
pub fn patch_plan(
    engine: &Engine,
    kind: &AlgoKind,
    base_sizes: &BlockSizes,
    base_plan: &Arc<CommPlan>,
    new_sizes: &BlockSizes,
) -> Option<Arc<CommPlan>> {
    let p = engine.topo.p();
    if base_plan.p != p || base_plan.q != engine.topo.q() || new_sizes.p() != p {
        return None;
    }
    let changed = new_sizes.row_diff(base_sizes, PLAN_PATCH_MAX_ROWS)?;
    if changed.is_empty() {
        return Some(base_plan.clone());
    }
    if new_sizes.is_sparse() {
        for &src in &changed {
            let old: Vec<usize> = base_sizes.row_view(src).entries().map(|(d, _)| d).collect();
            let new: Vec<usize> = new_sizes.row_view(src).entries().map(|(d, _)| d).collect();
            if old != new {
                return None;
            }
        }
    }
    let mut replacements = Vec::with_capacity(changed.len());
    for &src in &changed {
        replacements.push((src, linear_rank_plan(kind, new_sizes, src)?));
    }
    let patched = Arc::new(base_plan.with_rank_plans(replacements));
    engine
        .plan_cache
        .insert(plan_key(engine, kind, new_sizes), patched.clone());
    Some(patched)
}

/// Emit rank `me`'s plan alone — defined (and patchable) only for the
/// linear families, whose per-rank schedules depend solely on row `me`.
fn linear_rank_plan(kind: &AlgoKind, sizes: &BlockSizes, me: usize) -> Option<RankPlan> {
    use linear::{SparseBatching, SparseOrder};
    let sparse = sizes.is_sparse();
    let mut b = PlanBuilder::new(me, sizes.p());
    match *kind {
        AlgoKind::SpreadOut => {
            if sparse {
                linear::plan_sparse_rank(
                    &mut b,
                    sizes,
                    me,
                    SparseOrder::RoundRobin,
                    SparseBatching::SingleWait,
                );
            } else {
                linear::plan_spread_out_rank(&mut b, sizes, me);
            }
        }
        AlgoKind::OmpiLinear => {
            if sparse {
                linear::plan_sparse_rank(
                    &mut b,
                    sizes,
                    me,
                    SparseOrder::Ascending,
                    SparseBatching::SingleWait,
                );
            } else {
                linear::plan_ompi_linear_rank(&mut b, sizes, me);
            }
        }
        AlgoKind::Pairwise => {
            if sparse {
                linear::plan_sparse_rank(
                    &mut b,
                    sizes,
                    me,
                    SparseOrder::Pairwise,
                    SparseBatching::PerStep,
                );
            } else {
                linear::plan_pairwise_rank(&mut b, sizes, me);
            }
        }
        AlgoKind::Scattered { block_count } => {
            if sparse {
                linear::plan_sparse_rank(
                    &mut b,
                    sizes,
                    me,
                    SparseOrder::RoundRobin,
                    SparseBatching::Chunk(block_count),
                );
            } else {
                linear::plan_scattered_rank(&mut b, sizes, me, block_count);
            }
        }
        AlgoKind::Vendor => {
            if sparse {
                linear::plan_sparse_rank(
                    &mut b,
                    sizes,
                    me,
                    SparseOrder::RoundRobin,
                    SparseBatching::Chunk(VENDOR_BLOCK_COUNT),
                );
            } else {
                linear::plan_scattered_rank(&mut b, sizes, me, VENDOR_BLOCK_COUNT);
            }
        }
        _ => return None,
    }
    Some(b.finish())
}

/// The `tuna:auto` radix, resolved at compile time exactly as dispatch
/// resolves it: the allreduced total is exact u64 arithmetic, so the
/// compile-time mean is bit-identical to every rank's allreduced mean,
/// and the tuning-table-then-heuristic policy is the same one.
fn tuna_auto_radix(engine: &Engine, sizes: &BlockSizes) -> usize {
    let p = sizes.p();
    let total = (0..p)
        .map(|s| sizes.row_view(s).total())
        .fold(0u64, u64::wrapping_add);
    let mean = total as f64 / (p as f64 * p as f64);
    engine
        .tuning
        .as_deref()
        .and_then(|t| t.lookup_radix(engine.profile.name, p, engine.topo.q(), mean))
        .unwrap_or_else(|| tuning::heuristic_radix(p, mean))
}

/// Compile `kind`'s [`CommPlan`] from the counts matrix — without
/// running anything. Per the plan-determinism contract (`comm::plan`),
/// the result depends only on the matrix and on resolved parameters;
/// `tuna:auto` resolves its radix here exactly as dispatch would (same
/// allreduced mean, same tuning-table-then-heuristic policy) and emits
/// the agreement allreduce the threaded run performs.
///
/// Worker count comes from the engine's `compile-threads` policy; by
/// the parallel-compile determinism argument (`comm::plan`) the result
/// is representation-identical for every thread count.
pub fn compile_plan(engine: &Engine, kind: &AlgoKind, sizes: &BlockSizes) -> Result<CommPlan> {
    compile_plan_threads(engine, kind, sizes, engine.compile_threads_for(sizes.p()))
}

/// [`compile_plan`] with an explicit worker count. Public for the
/// serial-vs-parallel equality tests and the compile-speedup bench;
/// everything else should let the engine resolve its policy.
pub fn compile_plan_threads(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
    threads: usize,
) -> Result<CommPlan> {
    let topo = engine.topo;
    let p = topo.p();
    if sizes.p() != p {
        return Err(TunaError::config(format!(
            "workload is for P={} but engine has P={p}",
            sizes.p()
        )));
    }
    kind.check(p, topo.q())?;

    let sparse = sizes.is_sparse();
    let q = topo.q();
    let plan = match *kind {
        AlgoKind::SpreadOut
        | AlgoKind::OmpiLinear
        | AlgoKind::Pairwise
        | AlgoKind::Scattered { .. }
        | AlgoKind::Vendor => {
            // The linear families are per-rank emitters (dense and
            // sparse), so they feed the parallel packer directly.
            CommPlan::build_parallel(p, q, kind.name(), 0, 0, threads, |me| {
                linear_rank_plan(kind, sizes, me)
                    .expect("linear family has a per-rank emitter")
                    .ops
            })
        }
        AlgoKind::Bruck2 | AlgoKind::Tuna { .. } | AlgoKind::TunaAuto => {
            let (radix, auto) = match *kind {
                AlgoKind::Bruck2 => (2, false),
                AlgoKind::Tuna { radix } => (radix, false),
                AlgoKind::TunaAuto => (tuna_auto_radix(engine, sizes), true),
                _ => unreachable!(),
            };
            let fp = tuna::flat_plan(sizes, radix, sparse);
            let (t_peak, rounds) = fp.stats();
            CommPlan::build_parallel(p, q, kind.name(), t_peak, rounds, threads, |me| {
                let mut b = PlanBuilder::new(me, p);
                if auto {
                    // Dispatch preamble: the radix-agreement allreduce,
                    // timed like any other traffic.
                    b.allreduce();
                }
                fp.emit_rank(&mut b, me);
                b.finish().ops
            })
        }
        AlgoKind::Hier { local, global } => {
            let (ranks, t_peak, rounds) = hier::plan_build(sizes, topo, local, global, threads);
            CommPlan::from_rank_plans(p, q, kind.name(), ranks, t_peak, rounds)
        }
    };
    Ok(plan)
}

/// The pre-forge serial reference: every rank's op list through the
/// aggregate per-family builder emitters, exactly as `compile_plan`
/// built plans before the parallel packer and the interned arena. Kept
/// as the oracle for the IR property tests (arena decode == builder
/// output for every rank) — not used on any hot path.
#[doc(hidden)]
pub fn compile_rank_plans_serial(
    engine: &Engine,
    kind: &AlgoKind,
    sizes: &BlockSizes,
) -> Result<(Vec<RankPlan>, usize, usize)> {
    let topo = engine.topo;
    let p = topo.p();
    if sizes.p() != p {
        return Err(TunaError::config(format!(
            "workload is for P={} but engine has P={p}",
            sizes.p()
        )));
    }
    kind.check(p, topo.q())?;

    let sparse = sizes.is_sparse();
    let mut builders: Vec<PlanBuilder> = (0..p).map(|me| PlanBuilder::new(me, p)).collect();
    let (t_peak, rounds) = match *kind {
        AlgoKind::SpreadOut => {
            if sparse {
                linear::plan_spread_out_sparse(&mut builders, sizes);
            } else {
                linear::plan_spread_out(&mut builders, sizes);
            }
            (0, 0)
        }
        AlgoKind::OmpiLinear => {
            if sparse {
                linear::plan_ompi_linear_sparse(&mut builders, sizes);
            } else {
                linear::plan_ompi_linear(&mut builders, sizes);
            }
            (0, 0)
        }
        AlgoKind::Pairwise => {
            if sparse {
                linear::plan_pairwise_sparse(&mut builders, sizes);
            } else {
                linear::plan_pairwise(&mut builders, sizes);
            }
            (0, 0)
        }
        AlgoKind::Scattered { block_count } => {
            if sparse {
                linear::plan_scattered_sparse(&mut builders, sizes, block_count);
            } else {
                linear::plan_scattered(&mut builders, sizes, block_count);
            }
            (0, 0)
        }
        AlgoKind::Vendor => {
            if sparse {
                linear::plan_scattered_sparse(&mut builders, sizes, VENDOR_BLOCK_COUNT);
            } else {
                linear::plan_scattered(&mut builders, sizes, VENDOR_BLOCK_COUNT);
            }
            (0, 0)
        }
        AlgoKind::Bruck2 if sparse => tuna::plan_into_sparse(&mut builders, sizes, 2),
        AlgoKind::Bruck2 => tuna::plan_into(&mut builders, sizes, 2),
        AlgoKind::Tuna { radix } if sparse => {
            tuna::plan_into_sparse(&mut builders, sizes, radix)
        }
        AlgoKind::Tuna { radix } => tuna::plan_into(&mut builders, sizes, radix),
        AlgoKind::TunaAuto => {
            for b in builders.iter_mut() {
                b.allreduce();
            }
            let radix = tuna_auto_radix(engine, sizes);
            if sparse {
                tuna::plan_into_sparse(&mut builders, sizes, radix)
            } else {
                tuna::plan_into(&mut builders, sizes, radix)
            }
        }
        AlgoKind::Hier { local, global } => {
            return Ok(hier::plan_build(sizes, topo, local, global, 1));
        }
    };
    Ok((
        builders.into_iter().map(PlanBuilder::finish).collect(),
        t_peak,
        rounds,
    ))
}

/// Check a received block set: complete origin coverage (`expect_n`
/// structural senders — P for dense workloads), correct destination,
/// fingerprint-validated sizes, and (in real mode) intact byte patterns.
/// A phantom send for a structurally absent pair shows up as an excess
/// block and fails the count check.
fn validate_received(me: usize, expect_n: usize, recv: &[Block], expect_fp: u64, real: bool) -> bool {
    if recv.len() != expect_n {
        return false;
    }
    let mut origins = HashSet::with_capacity(expect_n);
    let mut fp = 0u64;
    for b in recv {
        if b.dest as usize != me {
            return false;
        }
        if !origins.insert(b.origin) {
            return false;
        }
        fp = fp.wrapping_add(fingerprint_one(b.origin as usize, b.len()));
        if real && b.data.check_pattern(b.origin as usize, me).is_err() {
            return false;
        }
    }
    fp == expect_fp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds() {
        assert_eq!(AlgoKind::parse("spread-out").unwrap(), AlgoKind::SpreadOut);
        assert_eq!(AlgoKind::parse("ompi-linear").unwrap(), AlgoKind::OmpiLinear);
        assert_eq!(AlgoKind::parse("pairwise").unwrap(), AlgoKind::Pairwise);
        assert_eq!(
            AlgoKind::parse("scattered:b=16").unwrap(),
            AlgoKind::Scattered { block_count: 16 }
        );
        assert_eq!(AlgoKind::parse("vendor").unwrap(), AlgoKind::Vendor);
        assert_eq!(AlgoKind::parse("bruck2").unwrap(), AlgoKind::Bruck2);
        assert_eq!(AlgoKind::parse("tuna:r=8").unwrap(), AlgoKind::Tuna { radix: 8 });
        assert_eq!(AlgoKind::parse("tuna:auto").unwrap(), AlgoKind::TunaAuto);
        assert_eq!(AlgoKind::parse("tuna:r=auto").unwrap(), AlgoKind::TunaAuto);
        assert_eq!(
            AlgoKind::parse("hier:l=tuna:r=4,g=coalesced:b=2").unwrap(),
            AlgoKind::hier_coalesced(4, 2)
        );
        assert_eq!(
            AlgoKind::parse("hier:l=linear,g=bruck:r=2").unwrap(),
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } }
        );
        assert_eq!(
            AlgoKind::parse("hier:g=linear,l=tuna:r=4").unwrap(),
            AlgoKind::Hier { local: LocalAlgo::Tuna { radix: 4 }, global: GlobalAlgo::Linear }
        );
    }

    #[test]
    fn legacy_hier_specs_parse_as_composition_aliases() {
        assert_eq!(
            AlgoKind::parse("tuna-hier-coalesced:r=4,b=2").unwrap(),
            AlgoKind::hier_coalesced(4, 2)
        );
        assert_eq!(
            AlgoKind::parse("tuna-hier-staggered:b=2,r=4").unwrap(),
            AlgoKind::hier_staggered(4, 2)
        );
        // The alias round-trips through the *new* canonical spec.
        let k = AlgoKind::parse("tuna-hier-coalesced:r=4,b=2").unwrap();
        assert_eq!(k.spec(), "hier:l=tuna:r=4,g=coalesced:b=2");
        assert_eq!(AlgoKind::parse(&k.spec()).unwrap(), k);
    }

    #[test]
    fn parse_errors_name_the_problem() {
        // A bare `tuna` no longer fails silently: the error names `r`.
        let e = AlgoKind::parse("tuna").unwrap_err().to_string();
        assert!(e.contains("missing parameter `r`"), "{e}");
        let e = AlgoKind::parse("scattered").unwrap_err().to_string();
        assert!(e.contains("missing parameter `b`"), "{e}");
        let e = AlgoKind::parse("tuna-hier-coalesced:r=4").unwrap_err().to_string();
        assert!(e.contains("missing parameter `b`"), "{e}");
        let e = AlgoKind::parse("tuna:r=zero").unwrap_err().to_string();
        assert!(e.contains("invalid value `zero`"), "{e}");
        let e = AlgoKind::parse("nope").unwrap_err().to_string();
        assert!(e.contains("unknown algorithm `nope`"), "{e}");
        // Composition errors name the level and the parameter.
        let e = AlgoKind::parse("hier:l=tuna:r=4").unwrap_err().to_string();
        assert!(e.contains("missing global level"), "{e}");
        let e = AlgoKind::parse("hier:g=linear").unwrap_err().to_string();
        assert!(e.contains("missing local level"), "{e}");
        let e = AlgoKind::parse("hier:l=tuna,g=linear").unwrap_err().to_string();
        assert!(e.contains("missing parameter `r`"), "{e}");
        let e = AlgoKind::parse("hier:l=linear,g=zig").unwrap_err().to_string();
        assert!(e.contains("unknown global algorithm `zig`"), "{e}");
    }

    #[test]
    fn spec_roundtrips_through_parse() {
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 7 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 5 },
            AlgoKind::TunaAuto,
            AlgoKind::hier_coalesced(3, 2),
            AlgoKind::hier_staggered(4, 9),
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear },
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 3 } },
            AlgoKind::Hier { local: LocalAlgo::Tuna { radix: 2 }, global: GlobalAlgo::Linear },
            AlgoKind::Hier {
                local: LocalAlgo::Tuna { radix: 6 },
                global: GlobalAlgo::Bruck { radix: 4 },
            },
        ] {
            assert_eq!(AlgoKind::parse(&kind.spec()).unwrap(), kind, "{}", kind.spec());
        }
    }

    #[test]
    fn names_include_params() {
        assert_eq!(AlgoKind::Tuna { radix: 4 }.name(), "tuna(r=4)");
        let n = AlgoKind::hier_coalesced(2, 8).name();
        assert!(n.contains("tuna(r=2)") && n.contains("coalesced(b=8)"), "{n}");
        assert_eq!(
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } }
                .name(),
            "hier(l=linear,g=bruck(r=2))"
        );
    }

    #[test]
    fn check_rejects_bad_params() {
        assert!(AlgoKind::Tuna { radix: 1 }.check(8, 2).is_err());
        assert!(AlgoKind::Tuna { radix: 9 }.check(8, 2).is_err());
        assert!(AlgoKind::Tuna { radix: 8 }.check(8, 2).is_ok());
        assert!(AlgoKind::Scattered { block_count: 0 }.check(8, 2).is_err());
        assert!(AlgoKind::hier_coalesced(4, 1).check(8, 2).is_err()); // radix > Q
        assert!(AlgoKind::hier_coalesced(2, 1).check(8, 1).is_err()); // Q < 2
        assert!(AlgoKind::hier_coalesced(2, 0).check(8, 2).is_err()); // bc = 0
        assert!(AlgoKind::hier_staggered(2, 1).check(8, 4).is_ok());
        // Compositions validate level by level.
        let lin_bruck = |r: usize| AlgoKind::Hier {
            local: LocalAlgo::Linear,
            global: GlobalAlgo::Bruck { radix: r },
        };
        assert!(lin_bruck(2).check(8, 2).is_ok()); // N = 4
        assert!(lin_bruck(4).check(8, 2).is_ok()); // radix = N
        assert!(lin_bruck(5).check(8, 2).is_err()); // radix > N
        assert!(lin_bruck(1).check(8, 2).is_err()); // radix < 2
        assert!(AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Linear }
            .check(8, 1)
            .is_err()); // Q < 2 still rejected
    }

    #[test]
    fn tuna_auto_prefers_attached_tuning_table() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::{BlockSizes, Dist};

        let (p, q) = (12usize, 4usize);
        let profile = MachineProfile::test_flat();
        let sizes = BlockSizes::generate(p, Dist::Uniform { max: 64 }, 3);
        let total: u64 = (0..p).map(|s| sizes.row(s).iter().sum::<u64>()).sum();
        let mean = total as f64 / (p * p) as f64;
        let heur = tuning::heuristic_radix(p, mean);
        let table_radix = 5usize;
        assert_ne!(heur, table_radix, "pick a table radix the heuristic never yields");

        let table = tuning::TuningTable {
            entries: vec![tuning::TuningEntry {
                machine: profile.name.to_string(),
                p,
                q,
                dist: "uniform".into(),
                mean_block: mean,
                rank: 1,
                algo: AlgoKind::Tuna { radix: table_radix },
                model_time: 1e-3,
                measured_time: None,
            }],
        };

        let plain = Engine::new(profile.clone(), Topology::new(p, q));
        let tuned = Engine::new(profile, Topology::new(p, q))
            .with_tuning(Some(Arc::new(table)));

        let auto_plain = run_alltoallv(&plain, &AlgoKind::TunaAuto, &sizes, true).unwrap();
        let auto_tuned = run_alltoallv(&tuned, &AlgoKind::TunaAuto, &sizes, true).unwrap();
        let fixed_heur =
            run_alltoallv(&plain, &AlgoKind::Tuna { radix: heur }, &sizes, true).unwrap();
        let fixed_table =
            run_alltoallv(&plain, &AlgoKind::Tuna { radix: table_radix }, &sizes, true).unwrap();

        // Without a table: heuristic schedule; with: the stored radix.
        assert_eq!(auto_plain.rounds, fixed_heur.rounds);
        assert_eq!(auto_tuned.rounds, fixed_table.rounds);
        assert_ne!(auto_tuned.rounds, auto_plain.rounds);
    }

    #[test]
    fn exec_mode_parses_and_resolves() {
        assert_eq!(ExecMode::parse("auto"), Some(ExecMode::Auto));
        assert_eq!(ExecMode::parse("threaded"), Some(ExecMode::Threaded));
        assert_eq!(ExecMode::parse("replay"), Some(ExecMode::Replay));
        assert_eq!(ExecMode::parse("nope"), None);
        assert_eq!(ExecMode::Auto.resolve(true), ExecMode::Threaded);
        assert_eq!(ExecMode::Auto.resolve(false), ExecMode::Replay);
        assert_eq!(ExecMode::Replay.resolve(true), ExecMode::Replay);
        assert_eq!(ExecMode::Threaded.resolve(false), ExecMode::Threaded);
    }

    #[test]
    fn replay_mode_rejects_real_payloads() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::Dist;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(8, 2));
        let sizes = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 1);
        let kind = AlgoKind::Tuna { radix: 2 };
        let err = run_alltoallv_mode(&e, &kind, &sizes, true, ExecMode::Replay)
            .unwrap_err()
            .to_string();
        assert!(err.contains("phantom-only"), "{err}");
        // Auto with real payloads falls back to the threaded oracle.
        let rep = run_alltoallv_mode(&e, &kind, &sizes, true, ExecMode::Auto).unwrap();
        assert!(rep.validated);
    }

    #[test]
    fn plans_depend_only_on_the_counts_matrix() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::Dist;
        // Same (P, dist, seed) twice, plus a payload-mode flip on the
        // threaded side, never changes the compiled plan.
        let e = Engine::new(MachineProfile::fugaku(), Topology::new(12, 4));
        let sizes = BlockSizes::generate(12, Dist::PowerLaw { max: 256, skew: 3.0 }, 9);
        let again = BlockSizes::generate(12, Dist::PowerLaw { max: 256, skew: 3.0 }, 9);
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 3 },
            AlgoKind::hier_coalesced(2, 2),
            AlgoKind::Hier {
                local: LocalAlgo::Linear,
                global: GlobalAlgo::Bruck { radix: 2 },
            },
        ] {
            let a = compile_plan(&e, &kind, &sizes).unwrap();
            let b = compile_plan(&e, &kind, &again).unwrap();
            assert_eq!(a, b, "{} plan not a pure function of the matrix", kind.name());
            assert!(a.total_ops() > 0);
        }
        // A different seed gives a different matrix and (generically) a
        // different plan.
        let other = BlockSizes::generate(12, Dist::PowerLaw { max: 256, skew: 3.0 }, 10);
        let a = compile_plan(&e, &AlgoKind::Tuna { radix: 3 }, &sizes).unwrap();
        let c = compile_plan(&e, &AlgoKind::Tuna { radix: 3 }, &other).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn plan_for_caches_per_engine() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::Dist;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(8, 2));
        let sizes = BlockSizes::generate(8, Dist::Uniform { max: 128 }, 3);
        let kind = AlgoKind::Tuna { radix: 2 };
        let a = plan_for(&e, &kind, &sizes).unwrap();
        let b = plan_for(&e, &kind, &sizes).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert_eq!(e.plan_cache.stats(), (1, 1));
        // Different algo or workload compiles a fresh plan.
        let c = plan_for(&e, &AlgoKind::Tuna { radix: 4 }, &sizes).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        let other = BlockSizes::generate(8, Dist::Uniform { max: 128 }, 4);
        let d = plan_for(&e, &kind, &other).unwrap();
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(e.plan_cache.len(), 3);
    }

    #[test]
    fn equal_content_workloads_share_one_cache_entry() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::Dist;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(16, 4));
        let kind = AlgoKind::Tuna { radix: 4 };
        // Two *separately constructed* generator-backed workloads with
        // equal contents (same descriptor) hit the same cache entry —
        // the identity hash is content identity, not object identity.
        let a = BlockSizes::generate(16, Dist::Sparse { nnz: 3, max: 128 }, 9);
        let b = BlockSizes::generate(16, Dist::Sparse { nnz: 3, max: 128 }, 9);
        let pa = plan_for(&e, &kind, &a).unwrap();
        let pb = plan_for(&e, &kind, &b).unwrap();
        assert!(Arc::ptr_eq(&pa, &pb), "equal generator descriptors must share a plan");
        assert_eq!(e.plan_cache.stats(), (1, 1));
        // Equal-content CSR workloads, built independently, share too —
        // hashed incrementally through the row views, no dense
        // materialization.
        let rows = || {
            vec![
                vec![(1usize, 16u64), (3, 8)],
                vec![],
                vec![(0, 24)],
                vec![(2, 8)],
            ]
        };
        let c1 = BlockSizes::from_sparse_rows(4, rows());
        let c2 = BlockSizes::from_sparse_rows(4, rows());
        let e4 = Engine::new(MachineProfile::test_flat(), Topology::new(4, 2));
        let pc1 = plan_for(&e4, &kind, &c1).unwrap();
        let pc2 = plan_for(&e4, &kind, &c2).unwrap();
        assert!(Arc::ptr_eq(&pc1, &pc2));
        assert_eq!(e4.plan_cache.stats(), (1, 1));
    }

    #[test]
    fn sparse_runs_validate_and_skip_absent_pairs() {
        use crate::comm::{Engine, Topology};
        use crate::model::MachineProfile;
        use crate::workload::Dist;
        let (p, q) = (16usize, 4usize);
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 4, max: 256 }, 11);
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::TunaAuto,
            AlgoKind::hier_coalesced(2, 1),
        ] {
            let rep = run_alltoallv(&e, &kind, &sizes, true).unwrap();
            assert!(rep.validated, "{}", kind.name());
        }
        // The structural message budget: a sparse spread-out run sends
        // exactly one message per off-diagonal structural entry.
        let offdiag: u64 = (0..p)
            .map(|s| sizes.row_view(s).entries().filter(|&(d, _)| d != s).count() as u64)
            .sum();
        let rep = run_alltoallv(&e, &AlgoKind::SpreadOut, &sizes, false).unwrap();
        assert_eq!(rep.counters.total_msgs(), offdiag);
    }

    #[test]
    fn validate_received_catches_problems() {
        let mk = |origin: usize, dest: usize, len: u64| Block::new(origin, dest, DataBuf::Phantom(len));
        let p = 3;
        let sizes = [5u64, 7, 9];
        let fp: u64 = (0..3).map(|s| fingerprint_one(s, sizes[s])).fold(0, u64::wrapping_add);
        let good: Vec<Block> = (0..3).map(|s| mk(s, 1, sizes[s])).collect();
        assert!(validate_received(1, p, &good, fp, false));
        // Missing a block.
        assert!(!validate_received(1, p, &good[..2], fp, false));
        // Duplicate origin.
        let dup = vec![mk(0, 1, 5), mk(0, 1, 7), mk(2, 1, 9)];
        assert!(!validate_received(1, p, &dup, fp, false));
        // Wrong destination.
        let wrong = vec![mk(0, 2, 5), mk(1, 1, 7), mk(2, 1, 9)];
        assert!(!validate_received(1, p, &wrong, fp, false));
        // Wrong size breaks fingerprint.
        let bad = vec![mk(0, 1, 6), mk(1, 1, 7), mk(2, 1, 9)];
        assert!(!validate_received(1, p, &bad, fp, false));
    }
}
