//! **TuNA_l^g** — hierarchical tunable non-uniform all-to-all (§IV,
//! Algorithms 2 and 3).
//!
//! Two decoupled phases:
//!
//! 1. **Intra-node** (implicit groups, §IV-A(a)): the P data blocks at
//!    each rank are viewed as N groups of Q (group k = blocks destined to
//!    node k's ranks). All N groups run *concurrently* through one TuNA
//!    slot exchange over the node's Q ranks: group offset `j`'s slot at
//!    rank `(n, g)` aggregates the N sub-blocks destined to
//!    `(k, (g+j) mod Q)` for every node `k` — the TuNA metadata phase
//!    doubles as the size exchange the implicit strategy needs, at no
//!    extra cost. Afterwards rank `(n, g)` holds, for every node `k`, the
//!    Q blocks `{(n, g') → (k, g)}` — exactly what the Q-port inter-node
//!    phase wants.
//! 2. **Inter-node** (§IV-A(b)): rank `(n, g)` exchanges only with ranks
//!    of the same group id `g` (Q-port model), using the scattered
//!    algorithm's batched non-blocking pattern with tunable
//!    `block_count`:
//!    * **coalesced** (Alg. 3): one message of Q blocks per target node —
//!      N−1 rounds — after a local rearrangement pass that compacts T;
//!    * **staggered** (Alg. 2): one block per message — Q·(N−1) rounds.
//!
//! The intra-node slot that aggregates N sub-blocks, the bucketing by
//! destination node, and both inter-node exchanges move payload *views*
//! only (`comm::buffer` ropes): blocks stay whole and are batched by
//! value, so aggregation never touches payload bytes on the host. The
//! `ctx.copy` charges keep modeling the rearrangement cost on the
//! simulated machine's clock.

use super::tuna::{tuna_core, SlotContent};
use super::AlgoStats;
use crate::comm::engine::{RecvReq, SendReq};
use crate::comm::{Block, Payload, Phase, PlanBuilder, RankCtx, Topology};
use crate::workload::BlockSizes;

/// Tag space for the inter-node phase (the intra-node core uses tags from
/// 0; K_intra <= Q so this is comfortably disjoint).
const INTER_TAG: u32 = 1_000_000;

/// Run hierarchical TuNA. `radix` tunes the intra-node TuNA (2..=Q);
/// `block_count` batches the inter-node scattered exchange; `coalesced`
/// selects Algorithm 3 (true) or Algorithm 2 (false).
pub fn run(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    radix: usize,
    block_count: usize,
    coalesced: bool,
) -> (Vec<Block>, AlgoStats) {
    let topo = *ctx.topo();
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    let me = ctx.rank();
    let my_node = topo.node_of(me);
    let g = topo.group_rank(me);
    assert_eq!(blocks.len(), p);
    assert!(q >= 2, "hierarchical TuNA needs Q >= 2");
    assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
    assert!(block_count >= 1);

    // ---- prepare (Alg. 3 lines 1-5): global max block size M, index
    // arrays.
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64);
    ctx.phase_lap(Phase::Prepare);

    // ---- intra-node phase: one TuNA over the node's Q ranks; slot j
    // aggregates the N sub-blocks destined to group-rank (g + j) % Q.
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        let d = b.dest as usize;
        by_dest[d] = Some(b);
    }
    let slots: Vec<SlotContent> = (0..q)
        .map(|j| {
            let dest_g = (g + j) % q;
            (0..n_nodes)
                .map(|k| {
                    by_dest[topo.rank_of(k, dest_g)]
                        .take()
                        .expect("one block per destination")
                })
                .collect()
        })
        .collect();

    let intra = tuna_core(ctx, my_node * q, q, radix, n_nodes, slots, 0);
    let mut stats = intra.stats;

    // Bucket the now group-aligned blocks by destination node: bucket[k] =
    // the Q blocks {(my_node, g') -> (k, g)}.
    let mut buckets: Vec<Vec<Block>> = (0..n_nodes).map(|_| Vec::with_capacity(q)).collect();
    for content in intra.slots {
        for b in content {
            debug_assert_eq!(topo.group_rank(b.dest as usize), g, "intra phase must align groups");
            buckets[topo.node_of(b.dest as usize)].push(b);
        }
    }
    // Deterministic order inside each bucket (by origin) so staggered
    // senders/receivers pair messages identically.
    for bucket in buckets.iter_mut() {
        bucket.sort_by_key(|b| b.origin);
    }

    // Own node's bucket is final.
    let mut recv: Vec<Block> = Vec::with_capacity(p);
    ctx.phase_mark();
    ctx.copy(buckets[my_node].iter().map(|b| b.len()).sum());
    recv.extend(std::mem::take(&mut buckets[my_node]));
    ctx.phase_lap(Phase::Replace);

    if n_nodes == 1 {
        return (recv, stats);
    }

    if coalesced {
        // ---- Alg. 3 lines 19-30: rearrange T (compact empty segments),
        // then batched node-level rounds of one Q-block message each.
        ctx.phase_mark();
        let staged_bytes: u64 = buckets.iter().flatten().map(|b| b.len()).sum();
        ctx.copy(staged_bytes);
        ctx.phase_lap(Phase::Rearrange);

        let mut round = 0usize; // node offsets 1..N-1
        while round < n_nodes - 1 {
            let batch = block_count.min(n_nodes - 1 - round);
            let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
            let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
            for i in 0..batch {
                let off = round + i + 1;
                let ndst = (my_node + n_nodes - off) % n_nodes;
                let nsrc = (my_node + off) % n_nodes;
                let tag = INTER_TAG + off as u32;
                recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                let payload = Payload::Blocks(std::mem::take(&mut buckets[ndst]));
                sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
            }
            for pl in ctx.waitall(&sends, &recvs) {
                recv.extend(pl.into_blocks());
            }
            stats.rounds += batch;
            round += batch;
        }
        ctx.phase_lap(Phase::InterNode);
    } else {
        // ---- Alg. 2: staggered — one block per message, Q*(N-1) steps,
        // batched by block_count.
        ctx.phase_mark();
        let total_steps = (n_nodes - 1) * q;
        let mut step = 0usize;
        while step < total_steps {
            let batch = block_count.min(total_steps - step);
            let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
            let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
            for i in 0..batch {
                let idx = step + i;
                let off = idx / q + 1; // node offset 1..N-1
                let j = idx % q; // which of the Q blocks
                let ndst = (my_node + n_nodes - off) % n_nodes;
                let nsrc = (my_node + off) % n_nodes;
                let tag = INTER_TAG + idx as u32;
                recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                // The tombstone left behind is never sent or validated;
                // the real block moves out as a view, bytes untouched.
                let block = std::mem::replace(
                    &mut buckets[ndst][j],
                    Block::new(0, 0, crate::comm::DataBuf::Phantom(0)),
                );
                sends.push(ctx.isend(topo.rank_of(ndst, g), tag, Payload::Blocks(vec![block])));
            }
            for pl in ctx.waitall(&sends, &recvs) {
                recv.extend(pl.into_blocks());
            }
            stats.rounds += 1;
            step += batch;
        }
        ctx.phase_lap(Phase::InterNode);
    }

    debug_assert_eq!(recv.len(), p);
    (recv, stats)
}

// ---- plan compiler --------------------------------------------------------

/// Compile hierarchical TuNA ([`run`]) for every rank from the counts
/// matrix. The intra-node phase is a per-node [`super::tuna::plan_core`]
/// joint simulation with arity N; the inter-node phase's message and copy
/// sizes come from the matrix in closed form — after the intra phase,
/// rank `(n, g)`'s bucket for node `k` holds exactly the blocks
/// `{(n, g') → (k, g)}` in ascending `g'` order.
pub(crate) fn plan_into(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    topo: Topology,
    radix: usize,
    block_count: usize,
    coalesced: bool,
) -> (usize, usize) {
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    assert!(q >= 2, "hierarchical TuNA needs Q >= 2");
    assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
    assert!(block_count >= 1);
    let rows: Vec<Vec<u64>> = (0..p).map(|s| sizes.row(s)).collect();

    // Prepare: global allreduce for M + index array write.
    for b in builders.iter_mut() {
        b.mark();
        b.allreduce();
        b.copy(4 * p as u64);
        b.lap(Phase::Prepare);
    }

    // Intra-node phase, one joint core simulation per node: slot j of
    // rank (node, g) aggregates the N sub-blocks destined (k, (g+j)%Q).
    let mut t_peak = 0usize;
    let mut rounds = 0usize;
    for node in 0..n_nodes {
        let base = node * q;
        let mut slots: Vec<Vec<u64>> = (0..q)
            .map(|g| {
                let row = &rows[base + g];
                (0..q)
                    .map(|j| {
                        let dest_g = (g + j) % q;
                        (0..n_nodes).map(|k| row[topo.rank_of(k, dest_g)]).sum()
                    })
                    .collect()
            })
            .collect();
        let stats = super::tuna::plan_core(builders, base, q, radix, n_nodes, &mut slots, 0);
        t_peak = stats.t_peak;
        rounds = stats.rounds;
    }
    if n_nodes > 1 {
        rounds += if coalesced {
            n_nodes - 1
        } else {
            let total_steps = (n_nodes - 1) * q;
            (total_steps + block_count - 1) / block_count
        };
    }

    // Inter-node phase per rank. `bucket_block(me, k, j)` is the size of
    // the j-th (origin-sorted) block of `me`'s bucket for node `k`.
    for me in 0..p {
        let my_node = topo.node_of(me);
        let g = topo.group_rank(me);
        let bucket_block = |k: usize, j: usize| rows[topo.rank_of(my_node, j)][topo.rank_of(k, g)];
        let bucket_sum = |k: usize| (0..q).map(|j| bucket_block(k, j)).sum::<u64>();
        let b = &mut builders[me];

        // Own node's bucket is final: a local copy.
        b.mark();
        b.copy(bucket_sum(my_node));
        b.lap(Phase::Replace);
        if n_nodes == 1 {
            continue;
        }

        if coalesced {
            b.mark();
            let staged: u64 = (0..n_nodes).filter(|&k| k != my_node).map(|k| bucket_sum(k)).sum();
            b.copy(staged);
            b.lap(Phase::Rearrange);

            let mut round = 0usize;
            while round < n_nodes - 1 {
                let batch = block_count.min(n_nodes - 1 - round);
                for i in 0..batch {
                    let off = round + i + 1;
                    let ndst = (my_node + n_nodes - off) % n_nodes;
                    let nsrc = (my_node + off) % n_nodes;
                    let tag = INTER_TAG + off as u32;
                    b.recv(topo.rank_of(nsrc, g), tag);
                    b.send(topo.rank_of(ndst, g), tag, bucket_sum(ndst));
                }
                b.wait();
                round += batch;
            }
            b.lap(Phase::InterNode);
        } else {
            b.mark();
            let total_steps = (n_nodes - 1) * q;
            let mut step = 0usize;
            while step < total_steps {
                let batch = block_count.min(total_steps - step);
                for i in 0..batch {
                    let idx = step + i;
                    let off = idx / q + 1;
                    let j = idx % q;
                    let ndst = (my_node + n_nodes - off) % n_nodes;
                    let nsrc = (my_node + off) % n_nodes;
                    let tag = INTER_TAG + idx as u32;
                    b.recv(topo.rank_of(nsrc, g), tag);
                    b.send(topo.rank_of(ndst, g), tag, bucket_block(ndst, j));
                }
                b.wait();
                step += batch;
            }
            b.lap(Phase::InterNode);
        }
    }
    (t_peak, rounds)
}

#[cfg(test)]
mod tests {
    use crate::algos::AlgoKind;
    use crate::comm::{Engine, Topology};
    use crate::model::MachineProfile;
    use crate::util::prop::forall;
    use crate::workload::{BlockSizes, Dist};

    fn run_case(
        p: usize,
        q: usize,
        r: usize,
        bc: usize,
        coalesced: bool,
        dist: Dist,
        seed: u64,
    ) -> crate::algos::RunReport {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        let kind = if coalesced {
            AlgoKind::TunaHierCoalesced { radix: r, block_count: bc }
        } else {
            AlgoKind::TunaHierStaggered { radix: r, block_count: bc }
        };
        crate::algos::run_alltoallv(&e, &kind, &sizes, true).expect("hier run must validate")
    }

    #[test]
    fn coalesced_basic() {
        run_case(8, 4, 2, 1, true, Dist::Uniform { max: 256 }, 1);
        run_case(12, 4, 4, 2, true, Dist::Uniform { max: 256 }, 2);
        run_case(16, 4, 2, 3, true, Dist::Uniform { max: 128 }, 3);
    }

    #[test]
    fn staggered_basic() {
        run_case(8, 4, 2, 1, false, Dist::Uniform { max: 256 }, 1);
        run_case(12, 4, 3, 5, false, Dist::Uniform { max: 256 }, 2);
        run_case(16, 4, 4, 64, false, Dist::Uniform { max: 128 }, 3);
    }

    #[test]
    fn single_node_degenerates_to_intra_only() {
        let rep = run_case(6, 6, 2, 1, true, Dist::Uniform { max: 64 }, 4);
        assert!(rep.validated);
    }

    #[test]
    fn two_ranks_per_node() {
        run_case(8, 2, 2, 1, true, Dist::Uniform { max: 64 }, 5);
        run_case(8, 2, 2, 2, false, Dist::Uniform { max: 64 }, 5);
    }

    #[test]
    fn nonuniform_distributions_validate() {
        for dist in [
            Dist::normal_default(),
            Dist::powerlaw_default(),
            Dist::FftN1,
            Dist::FftN2,
        ] {
            run_case(16, 4, 3, 2, true, dist, 7);
            run_case(16, 4, 3, 7, false, dist, 7);
        }
    }

    #[test]
    fn property_random_configs_validate() {
        forall("hier validates", 20, |rng| {
            let q = 2 + rng.next_below(5) as usize; // 2..=6
            let n = 2 + rng.next_below(4) as usize; // 2..=5 nodes
            let p = q * n;
            let r = 2 + rng.next_below(q as u64 - 1) as usize;
            let coalesced = rng.next_below(2) == 0;
            let max_bc = if coalesced { n - 1 } else { (n - 1) * q };
            let bc = 1 + rng.next_below(max_bc as u64) as usize;
            let rep = run_case(p, q, r, bc, coalesced, Dist::Uniform { max: 128 }, rng.next_u64());
            if rep.validated {
                Ok(())
            } else {
                Err(format!("P={p} Q={q} r={r} bc={bc} coalesced={coalesced}"))
            }
        });
    }

    #[test]
    fn coalesced_fewer_inter_messages_than_staggered() {
        let p = 16;
        let q = 4;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 512 }, 0);
        let co = crate::algos::run_alltoallv(
            &e,
            &AlgoKind::TunaHierCoalesced { radix: 2, block_count: 1 },
            &sizes,
            false,
        )
        .unwrap();
        let st = crate::algos::run_alltoallv(
            &e,
            &AlgoKind::TunaHierStaggered { radix: 2, block_count: 1 },
            &sizes,
            false,
        )
        .unwrap();
        // Staggered sends Q times as many inter-node data messages: the
        // difference over coalesced is exactly P * (N-1) * (Q-1) extra
        // (both also share the prepare-phase allreduce traffic).
        let n_nodes = p / q;
        let extra = (p * (n_nodes - 1) * (q - 1)) as u64;
        assert_eq!(
            st.counters.msgs_global - co.counters.msgs_global,
            extra,
            "staggered {} vs coalesced {} global msgs",
            st.counters.msgs_global,
            co.counters.msgs_global
        );
        // Both move the same payload bytes across nodes.
        assert_eq!(st.counters.bytes_global, co.counters.bytes_global);
    }

    #[test]
    fn intra_traffic_stays_local() {
        // All phase-1 traffic must be intra-node: with N=2 nodes the only
        // global messages are inter-node data + the prepare allreduce.
        let p = 8;
        let q = 4;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 100 }, 0);
        let rep = crate::algos::run_alltoallv(
            &e,
            &AlgoKind::TunaHierCoalesced { radix: 2, block_count: 1 },
            &sizes,
            false,
        )
        .unwrap();
        // Inter-node payload: each rank sends (N-1)=1 message of Q blocks
        // of 100 B = 400 B; total = 8 * 400 = 3200 data bytes. Allreduce
        // adds a few 8 B scalars across nodes.
        let data_global = 8 * 400;
        assert!(rep.counters.bytes_global >= data_global);
        assert!(
            rep.counters.bytes_global <= data_global + 8 * 8 * 4,
            "unexpected global traffic: {}",
            rep.counters.bytes_global
        );
        assert!(rep.counters.bytes_local > 0);
    }
}
