//! Radix-r encoding math underpinning TuNA (§III-A, §III-C).
//!
//! Block *offsets* `o = (dest − rank) mod P` are encoded in base `r` with
//! `w = ⌈log_r P⌉` digits. Communication round `(x, z)` (digit position
//! `x`, digit value `z`) moves every held block whose `x`-th digit equals
//! `z` forward by `z·r^x` ranks, clearing that digit. Offsets with exactly
//! one non-zero digit are *direct*: delivered in a single send, never
//! stored in the temporary buffer `T` — which is what yields the tight
//! bound `B = P − (K + 1)` and the slot map `t = o − 1 − dx·(r−1) − dz`.

/// `⌈log_r(p)⌉`: number of base-`r` digits needed for offsets `0..p`.
pub fn ceil_log(r: usize, p: usize) -> usize {
    assert!(r >= 2, "radix must be >= 2");
    assert!(p >= 1);
    if p == 1 {
        return 1;
    }
    let mut w = 0usize;
    let mut pow = 1u128;
    while pow < p as u128 {
        pow *= r as u128;
        w += 1;
    }
    w
}

/// Digit `x` of `o` in base `r`.
#[inline]
pub fn digit(o: usize, x: usize, r: usize) -> usize {
    (o / r.pow(x as u32)) % r
}

/// One communication round of the parameterized algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Round {
    /// Digit position, `0 <= x < w`.
    pub x: usize,
    /// Digit value, `1 <= z < r`.
    pub z: usize,
    /// Rank distance moved: `z * r^x`.
    pub step: usize,
}

/// The round schedule for radix `r` over `p` ranks: all `(x, z)` with
/// `z·r^x < p` in ascending `(x, z)` order. Its length is the paper's `K`,
/// bounded by `w·(r−1)`.
pub fn rounds(r: usize, p: usize) -> Vec<Round> {
    assert!(r >= 2);
    assert!(p >= 1);
    let w = ceil_log(r, p);
    let mut out = Vec::new();
    for x in 0..w {
        let pow = r.checked_pow(x as u32).expect("radix overflow");
        for z in 1..r {
            let step = z.checked_mul(pow).expect("radix overflow");
            if step >= p {
                break;
            }
            out.push(Round { x, z, step });
        }
    }
    out
}

/// The paper's `K`: number of communication rounds.
pub fn k_rounds(r: usize, p: usize) -> usize {
    rounds(r, p).len()
}

/// Tight temporary-buffer bound `B = P − (K + 1)` (§III-C): `K` direct
/// offsets plus the self block never occupy `T`.
pub fn temp_bound(r: usize, p: usize) -> usize {
    p - (k_rounds(r, p) + 1)
}

/// Is offset `o` *direct* (exactly one non-zero base-`r` digit)? Direct
/// blocks go straight to their destination and skip `T`. `o = 0` is the
/// self block (also never in `T`, counted separately).
pub fn is_direct(o: usize, r: usize) -> bool {
    if o == 0 {
        return false;
    }
    let mut v = o;
    let mut nonzero = 0;
    while v > 0 {
        if v % r != 0 {
            nonzero += 1;
            if nonzero > 1 {
                return false;
            }
        }
        v /= r;
    }
    nonzero == 1
}

/// Highest non-zero digit position of `o >= 1` in base `r` (the paper's
/// `dx`), and its value (`dz`).
pub fn top_digit(o: usize, r: usize) -> (usize, usize) {
    assert!(o >= 1);
    let mut dx = 0;
    let mut v = o;
    while v >= r {
        v /= r;
        dx += 1;
    }
    (dx, v)
}

/// The paper's T-slot index map (§III-C): `t = o − 1 − dx·(r−1) − dz`,
/// defined for non-direct, non-zero offsets. Subtracts from `o` the number
/// of direct offsets (and the self offset) smaller than `o`.
pub fn temp_slot(o: usize, r: usize) -> usize {
    debug_assert!(o >= 1 && !is_direct(o, r), "temp_slot only for T-resident offsets");
    let (dx, dz) = top_digit(o, r);
    o - 1 - dx * (r - 1) - dz
}

/// Exact number of offsets in `[0, p)` whose `x`-th base-`r` digit equals
/// `z` — the per-round send-block (slot) count, and the building block of
/// the analytic model's `D`.
pub fn offsets_with_digit(x: usize, z: usize, r: usize, p: usize) -> usize {
    let m = r.pow(x as u32);
    let period = m * r;
    let full = p / period;
    let rem = p % period;
    full * m + rem.saturating_sub(z * m).min(m)
}

/// Total data-block (slot) transmissions across all rounds — the paper's
/// `D`, bounded by `w·(r−1)·r^{w−1}`.
pub fn d_total(r: usize, p: usize) -> usize {
    rounds(r, p)
        .iter()
        .map(|rd| offsets_with_digit(rd.x, rd.z, r, p))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_proc_count};

    #[test]
    fn ceil_log_basics() {
        assert_eq!(ceil_log(2, 1), 1);
        assert_eq!(ceil_log(2, 2), 1);
        assert_eq!(ceil_log(2, 4), 2);
        assert_eq!(ceil_log(2, 5), 3);
        assert_eq!(ceil_log(3, 9), 2);
        assert_eq!(ceil_log(3, 10), 3);
        assert_eq!(ceil_log(16, 256), 2);
        assert_eq!(ceil_log(256, 256), 1);
    }

    #[test]
    fn classic_bruck_round_count() {
        // r = 2, P = 2^m: K = log2 P, steps are powers of two.
        let rs = rounds(2, 16);
        assert_eq!(rs.len(), 4);
        assert_eq!(
            rs.iter().map(|r| r.step).collect::<Vec<_>>(),
            vec![1, 2, 4, 8]
        );
    }

    #[test]
    fn spread_out_limit() {
        // r >= P: one digit, K = P − 1, no temporary buffer.
        let p = 12;
        assert_eq!(k_rounds(p, p), p - 1);
        assert_eq!(temp_bound(p, p), 0);
    }

    #[test]
    fn k_bounded_by_w_r_minus_1() {
        forall("K <= w(r-1)", 200, |rng| {
            let p = gen_proc_count(rng, 600);
            let r = 2 + rng.next_below(p as u64) as usize;
            let w = ceil_log(r, p);
            let k = k_rounds(r, p);
            if k <= w * (r - 1) {
                Ok(())
            } else {
                Err(format!("P={p} r={r}: K={k} > w(r-1)={}", w * (r - 1)))
            }
        });
    }

    #[test]
    fn every_offset_clears_via_round_schedule() {
        // Simulating the digit-clearing: every offset must reach zero by
        // applying the schedule's steps whenever the digit matches.
        forall("offsets clear", 120, |rng| {
            let p = gen_proc_count(rng, 400);
            let r = 2 + rng.next_below(p as u64) as usize;
            let schedule = rounds(r, p);
            for o0 in 0..p {
                let mut o = o0;
                for rd in &schedule {
                    if digit(o, rd.x, r) == rd.z {
                        o -= rd.step;
                    }
                }
                if o != 0 {
                    return Err(format!("P={p} r={r}: offset {o0} left at {o}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn direct_offsets_are_exactly_the_round_steps() {
        forall("direct==steps", 120, |rng| {
            let p = gen_proc_count(rng, 400);
            let r = 2 + rng.next_below(p as u64) as usize;
            let steps: std::collections::HashSet<usize> =
                rounds(r, p).iter().map(|rd| rd.step).collect();
            for o in 1..p {
                if is_direct(o, r) != steps.contains(&o) {
                    return Err(format!("P={p} r={r} o={o}: direct mismatch"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn temp_slot_is_bijection_onto_temp_bound() {
        // §III-C's claim: the map t(o) sends the non-direct offsets
        // bijectively onto [0, B).
        forall("t-map bijection", 150, |rng| {
            let p = gen_proc_count(rng, 500);
            let r = 2 + rng.next_below(p as u64) as usize;
            let b = temp_bound(r, p);
            let mut seen = vec![false; b];
            for o in 1..p {
                if is_direct(o, r) {
                    continue;
                }
                let t = temp_slot(o, r);
                if t >= b {
                    return Err(format!("P={p} r={r} o={o}: t={t} >= B={b}"));
                }
                if seen[t] {
                    return Err(format!("P={p} r={r} o={o}: slot {t} reused"));
                }
                seen[t] = true;
            }
            if seen.iter().all(|&s| s) {
                Ok(())
            } else {
                Err(format!("P={p} r={r}: map not onto, B={b}"))
            }
        });
    }

    #[test]
    fn offsets_with_digit_matches_bruteforce() {
        forall("digit count", 150, |rng| {
            let p = gen_proc_count(rng, 500);
            let r = 2 + rng.next_below(16.min(p as u64)) as usize;
            let w = ceil_log(r, p);
            for x in 0..w {
                for z in 1..r {
                    let brute = (0..p).filter(|&o| digit(o, x, r) == z).count();
                    let fast = offsets_with_digit(x, z, r, p);
                    if brute != fast {
                        return Err(format!("P={p} r={r} x={x} z={z}: {fast} != {brute}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn d_total_bounded_and_monotone_tradeoff() {
        // §III-A: K and D are inversely correlated in r — raising the
        // radix adds rounds (K grows: latency cost) but removes duplicate
        // forwarding (D shrinks: bandwidth saving). r = 2 minimizes K;
        // r = P minimizes D.
        let p = 256;
        let mut last_k = 0usize;
        let mut last_d = usize::MAX;
        for r in [2usize, 4, 16, 64, 256] {
            let w = ceil_log(r, p);
            let k = k_rounds(r, p);
            let d = d_total(r, p);
            assert!(d <= w * (r - 1) * r.pow(w as u32 - 1), "D bound violated r={r}");
            assert!(k >= last_k, "K must not shrink as r grows (r={r})");
            assert!(d <= last_d, "D must not grow as r grows (r={r})");
            last_k = k;
            last_d = d;
        }
        // Extremes: r=2 sends the most duplicate data; r=P sends exactly
        // the P-1 non-self blocks.
        assert_eq!(d_total(p, p), p - 1);
        assert!(d_total(2, p) > d_total(p, p));
    }

    #[test]
    fn top_digit_examples() {
        assert_eq!(top_digit(5, 2), (2, 1)); // 101b
        assert_eq!(top_digit(7, 3), (1, 2)); // 21 base 3
        assert_eq!(top_digit(1, 7), (0, 1));
    }
}
