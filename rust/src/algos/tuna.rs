//! **TuNA** — tunable-radix non-uniform all-to-all (Algorithm 1).
//!
//! Slot-indexed store-and-forward, generalizing Bruck to radix `r`:
//! rank `p`'s slot `j` initially holds the block destined to `(p + j) mod
//! P`. In round `(x, z)` every slot whose offset's `x`-th base-`r` digit
//! equals `z` is sent to rank `p + z·r^x` — a two-phase exchange (sizes
//! first, then payloads) so non-uniform blocks can be received — and the
//! incoming slot replaces the outgoing one. The invariant (provable by
//! induction over digits, see `radix::tests::every_offset_clears_via_round
//! _schedule`): after digit `x` is processed, slot `j` at rank `p` holds
//! content destined to `p + clear_digits_le_x(j)`; after the last round,
//! every slot holds a final block and `R[j]` is the block from rank
//! `(p − j) mod P` — in ascending order, no inverse rotation (§III-B).
//!
//! Slots with a single non-zero digit (*direct*) receive content exactly
//! once — already final — so only the `B = P − (K+1)` non-direct slots
//! ever store intermediate data: the paper's tight temporary-buffer bound
//! (§III-C), asserted at runtime here and property-tested in `radix`.
//!
//! Host-side, every slot movement is zero-copy: packing a round's moving
//! slots into the send batch, the exchange itself, and the incoming slot
//! replacement all move rope views (`comm::buffer`), so a block crossing
//! K rounds is written once at its origin and read once at its sink. The
//! `ctx.copy` charges below model what a real MPI implementation's
//! pack/unpack would cost on the simulated machine — they advance virtual
//! time, not host bytes (`Counters::bytes_copied` vs `copied_bytes`).

use super::radix::{self, Round};
use super::AlgoStats;
use crate::comm::{Block, Payload, Phase, PlanBuilder, RankCtx};
use crate::workload::BlockSizes;

/// A slot's content: one or more blocks that travel as a unit. Flat TuNA
/// has one block per slot; hierarchical intra-node TuNA aggregates the N
/// per-node sub-blocks of a group offset into one slot.
pub type SlotContent = Vec<Block>;

/// Outcome of the slot engine: final slot contents plus stats.
pub(crate) struct CoreOutcome {
    pub slots: Vec<SlotContent>,
    pub stats: AlgoStats,
}

/// Run the TuNA slot engine over the strided rank group
/// `{base + i * stride : i in 0..q}`. `slots[j]` is this rank's initial
/// content for group offset `j` (`slots[0]` is the self slot and never
/// moves); every *moving* slot must hold exactly `arity` sub-blocks (1
/// for flat TuNA, N for the intra-node phase of TuNA_l^g, Q for the
/// inter-node Bruck phase, whose groups are the stride-Q "same group
/// rank" port sets). `tag_base` reserves `2 * K` tags. Phase time is
/// attributed to Metadata / Data / Replace, or — when `lap` is set —
/// entirely to that one phase (the inter-node Bruck exchange charges
/// [`Phase::InterNode`] so compositions stay comparable per phase); the
/// caller owns Prepare.
pub(crate) fn tuna_core(
    ctx: &mut RankCtx,
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    arity: usize,
    mut slots: Vec<SlotContent>,
    tag_base: u32,
    lap: Option<Phase>,
) -> CoreOutcome {
    assert_eq!(slots.len(), q, "need one slot per group offset");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let me = ctx.rank();
    debug_assert!(
        me >= base && (me - base) % stride == 0 && (me - base) / stride < q,
        "rank outside group"
    );
    let my_g = (me - base) / stride;

    let schedule: Vec<Round> = radix::rounds(radix_r, q);
    let k = schedule.len();
    let b_bound = radix::temp_bound(radix_r, q);

    // Temporary-buffer occupancy tracking: a slot is "in T" while it holds
    // foreign, non-final content.
    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;

    for (round_idx, rd) in schedule.iter().enumerate() {
        let dst = base + ((my_g + rd.step) % q) * stride;
        let src = base + ((my_g + q - rd.step) % q) * stride;
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;

        // Slot offsets moving this round, ascending (same set on all ranks).
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();
        debug_assert!(!moving.is_empty());
        debug_assert!(moving.len() <= radix::offsets_with_digit(rd.x, rd.z, radix_r, q));

        // ---- phase 1: metadata (per-sub-block sizes) --------------------
        ctx.phase_mark();
        let out_meta: Vec<u64> = moving
            .iter()
            .flat_map(|&j| slots[j].iter().map(|b| b.len()))
            .collect();
        let ms = ctx.isend(dst, meta_tag, Payload::Meta(out_meta));
        let mr = ctx.irecv(src, meta_tag);
        let in_meta = ctx.waitall(&[ms], &[mr]).pop().unwrap().into_meta();
        ctx.phase_lap(ph_meta);

        // ---- phase 2: data ----------------------------------------------
        // Pack moving slots into the send buffer (charged as Replace, the
        // paper's inter-buffer copying cost), then exchange.
        let mut out_blocks: Vec<Block> = Vec::new();
        let mut sent_foreign_bytes = 0u64;
        for &j in &moving {
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
            let content = std::mem::take(&mut slots[j]);
            sent_foreign_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            out_blocks.extend(content);
        }
        ctx.copy(sent_foreign_bytes); // pack into send buffer
        ctx.phase_lap(ph_replace);

        let ds = ctx.isend(dst, data_tag, Payload::Blocks(out_blocks));
        let dr = ctx.irecv(src, data_tag);
        let in_blocks = ctx.waitall(&[ds], &[dr]).pop().unwrap().into_blocks();
        debug_assert_eq!(in_blocks.len(), in_meta.len());
        debug_assert!(in_blocks
            .iter()
            .zip(in_meta.iter())
            .all(|(b, &m)| b.len() == m));
        ctx.phase_lap(ph_data);

        // Unpack: contents land in the same slot indices they left at the
        // sender. A slot is final once its top digit's round has passed.
        let mut recv_bytes = 0u64;
        let mut iter = in_blocks.into_iter();
        for &j in &moving {
            // Sub-block count per slot (`arity`) is conserved along the
            // whole path (contents are replaced wholesale), so the
            // receiver splits the incoming batch positionally.
            let _ = j;
            let mut content: SlotContent = Vec::with_capacity(arity);
            for _ in 0..arity {
                content.push(iter.next().expect("metadata/data mismatch"));
            }
            recv_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            let is_final = top_x == rd.x && top_z == rd.z;
            if !is_final {
                debug_assert!(
                    !radix::is_direct(j, radix_r),
                    "direct slot {j} received intermediate content"
                );
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
                assert!(
                    t_now <= b_bound,
                    "T occupancy {t_now} exceeded bound B={b_bound} (q={q}, r={radix_r})"
                );
            }
            slots[j] = content;
        }
        debug_assert!(iter.next().is_none());
        ctx.copy(recv_bytes); // store into T / R
        ctx.phase_lap(ph_replace);
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");

    CoreOutcome {
        slots,
        stats: AlgoStats {
            t_peak,
            rounds: k,
        },
    }
}

/// Sparse-mode slot engine: the same schedule as [`tuna_core`] (slots
/// move on the identical structural round plan), but slots hold a
/// *variable* number of blocks — structurally absent traffic simply is
/// not there. Three deltas from the dense core, mirrored exactly by the
/// sparse plan compilers ([`plan_core_sparse`] and the streaming flat
/// compiler):
///
/// 1. **Self-describing metadata.** Each moving slot contributes
///    `[count, size...]` to the metadata message (dense mode sends a
///    fixed `arity` sizes per slot), so the receiver can split the
///    incoming block batch without a fixed arity.
/// 2. **No phantom data messages.** The data message is sent only when
///    the outgoing batch is non-empty, and the matching receive is
///    posted only when the (metadata-announced) incoming count is > 0.
///    Metadata always flows: it is the control plane.
/// 3. **Structural T tracking.** A slot occupies T on any non-final
///    arrival, content or not — so `t_peak` stays a pure function of
///    `(r, q)`, identical on every rank and in the compiled plan.
pub(crate) fn tuna_core_sparse(
    ctx: &mut RankCtx,
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    mut slots: Vec<SlotContent>,
    tag_base: u32,
    lap: Option<Phase>,
) -> CoreOutcome {
    assert_eq!(slots.len(), q, "need one slot per group offset");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let me = ctx.rank();
    debug_assert!(
        me >= base && (me - base) % stride == 0 && (me - base) / stride < q,
        "rank outside group"
    );
    let my_g = (me - base) / stride;

    let schedule: Vec<Round> = radix::rounds(radix_r, q);
    let k = schedule.len();
    let b_bound = radix::temp_bound(radix_r, q);

    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;

    for (round_idx, rd) in schedule.iter().enumerate() {
        let dst = base + ((my_g + rd.step) % q) * stride;
        let src = base + ((my_g + q - rd.step) % q) * stride;
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();

        // ---- phase 1: metadata ([count, sizes...] per moving slot) -----
        ctx.phase_mark();
        let mut out_meta: Vec<u64> = Vec::with_capacity(moving.len());
        for &j in &moving {
            out_meta.push(slots[j].len() as u64);
            out_meta.extend(slots[j].iter().map(|b| b.len()));
        }
        let ms = ctx.isend(dst, meta_tag, Payload::Meta(out_meta));
        let mr = ctx.irecv(src, meta_tag);
        let in_meta = ctx.waitall(&[ms], &[mr]).pop().unwrap().into_meta();
        ctx.phase_lap(ph_meta);

        // ---- phase 2: data, skipped entirely when a side is empty ------
        let mut out_blocks: Vec<Block> = Vec::new();
        let mut sent_bytes = 0u64;
        for &j in &moving {
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
            let content = std::mem::take(&mut slots[j]);
            sent_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            out_blocks.extend(content);
        }
        ctx.copy(sent_bytes);
        ctx.phase_lap(ph_replace);

        // Incoming block count, announced by the metadata message.
        let mut in_total = 0usize;
        {
            let mut c = 0usize;
            for _ in &moving {
                let cnt = in_meta[c] as usize;
                in_total += cnt;
                c += 1 + cnt;
            }
            debug_assert_eq!(c, in_meta.len(), "malformed sparse metadata");
        }
        let mut sends = Vec::with_capacity(1);
        let mut recvs = Vec::with_capacity(1);
        if !out_blocks.is_empty() {
            sends.push(ctx.isend(dst, data_tag, Payload::Blocks(out_blocks)));
        }
        if in_total > 0 {
            recvs.push(ctx.irecv(src, data_tag));
        }
        let in_blocks: Vec<Block> = ctx
            .waitall(&sends, &recvs)
            .pop()
            .map(Payload::into_blocks)
            .unwrap_or_default();
        debug_assert_eq!(in_blocks.len(), in_total);
        ctx.phase_lap(ph_data);

        // Unpack by the metadata counts; T occupancy is structural.
        let mut recv_bytes = 0u64;
        let mut blocks_iter = in_blocks.into_iter();
        let mut c = 0usize;
        for &j in &moving {
            let cnt = in_meta[c] as usize;
            c += 1 + cnt;
            let mut content: SlotContent = Vec::with_capacity(cnt);
            for _ in 0..cnt {
                content.push(blocks_iter.next().expect("metadata/data mismatch"));
            }
            recv_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            let is_final = top_x == rd.x && top_z == rd.z;
            if !is_final {
                debug_assert!(
                    !radix::is_direct(j, radix_r),
                    "direct slot {j} received intermediate content"
                );
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
                assert!(
                    t_now <= b_bound,
                    "T occupancy {t_now} exceeded bound B={b_bound} (q={q}, r={radix_r})"
                );
            }
            slots[j] = content;
        }
        debug_assert!(blocks_iter.next().is_none());
        ctx.copy(recv_bytes);
        ctx.phase_lap(ph_replace);
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");

    CoreOutcome {
        slots,
        stats: AlgoStats { t_peak, rounds: k },
    }
}

/// Flat TuNA over the whole communicator (Algorithm 1).
pub fn run(ctx: &mut RankCtx, blocks: Vec<Block>, radix_r: usize) -> (Vec<Block>, AlgoStats) {
    let p = ctx.size();
    let me = ctx.rank();
    assert_eq!(blocks.len(), p);
    let radix_r = radix_r.min(p).max(2);

    // ---- prepare: allreduce for M, index array setup (Alg. 1 lines 1-5).
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64); // rotation/index array write
    ctx.phase_lap(Phase::Prepare);

    // slots[j] = my block destined (me + j) mod P.
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        let d = b.dest as usize;
        by_dest[d] = Some(b);
    }
    let slots: Vec<SlotContent> = (0..p)
        .map(|j| {
            let d = (me + j) % p;
            vec![by_dest[d].take().expect("one block per destination")]
        })
        .collect();

    let out = tuna_core(ctx, 0, 1, p, radix_r, 1, slots, 0, None);

    // Self block delivery is a local copy.
    ctx.phase_mark();
    ctx.copy(out.slots[0].iter().map(|b| b.len()).sum());
    ctx.phase_lap(Phase::Replace);

    let mut recv: Vec<Block> = Vec::with_capacity(p);
    for (j, content) in out.slots.into_iter().enumerate() {
        for b in content {
            debug_assert_eq!(
                b.origin as usize,
                (me + p - j) % p,
                "slot {j} final origin mismatch"
            );
            recv.push(b);
        }
    }
    (recv, out.stats)
}

/// Flat TuNA over a structurally sparse workload: the same schedule as
/// [`run`], with the slot engine in sparse mode — absent `(src, dst)`
/// pairs occupy no slot, ship no data message, and leave no rope
/// segment. `blocks` holds only the rank's structural blocks.
pub fn run_sparse(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    radix_r: usize,
) -> (Vec<Block>, AlgoStats) {
    let p = ctx.size();
    let me = ctx.rank();
    let radix_r = radix_r.min(p).max(2);

    // ---- prepare: identical to the dense preamble (the allreduce
    // schedule is value-independent).
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64);
    ctx.phase_lap(Phase::Prepare);

    // slots[j] = my block destined (me + j) mod P, when structural.
    let mut slots: Vec<SlotContent> = (0..p).map(|_| Vec::new()).collect();
    for b in blocks {
        let j = (b.dest as usize + p - me) % p;
        debug_assert!(slots[j].is_empty(), "one block per destination");
        slots[j].push(b);
    }

    let out = tuna_core_sparse(ctx, 0, 1, p, radix_r, slots, 0, None);

    // Self block delivery is a local copy (0 bytes when absent).
    ctx.phase_mark();
    ctx.copy(out.slots[0].iter().map(|b| b.len()).sum());
    ctx.phase_lap(Phase::Replace);

    let mut recv: Vec<Block> = Vec::new();
    for (j, content) in out.slots.into_iter().enumerate() {
        for b in content {
            debug_assert_eq!(
                b.origin as usize,
                (me + p - j) % p,
                "slot {j} final origin mismatch"
            );
            recv.push(b);
        }
    }
    (recv, out.stats)
}

// ---- plan compilers -------------------------------------------------------

/// Stats of a compiled slot-engine schedule (identical on every rank of
/// the group, so computed once).
pub(crate) struct CorePlanStats {
    pub t_peak: usize,
    pub rounds: usize,
}

/// Compile [`tuna_core`] for every rank of the strided group
/// `{base + i * stride : i in 0..q}` — a joint size-only simulation:
/// `slots[g][j]` holds the *total* bytes of group-rank `g`'s slot `j`
/// (its `arity` sub-blocks travel wholesale, so per-sub-block sizes are
/// never needed here) and is rotated through the group exactly as the
/// slot exchange moves contents. Ops are emitted per rank in the same
/// order `tuna_core` charges them, including the same `lap` phase
/// mapping.
///
/// `group[g]` is the builder of absolute rank `base + g * stride`; the
/// caller hands in just the group's builders (a contiguous slice), which
/// is what lets the hierarchical compiler run disjoint groups on worker
/// threads. `base`/`stride` are still needed to name absolute peer
/// ranks in the emitted sends/recvs.
pub(crate) fn plan_core(
    group: &mut [PlanBuilder],
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    arity: usize,
    slots: &mut [Vec<u64>],
    tag_base: u32,
    lap: Option<Phase>,
) -> CorePlanStats {
    assert_eq!(group.len(), q, "need one builder per group rank");
    assert_eq!(slots.len(), q, "need one slot row per group rank");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let schedule: Vec<Round> = radix::rounds(radix_r, q);

    // T occupancy evolves identically on every rank of the group.
    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;

    for (round_idx, rd) in schedule.iter().enumerate() {
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();
        let meta_bytes = 8 * (moving.len() * arity) as u64;
        // Outgoing payload bytes per group rank this round.
        let out_bytes: Vec<u64> = (0..q)
            .map(|g| moving.iter().map(|&j| slots[g][j]).sum())
            .collect();

        for g in 0..q {
            let b = &mut group[g];
            let dst = base + ((g + rd.step) % q) * stride;
            let src_g = (g + q - rd.step) % q;
            let src = base + src_g * stride;
            b.mark();
            b.send(dst, meta_tag, meta_bytes);
            b.recv(src, meta_tag);
            b.wait();
            b.lap(ph_meta);
            b.copy(out_bytes[g]); // pack into send buffer
            b.lap(ph_replace);
            b.send(dst, data_tag, out_bytes[g]);
            b.recv(src, data_tag);
            b.wait();
            b.lap(ph_data);
            b.copy(out_bytes[src_g]); // store incoming into T / R
            b.lap(ph_replace);
        }

        // Rotate the moving slot contents one step through the group and
        // track T exactly as the runtime does: packs release, then
        // non-final arrivals occupy.
        for &j in &moving {
            let col: Vec<u64> = (0..q).map(|g| slots[(g + q - rd.step) % q][j]).collect();
            for g in 0..q {
                slots[g][j] = col[g];
            }
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
        }
        for &j in &moving {
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            let is_final = top_x == rd.x && top_z == rd.z;
            if !is_final {
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
            }
        }
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");

    CorePlanStats {
        t_peak,
        rounds: schedule.len(),
    }
}

/// Sparse-mode joint compilation of [`tuna_core_sparse`] for a strided
/// group: `slots[g][j]` is `(bytes, structural block count)` of group
/// rank `g`'s slot `j`. Mirrors the sparse slot engine op-for-op:
/// self-describing metadata (`8·(moving + count)` wire bytes), data
/// messages only between non-empty endpoints, structural T tracking.
/// Like [`plan_core`], `group[g]` is absolute rank `base + g * stride`.
pub(crate) fn plan_core_sparse(
    group: &mut [PlanBuilder],
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    slots: &mut [Vec<(u64, u32)>],
    tag_base: u32,
    lap: Option<Phase>,
) -> CorePlanStats {
    assert_eq!(group.len(), q, "need one builder per group rank");
    assert_eq!(slots.len(), q, "need one slot row per group rank");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let schedule: Vec<Round> = radix::rounds(radix_r, q);

    for (round_idx, rd) in schedule.iter().enumerate() {
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();
        let out: Vec<(u64, u32)> = (0..q)
            .map(|g| {
                let mut bytes = 0u64;
                let mut cnt = 0u32;
                for &j in &moving {
                    bytes += slots[g][j].0;
                    cnt += slots[g][j].1;
                }
                (bytes, cnt)
            })
            .collect();

        for g in 0..q {
            let b = &mut group[g];
            let dst = base + ((g + rd.step) % q) * stride;
            let src_g = (g + q - rd.step) % q;
            let src = base + src_g * stride;
            b.mark();
            b.send(dst, meta_tag, 8 * (moving.len() as u64 + out[g].1 as u64));
            b.recv(src, meta_tag);
            b.wait();
            b.lap(ph_meta);
            b.copy(out[g].0);
            b.lap(ph_replace);
            if out[g].1 > 0 {
                b.send(dst, data_tag, out[g].0);
            }
            if out[src_g].1 > 0 {
                b.recv(src, data_tag);
            }
            b.wait();
            b.lap(ph_data);
            b.copy(out[src_g].0);
            b.lap(ph_replace);
        }

        // Rotate the moving slot contents one step through the group.
        for &j in &moving {
            let col: Vec<(u64, u32)> =
                (0..q).map(|g| slots[(g + q - rd.step) % q][j]).collect();
            for g in 0..q {
                slots[g][j] = col[g];
            }
        }
    }

    core_schedule_stats(radix_r, q)
}

/// Structural schedule stats of the slot engine: T occupancy evolves
/// identically on every rank (a slot occupies T on any non-final
/// arrival, content or not), so `t_peak` and the round count are pure
/// functions of `(r, q)`.
pub(crate) fn core_schedule_stats(radix_r: usize, q: usize) -> CorePlanStats {
    let schedule = radix::rounds(radix_r, q);
    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;
    for rd in &schedule {
        for j in (1..q).filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z) {
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
        }
        for j in (1..q).filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z) {
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            if !(top_x == rd.x && top_z == rd.z) {
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
            }
        }
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");
    CorePlanStats {
        t_peak,
        rounds: schedule.len(),
    }
}

/// Per-round, per-holder traffic of the flat slot exchange, accumulated
/// in **one streaming pass** over the row views — O(P·K) working memory
/// instead of the P×P slot matrix the joint simulation would need.
///
/// The key identity: slot offset `j` moves once per nonzero base-`r`
/// digit `(x, z)` of `j`, and when that round runs, the slot's content
/// (which started at its origin rank) has already advanced by the
/// cleared lower digits — `j mod r^x` ranks. So the block `(me → me+j)`
/// is packed, in round `(x, z)`, by rank `(me + j mod r^x) mod P`, and
/// one pass over every row scatters each entry into its rounds'
/// accumulators.
struct FlatSlotTraffic {
    /// `out_bytes[t][g]`: payload bytes rank `g` packs and sends in
    /// round `t`.
    out_bytes: Vec<Vec<u64>>,
    /// `out_cnt[t][g]`: structural blocks rank `g` sends in round `t`.
    out_cnt: Vec<Vec<u32>>,
    /// `moving[t]`: moving slot-offset count of round `t` (identical on
    /// every rank).
    moving: Vec<u64>,
    /// `self_bytes[g]`: rank `g`'s self block (slot 0; 0 when absent).
    self_bytes: Vec<u64>,
}

fn flat_slot_traffic(sizes: &BlockSizes, radix_r: usize) -> (Vec<Round>, FlatSlotTraffic) {
    let p = sizes.p();
    let schedule = radix::rounds(radix_r, p);
    let k = schedule.len();
    // Round index by (digit position, digit value).
    let w = radix::ceil_log(radix_r, p);
    let mut round_idx = vec![vec![usize::MAX; radix_r]; w];
    for (t, rd) in schedule.iter().enumerate() {
        round_idx[rd.x][rd.z] = t;
    }
    let mut moving = vec![0u64; k];
    for j in 1..p {
        let mut v = j;
        let mut x = 0usize;
        while v > 0 {
            let z = v % radix_r;
            if z != 0 {
                moving[round_idx[x][z]] += 1;
            }
            v /= radix_r;
            x += 1;
        }
    }
    let mut out_bytes = vec![vec![0u64; p]; k];
    let mut out_cnt = vec![vec![0u32; p]; k];
    let mut self_bytes = vec![0u64; p];
    for me in 0..p {
        let row = sizes.row_view(me);
        for (dst, val) in row.entries() {
            let j = (dst + p - me) % p;
            if j == 0 {
                self_bytes[me] = val;
                continue;
            }
            let mut v = j;
            let mut x = 0usize;
            let mut pow = 1usize; // r^x
            let mut cleared = 0usize; // j mod r^x
            while v > 0 {
                let z = v % radix_r;
                if z != 0 {
                    let t = round_idx[x][z];
                    let g = (me + cleared) % p;
                    out_bytes[t][g] += val;
                    out_cnt[t][g] += 1;
                }
                cleared += z * pow;
                pow *= radix_r;
                v /= radix_r;
                x += 1;
            }
        }
    }
    (
        schedule,
        FlatSlotTraffic {
            out_bytes,
            out_cnt,
            moving,
            self_bytes,
        },
    )
}

/// Compile flat TuNA ([`run`]) for every rank — **streaming**: one pass
/// over the row views builds the per-round traffic accumulators
/// ([`flat_slot_traffic`], O(P·K) memory), then each rank's op list is
/// emitted independently. No P×P matrix is ever materialized. Emits ops
/// bit-identically to the joint simulation it replaced (pinned by this
/// module's `streaming_plan_matches_joint_reference` test). Serial
/// reference path; `algos::compile_plan` drives the same [`FlatPlan`]
/// emitter through the parallel plan packer instead.
pub(crate) fn plan_into(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    radix_r: usize,
) -> (usize, usize) {
    let fp = flat_plan(sizes, radix_r, false);
    for (me, b) in builders.iter_mut().enumerate() {
        fp.emit_rank(b, me);
    }
    fp.stats()
}

/// Compile sparse flat TuNA ([`run_sparse`]) for every rank — the same
/// streaming emitter, with the sparse slot engine's wire format:
/// metadata carries `[count, sizes...]` per moving slot (`8·(moving +
/// count)` bytes), and data messages exist only between non-empty
/// endpoints. Serial reference path, like [`plan_into`].
pub(crate) fn plan_into_sparse(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    radix_r: usize,
) -> (usize, usize) {
    let fp = flat_plan(sizes, radix_r, true);
    for (me, b) in builders.iter_mut().enumerate() {
        fp.emit_rank(b, me);
    }
    fp.stats()
}

/// Precomputed flat-TuNA compile state: the round schedule plus the
/// per-round traffic accumulators, everything [`FlatPlan::emit_rank`]
/// needs to emit any single rank's ops independently (and hence from
/// parallel workers — the struct is immutable after construction).
pub(crate) struct FlatPlan {
    p: usize,
    radix: usize,
    sparse: bool,
    schedule: Vec<Round>,
    traffic: FlatSlotTraffic,
}

/// Build the shared compile state behind the flat-TuNA emitters: one op
/// shape, with exactly the sparse slot engine's two deltas (metadata
/// size expression, data-message guards) keyed off `sparse`.
pub(crate) fn flat_plan(sizes: &BlockSizes, radix_r: usize, sparse: bool) -> FlatPlan {
    let p = sizes.p();
    let radix = radix_r.min(p).max(2);
    let (schedule, traffic) = flat_slot_traffic(sizes, radix);
    FlatPlan {
        p,
        radix,
        sparse,
        schedule,
        traffic,
    }
}

impl FlatPlan {
    /// `(t_peak, rounds)` of the compiled schedule — structural, so
    /// independent of which ranks have been emitted.
    pub(crate) fn stats(&self) -> (usize, usize) {
        let stats = core_schedule_stats(self.radix, self.p);
        (stats.t_peak, stats.rounds)
    }

    /// Emit rank `me`'s complete flat-TuNA op list into `b`.
    pub(crate) fn emit_rank(&self, b: &mut PlanBuilder, me: usize) {
        let p = self.p;
        let sparse = self.sparse;
        let traffic = &self.traffic;
        // Prepare: allreduce for M + index array write, in one phase lap.
        b.mark();
        b.allreduce();
        b.copy(4 * p as u64);
        b.lap(Phase::Prepare);

        for (t, rd) in self.schedule.iter().enumerate() {
            let dst = (me + rd.step) % p;
            let src = (me + p - rd.step) % p;
            let meta_tag = 2 * t as u32;
            let data_tag = meta_tag + 1;
            let meta_bytes = if sparse {
                8 * (traffic.moving[t] + traffic.out_cnt[t][me] as u64)
            } else {
                8 * traffic.moving[t]
            };
            b.mark();
            b.send(dst, meta_tag, meta_bytes);
            b.recv(src, meta_tag);
            b.wait();
            b.lap(Phase::Metadata);
            b.copy(traffic.out_bytes[t][me]);
            b.lap(Phase::Replace);
            if !sparse || traffic.out_cnt[t][me] > 0 {
                b.send(dst, data_tag, traffic.out_bytes[t][me]);
            }
            if !sparse || traffic.out_cnt[t][src] > 0 {
                b.recv(src, data_tag);
            }
            b.wait();
            b.lap(Phase::Data);
            b.copy(traffic.out_bytes[t][src]);
            b.lap(Phase::Replace);
        }

        // Self-block delivery is a local copy (slot 0 never moves).
        b.mark();
        b.copy(traffic.self_bytes[me]);
        b.lap(Phase::Replace);
    }
}

#[cfg(test)]
mod tests {
    use crate::comm::{Engine, Topology};
    use crate::model::MachineProfile;
    use crate::util::prop::forall;
    use crate::workload::{BlockSizes, Dist};

    fn run_case(p: usize, q: usize, r: usize, dist: Dist, seed: u64) -> crate::algos::RunReport {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: r }, &sizes, true)
            .expect("tuna run must validate")
    }

    #[test]
    fn tuna_correct_radix2_pow2() {
        let rep = run_case(8, 2, 2, Dist::Uniform { max: 256 }, 1);
        assert_eq!(rep.rounds, 3);
        assert!(rep.t_peak <= 8 - 3 - 1);
    }

    #[test]
    fn tuna_correct_non_pow2() {
        for (p, r) in [(6, 2), (7, 3), (12, 5), (9, 3), (10, 10)] {
            let rep = run_case(p, 1, r, Dist::Uniform { max: 128 }, p as u64);
            assert!(rep.validated, "P={p} r={r}");
        }
    }

    #[test]
    fn tuna_radix_p_equals_linear_rounds() {
        // r >= P degenerates to spread-out: P-1 rounds, no T usage.
        let rep = run_case(8, 2, 8, Dist::Uniform { max: 256 }, 3);
        assert_eq!(rep.rounds, 7);
        assert_eq!(rep.t_peak, 0);
    }

    #[test]
    fn tuna_handles_zero_size_blocks() {
        let rep = run_case(8, 2, 2, Dist::PowerLaw { max: 64, skew: 6.0 }, 5);
        assert!(rep.validated);
        let rep = run_case(8, 2, 4, Dist::FftN1, 5);
        assert!(rep.validated);
    }

    #[test]
    fn t_peak_within_bound_many_configs() {
        forall("t_peak <= B", 25, |rng| {
            let p = 2 + rng.next_below(30) as usize;
            let r = (2 + rng.next_below(p as u64) as usize).min(p);
            let rep = run_case(p, 1, r, Dist::Uniform { max: 64 }, rng.next_u64());
            let b = crate::algos::radix::temp_bound(r, p);
            if rep.t_peak <= b {
                Ok(())
            } else {
                Err(format!("P={p} r={r}: t_peak {} > B {b}", rep.t_peak))
            }
        });
    }

    #[test]
    fn round_count_matches_k() {
        for (p, r) in [(16usize, 2usize), (16, 4), (27, 3), (20, 4)] {
            let rep = run_case(p, 1, r, Dist::Const { size: 64 }, 0);
            assert_eq!(rep.rounds, crate::algos::radix::k_rounds(r, p), "P={p} r={r}");
        }
    }

    #[test]
    fn radix_tradeoff_rounds_vs_bytes() {
        // §III-A trade-off: radix 2 minimizes rounds (K = log2 P) at the
        // cost of maximal duplicate forwarding; radix P executes P-1
        // rounds but ships every block exactly once.
        let p = 64;
        let e = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 1024 }, 0);
        let lo = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: 2 }, &sizes, false).unwrap();
        let hi = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: 64 }, &sizes, false).unwrap();
        assert!(lo.rounds < hi.rounds, "{} vs {}", lo.rounds, hi.rounds);
        assert!(
            lo.counters.total_bytes() > hi.counters.total_bytes(),
            "radix 2 must move more total bytes ({} vs {})",
            lo.counters.total_bytes(),
            hi.counters.total_bytes()
        );
    }

    #[test]
    fn phantom_and_real_agree_on_schedule() {
        // Same workload, phantom vs real payloads: identical virtual time
        // and identical byte counters (DESIGN.md validation #3).
        let p = 12;
        let e = Engine::new(MachineProfile::polaris(), Topology::new(p, 4));
        let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 9);
        let kind = crate::algos::AlgoKind::Tuna { radix: 3 };
        let real = crate::algos::run_alltoallv(&e, &kind, &sizes, true).unwrap();
        let phantom = crate::algos::run_alltoallv(&e, &kind, &sizes, false).unwrap();
        assert_eq!(real.makespan, phantom.makespan);
        // Virtual-time traffic is identical; only the host-side copy
        // accounting differs (real mode writes sources / reads sinks,
        // phantom mode moves no bytes at all).
        let mut rc = real.counters;
        let mut pc = phantom.counters;
        assert_eq!(rc.copied_bytes, 2 * sizes.total_bytes());
        assert_eq!(pc.copied_bytes, 0);
        rc.copied_bytes = 0;
        pc.copied_bytes = 0;
        assert_eq!(rc, pc);
    }

    #[test]
    fn direct_slots_never_store_intermediates() {
        // Exercised by the debug_assert in tuna_core across a sweep.
        forall("direct never in T", 15, |rng| {
            let p = 3 + rng.next_below(20) as usize;
            let r = 2 + rng.next_below(6) as usize;
            let rep = run_case(p, 1, r, Dist::Uniform { max: 96 }, rng.next_u64());
            if rep.validated {
                Ok(())
            } else {
                Err(format!("P={p} r={r} failed"))
            }
        });
    }

    #[test]
    fn streaming_plan_matches_joint_reference() {
        // The streaming flat compiler must emit bit-identical ops to the
        // joint P×P slot simulation it replaced (plan_core is still the
        // hier local-phase compiler, so the reference stays honest).
        use crate::comm::{Phase, PlanBuilder};
        for (p, r, dist, seed) in [
            (5usize, 2usize, Dist::Uniform { max: 128 }, 1u64),
            (8, 2, Dist::powerlaw_default(), 2),
            (12, 3, Dist::Uniform { max: 512 }, 3),
            (16, 4, Dist::normal_default(), 4),
            (27, 3, Dist::Uniform { max: 64 }, 5),
            (16, 16, Dist::Const { size: 96 }, 6),
        ] {
            let sizes = BlockSizes::generate(p, dist, seed);
            let mut stream: Vec<PlanBuilder> =
                (0..p).map(|me| PlanBuilder::new(me, p)).collect();
            let (tp_a, rd_a) = super::plan_into(&mut stream, &sizes, r);

            let rr = r.min(p).max(2);
            let mut joint: Vec<PlanBuilder> =
                (0..p).map(|me| PlanBuilder::new(me, p)).collect();
            for b in joint.iter_mut() {
                b.mark();
                b.allreduce();
                b.copy(4 * p as u64);
                b.lap(Phase::Prepare);
            }
            let mut slots: Vec<Vec<u64>> = (0..p)
                .map(|me| {
                    let row = sizes.row(me);
                    (0..p).map(|j| row[(me + j) % p]).collect()
                })
                .collect();
            let stats = super::plan_core(&mut joint, 0, 1, p, rr, 1, &mut slots, 0, None);
            for (me, b) in joint.iter_mut().enumerate() {
                b.mark();
                b.copy(slots[me][0]);
                b.lap(Phase::Replace);
            }
            assert_eq!((tp_a, rd_a), (stats.t_peak, stats.rounds), "stats P={p} r={r}");
            for (me, (a, refr)) in stream.into_iter().zip(joint).enumerate() {
                assert_eq!(a.finish(), refr.finish(), "rank {me} ops P={p} r={r}");
            }
        }
    }

    #[test]
    fn sparse_streaming_plan_matches_sparse_joint_reference() {
        use crate::comm::PlanBuilder;
        for (p, r, nnz, seed) in [
            (6usize, 2usize, 2usize, 1u64),
            (9, 3, 3, 2),
            (16, 4, 5, 3),
            (13, 2, 0, 4),
            (8, 8, 3, 5),
            (16, 2, 16, 6),
        ] {
            let sizes = BlockSizes::generate(p, Dist::Sparse { nnz, max: 256 }, seed);
            let mut stream: Vec<PlanBuilder> =
                (0..p).map(|me| PlanBuilder::new(me, p)).collect();
            let (tp_a, rd_a) = super::plan_into_sparse(&mut stream, &sizes, r);

            let rr = r.min(p).max(2);
            let mut joint: Vec<PlanBuilder> =
                (0..p).map(|me| PlanBuilder::new(me, p)).collect();
            for b in joint.iter_mut() {
                b.mark();
                b.allreduce();
                b.copy(4 * p as u64);
                b.lap(crate::comm::Phase::Prepare);
            }
            let mut slots: Vec<Vec<(u64, u32)>> = (0..p)
                .map(|me| {
                    let mut row = vec![(0u64, 0u32); p];
                    for (dst, val) in sizes.row_view(me).entries() {
                        let j = (dst + p - me) % p;
                        row[j] = (val, 1);
                    }
                    row
                })
                .collect();
            let self_bytes: Vec<u64> = (0..p).map(|me| sizes.row_view(me).get(me)).collect();
            for g in slots.iter_mut() {
                g[0] = (0, 0); // slot 0 never moves; self handled below
            }
            let stats =
                super::plan_core_sparse(&mut joint, 0, 1, p, rr, &mut slots, 0, None);
            for (me, b) in joint.iter_mut().enumerate() {
                b.mark();
                b.copy(self_bytes[me]);
                b.lap(crate::comm::Phase::Replace);
            }
            assert_eq!((tp_a, rd_a), (stats.t_peak, stats.rounds), "stats P={p} r={r}");
            for (me, (a, refr)) in stream.into_iter().zip(joint).enumerate() {
                assert_eq!(a.finish(), refr.finish(), "rank {me} ops P={p} r={r} nnz={nnz}");
            }
        }
    }

    #[test]
    fn sparse_plan_ops_scale_with_nnz_not_p2() {
        use crate::comm::PlanBuilder;
        let p = 512;
        let sizes = BlockSizes::generate(p, Dist::Sparse { nnz: 4, max: 128 }, 7);
        let mut builders: Vec<PlanBuilder> = (0..p).map(|me| PlanBuilder::new(me, p)).collect();
        super::plan_into_sparse(&mut builders, &sizes, 4);
        let total: usize = builders.into_iter().map(|b| b.finish().ops.len()).sum();
        // Per rank: prepare allreduce (O(log P)) + K rounds of a constant
        // op budget — independent of P², bounded well under dense linear.
        let k = crate::algos::radix::k_rounds(4, p);
        let per_rank_bound = 8 + 3 * 10 + 13 * k; // prepare + allreduce + rounds
        assert!(
            total <= p * per_rank_bound,
            "sparse flat plan too large: {total} ops (bound {})",
            p * per_rank_bound
        );
    }

    #[test]
    fn d_total_matches_observed_slot_sends() {
        // Counter cross-check: with Const sizes, global data bytes =
        // D(r,P) * size (each slot transmission carries exactly one block
        // of `size` bytes in flat TuNA).
        let p = 16;
        let size = 128u64;
        for r in [2usize, 4, 16] {
            let e = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
            let sizes = BlockSizes::generate(p, Dist::Const { size }, 0);
            let rep = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: r }, &sizes, false).unwrap();
            let d = crate::algos::radix::d_total(r, p) as u64;
            // Every rank sends the same slot schedule, so aggregate data
            // bytes = P * D * size, metadata = P * 8 * D; the only other
            // traffic is the prepare-phase allreduce (8 B scalars).
            let measured = rep.counters.total_bytes();
            let expect_data: u64 = p as u64 * d * size;
            let expect_meta: u64 = p as u64 * 8 * d;
            assert!(
                measured >= expect_data + expect_meta,
                "r={r}: measured {measured} < data+meta {}",
                expect_data + expect_meta
            );
            let slack = measured - expect_data - expect_meta;
            assert!(
                slack <= 64 * p as u64 * (p as f64).log2().ceil() as u64,
                "r={r}: unexpected extra traffic {slack}"
            );
        }
    }
}
