//! **TuNA** — tunable-radix non-uniform all-to-all (Algorithm 1).
//!
//! Slot-indexed store-and-forward, generalizing Bruck to radix `r`:
//! rank `p`'s slot `j` initially holds the block destined to `(p + j) mod
//! P`. In round `(x, z)` every slot whose offset's `x`-th base-`r` digit
//! equals `z` is sent to rank `p + z·r^x` — a two-phase exchange (sizes
//! first, then payloads) so non-uniform blocks can be received — and the
//! incoming slot replaces the outgoing one. The invariant (provable by
//! induction over digits, see `radix::tests::every_offset_clears_via_round
//! _schedule`): after digit `x` is processed, slot `j` at rank `p` holds
//! content destined to `p + clear_digits_le_x(j)`; after the last round,
//! every slot holds a final block and `R[j]` is the block from rank
//! `(p − j) mod P` — in ascending order, no inverse rotation (§III-B).
//!
//! Slots with a single non-zero digit (*direct*) receive content exactly
//! once — already final — so only the `B = P − (K+1)` non-direct slots
//! ever store intermediate data: the paper's tight temporary-buffer bound
//! (§III-C), asserted at runtime here and property-tested in `radix`.
//!
//! Host-side, every slot movement is zero-copy: packing a round's moving
//! slots into the send batch, the exchange itself, and the incoming slot
//! replacement all move rope views (`comm::buffer`), so a block crossing
//! K rounds is written once at its origin and read once at its sink. The
//! `ctx.copy` charges below model what a real MPI implementation's
//! pack/unpack would cost on the simulated machine — they advance virtual
//! time, not host bytes (`Counters::bytes_copied` vs `copied_bytes`).

use super::radix::{self, Round};
use super::AlgoStats;
use crate::comm::{Block, Payload, Phase, PlanBuilder, RankCtx};
use crate::workload::BlockSizes;

/// A slot's content: one or more blocks that travel as a unit. Flat TuNA
/// has one block per slot; hierarchical intra-node TuNA aggregates the N
/// per-node sub-blocks of a group offset into one slot.
pub type SlotContent = Vec<Block>;

/// Outcome of the slot engine: final slot contents plus stats.
pub(crate) struct CoreOutcome {
    pub slots: Vec<SlotContent>,
    pub stats: AlgoStats,
}

/// Run the TuNA slot engine over the strided rank group
/// `{base + i * stride : i in 0..q}`. `slots[j]` is this rank's initial
/// content for group offset `j` (`slots[0]` is the self slot and never
/// moves); every *moving* slot must hold exactly `arity` sub-blocks (1
/// for flat TuNA, N for the intra-node phase of TuNA_l^g, Q for the
/// inter-node Bruck phase, whose groups are the stride-Q "same group
/// rank" port sets). `tag_base` reserves `2 * K` tags. Phase time is
/// attributed to Metadata / Data / Replace, or — when `lap` is set —
/// entirely to that one phase (the inter-node Bruck exchange charges
/// [`Phase::InterNode`] so compositions stay comparable per phase); the
/// caller owns Prepare.
pub(crate) fn tuna_core(
    ctx: &mut RankCtx,
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    arity: usize,
    mut slots: Vec<SlotContent>,
    tag_base: u32,
    lap: Option<Phase>,
) -> CoreOutcome {
    assert_eq!(slots.len(), q, "need one slot per group offset");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let me = ctx.rank();
    debug_assert!(
        me >= base && (me - base) % stride == 0 && (me - base) / stride < q,
        "rank outside group"
    );
    let my_g = (me - base) / stride;

    let schedule: Vec<Round> = radix::rounds(radix_r, q);
    let k = schedule.len();
    let b_bound = radix::temp_bound(radix_r, q);

    // Temporary-buffer occupancy tracking: a slot is "in T" while it holds
    // foreign, non-final content.
    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;

    for (round_idx, rd) in schedule.iter().enumerate() {
        let dst = base + ((my_g + rd.step) % q) * stride;
        let src = base + ((my_g + q - rd.step) % q) * stride;
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;

        // Slot offsets moving this round, ascending (same set on all ranks).
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();
        debug_assert!(!moving.is_empty());
        debug_assert!(moving.len() <= radix::offsets_with_digit(rd.x, rd.z, radix_r, q));

        // ---- phase 1: metadata (per-sub-block sizes) --------------------
        ctx.phase_mark();
        let out_meta: Vec<u64> = moving
            .iter()
            .flat_map(|&j| slots[j].iter().map(|b| b.len()))
            .collect();
        let ms = ctx.isend(dst, meta_tag, Payload::Meta(out_meta));
        let mr = ctx.irecv(src, meta_tag);
        let in_meta = ctx.waitall(&[ms], &[mr]).pop().unwrap().into_meta();
        ctx.phase_lap(ph_meta);

        // ---- phase 2: data ----------------------------------------------
        // Pack moving slots into the send buffer (charged as Replace, the
        // paper's inter-buffer copying cost), then exchange.
        let mut out_blocks: Vec<Block> = Vec::new();
        let mut sent_foreign_bytes = 0u64;
        for &j in &moving {
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
            let content = std::mem::take(&mut slots[j]);
            sent_foreign_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            out_blocks.extend(content);
        }
        ctx.copy(sent_foreign_bytes); // pack into send buffer
        ctx.phase_lap(ph_replace);

        let ds = ctx.isend(dst, data_tag, Payload::Blocks(out_blocks));
        let dr = ctx.irecv(src, data_tag);
        let in_blocks = ctx.waitall(&[ds], &[dr]).pop().unwrap().into_blocks();
        debug_assert_eq!(in_blocks.len(), in_meta.len());
        debug_assert!(in_blocks
            .iter()
            .zip(in_meta.iter())
            .all(|(b, &m)| b.len() == m));
        ctx.phase_lap(ph_data);

        // Unpack: contents land in the same slot indices they left at the
        // sender. A slot is final once its top digit's round has passed.
        let mut recv_bytes = 0u64;
        let mut iter = in_blocks.into_iter();
        for &j in &moving {
            // Sub-block count per slot (`arity`) is conserved along the
            // whole path (contents are replaced wholesale), so the
            // receiver splits the incoming batch positionally.
            let _ = j;
            let mut content: SlotContent = Vec::with_capacity(arity);
            for _ in 0..arity {
                content.push(iter.next().expect("metadata/data mismatch"));
            }
            recv_bytes += content.iter().map(|b| b.len()).sum::<u64>();
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            let is_final = top_x == rd.x && top_z == rd.z;
            if !is_final {
                debug_assert!(
                    !radix::is_direct(j, radix_r),
                    "direct slot {j} received intermediate content"
                );
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
                assert!(
                    t_now <= b_bound,
                    "T occupancy {t_now} exceeded bound B={b_bound} (q={q}, r={radix_r})"
                );
            }
            slots[j] = content;
        }
        debug_assert!(iter.next().is_none());
        ctx.copy(recv_bytes); // store into T / R
        ctx.phase_lap(ph_replace);
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");

    CoreOutcome {
        slots,
        stats: AlgoStats {
            t_peak,
            rounds: k,
        },
    }
}

/// Flat TuNA over the whole communicator (Algorithm 1).
pub fn run(ctx: &mut RankCtx, blocks: Vec<Block>, radix_r: usize) -> (Vec<Block>, AlgoStats) {
    let p = ctx.size();
    let me = ctx.rank();
    assert_eq!(blocks.len(), p);
    let radix_r = radix_r.min(p).max(2);

    // ---- prepare: allreduce for M, index array setup (Alg. 1 lines 1-5).
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64); // rotation/index array write
    ctx.phase_lap(Phase::Prepare);

    // slots[j] = my block destined (me + j) mod P.
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        let d = b.dest as usize;
        by_dest[d] = Some(b);
    }
    let slots: Vec<SlotContent> = (0..p)
        .map(|j| {
            let d = (me + j) % p;
            vec![by_dest[d].take().expect("one block per destination")]
        })
        .collect();

    let out = tuna_core(ctx, 0, 1, p, radix_r, 1, slots, 0, None);

    // Self block delivery is a local copy.
    ctx.phase_mark();
    ctx.copy(out.slots[0].iter().map(|b| b.len()).sum());
    ctx.phase_lap(Phase::Replace);

    let mut recv: Vec<Block> = Vec::with_capacity(p);
    for (j, content) in out.slots.into_iter().enumerate() {
        for b in content {
            debug_assert_eq!(
                b.origin as usize,
                (me + p - j) % p,
                "slot {j} final origin mismatch"
            );
            recv.push(b);
        }
    }
    (recv, out.stats)
}

// ---- plan compilers -------------------------------------------------------

/// Stats of a compiled slot-engine schedule (identical on every rank of
/// the group, so computed once).
pub(crate) struct CorePlanStats {
    pub t_peak: usize,
    pub rounds: usize,
}

/// Compile [`tuna_core`] for every rank of the strided group
/// `{base + i * stride : i in 0..q}` — a joint size-only simulation:
/// `slots[g][j]` holds the *total* bytes of group-rank `g`'s slot `j`
/// (its `arity` sub-blocks travel wholesale, so per-sub-block sizes are
/// never needed here) and is rotated through the group exactly as the
/// slot exchange moves contents. Ops are emitted per rank in the same
/// order `tuna_core` charges them, including the same `lap` phase
/// mapping.
pub(crate) fn plan_core(
    builders: &mut [PlanBuilder],
    base: usize,
    stride: usize,
    q: usize,
    radix_r: usize,
    arity: usize,
    slots: &mut [Vec<u64>],
    tag_base: u32,
    lap: Option<Phase>,
) -> CorePlanStats {
    assert_eq!(slots.len(), q, "need one slot row per group rank");
    assert!(radix_r >= 2);
    assert!(stride >= 1);
    let (ph_meta, ph_data, ph_replace) = match lap {
        None => (Phase::Metadata, Phase::Data, Phase::Replace),
        Some(ph) => (ph, ph, ph),
    };
    let schedule: Vec<Round> = radix::rounds(radix_r, q);

    // T occupancy evolves identically on every rank of the group.
    let mut in_t = vec![false; q];
    let mut t_now = 0usize;
    let mut t_peak = 0usize;

    for (round_idx, rd) in schedule.iter().enumerate() {
        let meta_tag = tag_base + 2 * round_idx as u32;
        let data_tag = meta_tag + 1;
        let moving: Vec<usize> = (1..q)
            .filter(|&j| radix::digit(j, rd.x, radix_r) == rd.z)
            .collect();
        let meta_bytes = 8 * (moving.len() * arity) as u64;
        // Outgoing payload bytes per group rank this round.
        let out_bytes: Vec<u64> = (0..q)
            .map(|g| moving.iter().map(|&j| slots[g][j]).sum())
            .collect();

        for g in 0..q {
            let b = &mut builders[base + g * stride];
            let dst = base + ((g + rd.step) % q) * stride;
            let src_g = (g + q - rd.step) % q;
            let src = base + src_g * stride;
            b.mark();
            b.send(dst, meta_tag, meta_bytes);
            b.recv(src, meta_tag);
            b.wait();
            b.lap(ph_meta);
            b.copy(out_bytes[g]); // pack into send buffer
            b.lap(ph_replace);
            b.send(dst, data_tag, out_bytes[g]);
            b.recv(src, data_tag);
            b.wait();
            b.lap(ph_data);
            b.copy(out_bytes[src_g]); // store incoming into T / R
            b.lap(ph_replace);
        }

        // Rotate the moving slot contents one step through the group and
        // track T exactly as the runtime does: packs release, then
        // non-final arrivals occupy.
        for &j in &moving {
            let col: Vec<u64> = (0..q).map(|g| slots[(g + q - rd.step) % q][j]).collect();
            for g in 0..q {
                slots[g][j] = col[g];
            }
            if in_t[j] {
                in_t[j] = false;
                t_now -= 1;
            }
        }
        for &j in &moving {
            let (top_x, top_z) = radix::top_digit(j, radix_r);
            let is_final = top_x == rd.x && top_z == rd.z;
            if !is_final {
                in_t[j] = true;
                t_now += 1;
                t_peak = t_peak.max(t_now);
            }
        }
    }
    debug_assert_eq!(t_now, 0, "T must drain by the last round");

    CorePlanStats {
        t_peak,
        rounds: schedule.len(),
    }
}

/// Compile flat TuNA ([`run`]) for every rank from the counts matrix.
pub(crate) fn plan_into(
    builders: &mut [PlanBuilder],
    sizes: &BlockSizes,
    radix_r: usize,
) -> (usize, usize) {
    let p = sizes.p();
    let radix_r = radix_r.min(p).max(2);

    // Prepare: allreduce for M + index array write, inside one phase lap.
    for b in builders.iter_mut() {
        b.mark();
        b.allreduce();
        b.copy(4 * p as u64);
        b.lap(Phase::Prepare);
    }

    // slots[me][j] = bytes of my block destined (me + j) mod P.
    let mut slots: Vec<Vec<u64>> = (0..p)
        .map(|me| {
            let row = sizes.row(me);
            (0..p).map(|j| row[(me + j) % p]).collect()
        })
        .collect();

    let stats = plan_core(builders, 0, 1, p, radix_r, 1, &mut slots, 0, None);

    // Self-block delivery is a local copy (slot 0 never moves).
    for (me, b) in builders.iter_mut().enumerate() {
        b.mark();
        b.copy(slots[me][0]);
        b.lap(Phase::Replace);
    }
    (stats.t_peak, stats.rounds)
}

#[cfg(test)]
mod tests {
    use crate::comm::{Engine, Topology};
    use crate::model::MachineProfile;
    use crate::util::prop::forall;
    use crate::workload::{BlockSizes, Dist};

    fn run_case(p: usize, q: usize, r: usize, dist: Dist, seed: u64) -> crate::algos::RunReport {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: r }, &sizes, true)
            .expect("tuna run must validate")
    }

    #[test]
    fn tuna_correct_radix2_pow2() {
        let rep = run_case(8, 2, 2, Dist::Uniform { max: 256 }, 1);
        assert_eq!(rep.rounds, 3);
        assert!(rep.t_peak <= 8 - 3 - 1);
    }

    #[test]
    fn tuna_correct_non_pow2() {
        for (p, r) in [(6, 2), (7, 3), (12, 5), (9, 3), (10, 10)] {
            let rep = run_case(p, 1, r, Dist::Uniform { max: 128 }, p as u64);
            assert!(rep.validated, "P={p} r={r}");
        }
    }

    #[test]
    fn tuna_radix_p_equals_linear_rounds() {
        // r >= P degenerates to spread-out: P-1 rounds, no T usage.
        let rep = run_case(8, 2, 8, Dist::Uniform { max: 256 }, 3);
        assert_eq!(rep.rounds, 7);
        assert_eq!(rep.t_peak, 0);
    }

    #[test]
    fn tuna_handles_zero_size_blocks() {
        let rep = run_case(8, 2, 2, Dist::PowerLaw { max: 64, skew: 6.0 }, 5);
        assert!(rep.validated);
        let rep = run_case(8, 2, 4, Dist::FftN1, 5);
        assert!(rep.validated);
    }

    #[test]
    fn t_peak_within_bound_many_configs() {
        forall("t_peak <= B", 25, |rng| {
            let p = 2 + rng.next_below(30) as usize;
            let r = (2 + rng.next_below(p as u64) as usize).min(p);
            let rep = run_case(p, 1, r, Dist::Uniform { max: 64 }, rng.next_u64());
            let b = crate::algos::radix::temp_bound(r, p);
            if rep.t_peak <= b {
                Ok(())
            } else {
                Err(format!("P={p} r={r}: t_peak {} > B {b}", rep.t_peak))
            }
        });
    }

    #[test]
    fn round_count_matches_k() {
        for (p, r) in [(16usize, 2usize), (16, 4), (27, 3), (20, 4)] {
            let rep = run_case(p, 1, r, Dist::Const { size: 64 }, 0);
            assert_eq!(rep.rounds, crate::algos::radix::k_rounds(r, p), "P={p} r={r}");
        }
    }

    #[test]
    fn radix_tradeoff_rounds_vs_bytes() {
        // §III-A trade-off: radix 2 minimizes rounds (K = log2 P) at the
        // cost of maximal duplicate forwarding; radix P executes P-1
        // rounds but ships every block exactly once.
        let p = 64;
        let e = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 1024 }, 0);
        let lo = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: 2 }, &sizes, false).unwrap();
        let hi = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: 64 }, &sizes, false).unwrap();
        assert!(lo.rounds < hi.rounds, "{} vs {}", lo.rounds, hi.rounds);
        assert!(
            lo.counters.total_bytes() > hi.counters.total_bytes(),
            "radix 2 must move more total bytes ({} vs {})",
            lo.counters.total_bytes(),
            hi.counters.total_bytes()
        );
    }

    #[test]
    fn phantom_and_real_agree_on_schedule() {
        // Same workload, phantom vs real payloads: identical virtual time
        // and identical byte counters (DESIGN.md validation #3).
        let p = 12;
        let e = Engine::new(MachineProfile::polaris(), Topology::new(p, 4));
        let sizes = BlockSizes::generate(p, Dist::Uniform { max: 512 }, 9);
        let kind = crate::algos::AlgoKind::Tuna { radix: 3 };
        let real = crate::algos::run_alltoallv(&e, &kind, &sizes, true).unwrap();
        let phantom = crate::algos::run_alltoallv(&e, &kind, &sizes, false).unwrap();
        assert_eq!(real.makespan, phantom.makespan);
        // Virtual-time traffic is identical; only the host-side copy
        // accounting differs (real mode writes sources / reads sinks,
        // phantom mode moves no bytes at all).
        let mut rc = real.counters;
        let mut pc = phantom.counters;
        assert_eq!(rc.copied_bytes, 2 * sizes.total_bytes());
        assert_eq!(pc.copied_bytes, 0);
        rc.copied_bytes = 0;
        pc.copied_bytes = 0;
        assert_eq!(rc, pc);
    }

    #[test]
    fn direct_slots_never_store_intermediates() {
        // Exercised by the debug_assert in tuna_core across a sweep.
        forall("direct never in T", 15, |rng| {
            let p = 3 + rng.next_below(20) as usize;
            let r = 2 + rng.next_below(6) as usize;
            let rep = run_case(p, 1, r, Dist::Uniform { max: 96 }, rng.next_u64());
            if rep.validated {
                Ok(())
            } else {
                Err(format!("P={p} r={r} failed"))
            }
        });
    }

    #[test]
    fn d_total_matches_observed_slot_sends() {
        // Counter cross-check: with Const sizes, global data bytes =
        // D(r,P) * size (each slot transmission carries exactly one block
        // of `size` bytes in flat TuNA).
        let p = 16;
        let size = 128u64;
        for r in [2usize, 4, 16] {
            let e = Engine::new(MachineProfile::test_flat(), Topology::flat(p));
            let sizes = BlockSizes::generate(p, Dist::Const { size }, 0);
            let rep = crate::algos::run_alltoallv(&e, &crate::algos::AlgoKind::Tuna { radix: r }, &sizes, false).unwrap();
            let d = crate::algos::radix::d_total(r, p) as u64;
            // Every rank sends the same slot schedule, so aggregate data
            // bytes = P * D * size, metadata = P * 8 * D; the only other
            // traffic is the prepare-phase allreduce (8 B scalars).
            let measured = rep.counters.total_bytes();
            let expect_data: u64 = p as u64 * d * size;
            let expect_meta: u64 = p as u64 * 8 * d;
            assert!(
                measured >= expect_data + expect_meta,
                "r={r}: measured {measured} < data+meta {}",
                expect_data + expect_meta
            );
            let slack = measured - expect_data - expect_meta;
            assert!(
                slack <= 64 * p as u64 * (p as f64).log2().ceil() as u64,
                "r={r}: unexpected extra traffic {slack}"
            );
        }
    }
}
