//! **Composable TuNA_l^g** — the two-level hierarchy framework (§IV).
//!
//! The paper's title contribution is *configurability*: the intra-node
//! (local, `l`) and inter-node (global, `g`) algorithms of the hierarchy
//! are chosen independently. This module realizes that as a composition:
//! any [`LocalAlgo`] pairs with any [`GlobalAlgo`] under
//! `AlgoKind::Hier { local, global }` (spec `hier:l=<spec>,g=<spec>`);
//! the paper's Algorithms 2 and 3 are the compositions
//! `hier:l=tuna:r=R,g=staggered:b=B` and `hier:l=tuna:r=R,g=coalesced:b=B`
//! (still parseable under their legacy `tuna-hier-*` names).
//!
//! # Composition contract (the phase boundary)
//!
//! Every composition runs the same three stages; what each level may
//! assume about block layout at the boundary is fixed so the levels stay
//! independently swappable:
//!
//! 1. **Slot layout (input to the local level).** The P blocks at rank
//!    `(n, g)` are arranged into Q slots: slot `j` holds the N sub-blocks
//!    destined to `(k, (g + j) mod Q)` for `k = 0..N` — the implicit
//!    group view of §IV-A(a). Slot 0 (the self group offset) never moves.
//! 2. **Local phase output.** Whatever schedule the local algorithm runs,
//!    afterwards rank `(n, g)` must hold, for every node `k`, exactly the
//!    Q blocks `{(n, g') → (k, g)}` — i.e. all of its node's traffic
//!    whose destination *group rank* is `g`. Slot indices are free; only
//!    the held block set is contracted. The framework then buckets these
//!    by destination node (ascending origin within a bucket, so
//!    per-block global schedules pair messages identically on both
//!    sides), and delivers the own-node bucket locally.
//! 3. **Global phase input.** The global algorithm receives N buckets of
//!    exactly Q blocks each (bucket `k` = the blocks destined `(k, g)`),
//!    exchanges only with ranks of the same group rank `g` (the Q-port
//!    model), and must deliver every foreign bucket to its node. It may
//!    move buckets wholesale (coalesced/linear/Bruck) or per block
//!    (staggered); it must not assume anything about the local schedule
//!    that produced them.
//!
//! # Shipped implementations
//!
//! * [`LocalAlgo::Tuna`] — the TuNA slot exchange over the node's Q ranks
//!   (radix 2 = the Bruck-style log schedule; radix Q degenerates to a
//!   direct exchange). The TuNA metadata phase doubles as the size
//!   exchange the implicit strategy needs, at no extra cost.
//! * [`LocalAlgo::Linear`] — spread-out-style direct slot delivery: each
//!   slot goes straight to its final intra-node holder, Q−1 non-blocking
//!   pairs and one waitall, no metadata rounds, no temporary buffer.
//! * [`LocalAlgo::Balanced`] — the same Q−1 direct pairs as `linear`,
//!   posted in *measured heavy-first order* (per-slot bytes descending)
//!   so the fattest slot transfers start draining first. Enumerating the
//!   order costs an O(P·r) pass over the counts per rank, which is only
//!   worth paying when amortized — the schedule is therefore
//!   **persistent-only**: `LocalAlgo::parse` rejects it and the one-shot
//!   entry points refuse it; construct it through
//!   [`crate::comm::persist::PersistentColl`].
//! * [`GlobalAlgo::Coalesced`] — Alg. 3: one message of Q blocks per
//!   target node, batched by `block_count`, after a rearrangement pass
//!   that compacts T (N−1 messages).
//! * [`GlobalAlgo::Staggered`] — Alg. 2: one block per message, batched
//!   by `block_count` (Q·(N−1) messages).
//! * [`GlobalAlgo::Linear`] — spread-out over nodes: every coalesced
//!   node message posted in one burst, single waitall, no rearrangement.
//! * [`GlobalAlgo::Bruck`] — log-radix store-and-forward *across nodes*:
//!   the same TuNA slot engine run over the stride-Q group
//!   `{(k, g) : k = 0..N}` with node buckets as slots (arity Q), so
//!   inter-node latency-bound workloads get a log₂N-style schedule.
//!
//! Every hop at both levels moves payload *views* only (`comm::buffer`
//! ropes): blocks stay whole and are batched by value, so aggregation
//! never touches payload bytes on the host. The `ctx.copy` charges keep
//! modeling the rearrangement cost on the simulated machine's clock.

use super::tuna::{plan_core, plan_core_sparse, tuna_core, tuna_core_sparse, CorePlanStats, SlotContent};
use super::{AlgoKind, AlgoStats};
use crate::comm::engine::{RecvReq, SendReq};
use crate::comm::plan::chunk_ranges;
use crate::comm::{Block, Payload, Phase, PlanBuilder, RankCtx, RankPlan, Topology};
use crate::error::{Result, TunaError};
use crate::util::prng::Pcg64;
use crate::workload::BlockSizes;

/// Tag space for the inter-node phase (the intra-node core uses tags from
/// 0; K_intra <= 2Q so this is comfortably disjoint).
const INTER_TAG: u32 = 1_000_000;

/// Intra-node (local) level of the hierarchy: how the Q ranks of a node
/// rearrange their slots so every rank ends up holding its group rank's
/// share of the node's traffic (contract stage 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocalAlgo {
    /// TuNA slot exchange with tunable radix in `[2, Q]` (r = 2 is the
    /// Bruck-style log schedule).
    Tuna { radix: usize },
    /// Direct spread-out slot delivery: Q−1 non-blocking pairs, one
    /// waitall, no metadata rounds.
    Linear,
    /// Load-balanced direct delivery: the `Linear` pairs posted in
    /// measured heavy-first slot order (bytes descending, ties by slot
    /// index). Persistent-only — see the module header; `parse` rejects
    /// the spec and the one-shot entry points refuse the kind.
    Balanced,
}

impl LocalAlgo {
    /// Parse a local-level spec: `tuna:r=N` or `linear`.
    pub fn parse(s: &str) -> Result<LocalAlgo> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, a),
            None => (s, ""),
        };
        match head {
            "tuna" => Ok(LocalAlgo::Tuna {
                radix: param(head, args, "r")?,
            }),
            "linear" => Ok(LocalAlgo::Linear),
            "balanced" => Err(TunaError::config(
                "hier local `balanced` is persistent-only: its setup cost is \
                 per-handle, so it cannot be named in a one-shot spec — \
                 construct it through comm::persist::PersistentColl",
            )),
            other => Err(TunaError::config(format!(
                "hier: unknown local algorithm `{other}` (try tuna:r=N or linear)"
            ))),
        }
    }

    /// Parseable spec, the inverse of [`LocalAlgo::parse`] — except
    /// `balanced`, whose spec is intentionally *not* re-parseable (the
    /// schedule is persistent-only and must never round-trip into
    /// tuning tables or one-shot CLI runs).
    pub fn spec(&self) -> String {
        match self {
            LocalAlgo::Tuna { radix } => format!("tuna:r={radix}"),
            LocalAlgo::Linear => "linear".into(),
            LocalAlgo::Balanced => "balanced".into(),
        }
    }

    pub fn name(&self) -> String {
        match self {
            LocalAlgo::Tuna { radix } => format!("tuna(r={radix})"),
            LocalAlgo::Linear => "linear".into(),
            LocalAlgo::Balanced => "balanced".into(),
        }
    }
}

/// Inter-node (global) level of the hierarchy: how the N buckets of Q
/// blocks each reach their destination nodes over the Q-port groups
/// (contract stage 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalAlgo {
    /// Alg. 3: one Q-block message per target node, batched by
    /// `block_count`, after a T-compacting rearrangement pass.
    Coalesced { block_count: usize },
    /// Alg. 2: one block per message, batched by `block_count`.
    Staggered { block_count: usize },
    /// Spread-out over nodes: all N−1 coalesced messages in one burst.
    Linear,
    /// Log-radix TuNA slot exchange across nodes (radix in `[2, N]`).
    Bruck { radix: usize },
}

impl GlobalAlgo {
    /// Parse a global-level spec: `coalesced:b=N`, `staggered:b=N`,
    /// `linear` or `bruck:r=N`.
    pub fn parse(s: &str) -> Result<GlobalAlgo> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, a),
            None => (s, ""),
        };
        match head {
            "coalesced" => Ok(GlobalAlgo::Coalesced {
                block_count: param(head, args, "b")?,
            }),
            "staggered" => Ok(GlobalAlgo::Staggered {
                block_count: param(head, args, "b")?,
            }),
            "linear" => Ok(GlobalAlgo::Linear),
            "bruck" => Ok(GlobalAlgo::Bruck {
                radix: param(head, args, "r")?,
            }),
            other => Err(TunaError::config(format!(
                "hier: unknown global algorithm `{other}` \
                 (try coalesced:b=N, staggered:b=N, linear or bruck:r=N)"
            ))),
        }
    }

    /// Parseable spec, the inverse of [`GlobalAlgo::parse`].
    pub fn spec(&self) -> String {
        match self {
            GlobalAlgo::Coalesced { block_count } => format!("coalesced:b={block_count}"),
            GlobalAlgo::Staggered { block_count } => format!("staggered:b={block_count}"),
            GlobalAlgo::Linear => "linear".into(),
            GlobalAlgo::Bruck { radix } => format!("bruck:r={radix}"),
        }
    }

    pub fn name(&self) -> String {
        match self {
            GlobalAlgo::Coalesced { block_count } => format!("coalesced(b={block_count})"),
            GlobalAlgo::Staggered { block_count } => format!("staggered(b={block_count})"),
            GlobalAlgo::Linear => "linear".into(),
            GlobalAlgo::Bruck { radix } => format!("bruck(r={radix})"),
        }
    }

    /// Short family suffix for table columns (`hier-<this>`).
    pub fn family(&self) -> &'static str {
        match self {
            GlobalAlgo::Coalesced { .. } => "hier-coalesced",
            GlobalAlgo::Staggered { .. } => "hier-staggered",
            GlobalAlgo::Linear => "hier-linear",
            GlobalAlgo::Bruck { .. } => "hier-bruck",
        }
    }
}

/// `key=value` lookup inside a sub-spec's argument list, with errors that
/// name the missing or invalid parameter (mirrors `AlgoKind::parse`).
fn param(head: &str, args: &str, key: &str) -> Result<usize> {
    let raw = args
        .split(',')
        .find_map(|kv| kv.strip_prefix(key).and_then(|r| r.strip_prefix('=')));
    match raw {
        None => Err(TunaError::config(format!(
            "hier {head}: missing parameter `{key}` (expected `{head}:{key}=N`)"
        ))),
        Some(v) => v.parse().map_err(|_| {
            TunaError::config(format!(
                "hier {head}: invalid value `{v}` for parameter `{key}`"
            ))
        }),
    }
}

/// Split the `hier:` argument list `l=<spec>,g=<spec>` into the two
/// sub-specs. Sub-specs may themselves contain commas: a chunk that does
/// not start a new `l=` / `g=` key is glued back onto the one in
/// progress.
pub(crate) fn split_spec(args: &str) -> Result<(String, String)> {
    enum Cursor {
        None,
        Local,
        Global,
    }
    let mut local: Option<String> = None;
    let mut global: Option<String> = None;
    let mut cursor = Cursor::None;
    for chunk in args.split(',') {
        if let Some(rest) = chunk.strip_prefix("l=") {
            if local.is_some() {
                return Err(TunaError::config(format!(
                    "hier: duplicate local level `l=` in `{args}`"
                )));
            }
            local = Some(rest.to_string());
            cursor = Cursor::Local;
        } else if let Some(rest) = chunk.strip_prefix("g=") {
            if global.is_some() {
                return Err(TunaError::config(format!(
                    "hier: duplicate global level `g=` in `{args}`"
                )));
            }
            global = Some(rest.to_string());
            cursor = Cursor::Global;
        } else {
            let target = match cursor {
                Cursor::Local => local.as_mut(),
                Cursor::Global => global.as_mut(),
                Cursor::None => None,
            };
            match target {
                Some(spec) => {
                    spec.push(',');
                    spec.push_str(chunk);
                }
                None => {
                    return Err(TunaError::config(format!(
                        "hier: expected `l=<spec>,g=<spec>`, got `{args}`"
                    )))
                }
            }
        }
    }
    match (local, global) {
        (Some(l), Some(g)) => Ok((l, g)),
        (None, _) => Err(TunaError::config(
            "hier: missing local level `l=<spec>` (expected `hier:l=<spec>,g=<spec>`)",
        )),
        (_, None) => Err(TunaError::config(
            "hier: missing global level `g=<spec>` (expected `hier:l=<spec>,g=<spec>`)",
        )),
    }
}

/// Uniformly sample a runnable local×global composition for a topology
/// with `q >= 2` ranks per node and `n >= 2` nodes. This is the one
/// generator shared by the randomized property suites (correctness,
/// zero-copy, replay equivalence, and this module's own), so every
/// suite explores the same composition space with the same parameter
/// ranges; every returned kind passes [`AlgoKind::check`] for
/// `(q * n, q)`.
pub fn random_composition(rng: &mut Pcg64, q: usize, n: usize) -> AlgoKind {
    assert!(q >= 2 && n >= 2, "compositions need Q >= 2 and N >= 2");
    let local = match rng.next_below(2) {
        0 => LocalAlgo::Tuna {
            radix: 2 + rng.next_below(q as u64 - 1) as usize, // 2..=Q
        },
        _ => LocalAlgo::Linear,
    };
    let global = match rng.next_below(4) {
        0 => GlobalAlgo::Coalesced {
            block_count: 1 + rng.next_below((n - 1) as u64) as usize, // 1..=N-1
        },
        1 => GlobalAlgo::Staggered {
            block_count: 1 + rng.next_below(((n - 1) * q) as u64) as usize, // 1..=Q(N-1)
        },
        2 => GlobalAlgo::Linear,
        _ => GlobalAlgo::Bruck {
            radix: 2 + rng.next_below(n as u64 - 1) as usize, // 2..=N
        },
    };
    AlgoKind::Hier { local, global }
}

/// Validate a composition against a topology (called by
/// `AlgoKind::check`).
pub fn check(local: &LocalAlgo, global: &GlobalAlgo, _p: usize, q: usize, n: usize) -> Result<()> {
    let bad = |m: String| Err(TunaError::Config(m));
    if q < 2 {
        return bad(format!(
            "hier: a hierarchical composition needs Q >= 2 ranks per node, got {q}"
        ));
    }
    if let LocalAlgo::Tuna { radix } = *local {
        if radix < 2 || radix > q {
            return bad(format!("hier local tuna: radix {radix} outside [2, Q={q}]"));
        }
    }
    match *global {
        GlobalAlgo::Coalesced { block_count } | GlobalAlgo::Staggered { block_count }
            if block_count == 0 =>
        {
            bad("hier global: block_count must be >= 1".into())
        }
        // The inter-node phase only runs at N >= 2 nodes; a single-node
        // topology skips it, so any radix >= 2 is acceptable there.
        GlobalAlgo::Bruck { radix } if radix < 2 || (n >= 2 && radix > n) => {
            bad(format!("hier global bruck: radix {radix} outside [2, N={n}]"))
        }
        _ => Ok(()),
    }
}

/// Run a hierarchical composition on one rank (see the module header for
/// the three-stage contract).
pub fn run(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    local: LocalAlgo,
    global: GlobalAlgo,
) -> (Vec<Block>, AlgoStats) {
    let topo = *ctx.topo();
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    let me = ctx.rank();
    let my_node = topo.node_of(me);
    let g = topo.group_rank(me);
    assert_eq!(blocks.len(), p);
    assert!(q >= 2, "hierarchical TuNA needs Q >= 2");

    // ---- prepare (Alg. 3 lines 1-5): global max block size M, index
    // arrays.
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64);
    ctx.phase_lap(Phase::Prepare);

    // ---- contract stage 1: the slot layout. Slot j aggregates the N
    // sub-blocks destined to group-rank (g + j) % Q.
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        let d = b.dest as usize;
        by_dest[d] = Some(b);
    }
    let slots: Vec<SlotContent> = (0..q)
        .map(|j| {
            let dest_g = (g + j) % q;
            (0..n_nodes)
                .map(|k| {
                    by_dest[topo.rank_of(k, dest_g)]
                        .take()
                        .expect("one block per destination")
                })
                .collect()
        })
        .collect();

    // ---- local phase.
    let (slots, mut stats) = match local {
        LocalAlgo::Tuna { radix } => {
            assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
            let out = tuna_core(ctx, my_node * q, 1, q, radix, n_nodes, slots, 0, None);
            (out.slots, out.stats)
        }
        LocalAlgo::Linear => run_local_linear(ctx, my_node * q, q, g, slots),
        LocalAlgo::Balanced => run_local_balanced(ctx, my_node * q, q, g, slots),
    };

    // ---- contract stage 2 → 3: bucket the now group-aligned blocks by
    // destination node: bucket[k] = the Q blocks {(my_node, g') -> (k, g)}.
    let mut buckets: Vec<Vec<Block>> = (0..n_nodes).map(|_| Vec::with_capacity(q)).collect();
    for content in slots {
        for b in content {
            debug_assert_eq!(topo.group_rank(b.dest as usize), g, "local phase must align groups");
            buckets[topo.node_of(b.dest as usize)].push(b);
        }
    }
    // Deterministic order inside each bucket (by origin) so per-block
    // global schedules pair messages identically on both sides.
    for bucket in buckets.iter_mut() {
        bucket.sort_by_key(|b| b.origin);
    }

    // Own node's bucket is final.
    let mut recv: Vec<Block> = Vec::with_capacity(p);
    ctx.phase_mark();
    ctx.copy(buckets[my_node].iter().map(|b| b.len()).sum());
    recv.extend(std::mem::take(&mut buckets[my_node]));
    ctx.phase_lap(Phase::Replace);

    if n_nodes == 1 {
        return (recv, stats);
    }

    // ---- global phase.
    match global {
        GlobalAlgo::Coalesced { block_count } => {
            assert!(block_count >= 1);
            // Alg. 3 lines 19-30: rearrange T (compact empty segments),
            // then batched node-level rounds of one Q-block message each.
            ctx.phase_mark();
            let staged_bytes: u64 = buckets.iter().flatten().map(|b| b.len()).sum();
            ctx.copy(staged_bytes);
            ctx.phase_lap(Phase::Rearrange);

            let mut round = 0usize; // node offsets 1..N-1
            while round < n_nodes - 1 {
                let batch = block_count.min(n_nodes - 1 - round);
                let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
                let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
                for i in 0..batch {
                    let off = round + i + 1;
                    let ndst = (my_node + n_nodes - off) % n_nodes;
                    let nsrc = (my_node + off) % n_nodes;
                    let tag = INTER_TAG + off as u32;
                    recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                    let payload = Payload::Blocks(std::mem::take(&mut buckets[ndst]));
                    sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
                }
                for pl in ctx.waitall(&sends, &recvs) {
                    recv.extend(pl.into_blocks());
                }
                stats.rounds += batch;
                round += batch;
            }
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Staggered { block_count } => {
            assert!(block_count >= 1);
            // Alg. 2: one block per message, Q*(N-1) steps, batched.
            ctx.phase_mark();
            let total_steps = (n_nodes - 1) * q;
            let mut step = 0usize;
            while step < total_steps {
                let batch = block_count.min(total_steps - step);
                let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
                let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
                for i in 0..batch {
                    let idx = step + i;
                    let off = idx / q + 1; // node offset 1..N-1
                    let j = idx % q; // which of the Q blocks
                    let ndst = (my_node + n_nodes - off) % n_nodes;
                    let nsrc = (my_node + off) % n_nodes;
                    let tag = INTER_TAG + idx as u32;
                    recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                    // The tombstone left behind is never sent or
                    // validated; the real block moves out as a view.
                    let block = std::mem::replace(
                        &mut buckets[ndst][j],
                        Block::new(0, 0, crate::comm::DataBuf::Phantom(0)),
                    );
                    let payload = Payload::Blocks(vec![block]);
                    sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
                }
                for pl in ctx.waitall(&sends, &recvs) {
                    recv.extend(pl.into_blocks());
                }
                stats.rounds += 1;
                step += batch;
            }
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Linear => {
            // Spread-out over nodes: all N-1 coalesced messages in one
            // burst, single waitall, no rearrangement pass.
            ctx.phase_mark();
            let mut sends: Vec<SendReq> = Vec::with_capacity(n_nodes - 1);
            let mut recvs: Vec<RecvReq> = Vec::with_capacity(n_nodes - 1);
            for off in 1..n_nodes {
                let ndst = (my_node + n_nodes - off) % n_nodes;
                let nsrc = (my_node + off) % n_nodes;
                let tag = INTER_TAG + off as u32;
                recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                let payload = Payload::Blocks(std::mem::take(&mut buckets[ndst]));
                sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
            }
            for pl in ctx.waitall(&sends, &recvs) {
                recv.extend(pl.into_blocks());
            }
            stats.rounds += 1;
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Bruck { radix } => {
            // Node-level TuNA slot exchange over the stride-Q group
            // {(k, g)}: slot j = the bucket for node (my_node + j) % N
            // (arity Q). Slot 0 is the own-node bucket, already
            // delivered above, and never moves.
            let radix = radix.min(n_nodes).max(2);
            let node_slots: Vec<SlotContent> = (0..n_nodes)
                .map(|j| {
                    if j == 0 {
                        Vec::new()
                    } else {
                        std::mem::take(&mut buckets[(my_node + j) % n_nodes])
                    }
                })
                .collect();
            // Lap mapping: every round of the node-level exchange is
            // inter-node time, so compositions stay comparable per
            // phase with the coalesced/staggered/linear globals.
            let out = tuna_core(
                ctx,
                g,
                q,
                n_nodes,
                radix,
                q,
                node_slots,
                INTER_TAG,
                Some(Phase::InterNode),
            );
            for (j, content) in out.slots.into_iter().enumerate() {
                if j > 0 {
                    recv.extend(content);
                }
            }
            stats.rounds += out.stats.rounds;
            stats.t_peak = stats.t_peak.max(out.stats.t_peak);
        }
    }

    debug_assert_eq!(recv.len(), p);
    (recv, stats)
}

// ---- structural-sparse schedules ------------------------------------------
//
// On a sparse workload every level of the hierarchy exchanges only where
// structural traffic exists. The predicates and event schedules below
// are shared verbatim between the threaded runners and the plan
// compilers — both sides answer "who sends what to whom" from the same
// `Counts` queries, so the two execution modes cannot drift
// (`tests/replay_equivalence.rs` pins them bit-identical).

/// Does rank `src`'s stage-1 slot destined to group rank `dest_g` hold
/// any structural block (i.e. does `src` send to *any* rank whose group
/// rank is `dest_g`)?
pub(crate) fn sparse_slot_nonempty(
    sizes: &BlockSizes,
    topo: &Topology,
    src: usize,
    dest_g: usize,
) -> bool {
    sizes
        .row_view(src)
        .entries()
        .any(|(dst, _)| topo.group_rank(dst) == dest_g)
}

/// Foreign nodes that structurally send to `me` (sorted ascending).
pub(crate) fn sparse_sender_nodes(
    sizes: &BlockSizes,
    topo: &Topology,
    me: usize,
) -> Vec<usize> {
    let senders = sizes.senders();
    let my_node = topo.node_of(me);
    let mut nodes: Vec<usize> = Vec::new();
    for &src in senders[me].iter() {
        let k = topo.node_of(src as usize);
        if k != my_node && nodes.last() != Some(&k) {
            nodes.push(k);
        }
    }
    nodes
}

/// Structural senders of `me` living on node `k` (sorted ascending) —
/// exactly the origin order of node `k`'s bucket for `me`, which is what
/// pairs the staggered global's per-block messages on both sides.
pub(crate) fn sparse_senders_in_node(
    sizes: &BlockSizes,
    topo: &Topology,
    me: usize,
    k: usize,
) -> Vec<u32> {
    sizes.senders()[me]
        .iter()
        .copied()
        .filter(|&s| topo.node_of(s as usize) == k)
        .collect()
}

/// Ascending node-offset events of the sparse coalesced/linear global
/// phase for one rank: at offset `off` the rank sends its bucket to node
/// `(my_node − off)` when non-empty, and receives from node
/// `(my_node + off)` when that node structurally sends to it. Offsets
/// with neither are skipped entirely — no phantom node messages.
pub(crate) fn sparse_node_events(
    topo: &Topology,
    me: usize,
    send_nonempty: impl Fn(usize) -> bool,
    recv_nodes: &[usize],
) -> Vec<(usize, Option<usize>, Option<usize>)> {
    let n = topo.nodes();
    let my_node = topo.node_of(me);
    let mut recv_set = vec![false; n];
    for &k in recv_nodes {
        recv_set[k] = true;
    }
    let mut out = Vec::new();
    for off in 1..n {
        let ndst = (my_node + n - off) % n;
        let nsrc = (my_node + off) % n;
        let s = if send_nonempty(ndst) { Some(ndst) } else { None };
        let r = if recv_set[nsrc] { Some(nsrc) } else { None };
        if s.is_some() || r.is_some() {
            out.push((off, s, r));
        }
    }
    out
}

/// One per-block step of the sparse staggered global phase, keyed by the
/// dense schedule's step index `idx = (off−1)·Q + pos` (`pos` = position
/// in the origin-sorted bucket), which is also its message tag offset.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct SparseStagEvent {
    /// Send the `pos`-th block of my bucket for node `ndst`.
    pub send: Option<(usize, usize)>, // (ndst, pos)
    /// Receive the `pos`-th block node `nsrc` holds for me.
    pub recv: Option<usize>, // nsrc
}

/// Merged, idx-ascending staggered events for one rank.
/// `send_counts[k]` is the rank's bucket size for node `k`;
/// `recv_counts[k]` how many blocks node `k` holds for this rank.
pub(crate) fn sparse_stag_events(
    topo: &Topology,
    me: usize,
    send_counts: &[usize],
    recv_counts: &[usize],
) -> Vec<(usize, SparseStagEvent)> {
    let n = topo.nodes();
    let q = topo.q();
    let my_node = topo.node_of(me);
    let mut map: std::collections::BTreeMap<usize, SparseStagEvent> =
        std::collections::BTreeMap::new();
    for off in 1..n {
        let ndst = (my_node + n - off) % n;
        let nsrc = (my_node + off) % n;
        for pos in 0..send_counts[ndst] {
            map.entry((off - 1) * q + pos).or_default().send = Some((ndst, pos));
        }
        for pos in 0..recv_counts[nsrc] {
            map.entry((off - 1) * q + pos).or_default().recv = Some(nsrc);
        }
    }
    map.into_iter().collect()
}

/// Run a hierarchical composition on a structurally sparse workload:
/// the same three-stage contract as [`run`], with every level skipping
/// absent traffic — sparse slot engine locally, non-empty node buckets
/// only globally. `blocks` holds just the rank's structural blocks.
pub fn run_sparse(
    ctx: &mut RankCtx,
    blocks: Vec<Block>,
    local: LocalAlgo,
    global: GlobalAlgo,
    sizes: &BlockSizes,
) -> (Vec<Block>, AlgoStats) {
    let topo = *ctx.topo();
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    let me = ctx.rank();
    let my_node = topo.node_of(me);
    let g = topo.group_rank(me);
    assert!(q >= 2, "hierarchical TuNA needs Q >= 2");

    // ---- prepare: identical preamble to the dense path.
    ctx.phase_mark();
    let local_max = blocks.iter().map(|b| b.len()).max().unwrap_or(0);
    let _m = ctx.allreduce_max(local_max);
    ctx.copy(4 * p as u64);
    ctx.phase_lap(Phase::Prepare);

    // ---- contract stage 1: slot layout over the structural blocks only
    // (ascending node within a slot, exactly like the dense layout).
    let mut by_dest: Vec<Option<Block>> = (0..p).map(|_| None).collect();
    for b in blocks {
        by_dest[b.dest as usize] = Some(b);
    }
    let slots: Vec<SlotContent> = (0..q)
        .map(|j| {
            let dest_g = (g + j) % q;
            (0..n_nodes)
                .filter_map(|k| by_dest[topo.rank_of(k, dest_g)].take())
                .collect()
        })
        .collect();

    // ---- local phase.
    let (slots, mut stats) = match local {
        LocalAlgo::Tuna { radix } => {
            assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
            let out = tuna_core_sparse(ctx, my_node * q, 1, q, radix, slots, 0, None);
            (out.slots, out.stats)
        }
        LocalAlgo::Linear => run_local_linear_sparse(ctx, my_node * q, q, g, slots, sizes, &topo),
        LocalAlgo::Balanced => {
            run_local_balanced_sparse(ctx, my_node * q, q, g, slots, sizes, &topo)
        }
    };

    // ---- bucket by destination node, origin-sorted.
    let mut buckets: Vec<Vec<Block>> = (0..n_nodes).map(|_| Vec::new()).collect();
    for content in slots {
        for b in content {
            debug_assert_eq!(topo.group_rank(b.dest as usize), g, "local phase must align groups");
            buckets[topo.node_of(b.dest as usize)].push(b);
        }
    }
    for bucket in buckets.iter_mut() {
        bucket.sort_by_key(|b| b.origin);
    }

    // Own node's bucket is final (0-byte copy when empty).
    let mut recv: Vec<Block> = Vec::new();
    ctx.phase_mark();
    ctx.copy(buckets[my_node].iter().map(|b| b.len()).sum());
    recv.extend(std::mem::take(&mut buckets[my_node]));
    ctx.phase_lap(Phase::Replace);

    if n_nodes == 1 {
        return (recv, stats);
    }

    // ---- global phase, structural events only.
    match global {
        GlobalAlgo::Coalesced { block_count } => {
            assert!(block_count >= 1);
            ctx.phase_mark();
            let staged: u64 = buckets.iter().flatten().map(|b| b.len()).sum();
            ctx.copy(staged);
            ctx.phase_lap(Phase::Rearrange);

            let recv_nodes = sparse_sender_nodes(sizes, &topo, me);
            let events =
                sparse_node_events(&topo, me, |k| !buckets[k].is_empty(), &recv_nodes);
            let mut i = 0usize;
            while i < events.len() {
                let batch = block_count.min(events.len() - i);
                let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
                let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
                for &(off, s, r) in &events[i..i + batch] {
                    let tag = INTER_TAG + off as u32;
                    if let Some(nsrc) = r {
                        recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                    }
                    if let Some(ndst) = s {
                        let payload = Payload::Blocks(std::mem::take(&mut buckets[ndst]));
                        sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
                    }
                }
                for pl in ctx.waitall(&sends, &recvs) {
                    recv.extend(pl.into_blocks());
                }
                stats.rounds += batch;
                i += batch;
            }
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Staggered { block_count } => {
            assert!(block_count >= 1);
            ctx.phase_mark();
            let send_counts: Vec<usize> = buckets.iter().map(Vec::len).collect();
            let recv_counts: Vec<usize> = (0..n_nodes)
                .map(|k| {
                    if k == my_node {
                        0
                    } else {
                        sparse_senders_in_node(sizes, &topo, me, k).len()
                    }
                })
                .collect();
            let events = sparse_stag_events(&topo, me, &send_counts, &recv_counts);
            let mut i = 0usize;
            while i < events.len() {
                let batch = block_count.min(events.len() - i);
                let mut sends: Vec<SendReq> = Vec::with_capacity(batch);
                let mut recvs: Vec<RecvReq> = Vec::with_capacity(batch);
                for &(idx, ev) in &events[i..i + batch] {
                    let tag = INTER_TAG + idx as u32;
                    if let Some(nsrc) = ev.recv {
                        recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                    }
                    if let Some((ndst, pos)) = ev.send {
                        // The tombstone left behind is never sent; blocks
                        // leave the bucket in origin order.
                        let block = std::mem::replace(
                            &mut buckets[ndst][pos],
                            Block::new(0, 0, crate::comm::DataBuf::Phantom(0)),
                        );
                        sends.push(ctx.isend(topo.rank_of(ndst, g), tag, Payload::Blocks(vec![block])));
                    }
                }
                for pl in ctx.waitall(&sends, &recvs) {
                    recv.extend(pl.into_blocks());
                }
                stats.rounds += 1;
                i += batch;
            }
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Linear => {
            ctx.phase_mark();
            let recv_nodes = sparse_sender_nodes(sizes, &topo, me);
            let events =
                sparse_node_events(&topo, me, |k| !buckets[k].is_empty(), &recv_nodes);
            let mut sends: Vec<SendReq> = Vec::with_capacity(events.len());
            let mut recvs: Vec<RecvReq> = Vec::with_capacity(events.len());
            for &(off, s, r) in &events {
                let tag = INTER_TAG + off as u32;
                if let Some(nsrc) = r {
                    recvs.push(ctx.irecv(topo.rank_of(nsrc, g), tag));
                }
                if let Some(ndst) = s {
                    let payload = Payload::Blocks(std::mem::take(&mut buckets[ndst]));
                    sends.push(ctx.isend(topo.rank_of(ndst, g), tag, payload));
                }
            }
            for pl in ctx.waitall(&sends, &recvs) {
                recv.extend(pl.into_blocks());
            }
            stats.rounds += 1;
            ctx.phase_lap(Phase::InterNode);
        }
        GlobalAlgo::Bruck { radix } => {
            let radix = radix.min(n_nodes).max(2);
            let node_slots: Vec<SlotContent> = (0..n_nodes)
                .map(|j| {
                    if j == 0 {
                        Vec::new()
                    } else {
                        std::mem::take(&mut buckets[(my_node + j) % n_nodes])
                    }
                })
                .collect();
            let out = tuna_core_sparse(
                ctx,
                g,
                q,
                n_nodes,
                radix,
                node_slots,
                INTER_TAG,
                Some(Phase::InterNode),
            );
            for (j, content) in out.slots.into_iter().enumerate() {
                if j > 0 {
                    recv.extend(content);
                }
            }
            stats.rounds += out.stats.rounds;
            stats.t_peak = stats.t_peak.max(out.stats.t_peak);
        }
    }

    (recv, stats)
}

/// [`LocalAlgo::Linear`] on a sparse workload: the dense direct
/// delivery with empty slots skipped on both sides (the receive
/// predicate is [`sparse_slot_nonempty`], shared with the compiler).
fn run_local_linear_sparse(
    ctx: &mut RankCtx,
    base: usize,
    q: usize,
    g: usize,
    mut slots: Vec<SlotContent>,
    sizes: &BlockSizes,
    topo: &Topology,
) -> (Vec<SlotContent>, AlgoStats) {
    ctx.phase_mark();
    let mut sends: Vec<SendReq> = Vec::new();
    let mut recvs: Vec<RecvReq> = Vec::new();
    let mut recv_js: Vec<usize> = Vec::new();
    for j in 1..q {
        let dst = base + (g + j) % q;
        let src = base + (g + q - j) % q;
        if sparse_slot_nonempty(sizes, topo, src, g) {
            recvs.push(ctx.irecv(src, j as u32));
            recv_js.push(j);
        }
        if !slots[j].is_empty() {
            let payload = Payload::Blocks(std::mem::take(&mut slots[j]));
            sends.push(ctx.isend(dst, j as u32, payload));
        }
    }
    for (j, pl) in recv_js.into_iter().zip(ctx.waitall(&sends, &recvs)) {
        slots[j] = pl.into_blocks();
    }
    ctx.phase_lap(Phase::Data);
    (slots, AlgoStats { t_peak: 0, rounds: 1 })
}

/// The load-balanced drain order of [`LocalAlgo::Balanced`]: slot
/// indices `1..Q` sorted by measured slot bytes descending, ties broken
/// by ascending index. Shared verbatim between the threaded runners and
/// the plan compilers — both sides derive `slot_bytes` from the same
/// counts, so the permutation (and with it bit-identity) cannot drift.
pub(crate) fn balanced_order(slot_bytes: &[u64]) -> Vec<usize> {
    let mut order: Vec<usize> = (1..slot_bytes.len()).collect();
    order.sort_by_key(|&j| (std::cmp::Reverse(slot_bytes[j]), j));
    order
}

/// [`LocalAlgo::Balanced`]: the `Linear` pairs posted in heavy-first
/// slot order, so the fattest transfer is in flight before the light
/// ones queue behind it on the intra-node links.
fn run_local_balanced(
    ctx: &mut RankCtx,
    base: usize,
    q: usize,
    g: usize,
    mut slots: Vec<SlotContent>,
) -> (Vec<SlotContent>, AlgoStats) {
    ctx.phase_mark();
    let bytes: Vec<u64> = slots
        .iter()
        .map(|s| s.iter().map(|b| b.len()).sum())
        .collect();
    let order = balanced_order(&bytes);
    let mut sends: Vec<SendReq> = Vec::with_capacity(q - 1);
    let mut recvs: Vec<RecvReq> = Vec::with_capacity(q - 1);
    for &j in &order {
        let dst = base + (g + j) % q;
        let src = base + (g + q - j) % q;
        recvs.push(ctx.irecv(src, j as u32));
        let payload = Payload::Blocks(std::mem::take(&mut slots[j]));
        sends.push(ctx.isend(dst, j as u32, payload));
    }
    for (&j, pl) in order.iter().zip(ctx.waitall(&sends, &recvs)) {
        slots[j] = pl.into_blocks();
    }
    ctx.phase_lap(Phase::Data);
    (slots, AlgoStats { t_peak: 0, rounds: 1 })
}

/// [`LocalAlgo::Balanced`] on a sparse workload: the sparse `Linear`
/// gates evaluated in heavy-first slot order (ordering by structural
/// slot bytes; absent slots sort last and are skipped on both sides).
fn run_local_balanced_sparse(
    ctx: &mut RankCtx,
    base: usize,
    q: usize,
    g: usize,
    mut slots: Vec<SlotContent>,
    sizes: &BlockSizes,
    topo: &Topology,
) -> (Vec<SlotContent>, AlgoStats) {
    ctx.phase_mark();
    let bytes: Vec<u64> = slots
        .iter()
        .map(|s| s.iter().map(|b| b.len()).sum())
        .collect();
    let order = balanced_order(&bytes);
    let mut sends: Vec<SendReq> = Vec::new();
    let mut recvs: Vec<RecvReq> = Vec::new();
    let mut recv_js: Vec<usize> = Vec::new();
    for &j in &order {
        let dst = base + (g + j) % q;
        let src = base + (g + q - j) % q;
        if sparse_slot_nonempty(sizes, topo, src, g) {
            recvs.push(ctx.irecv(src, j as u32));
            recv_js.push(j);
        }
        if !slots[j].is_empty() {
            let payload = Payload::Blocks(std::mem::take(&mut slots[j]));
            sends.push(ctx.isend(dst, j as u32, payload));
        }
    }
    for (j, pl) in recv_js.into_iter().zip(ctx.waitall(&sends, &recvs)) {
        slots[j] = pl.into_blocks();
    }
    ctx.phase_lap(Phase::Data);
    (slots, AlgoStats { t_peak: 0, rounds: 1 })
}

/// [`LocalAlgo::Linear`]: direct spread-out slot delivery within the
/// node. Each slot already names its final intra-node holder — send it
/// straight there, Q−1 non-blocking pairs, one waitall.
fn run_local_linear(
    ctx: &mut RankCtx,
    base: usize,
    q: usize,
    g: usize,
    mut slots: Vec<SlotContent>,
) -> (Vec<SlotContent>, AlgoStats) {
    ctx.phase_mark();
    let mut sends: Vec<SendReq> = Vec::with_capacity(q - 1);
    let mut recvs: Vec<RecvReq> = Vec::with_capacity(q - 1);
    for j in 1..q {
        let dst = base + (g + j) % q;
        let src = base + (g + q - j) % q;
        recvs.push(ctx.irecv(src, j as u32));
        let payload = Payload::Blocks(std::mem::take(&mut slots[j]));
        sends.push(ctx.isend(dst, j as u32, payload));
    }
    for (j, pl) in (1..q).zip(ctx.waitall(&sends, &recvs)) {
        slots[j] = pl.into_blocks();
    }
    ctx.phase_lap(Phase::Data);
    (slots, AlgoStats { t_peak: 0, rounds: 1 })
}

// ---- plan compiler --------------------------------------------------------

/// Compile a hierarchical composition ([`run`]) for every rank from the
/// counts matrix, returning the per-rank op lists plus `(t_peak,
/// rounds)`. The local phase is a per-node joint simulation; the global
/// phase's message and copy sizes come from the matrix in closed form —
/// after the local phase, rank `(n, g)`'s bucket for node `k` holds
/// exactly the blocks `{(n, g') → (k, g)}` in ascending `g'` order.
///
/// Compilation **streams node by node**: each node's stage touches only
/// its own Q builders and Q rows (working memory O(Q·P) dense / O(node
/// nnz) sparse), which is what makes the per-node split embarrassingly
/// parallel — `threads > 1` compiles contiguous node chunks on scoped
/// workers, and reassembly by rank index keeps the result op-for-op
/// identical to the serial pass (the plan-determinism contract of
/// `comm::plan`). The one cross-node stage is a `bruck` global level,
/// whose joint simulations run per group rank `g` over the bucket-sum
/// matrix (O(P·N) transient) accumulated in stage one; the Q groups are
/// disjoint builder sets too and parallelize the same way after a
/// g-major permutation.
pub(crate) fn plan_build(
    sizes: &BlockSizes,
    topo: Topology,
    local: LocalAlgo,
    global: GlobalAlgo,
    threads: usize,
) -> (Vec<RankPlan>, usize, usize) {
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    assert!(q >= 2, "hierarchical TuNA needs Q >= 2");
    let use_bs = matches!(global, GlobalAlgo::Bruck { .. }) && n_nodes > 1;
    let bruck_radix = match global {
        GlobalAlgo::Bruck { radix } => radix.min(n_nodes).max(2),
        _ => 2,
    };

    if sizes.is_sparse() {
        let node_fn = |node: usize, nb: &mut [PlanBuilder], bs: &mut [Vec<(u64, u32)>]| {
            plan_node_sparse(sizes, topo, local, global, node, nb, bs)
        };
        let tail_fn = |g: usize, col: &mut [PlanBuilder], bs: &[Vec<(u64, u32)>]| {
            let mut node_slots: Vec<Vec<(u64, u32)>> = (0..n_nodes)
                .map(|m| {
                    (0..n_nodes)
                        .map(|j| {
                            if j == 0 {
                                (0, 0)
                            } else {
                                bs[topo.rank_of(m, g)][(m + j) % n_nodes]
                            }
                        })
                        .collect()
                })
                .collect();
            plan_core_sparse(
                col,
                g,
                q,
                n_nodes,
                bruck_radix,
                &mut node_slots,
                INTER_TAG,
                Some(Phase::InterNode),
            )
        };
        let tail: Option<&(dyn Fn(usize, &mut [PlanBuilder], &[Vec<(u64, u32)>]) -> CorePlanStats + Sync)> =
            if use_bs { Some(&tail_fn) } else { None };
        plan_build_impl(p, q, n_nodes, threads, use_bs, &node_fn, tail)
    } else {
        let node_fn = |node: usize, nb: &mut [PlanBuilder], bs: &mut [Vec<u64>]| {
            plan_node_dense(sizes, topo, local, global, node, nb, bs)
        };
        let tail_fn = |g: usize, col: &mut [PlanBuilder], bs: &[Vec<u64>]| {
            let mut node_slots: Vec<Vec<u64>> = (0..n_nodes)
                .map(|m| {
                    (0..n_nodes)
                        .map(|j| {
                            if j == 0 {
                                0
                            } else {
                                bs[topo.rank_of(m, g)][(m + j) % n_nodes]
                            }
                        })
                        .collect()
                })
                .collect();
            plan_core(
                col,
                g,
                q,
                n_nodes,
                bruck_radix,
                q,
                &mut node_slots,
                INTER_TAG,
                Some(Phase::InterNode),
            )
        };
        let tail: Option<&(dyn Fn(usize, &mut [PlanBuilder], &[Vec<u64>]) -> CorePlanStats + Sync)> =
            if use_bs { Some(&tail_fn) } else { None };
        plan_build_impl(p, q, n_nodes, threads, use_bs, &node_fn, tail)
    }
}

/// Per-node schedule stats, combined across nodes by element-wise max:
/// `t_peak`/`rounds` are identical on every node (structural functions
/// of the composition), and the sparse global phases already combine
/// their per-rank round counts by max.
#[derive(Clone, Copy, Default)]
struct NodeOut {
    t_peak: usize,
    rounds: usize,
    global_rounds: usize,
}

impl NodeOut {
    fn merge(&mut self, o: NodeOut) {
        self.t_peak = self.t_peak.max(o.t_peak);
        self.rounds = self.rounds.max(o.rounds);
        self.global_rounds = self.global_rounds.max(o.global_rounds);
    }
}

/// The two-stage parallel driver shared by the dense and sparse
/// compilers. Stage one runs `node_fn` over contiguous node chunks
/// (each node owns builders `node·Q .. (node+1)·Q` and, for a bruck
/// global, its own Q rows of the bucket-sum matrix — all disjoint).
/// Stage two, when `tail_fn` is given, permutes the builders g-major so
/// each cross-node group `{(k, g) : k}` is one contiguous slice, runs
/// the joint simulations over group chunks, then restores rank order.
/// Worker chunks are contiguous and ascending, so assembly by rank
/// index is trivially deterministic for any thread count.
fn plan_build_impl<T: Clone + Default + Send + Sync>(
    p: usize,
    q: usize,
    n_nodes: usize,
    threads: usize,
    use_bs: bool,
    node_fn: &(dyn Fn(usize, &mut [PlanBuilder], &mut [Vec<T>]) -> NodeOut + Sync),
    tail_fn: Option<&(dyn Fn(usize, &mut [PlanBuilder], &[Vec<T>]) -> CorePlanStats + Sync)>,
) -> (Vec<RankPlan>, usize, usize) {
    let mut bs_full: Vec<Vec<T>> = if use_bs {
        vec![vec![T::default(); n_nodes]; p]
    } else {
        Vec::new()
    };
    let new_node = |node: usize| -> Vec<PlanBuilder> {
        (node * q..(node + 1) * q)
            .map(|r| PlanBuilder::new(r, p))
            .collect()
    };
    let new_node = &new_node;

    let mut agg = NodeOut::default();
    let mut per_node: Vec<Vec<PlanBuilder>> = Vec::with_capacity(n_nodes);
    let workers = threads.max(1).min(n_nodes);
    if workers <= 1 {
        for node in 0..n_nodes {
            let mut nb = new_node(node);
            let mut empty: [Vec<T>; 0] = [];
            let bs_node: &mut [Vec<T>] = if use_bs {
                &mut bs_full[node * q..(node + 1) * q]
            } else {
                &mut empty
            };
            agg.merge(node_fn(node, &mut nb, bs_node));
            per_node.push(nb);
        }
    } else {
        let ranges = chunk_ranges(n_nodes, workers);
        let mut bs_chunks: Vec<&mut [Vec<T>]> = Vec::with_capacity(ranges.len());
        {
            let mut rest: &mut [Vec<T>] = &mut bs_full;
            for r in &ranges {
                let take = if use_bs { (r.end - r.start) * q } else { 0 };
                let (head, tail) = rest.split_at_mut(take);
                bs_chunks.push(head);
                rest = tail;
            }
        }
        let results: Vec<(Vec<Vec<PlanBuilder>>, NodeOut)> = std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .cloned()
                .zip(bs_chunks)
                .map(|(r, mut bs_chunk)| {
                    s.spawn(move || {
                        let mut nodes = Vec::with_capacity(r.end - r.start);
                        let mut agg = NodeOut::default();
                        for (i, node) in r.enumerate() {
                            let mut nb = new_node(node);
                            let mut empty: [Vec<T>; 0] = [];
                            let bs_node: &mut [Vec<T>] = if use_bs {
                                &mut bs_chunk[i * q..(i + 1) * q]
                            } else {
                                &mut empty
                            };
                            agg.merge(node_fn(node, &mut nb, bs_node));
                            nodes.push(nb);
                        }
                        (nodes, agg)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("hier plan worker panicked"))
                .collect()
        });
        for (nodes, out) in results {
            per_node.extend(nodes);
            agg.merge(out);
        }
    }

    if let Some(tail_fn) = tail_fn {
        if n_nodes > 1 {
            // Permute to g-major: by_g[g][m] is rank (m, g)'s builder.
            let mut by_g: Vec<Vec<PlanBuilder>> =
                (0..q).map(|_| Vec::with_capacity(n_nodes)).collect();
            for nb in per_node {
                for (g, b) in nb.into_iter().enumerate() {
                    by_g[g].push(b);
                }
            }
            let bs_ref = &bs_full;
            let tail_workers = threads.max(1).min(q);
            let mut stats: Option<CorePlanStats> = None;
            if tail_workers <= 1 {
                for (g, col) in by_g.iter_mut().enumerate() {
                    stats = Some(tail_fn(g, col, bs_ref));
                }
            } else {
                let ranges = chunk_ranges(q, tail_workers);
                let collected: Vec<Option<CorePlanStats>> = std::thread::scope(|s| {
                    let mut handles = Vec::with_capacity(ranges.len());
                    let mut rest: &mut [Vec<PlanBuilder>] = &mut by_g;
                    for r in ranges {
                        let (head, rest_tail) = rest.split_at_mut(r.end - r.start);
                        rest = rest_tail;
                        handles.push(s.spawn(move || {
                            let mut st = None;
                            for (i, g) in r.enumerate() {
                                st = Some(tail_fn(g, &mut head[i], bs_ref));
                            }
                            st
                        }));
                    }
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("hier tail worker panicked"))
                        .collect()
                });
                stats = collected.into_iter().flatten().last();
            }
            if let Some(st) = stats {
                agg.global_rounds = st.rounds;
                agg.t_peak = agg.t_peak.max(st.t_peak);
            }
            // Restore rank order.
            let mut ranks: Vec<RankPlan> = vec![RankPlan::default(); p];
            for (g, col) in by_g.into_iter().enumerate() {
                for (m, b) in col.into_iter().enumerate() {
                    ranks[m * q + g] = b.finish();
                }
            }
            return (ranks, agg.t_peak, agg.rounds + agg.global_rounds);
        }
    }

    let mut ranks = Vec::with_capacity(p);
    for nb in per_node {
        for b in nb {
            ranks.push(b.finish());
        }
    }
    (ranks, agg.t_peak, agg.rounds + agg.global_rounds)
}

/// Stage one of the dense compiler for a single node: the prepare
/// preamble, the local-phase joint simulation, the own-bucket copy, and
/// the non-Bruck global phase — everything that touches only this
/// node's Q builders (`nb[g]` is rank `node·Q + g`) and Q matrix rows.
/// A `bruck` global level instead records the node's bucket sums in
/// `bs_node` for the cross-node stage the driver runs afterwards.
fn plan_node_dense(
    sizes: &BlockSizes,
    topo: Topology,
    local: LocalAlgo,
    global: GlobalAlgo,
    node: usize,
    nb: &mut [PlanBuilder],
    bs_node: &mut [Vec<u64>],
) -> NodeOut {
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    let base = node * q;
    let mut out = NodeOut::default();

    // Prepare: global allreduce for M + index array write.
    for b in nb.iter_mut() {
        b.mark();
        b.allreduce();
        b.copy(4 * p as u64);
        b.lap(Phase::Prepare);
    }

    // The only slice of the matrix held at a time: this node's rows.
    let rows: Vec<Vec<u64>> = (0..q).map(|g| sizes.row(base + g)).collect();
    // Bytes of rank (node, g)'s slot j after stage 1 of the contract.
    let slot_bytes = |g: usize, j: usize| -> u64 {
        let dest_g = (g + j) % q;
        (0..n_nodes).map(|k| rows[g][topo.rank_of(k, dest_g)]).sum()
    };

    // ---- local phase, one joint simulation per node.
    match local {
        LocalAlgo::Tuna { radix } => {
            assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
            let mut slots: Vec<Vec<u64>> = (0..q)
                .map(|g| (0..q).map(|j| slot_bytes(g, j)).collect())
                .collect();
            let stats = plan_core(nb, base, 1, q, radix, n_nodes, &mut slots, 0, None);
            out.t_peak = stats.t_peak;
            out.rounds = stats.rounds;
        }
        LocalAlgo::Linear => {
            for g in 0..q {
                let b = &mut nb[g];
                b.mark();
                for j in 1..q {
                    let dst = base + (g + j) % q;
                    let src = base + (g + q - j) % q;
                    b.recv(src, j as u32);
                    b.send(dst, j as u32, slot_bytes(g, j));
                }
                b.wait();
                b.lap(Phase::Data);
            }
            out.rounds = 1;
        }
        LocalAlgo::Balanced => {
            for g in 0..q {
                let bytes: Vec<u64> = (0..q).map(|j| slot_bytes(g, j)).collect();
                let order = balanced_order(&bytes);
                let b = &mut nb[g];
                b.mark();
                for &j in &order {
                    let dst = base + (g + j) % q;
                    let src = base + (g + q - j) % q;
                    b.recv(src, j as u32);
                    b.send(dst, j as u32, bytes[j]);
                }
                b.wait();
                b.lap(Phase::Data);
            }
            out.rounds = 1;
        }
    }

    // `bucket_block(g, k, j)` is the size of the j-th (origin-sorted)
    // block of rank (node, g)'s bucket for node `k`.
    let bucket_block = |g: usize, k: usize, j: usize| rows[j][topo.rank_of(k, g)];
    let bucket_sum = |g: usize, k: usize| (0..q).map(|j| bucket_block(g, k, j)).sum::<u64>();

    // Own node's bucket is final: a local copy on every rank.
    for g in 0..q {
        let b = &mut nb[g];
        b.mark();
        b.copy(bucket_sum(g, node));
        b.lap(Phase::Replace);
    }
    if n_nodes == 1 {
        return out;
    }

    // ---- global phase for this node's ranks.
    match global {
        GlobalAlgo::Coalesced { block_count } => {
            assert!(block_count >= 1);
            out.global_rounds = n_nodes - 1;
            for g in 0..q {
                let b = &mut nb[g];
                b.mark();
                let staged: u64 = (0..n_nodes)
                    .filter(|&k| k != node)
                    .map(|k| bucket_sum(g, k))
                    .sum();
                b.copy(staged);
                b.lap(Phase::Rearrange);

                let mut round = 0usize;
                while round < n_nodes - 1 {
                    let batch = block_count.min(n_nodes - 1 - round);
                    for i in 0..batch {
                        let off = round + i + 1;
                        let ndst = (node + n_nodes - off) % n_nodes;
                        let nsrc = (node + off) % n_nodes;
                        let tag = INTER_TAG + off as u32;
                        b.recv(topo.rank_of(nsrc, g), tag);
                        b.send(topo.rank_of(ndst, g), tag, bucket_sum(g, ndst));
                    }
                    b.wait();
                    round += batch;
                }
                b.lap(Phase::InterNode);
            }
        }
        GlobalAlgo::Staggered { block_count } => {
            assert!(block_count >= 1);
            let total_steps = (n_nodes - 1) * q;
            out.global_rounds = total_steps.div_ceil(block_count);
            for g in 0..q {
                let b = &mut nb[g];
                b.mark();
                let mut step = 0usize;
                while step < total_steps {
                    let batch = block_count.min(total_steps - step);
                    for i in 0..batch {
                        let idx = step + i;
                        let off = idx / q + 1;
                        let j = idx % q;
                        let ndst = (node + n_nodes - off) % n_nodes;
                        let nsrc = (node + off) % n_nodes;
                        let tag = INTER_TAG + idx as u32;
                        b.recv(topo.rank_of(nsrc, g), tag);
                        b.send(topo.rank_of(ndst, g), tag, bucket_block(g, ndst, j));
                    }
                    b.wait();
                    step += batch;
                }
                b.lap(Phase::InterNode);
            }
        }
        GlobalAlgo::Linear => {
            out.global_rounds = 1;
            for g in 0..q {
                let b = &mut nb[g];
                b.mark();
                for off in 1..n_nodes {
                    let ndst = (node + n_nodes - off) % n_nodes;
                    let nsrc = (node + off) % n_nodes;
                    let tag = INTER_TAG + off as u32;
                    b.recv(topo.rank_of(nsrc, g), tag);
                    b.send(topo.rank_of(ndst, g), tag, bucket_sum(g, ndst));
                }
                b.wait();
                b.lap(Phase::InterNode);
            }
        }
        GlobalAlgo::Bruck { .. } => {
            for g in 0..q {
                for k in 0..n_nodes {
                    bs_node[g][k] = bucket_sum(g, k);
                }
            }
        }
    }
    out
}

/// Stage one of the sparse compiler for a single node — the sparse
/// analog of [`plan_node_dense`], with every schedule derived from the
/// structural entries only: op counts scale with the node's nonzeros,
/// and the event/predicate helpers are the very functions the threaded
/// runner calls.
fn plan_node_sparse(
    sizes: &BlockSizes,
    topo: Topology,
    local: LocalAlgo,
    global: GlobalAlgo,
    node: usize,
    nb: &mut [PlanBuilder],
    bs_node: &mut [Vec<(u64, u32)>],
) -> NodeOut {
    let p = topo.p();
    let q = topo.q();
    let n_nodes = topo.nodes();
    let base = node * q;
    let mut out = NodeOut::default();

    for b in nb.iter_mut() {
        b.mark();
        b.allreduce();
        b.copy(4 * p as u64);
        b.lap(Phase::Prepare);
    }

    // One pass over the node's structural entries builds the local
    // slot matrix and the origin-ordered bucket size lists.
    let mut slots: Vec<Vec<(u64, u32)>> = vec![vec![(0u64, 0u32); q]; q];
    let mut bucket_entries: Vec<Vec<Vec<u64>>> = vec![vec![Vec::new(); n_nodes]; q];
    for j in 0..q {
        for (dst, val) in sizes.row_view(base + j).entries() {
            let dest_g = topo.group_rank(dst);
            let k = topo.node_of(dst);
            let slot_j = (dest_g + q - j) % q;
            slots[j][slot_j].0 += val;
            slots[j][slot_j].1 += 1;
            bucket_entries[dest_g][k].push(val);
        }
    }

    // ---- local phase.
    match local {
        LocalAlgo::Tuna { radix } => {
            assert!((2..=q).contains(&radix), "intra radix must be in [2, Q]");
            let stats = plan_core_sparse(nb, base, 1, q, radix, &mut slots, 0, None);
            out.t_peak = stats.t_peak;
            out.rounds = stats.rounds;
        }
        LocalAlgo::Linear => {
            for g in 0..q {
                let b = &mut nb[g];
                b.mark();
                for j in 1..q {
                    let dst = base + (g + j) % q;
                    let src_g = (g + q - j) % q;
                    if slots[src_g][j].1 > 0 {
                        b.recv(base + src_g, j as u32);
                    }
                    if slots[g][j].1 > 0 {
                        b.send(dst, j as u32, slots[g][j].0);
                    }
                }
                b.wait();
                b.lap(Phase::Data);
            }
            out.rounds = 1;
        }
        LocalAlgo::Balanced => {
            for g in 0..q {
                let bytes: Vec<u64> = (0..q).map(|j| slots[g][j].0).collect();
                let order = balanced_order(&bytes);
                let b = &mut nb[g];
                b.mark();
                for &j in &order {
                    let dst = base + (g + j) % q;
                    let src_g = (g + q - j) % q;
                    if slots[src_g][j].1 > 0 {
                        b.recv(base + src_g, j as u32);
                    }
                    if slots[g][j].1 > 0 {
                        b.send(dst, j as u32, bytes[j]);
                    }
                }
                b.wait();
                b.lap(Phase::Data);
            }
            out.rounds = 1;
        }
    }

    let bucket_sum = |g: usize, k: usize| bucket_entries[g][k].iter().sum::<u64>();

    // Own node's bucket is final.
    for g in 0..q {
        let b = &mut nb[g];
        b.mark();
        b.copy(bucket_sum(g, node));
        b.lap(Phase::Replace);
    }
    if n_nodes == 1 {
        return out;
    }

    // ---- global phase for this node's ranks, structural events only.
    match global {
        GlobalAlgo::Coalesced { block_count } => {
            assert!(block_count >= 1);
            for g in 0..q {
                let me = base + g;
                let b = &mut nb[g];
                b.mark();
                let staged: u64 = (0..n_nodes)
                    .filter(|&k| k != node)
                    .map(|k| bucket_sum(g, k))
                    .sum();
                b.copy(staged);
                b.lap(Phase::Rearrange);

                let recv_nodes = sparse_sender_nodes(sizes, &topo, me);
                let events = sparse_node_events(
                    &topo,
                    me,
                    |k| !bucket_entries[g][k].is_empty(),
                    &recv_nodes,
                );
                let mut i = 0usize;
                while i < events.len() {
                    let batch = block_count.min(events.len() - i);
                    for &(off, s, r) in &events[i..i + batch] {
                        let tag = INTER_TAG + off as u32;
                        if let Some(nsrc) = r {
                            b.recv(topo.rank_of(nsrc, g), tag);
                        }
                        if let Some(ndst) = s {
                            b.send(topo.rank_of(ndst, g), tag, bucket_sum(g, ndst));
                        }
                    }
                    b.wait();
                    i += batch;
                }
                b.lap(Phase::InterNode);
                out.global_rounds = out.global_rounds.max(events.len());
            }
        }
        GlobalAlgo::Staggered { block_count } => {
            assert!(block_count >= 1);
            for g in 0..q {
                let me = base + g;
                let b = &mut nb[g];
                b.mark();
                let send_counts: Vec<usize> = (0..n_nodes)
                    .map(|k| if k == node { 0 } else { bucket_entries[g][k].len() })
                    .collect();
                let recv_counts: Vec<usize> = (0..n_nodes)
                    .map(|k| {
                        if k == node {
                            0
                        } else {
                            sparse_senders_in_node(sizes, &topo, me, k).len()
                        }
                    })
                    .collect();
                let events = sparse_stag_events(&topo, me, &send_counts, &recv_counts);
                let mut waits = 0usize;
                let mut i = 0usize;
                while i < events.len() {
                    let batch = block_count.min(events.len() - i);
                    for &(idx, ev) in &events[i..i + batch] {
                        let tag = INTER_TAG + idx as u32;
                        if let Some(nsrc) = ev.recv {
                            b.recv(topo.rank_of(nsrc, g), tag);
                        }
                        if let Some((ndst, pos)) = ev.send {
                            b.send(
                                topo.rank_of(ndst, g),
                                tag,
                                bucket_entries[g][ndst][pos],
                            );
                        }
                    }
                    b.wait();
                    waits += 1;
                    i += batch;
                }
                b.lap(Phase::InterNode);
                out.global_rounds = out.global_rounds.max(waits);
            }
        }
        GlobalAlgo::Linear => {
            out.global_rounds = out.global_rounds.max(1);
            for g in 0..q {
                let me = base + g;
                let b = &mut nb[g];
                b.mark();
                let recv_nodes = sparse_sender_nodes(sizes, &topo, me);
                let events = sparse_node_events(
                    &topo,
                    me,
                    |k| !bucket_entries[g][k].is_empty(),
                    &recv_nodes,
                );
                for &(off, s, r) in &events {
                    let tag = INTER_TAG + off as u32;
                    if let Some(nsrc) = r {
                        b.recv(topo.rank_of(nsrc, g), tag);
                    }
                    if let Some(ndst) = s {
                        b.send(topo.rank_of(ndst, g), tag, bucket_sum(g, ndst));
                    }
                }
                b.wait();
                b.lap(Phase::InterNode);
            }
        }
        GlobalAlgo::Bruck { .. } => {
            for g in 0..q {
                for k in 0..n_nodes {
                    if k != node {
                        bs_node[g][k] =
                            (bucket_sum(g, k), bucket_entries[g][k].len() as u32);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::AlgoKind;
    use crate::comm::{Engine, Topology};
    use crate::model::MachineProfile;
    use crate::util::prop::forall;
    use crate::workload::{BlockSizes, Dist};

    fn run_kind(
        p: usize,
        q: usize,
        kind: AlgoKind,
        dist: Dist,
        seed: u64,
    ) -> crate::algos::RunReport {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, dist, seed);
        crate::algos::run_alltoallv(&e, &kind, &sizes, true).expect("hier run must validate")
    }

    fn run_case(
        p: usize,
        q: usize,
        r: usize,
        bc: usize,
        coalesced: bool,
        dist: Dist,
        seed: u64,
    ) -> crate::algos::RunReport {
        let kind = if coalesced {
            AlgoKind::hier_coalesced(r, bc)
        } else {
            AlgoKind::hier_staggered(r, bc)
        };
        run_kind(p, q, kind, dist, seed)
    }

    #[test]
    fn coalesced_basic() {
        run_case(8, 4, 2, 1, true, Dist::Uniform { max: 256 }, 1);
        run_case(12, 4, 4, 2, true, Dist::Uniform { max: 256 }, 2);
        run_case(16, 4, 2, 3, true, Dist::Uniform { max: 128 }, 3);
    }

    #[test]
    fn staggered_basic() {
        run_case(8, 4, 2, 1, false, Dist::Uniform { max: 256 }, 1);
        run_case(12, 4, 3, 5, false, Dist::Uniform { max: 256 }, 2);
        run_case(16, 4, 4, 64, false, Dist::Uniform { max: 128 }, 3);
    }

    #[test]
    fn every_local_global_composition_validates() {
        let (p, q) = (12usize, 4usize);
        let n = p / q;
        let locals = [
            LocalAlgo::Tuna { radix: 2 },
            LocalAlgo::Tuna { radix: 4 },
            LocalAlgo::Linear,
        ];
        for local in locals {
            for global in [
                GlobalAlgo::Coalesced { block_count: 2 },
                GlobalAlgo::Staggered { block_count: 3 },
                GlobalAlgo::Linear,
                GlobalAlgo::Bruck { radix: 2 },
                GlobalAlgo::Bruck { radix: n },
            ] {
                let kind = AlgoKind::Hier { local, global };
                let rep = run_kind(p, q, kind, Dist::Uniform { max: 128 }, 5);
                assert!(rep.validated, "{}", kind.name());
            }
        }
    }

    #[test]
    fn single_node_degenerates_to_local_only() {
        for local in [LocalAlgo::Tuna { radix: 2 }, LocalAlgo::Linear] {
            let globals = [
                GlobalAlgo::Coalesced { block_count: 1 },
                GlobalAlgo::Bruck { radix: 2 },
            ];
            for global in globals {
                let rep = run_kind(
                    6,
                    6,
                    AlgoKind::Hier { local, global },
                    Dist::Uniform { max: 64 },
                    4,
                );
                assert!(rep.validated);
            }
        }
    }

    #[test]
    fn two_ranks_per_node() {
        run_case(8, 2, 2, 1, true, Dist::Uniform { max: 64 }, 5);
        run_case(8, 2, 2, 2, false, Dist::Uniform { max: 64 }, 5);
        run_kind(
            8,
            2,
            AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
            Dist::Uniform { max: 64 },
            5,
        );
    }

    #[test]
    fn nonuniform_distributions_validate() {
        for dist in [
            Dist::normal_default(),
            Dist::powerlaw_default(),
            Dist::FftN1,
            Dist::FftN2,
        ] {
            run_case(16, 4, 3, 2, true, dist, 7);
            run_case(16, 4, 3, 7, false, dist, 7);
            run_kind(
                16,
                4,
                AlgoKind::Hier { local: LocalAlgo::Linear, global: GlobalAlgo::Bruck { radix: 2 } },
                dist,
                7,
            );
        }
    }

    #[test]
    fn property_random_compositions_validate() {
        forall("hier compositions validate", 24, |rng| {
            let q = 2 + rng.next_below(5) as usize; // 2..=6
            let n = 2 + rng.next_below(4) as usize; // 2..=5 nodes
            let p = q * n;
            let kind = random_composition(rng, q, n);
            let rep = run_kind(p, q, kind, Dist::Uniform { max: 128 }, rng.next_u64());
            if rep.validated {
                Ok(())
            } else {
                Err(format!("P={p} Q={q} {}", kind.name()))
            }
        });
    }

    #[test]
    fn coalesced_fewer_inter_messages_than_staggered() {
        let p = 16;
        let q = 4;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 512 }, 0);
        let co = crate::algos::run_alltoallv(&e, &AlgoKind::hier_coalesced(2, 1), &sizes, false)
            .unwrap();
        let st = crate::algos::run_alltoallv(&e, &AlgoKind::hier_staggered(2, 1), &sizes, false)
            .unwrap();
        // Staggered sends Q times as many inter-node data messages: the
        // difference over coalesced is exactly P * (N-1) * (Q-1) extra
        // (both also share the prepare-phase allreduce traffic).
        let n_nodes = p / q;
        let extra = (p * (n_nodes - 1) * (q - 1)) as u64;
        assert_eq!(
            st.counters.msgs_global - co.counters.msgs_global,
            extra,
            "staggered {} vs coalesced {} global msgs",
            st.counters.msgs_global,
            co.counters.msgs_global
        );
        // Both move the same payload bytes across nodes.
        assert_eq!(st.counters.bytes_global, co.counters.bytes_global);
    }

    #[test]
    fn bruck_global_trades_messages_for_forwarded_bytes() {
        // Log-radix inter-node exchange: fewer node-level messages per
        // rank than the N-1 of the linear/coalesced schedules, at the
        // cost of forwarding bucket bytes through intermediate nodes.
        let p = 32;
        let q = 4; // N = 8 nodes
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 256 }, 0);
        let lin = crate::algos::run_alltoallv(
            &e,
            &AlgoKind::Hier { local: LocalAlgo::Tuna { radix: 2 }, global: GlobalAlgo::Linear },
            &sizes,
            false,
        )
        .unwrap();
        let brk = crate::algos::run_alltoallv(
            &e,
            &AlgoKind::Hier {
                local: LocalAlgo::Tuna { radix: 2 },
                global: GlobalAlgo::Bruck { radix: 2 },
            },
            &sizes,
            false,
        )
        .unwrap();
        // log2(8) = 3 rounds of (meta + data) vs 7 one-shot messages:
        // fewer data messages, more forwarded bytes.
        assert!(
            brk.counters.msgs_global < lin.counters.msgs_global,
            "bruck {} msgs vs linear {}",
            brk.counters.msgs_global,
            lin.counters.msgs_global
        );
        assert!(
            brk.counters.bytes_global > lin.counters.bytes_global,
            "bruck must forward more bytes ({} vs {})",
            brk.counters.bytes_global,
            lin.counters.bytes_global
        );
    }

    #[test]
    fn intra_traffic_stays_local() {
        // All local-phase traffic must be intra-node: with N=2 nodes the
        // only global messages are inter-node data + the prepare
        // allreduce.
        let p = 8;
        let q = 4;
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(p, q));
        let sizes = BlockSizes::generate(p, Dist::Const { size: 100 }, 0);
        for local in [LocalAlgo::Tuna { radix: 2 }, LocalAlgo::Linear] {
            let rep = crate::algos::run_alltoallv(
                &e,
                &AlgoKind::Hier { local, global: GlobalAlgo::Coalesced { block_count: 1 } },
                &sizes,
                false,
            )
            .unwrap();
            // Inter-node payload: each rank sends (N-1)=1 message of Q
            // blocks of 100 B = 400 B; total = 8 * 400 = 3200 data bytes.
            // Allreduce adds a few 8 B scalars across nodes.
            let data_global = 8 * 400;
            assert!(rep.counters.bytes_global >= data_global);
            assert!(
                rep.counters.bytes_global <= data_global + 8 * 8 * 4,
                "unexpected global traffic: {}",
                rep.counters.bytes_global
            );
            assert!(rep.counters.bytes_local > 0);
        }
    }

    #[test]
    fn balanced_order_is_heavy_first_and_deterministic() {
        // Slot 0 never participates; heavier slots drain first; byte
        // ties break by ascending slot index so the permutation is a
        // pure function of the counts.
        assert_eq!(balanced_order(&[99, 10, 30, 20]), vec![2, 3, 1]);
        assert_eq!(balanced_order(&[0, 5, 5, 5]), vec![1, 2, 3]);
        assert_eq!(balanced_order(&[7, 0, 0]), vec![1, 2]);
        assert_eq!(balanced_order(&[4]), Vec::<usize>::new());
    }

    #[test]
    fn balanced_local_is_not_parseable() {
        // Persistent-only: the spec never round-trips, so tuning tables
        // and one-shot CLI runs cannot name it.
        let e = LocalAlgo::parse("balanced").unwrap_err().to_string();
        assert!(e.contains("persistent-only"), "{e}");
        let e = AlgoKind::parse("hier:l=balanced,g=linear").unwrap_err().to_string();
        assert!(e.contains("persistent-only"), "{e}");
        assert_eq!(LocalAlgo::Balanced.spec(), "balanced");
        assert!(AlgoKind::Hier {
            local: LocalAlgo::Balanced,
            global: GlobalAlgo::Linear,
        }
        .persistent_only());
    }

    #[test]
    fn sub_spec_parsing_round_trips_and_errors() {
        for local in [LocalAlgo::Tuna { radix: 7 }, LocalAlgo::Linear] {
            assert_eq!(LocalAlgo::parse(&local.spec()).unwrap(), local);
        }
        for global in [
            GlobalAlgo::Coalesced { block_count: 3 },
            GlobalAlgo::Staggered { block_count: 9 },
            GlobalAlgo::Linear,
            GlobalAlgo::Bruck { radix: 4 },
        ] {
            assert_eq!(GlobalAlgo::parse(&global.spec()).unwrap(), global);
        }
        assert!(LocalAlgo::parse("tuna").unwrap_err().to_string().contains("`r`"));
        assert!(GlobalAlgo::parse("coalesced").unwrap_err().to_string().contains("`b`"));
        assert!(LocalAlgo::parse("nope").is_err());
        assert!(GlobalAlgo::parse("nope").is_err());

        let (l, g) = split_spec("l=tuna:r=4,g=coalesced:b=2").unwrap();
        assert_eq!((l.as_str(), g.as_str()), ("tuna:r=4", "coalesced:b=2"));
        let (l, g) = split_spec("g=linear,l=linear").unwrap();
        assert_eq!((l.as_str(), g.as_str()), ("linear", "linear"));
        assert!(split_spec("l=linear").is_err());
        assert!(split_spec("g=linear").is_err());
        assert!(split_spec("bogus").is_err());
        // Duplicate levels are a loud error, never a silent overwrite.
        let e = split_spec("l=tuna:r=8,l=linear,g=linear").unwrap_err().to_string();
        assert!(e.contains("duplicate local"), "{e}");
        let e = split_spec("l=linear,g=linear,g=bruck:r=2").unwrap_err().to_string();
        assert!(e.contains("duplicate global"), "{e}");
    }
}
