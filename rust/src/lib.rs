//! # tuna-alltoall
//!
//! A full reproduction of **"Configurable Non-uniform All-to-all
//! Algorithms"** (Fan, Domke, Ba, Kumar, 2024): the tunable-radix
//! non-uniform all-to-all algorithm **TuNA**, the composable two-level
//! hierarchy **TuNA_l^g** ([`algos::hier`]: any intra-node algorithm
//! paired with any inter-node algorithm, spec `hier:l=…,g=…`; the
//! paper's staggered/coalesced variants are two of its compositions),
//! the linear baselines the paper
//! compares against (spread-out, OpenMPI linear, pairwise, scattered), a
//! hierarchical virtual-time network engine to run them on, the paper's
//! applications (distributed FFT via PJRT-executed Pallas kernels, graph
//! transitive closure), a harness regenerating every evaluation
//! figure (Fig. 7 - Fig. 16), and **TunaSelect**
//! ([`algos::select`]): cost-model-driven auto-selection across every
//! algorithm family, persisted as versioned tuning tables.
//!
//! Phantom (size-only) collectives additionally run in a **plan/replay**
//! execution mode ([`comm::plan`] + [`comm::replay`], selected through
//! [`algos::ExecMode`]): schedules compile from the counts matrix into
//! cached [`comm::CommPlan`]s and replay on a single-threaded
//! discrete-event executor with timing bit-identical to the threaded
//! engine — the lever that makes P = 4096+ model sweeps cheap.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```
//! use tuna::comm::{Engine, Topology};
//! use tuna::model::MachineProfile;
//! use tuna::algos::{self, AlgoKind};
//! use tuna::workload::{BlockSizes, Dist};
//!
//! // 16 ranks, 4 per node, Fugaku-like cost model.
//! let engine = Engine::new(MachineProfile::fugaku(), Topology::new(16, 4));
//! let sizes = BlockSizes::generate(16, Dist::Uniform { max: 1024 }, 42);
//! let report = algos::run_alltoallv(
//!     &engine,
//!     &AlgoKind::Tuna { radix: 4 },
//!     &sizes,
//!     /*real_payloads=*/ true,
//! ).unwrap();
//! assert!(report.validated);
//! println!("simulated time: {:.3} ms", report.makespan * 1e3);
//! ```

// CI enforces `cargo clippy -- -D warnings`; the allows below are
// deliberate crate-wide style choices, not suppressed bugs: the
// simulation code is index-heavy numeric code where explicit ranges
// mirror the paper's per-rank/per-slot formulas, the engine/plan entry
// points intentionally mirror MPI call signatures (many positional
// parameters), and `Clock::new`-style constructors stay explicit rather
// than deriving `Default`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::manual_div_ceil
)]

pub mod algos;
pub mod apps;
pub mod comm;
pub mod coordinator;
pub mod error;
pub mod harness;
pub mod model;
pub mod runtime;
pub mod util;
pub mod workload;

pub use error::{Result, TunaError};
