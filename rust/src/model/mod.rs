//! Machine performance model.
//!
//! The paper evaluates on Polaris (Slingshot Dragonfly, Cray MPICH) and
//! Fugaku (Tofu-D, Fujitsu OpenMPI). We have neither, so the engine runs
//! every rank with a *virtual clock* driven by a hierarchical LogGP-style
//! cost model with an explicit congestion term (see DESIGN.md §2). The same
//! parameters feed the closed-form estimator in [`analytic`].
//!
//! Model per message of `b` bytes on link class L ∈ {local, global}:
//!
//! * sender: `o_send(L)` software overhead, then the tx port serializes the
//!   payload at `b * beta(L) * f_tx(m)` where `m` is the number of sends
//!   outstanding since the last wait (the *burst size* that `block_count`
//!   tunes) and `f_tx` is the congestion factor from [`congestion`];
//! * wire: `alpha(L)` latency;
//! * receiver: the rx port drains matched messages in virtual-arrival order
//!   at `b * beta(L) * f_rx(q)` where `q` is the instantaneous rx queue
//!   depth (incast penalty), plus `o_recv(L)` per message.
//!
//! Local memory movement (packing, buffer rearrangement) costs
//! `bytes / mem_bw` on the rank's own clock.

pub mod analytic;
pub mod congestion;

/// Link class: intra-node shared memory vs inter-node network.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Link {
    Local,
    Global,
}

/// Parameters of the hierarchical LogGP + congestion model. Times in
/// seconds, bandwidths in bytes/second.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Wire latency, intra-node (shared-memory hop).
    pub alpha_l: f64,
    /// Wire latency, inter-node.
    pub alpha_g: f64,
    /// Per-byte time intra-node (1 / shared-memory bandwidth per rank).
    pub beta_l: f64,
    /// Per-byte time inter-node (1 / NIC bandwidth share per rank).
    pub beta_g: f64,
    /// Per-message software overhead on the send side.
    pub o_send_l: f64,
    pub o_send_g: f64,
    /// Per-message software overhead on the receive side.
    pub o_recv_l: f64,
    pub o_recv_g: f64,
    /// Plain memcpy bandwidth for local packing / rearrangement.
    pub mem_bw: f64,
    /// Congestion parameters (see [`congestion`]).
    pub congestion: congestion::CongestionParams,
}

impl MachineProfile {
    #[inline]
    pub fn alpha(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.alpha_l,
            Link::Global => self.alpha_g,
        }
    }

    #[inline]
    pub fn beta(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.beta_l,
            Link::Global => self.beta_g,
        }
    }

    #[inline]
    pub fn o_send(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.o_send_l,
            Link::Global => self.o_send_g,
        }
    }

    #[inline]
    pub fn o_recv(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.o_recv_l,
            Link::Global => self.o_recv_g,
        }
    }

    /// Cost of a local memory copy of `bytes`.
    #[inline]
    pub fn copy_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.mem_bw
    }

    /// Polaris-like profile: Slingshot network — low latency, high
    /// bandwidth, moderate per-message MPI overhead; fast on-node xeon-class
    /// shared memory.
    pub fn polaris() -> MachineProfile {
        MachineProfile {
            name: "polaris",
            alpha_l: 4.0e-7,
            alpha_g: 2.2e-6,
            beta_l: 1.0 / 10.0e9,
            beta_g: 1.0 / 1.5e9,
            o_send_l: 2.5e-7,
            o_send_g: 1.1e-6,
            o_recv_l: 2.5e-7,
            o_recv_g: 1.1e-6,
            mem_bw: 8.0e9,
            congestion: congestion::CongestionParams::polaris(),
        }
    }

    /// Fugaku-like profile: Tofu-D — comparable wire latency but markedly
    /// higher per-message software overhead (the paper's MPI_Alltoallv
    /// baseline is ~8x slower on Fugaku than Polaris at the same P, S), and
    /// lower per-rank injection bandwidth (A64FX, 32 ranks sharing TNIs).
    pub fn fugaku() -> MachineProfile {
        MachineProfile {
            name: "fugaku",
            alpha_l: 6.0e-7,
            alpha_g: 3.0e-6,
            beta_l: 1.0 / 6.0e9,
            beta_g: 1.0 / 0.8e9,
            o_send_l: 4.0e-7,
            o_send_g: 4.5e-6,
            o_recv_l: 4.0e-7,
            o_recv_g: 4.5e-6,
            mem_bw: 5.0e9,
            congestion: congestion::CongestionParams::fugaku(),
        }
    }

    /// A deliberately simple profile for unit tests: alpha/beta/overheads
    /// are round numbers and congestion is off, so expected virtual times
    /// can be computed by hand.
    pub fn test_flat() -> MachineProfile {
        MachineProfile {
            name: "test-flat",
            alpha_l: 1e-6,
            alpha_g: 1e-6,
            beta_l: 1e-9,
            beta_g: 1e-9,
            o_send_l: 1e-7,
            o_send_g: 1e-7,
            o_recv_l: 1e-7,
            o_recv_g: 1e-7,
            mem_bw: 1e9,
            congestion: congestion::CongestionParams::off(),
        }
    }

    pub fn by_name(name: &str) -> Option<MachineProfile> {
        match name {
            "polaris" => Some(Self::polaris()),
            "fugaku" => Some(Self::fugaku()),
            "test-flat" => Some(Self::test_flat()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_gap_present() {
        for p in [MachineProfile::polaris(), MachineProfile::fugaku()] {
            assert!(p.alpha_g > p.alpha_l, "{}: inter latency must exceed intra", p.name);
            assert!(p.beta_g > p.beta_l, "{}: inter byte-cost must exceed intra", p.name);
            assert!(p.o_send_g > p.o_send_l);
        }
    }

    #[test]
    fn fugaku_has_higher_message_overhead_than_polaris() {
        // This asymmetry drives the paper's larger speedups on Fugaku.
        assert!(MachineProfile::fugaku().o_send_g > MachineProfile::polaris().o_send_g);
    }

    #[test]
    fn accessors_match_fields() {
        let p = MachineProfile::test_flat();
        assert_eq!(p.alpha(Link::Local), p.alpha_l);
        assert_eq!(p.alpha(Link::Global), p.alpha_g);
        assert_eq!(p.beta(Link::Local), p.beta_l);
        assert_eq!(p.o_send(Link::Global), p.o_send_g);
        assert_eq!(p.o_recv(Link::Local), p.o_recv_l);
    }

    #[test]
    fn copy_cost_linear() {
        let p = MachineProfile::test_flat();
        assert!((p.copy_cost(1_000_000) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(MachineProfile::by_name("polaris").unwrap().name, "polaris");
        assert_eq!(MachineProfile::by_name("fugaku").unwrap().name, "fugaku");
        assert!(MachineProfile::by_name("summit").is_none());
    }
}
