//! Machine performance model.
//!
//! The paper evaluates on Polaris (Slingshot Dragonfly, Cray MPICH) and
//! Fugaku (Tofu-D, Fujitsu OpenMPI). We have neither, so the engine runs
//! every rank with a *virtual clock* driven by a hierarchical LogGP-style
//! cost model with an explicit congestion term (see DESIGN.md §2). The same
//! parameters feed the closed-form estimator in [`analytic`].
//!
//! Model per message of `b` bytes on link class L ∈ {local, global}:
//!
//! * sender: `o_send(L)` software overhead, then the tx port serializes the
//!   payload at `b * beta(L) * f_tx(m)` where `m` is the number of sends
//!   outstanding since the last wait (the *burst size* that `block_count`
//!   tunes) and `f_tx` is the congestion factor from [`congestion`];
//! * wire: `alpha(L)` latency;
//! * receiver: the rx port drains matched messages in virtual-arrival order
//!   at `b * beta(L) * f_rx(q)` where `q` is the instantaneous rx queue
//!   depth (incast penalty), plus `o_recv(L)` per message.
//!
//! Local memory movement (packing, buffer rearrangement) costs
//! `bytes / mem_bw` on the rank's own clock.

pub mod analytic;
pub mod congestion;

/// Link class: intra-node shared memory vs inter-node network.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Link {
    Local,
    Global,
}

/// Parameters of the hierarchical LogGP + congestion model. Times in
/// seconds, bandwidths in bytes/second.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    pub name: &'static str,
    /// Wire latency, intra-node (shared-memory hop).
    pub alpha_l: f64,
    /// Wire latency, inter-node.
    pub alpha_g: f64,
    /// Per-byte time intra-node (1 / shared-memory bandwidth per rank).
    pub beta_l: f64,
    /// Per-byte time inter-node (1 / NIC bandwidth share per rank).
    pub beta_g: f64,
    /// Per-message software overhead on the send side.
    pub o_send_l: f64,
    pub o_send_g: f64,
    /// Per-message software overhead on the receive side.
    pub o_recv_l: f64,
    pub o_recv_g: f64,
    /// Plain memcpy bandwidth for local packing / rearrangement.
    pub mem_bw: f64,
    /// Congestion parameters (see [`congestion`]).
    pub congestion: congestion::CongestionParams,
}

impl MachineProfile {
    #[inline]
    pub fn alpha(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.alpha_l,
            Link::Global => self.alpha_g,
        }
    }

    #[inline]
    pub fn beta(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.beta_l,
            Link::Global => self.beta_g,
        }
    }

    #[inline]
    pub fn o_send(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.o_send_l,
            Link::Global => self.o_send_g,
        }
    }

    #[inline]
    pub fn o_recv(&self, link: Link) -> f64 {
        match link {
            Link::Local => self.o_recv_l,
            Link::Global => self.o_recv_g,
        }
    }

    /// Cost of a local memory copy of `bytes`.
    #[inline]
    pub fn copy_cost(&self, bytes: u64) -> f64 {
        bytes as f64 / self.mem_bw
    }

    /// Polaris-like profile: Slingshot network — low latency, high
    /// bandwidth, moderate per-message MPI overhead; fast on-node xeon-class
    /// shared memory.
    pub fn polaris() -> MachineProfile {
        MachineProfile {
            name: "polaris",
            alpha_l: 4.0e-7,
            alpha_g: 2.2e-6,
            beta_l: 1.0 / 10.0e9,
            beta_g: 1.0 / 1.5e9,
            o_send_l: 2.5e-7,
            o_send_g: 1.1e-6,
            o_recv_l: 2.5e-7,
            o_recv_g: 1.1e-6,
            mem_bw: 8.0e9,
            congestion: congestion::CongestionParams::polaris(),
        }
    }

    /// Fugaku-like profile: Tofu-D — comparable wire latency but markedly
    /// higher per-message software overhead (the paper's MPI_Alltoallv
    /// baseline is ~8x slower on Fugaku than Polaris at the same P, S), and
    /// lower per-rank injection bandwidth (A64FX, 32 ranks sharing TNIs).
    pub fn fugaku() -> MachineProfile {
        MachineProfile {
            name: "fugaku",
            alpha_l: 6.0e-7,
            alpha_g: 3.0e-6,
            beta_l: 1.0 / 6.0e9,
            beta_g: 1.0 / 0.8e9,
            o_send_l: 4.0e-7,
            o_send_g: 4.5e-6,
            o_recv_l: 4.0e-7,
            o_recv_g: 4.5e-6,
            mem_bw: 5.0e9,
            congestion: congestion::CongestionParams::fugaku(),
        }
    }

    /// A deliberately simple profile for unit tests: alpha/beta/overheads
    /// are round numbers and congestion is off, so expected virtual times
    /// can be computed by hand.
    pub fn test_flat() -> MachineProfile {
        MachineProfile {
            name: "test-flat",
            alpha_l: 1e-6,
            alpha_g: 1e-6,
            beta_l: 1e-9,
            beta_g: 1e-9,
            o_send_l: 1e-7,
            o_send_g: 1e-7,
            o_recv_l: 1e-7,
            o_recv_g: 1e-7,
            mem_bw: 1e9,
            congestion: congestion::CongestionParams::off(),
        }
    }

    pub fn by_name(name: &str) -> Option<MachineProfile> {
        match name {
            "polaris" => Some(Self::polaris()),
            "fugaku" => Some(Self::fugaku()),
            "test-flat" => Some(Self::test_flat()),
            _ => None,
        }
    }

    /// Reject non-finite or out-of-range parameters with a typed
    /// configuration error before they can poison makespans downstream
    /// (a NaN latency turns every virtual time into NaN silently — the
    /// clock never re-checks). Called wherever an engine is built from
    /// caller-supplied parameters: `coordinator::measure`,
    /// `select::measure_parallel`, `ServeConfig::validate` and the
    /// harnesses. Latencies and overheads must be finite and >= 0;
    /// per-byte costs, memory bandwidth and the congestion caps/slopes
    /// must be finite, with `beta`/`mem_bw` strictly positive and the
    /// caps >= 1 (a factor below 1 would make congestion *speed up*
    /// transfers).
    pub fn validate(&self) -> crate::error::Result<()> {
        let bad = |field: &str, v: f64, need: &str| {
            Err(crate::error::TunaError::config(format!(
                "profile {}: {field} = {v} must be {need}",
                self.name
            )))
        };
        for (field, v) in [
            ("alpha_l", self.alpha_l),
            ("alpha_g", self.alpha_g),
            ("o_send_l", self.o_send_l),
            ("o_send_g", self.o_send_g),
            ("o_recv_l", self.o_recv_l),
            ("o_recv_g", self.o_recv_g),
        ] {
            if !v.is_finite() || v < 0.0 {
                return bad(field, v, "finite and >= 0");
            }
        }
        for (field, v) in [
            ("beta_l", self.beta_l),
            ("beta_g", self.beta_g),
            ("mem_bw", self.mem_bw),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return bad(field, v, "finite and > 0");
            }
        }
        let c = &self.congestion;
        for (field, v) in [("gamma_tx", c.gamma_tx), ("gamma_rx", c.gamma_rx)] {
            if !v.is_finite() || v < 0.0 {
                return bad(field, v, "finite and >= 0");
            }
        }
        for (field, v) in [("tx_cap", c.tx_cap), ("rx_cap", c.rx_cap)] {
            if !v.is_finite() || v < 1.0 {
                return bad(field, v, "finite and >= 1");
            }
        }
        if c.p_ref == 0 {
            return Err(crate::error::TunaError::config(format!(
                "profile {}: p_ref must be >= 1",
                self.name
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_gap_present() {
        for p in [MachineProfile::polaris(), MachineProfile::fugaku()] {
            assert!(p.alpha_g > p.alpha_l, "{}: inter latency must exceed intra", p.name);
            assert!(p.beta_g > p.beta_l, "{}: inter byte-cost must exceed intra", p.name);
            assert!(p.o_send_g > p.o_send_l);
        }
    }

    #[test]
    fn fugaku_has_higher_message_overhead_than_polaris() {
        // This asymmetry drives the paper's larger speedups on Fugaku.
        assert!(MachineProfile::fugaku().o_send_g > MachineProfile::polaris().o_send_g);
    }

    #[test]
    fn accessors_match_fields() {
        let p = MachineProfile::test_flat();
        assert_eq!(p.alpha(Link::Local), p.alpha_l);
        assert_eq!(p.alpha(Link::Global), p.alpha_g);
        assert_eq!(p.beta(Link::Local), p.beta_l);
        assert_eq!(p.o_send(Link::Global), p.o_send_g);
        assert_eq!(p.o_recv(Link::Local), p.o_recv_l);
    }

    #[test]
    fn copy_cost_linear() {
        let p = MachineProfile::test_flat();
        assert!((p.copy_cost(1_000_000) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(MachineProfile::by_name("polaris").unwrap().name, "polaris");
        assert_eq!(MachineProfile::by_name("fugaku").unwrap().name, "fugaku");
        assert!(MachineProfile::by_name("summit").is_none());
    }

    #[test]
    fn builtin_profiles_validate() {
        for p in [
            MachineProfile::polaris(),
            MachineProfile::fugaku(),
            MachineProfile::test_flat(),
        ] {
            p.validate().unwrap_or_else(|e| panic!("{}: {e}", p.name));
        }
    }

    #[test]
    fn validate_rejects_every_poisoned_field() {
        // Each (mutator, field name) poisons exactly one parameter; each
        // must come back as a typed configuration error naming it.
        type Mut = fn(&mut MachineProfile);
        let cases: Vec<(Mut, &str)> = vec![
            (|p| p.alpha_l = f64::NAN, "alpha_l"),
            (|p| p.alpha_g = f64::INFINITY, "alpha_g"),
            (|p| p.alpha_g = -1e-6, "alpha_g"),
            (|p| p.beta_l = 0.0, "beta_l"),
            (|p| p.beta_g = -1e-9, "beta_g"),
            (|p| p.beta_g = f64::NAN, "beta_g"),
            (|p| p.o_send_l = f64::NAN, "o_send_l"),
            (|p| p.o_send_g = -1.0, "o_send_g"),
            (|p| p.o_recv_l = f64::INFINITY, "o_recv_l"),
            (|p| p.o_recv_g = f64::NAN, "o_recv_g"),
            (|p| p.mem_bw = 0.0, "mem_bw"),
            (|p| p.mem_bw = f64::NEG_INFINITY, "mem_bw"),
            (|p| p.congestion.gamma_tx = -0.1, "gamma_tx"),
            (|p| p.congestion.gamma_rx = f64::NAN, "gamma_rx"),
            (|p| p.congestion.tx_cap = 0.5, "tx_cap"),
            (|p| p.congestion.rx_cap = f64::NAN, "rx_cap"),
        ];
        for (mutate, field) in cases {
            let mut p = MachineProfile::fugaku();
            mutate(&mut p);
            let e = p.validate().unwrap_err().to_string();
            assert!(e.contains("configuration"), "{field}: {e}");
            assert!(e.contains(field), "error should name `{field}`: {e}");
        }
        let mut p = MachineProfile::fugaku();
        p.congestion.p_ref = 0;
        let e = p.validate().unwrap_err().to_string();
        assert!(e.contains("p_ref"), "{e}");
    }
}
