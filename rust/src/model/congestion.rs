//! Congestion terms of the cost model.
//!
//! Pure endpoint LogGP cannot explain two effects the paper's evaluation
//! hinges on:
//!
//! 1. **Burst congestion** (`block_count` in the scattered algorithm and in
//!    the inter-node phase of TuNA_l^g): posting a large batch of
//!    simultaneous inter-node messages degrades effective bandwidth because
//!    the flows contend inside the network. We model the tx-side effective
//!    per-byte cost as
//!    `beta * f_tx(m) = beta * (1 + gamma_tx * max(0, m - knee) * scale(P))`
//!    where `m` is the number of sends outstanding since the last wait and
//!    `scale(P) = P / p_ref` captures that contention worsens with the
//!    total number of concurrent flows in the network. Together with the
//!    per-batch latency term this yields the U-shaped block_count curves of
//!    Fig. 10/12 and the "ideal block_count shrinks with S and P" trend.
//!
//! 2. **Incast** (OpenMPI's ascending linear algorithm): when many senders
//!    target one receiver simultaneously the rx queue builds up and drain
//!    bandwidth degrades: `beta * f_rx(q) = beta * (1 + gamma_rx * max(0,
//!    q - rx_knee))` with `q` the instantaneous queue depth at the rx port.
//!
//! Both factors apply to inter-node links only; intra-node transfers go
//! through shared memory where the fabric contention mechanism does not
//! exist (NUMA contention is folded into `beta_l`).

/// Tunable congestion parameters; see module docs for semantics.
#[derive(Clone, Debug)]
pub struct CongestionParams {
    /// Bandwidth-degradation slope per outstanding send beyond the knee.
    pub gamma_tx: f64,
    /// Outstanding-send count below which no tx congestion occurs.
    pub tx_knee: u32,
    /// Reference process count for the network-load scale factor.
    pub p_ref: u32,
    /// Cap on the tx factor (fabrics do not degrade unboundedly).
    pub tx_cap: f64,
    /// Incast degradation slope per queued message beyond the knee.
    pub gamma_rx: f64,
    /// Queue depth below which the rx port drains at full speed.
    pub rx_knee: u32,
    /// Cap on the rx factor.
    pub rx_cap: f64,
}

impl CongestionParams {
    /// No congestion at all — for hand-computable unit tests.
    pub fn off() -> CongestionParams {
        CongestionParams {
            gamma_tx: 0.0,
            tx_knee: u32::MAX,
            p_ref: 1024,
            tx_cap: 1.0,
            gamma_rx: 0.0,
            rx_knee: u32::MAX,
            rx_cap: 1.0,
        }
    }

    /// Dragonfly (Polaris): adaptive routing absorbs moderate bursts; the
    /// knee is relatively high and slopes gentle.
    pub fn polaris() -> CongestionParams {
        CongestionParams {
            gamma_tx: 0.0025,
            tx_knee: 16,
            p_ref: 1024,
            tx_cap: 24.0,
            gamma_rx: 0.06,
            rx_knee: 8,
            rx_cap: 12.0,
        }
    }

    /// 6D-torus Tofu-D (Fugaku): static routing, lower path diversity —
    /// bursts hurt earlier and harder.
    pub fn fugaku() -> CongestionParams {
        CongestionParams {
            gamma_tx: 0.006,
            tx_knee: 8,
            p_ref: 1024,
            tx_cap: 48.0,
            gamma_rx: 0.10,
            rx_knee: 6,
            rx_cap: 16.0,
        }
    }

    /// Effective tx bandwidth-degradation factor for a message posted while
    /// `outstanding` sends are already in flight from this rank, in a job
    /// of `p` total ranks.
    #[inline]
    pub fn tx_factor(&self, outstanding: u32, p: u32) -> f64 {
        let excess = outstanding.saturating_sub(self.tx_knee) as f64;
        if excess == 0.0 || self.gamma_tx == 0.0 {
            return 1.0;
        }
        let scale = (p as f64 / self.p_ref as f64).max(0.125);
        (1.0 + self.gamma_tx * excess * scale).min(self.tx_cap)
    }

    /// Effective rx drain-degradation factor at queue depth `depth`.
    #[inline]
    pub fn rx_factor(&self, depth: u32) -> f64 {
        let excess = depth.saturating_sub(self.rx_knee) as f64;
        if excess == 0.0 || self.gamma_rx == 0.0 {
            return 1.0;
        }
        (1.0 + self.gamma_rx * excess).min(self.rx_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_identity() {
        let c = CongestionParams::off();
        assert_eq!(c.tx_factor(10_000, 16384), 1.0);
        assert_eq!(c.rx_factor(10_000), 1.0);
    }

    #[test]
    fn tx_factor_monotone_in_outstanding() {
        let c = CongestionParams::fugaku();
        let mut last = 0.0;
        for m in [0u32, 8, 16, 64, 256, 1024] {
            let f = c.tx_factor(m, 4096);
            assert!(f >= last, "tx_factor must be monotone");
            last = f;
        }
        assert!(c.tx_factor(0, 4096) == 1.0);
    }

    #[test]
    fn tx_factor_scales_with_p() {
        let c = CongestionParams::fugaku();
        assert!(c.tx_factor(64, 16384) > c.tx_factor(64, 1024));
    }

    #[test]
    fn tx_factor_capped() {
        let c = CongestionParams::fugaku();
        assert!(c.tx_factor(u32::MAX, u32::MAX) <= c.tx_cap);
    }

    #[test]
    fn rx_factor_knee_and_cap() {
        let c = CongestionParams::polaris();
        assert_eq!(c.rx_factor(c.rx_knee), 1.0);
        assert!(c.rx_factor(c.rx_knee + 10) > 1.0);
        assert!(c.rx_factor(100_000) <= c.rx_cap);
    }

    #[test]
    fn fugaku_congests_earlier_than_polaris() {
        let f = CongestionParams::fugaku();
        let p = CongestionParams::polaris();
        assert!(f.tx_factor(64, 4096) > p.tx_factor(64, 4096));
    }
}
