//! Closed-form (single-rank replay) cost estimates.
//!
//! The threaded engine is exact but runs one OS thread per rank — fine up
//! to a few thousand ranks on this host, not for the paper's P = 16,384
//! sweeps (and linear algorithms are O(P²) messages). The estimator
//! replays *one representative rank* (rank 0) against the same
//! [`Clock`]/[`MachineProfile`] cost primitives the engine uses, mirroring
//! inbound traffic from the rank's own outbound schedule (valid for the
//! statistically symmetric workloads of the evaluation; skewed
//! distributions are run on the engine instead). Validated against the
//! engine in `tests/analytic_vs_engine.rs` — see DESIGN.md §6 (4).

use crate::algos::{radix, tuning, AlgoKind, GlobalAlgo, LocalAlgo, VENDOR_BLOCK_COUNT};
use crate::comm::clock::Clock;
use crate::comm::{Phase, PhaseBreakdown, Topology};
use crate::model::{Link, MachineProfile};
use crate::workload::BlockSizes;

/// Analytic estimate: simulated seconds plus a phase breakdown.
#[derive(Clone, Debug)]
pub struct Estimate {
    pub makespan: f64,
    pub phases: PhaseBreakdown,
}

/// Sparsity-aware workload summary consumed by
/// [`Estimator::estimate_shape`] and the selector: enough structure to
/// rank sparse workloads sensibly without touching the matrix again.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadShape {
    /// Mean block size over all P² pairs (absent entries count as 0) —
    /// the quantity the dense estimator always keyed on.
    pub mean_block: f64,
    /// Mean size of the structural entries alone (== `mean_block` for
    /// dense workloads).
    pub mean_structural: f64,
    /// Mean structural destinations per row (P for dense workloads).
    pub nnz_row: f64,
    /// Structural sparsity: absent pairs exchange nothing at all, and
    /// the sparse-aware schedules skip them.
    pub sparse: bool,
}

impl WorkloadShape {
    /// Summarize a workload — one sampled pass over the row views
    /// ([`BlockSizes::shape_stats`]), not three.
    pub fn of(sizes: &BlockSizes) -> WorkloadShape {
        let (mean_block, mean_structural, nnz_row) = sizes.shape_stats();
        WorkloadShape {
            mean_block,
            mean_structural,
            nnz_row,
            sparse: sizes.is_sparse(),
        }
    }

    /// A dense shape from a bare per-pair mean — what every pre-sparsity
    /// call site supplies; routed to the unchanged dense estimator.
    pub fn dense(mean_block: f64) -> WorkloadShape {
        WorkloadShape {
            mean_block,
            mean_structural: mean_block,
            nnz_row: f64::INFINITY,
            sparse: false,
        }
    }
}

/// Single-rank replay estimator.
pub struct Estimator<'a> {
    pub profile: &'a MachineProfile,
    pub topo: Topology,
}

impl<'a> Estimator<'a> {
    pub fn new(profile: &'a MachineProfile, topo: Topology) -> Self {
        Estimator { profile, topo }
    }

    /// Estimate the makespan of `kind` on a workload with mean block size
    /// `mean_block` bytes (per source-destination pair).
    pub fn estimate(&self, kind: &AlgoKind, mean_block: f64) -> Estimate {
        match *kind {
            AlgoKind::SpreadOut => self.linear(mean_block, usize::MAX, false),
            AlgoKind::OmpiLinear => self.linear(mean_block, usize::MAX, true),
            AlgoKind::Scattered { block_count } => self.linear(mean_block, block_count, false),
            AlgoKind::Vendor => self.linear(mean_block, VENDOR_BLOCK_COUNT, false),
            AlgoKind::Pairwise => self.pairwise(mean_block),
            AlgoKind::Bruck2 => self.tuna(mean_block, 2),
            AlgoKind::Tuna { radix } => self.tuna(mean_block, radix),
            AlgoKind::TunaAuto => {
                self.tuna(mean_block, tuning::heuristic_radix(self.topo.p(), mean_block))
            }
            AlgoKind::Hier { local, global } => self.hier(mean_block, local, global),
        }
    }

    /// Shape-aware estimate. Dense shapes take the exact dense paths
    /// (bit-identical to [`Estimator::estimate`], which the golden
    /// snapshots pin); sparse shapes model the *sparse-aware* schedules —
    /// linear families send ~nnz messages instead of P−1, the
    /// hierarchical global phase ships only expectedly non-empty node
    /// buckets, and the log families keep their structural round count
    /// with volume scaled by the per-pair mean.
    pub fn estimate_shape(&self, kind: &AlgoKind, shape: &WorkloadShape) -> Estimate {
        if !shape.sparse {
            return self.estimate(kind, shape.mean_block);
        }
        let p = self.topo.p();
        let nnz = shape.nnz_row.max(0.0).min(p as f64);
        let s_nz = shape.mean_structural.max(0.0);
        match *kind {
            AlgoKind::SpreadOut => self.linear_sparse(s_nz, nnz, usize::MAX, false),
            AlgoKind::OmpiLinear => self.linear_sparse(s_nz, nnz, usize::MAX, true),
            AlgoKind::Scattered { block_count } => {
                self.linear_sparse(s_nz, nnz, block_count, false)
            }
            AlgoKind::Vendor => self.linear_sparse(s_nz, nnz, VENDOR_BLOCK_COUNT, false),
            AlgoKind::Pairwise => self.linear_sparse(s_nz, nnz, 1, false),
            // Log families run their structural schedule regardless of
            // sparsity; per-round volume scales through the per-pair
            // mean, which the dense formulas already key on.
            AlgoKind::Bruck2 => self.tuna(shape.mean_block, 2),
            AlgoKind::Tuna { radix } => self.tuna(shape.mean_block, radix),
            AlgoKind::TunaAuto => self.tuna(
                shape.mean_block,
                tuning::heuristic_radix(p, shape.mean_block),
            ),
            AlgoKind::Hier { local, global } => {
                self.hier_sparse(shape.mean_block, s_nz, nnz, local, global)
            }
        }
    }

    /// Shape-aware estimate under fault injection: the healthy estimate
    /// scaled by [`crate::comm::FaultModel::analytic_slowdown`] —
    /// `makespan * mult + add`, where `mult` bounds the worst
    /// multiplicative clause (straggler CPU, link bandwidth/latency,
    /// jitter expectation) and `add` sums outage windows. Deliberately
    /// coarse: the estimator replays one representative rank, so it
    /// cannot localize a fault to the afflicted rank's critical path —
    /// the exact executors do that; this arm only keeps beyond-budget
    /// rankings fault-aware. Phase breakdowns are left unscaled (the
    /// slowdown is not attributable to a single phase).
    pub fn estimate_shape_faulted(
        &self,
        kind: &AlgoKind,
        shape: &WorkloadShape,
        faults: Option<&crate::comm::FaultModel>,
    ) -> Estimate {
        let mut est = self.estimate_shape(kind, shape);
        if let Some(model) = faults.filter(|m| !m.is_empty()) {
            let (mult, add) = model.analytic_slowdown();
            est.makespan = est.makespan * mult + add;
        }
        est
    }

    /// Estimate of the segmented overlap driver
    /// (`algos::run_alltoallv_segmented`): split the workload into
    /// `segments` equal chunks, estimate one chunk, then apply the
    /// per-segment overlap term
    /// `effective = max(comm, compute) + exposed remainder`.
    ///
    /// The **overlappable window** `w` of a segment is family-specific —
    /// the stitch hides only the final `Wait` batch of each chunk plan
    /// behind the next segment's compute:
    ///
    /// * single-burst linear (spread-out, ompi-linear): the whole data
    ///   phase is one batch — fully overlappable;
    /// * batched linear (scattered/vendor): one batch of
    ///   ⌈(P−1)/b⌉;
    /// * pairwise: one synchronized round of P−1;
    /// * bruck/tuna: one round of the radix schedule;
    /// * hierarchical: one batch/round of the *inter-node* phase (the
    ///   whole data phase when N = 1).
    ///
    /// With `overlap=false` the blocking stitch costs
    /// `K·(compute + t_seg)`; pipelined it costs
    /// `c + K·(t_seg − w) + (K−1)·max(c, w) + w` — at K = 1 both reduce
    /// to `c + t_seg`. This is what lets a fully overlappable
    /// latency-heavy family legitimately outrank the blocking winner
    /// once per-segment compute covers its window (the selector's
    /// `overlap=` mode, `algos::select`).
    pub fn estimate_segmented(
        &self,
        kind: &AlgoKind,
        shape: &WorkloadShape,
        segments: usize,
        overlap: bool,
        compute: f64,
    ) -> Estimate {
        let k = segments.max(1) as f64;
        let seg_shape = WorkloadShape {
            mean_block: shape.mean_block / k,
            mean_structural: shape.mean_structural / k,
            nnz_row: shape.nnz_row,
            sparse: shape.sparse,
        };
        let seg = self.estimate_shape(kind, &seg_shape);
        let t_seg = seg.makespan;
        let c = compute.max(0.0);
        let makespan = if !overlap {
            k * (c + t_seg)
        } else {
            let w = self.overlappable_window(kind, seg_shape.mean_block, &seg)
                .clamp(0.0, t_seg);
            let a = t_seg - w; // exposed per segment regardless of compute
            c + k * a + (k - 1.0) * c.max(w) + w
        };
        let mut phases = seg.phases;
        for s in phases.secs.iter_mut() {
            *s *= k;
        }
        phases.add(crate::comm::Phase::Compute, k * c);
        Estimate { makespan, phases }
    }

    /// [`Estimator::estimate_segmented`] under fault injection — the same
    /// coarse `makespan * mult + add` scaling as
    /// [`Estimator::estimate_shape_faulted`].
    pub fn estimate_segmented_faulted(
        &self,
        kind: &AlgoKind,
        shape: &WorkloadShape,
        segments: usize,
        overlap: bool,
        compute: f64,
        faults: Option<&crate::comm::FaultModel>,
    ) -> Estimate {
        let mut est = self.estimate_segmented(kind, shape, segments, overlap, compute);
        if let Some(model) = faults.filter(|m| !m.is_empty()) {
            let (mult, add) = model.analytic_slowdown();
            est.makespan = est.makespan * mult + add;
        }
        est
    }

    /// The slice of one segment's estimate that the pipelined stitch can
    /// hide behind the next segment's compute (see
    /// [`Estimator::estimate_segmented`]).
    fn overlappable_window(&self, kind: &AlgoKind, seg_mean: f64, seg: &Estimate) -> f64 {
        let p = self.topo.p();
        let q = self.topo.q();
        let n = self.topo.nodes();
        let batches = |units: usize, per: usize| -> f64 {
            (units.div_ceil(per.max(1))).max(1) as f64
        };
        let log_rounds = |r: usize, group: usize| -> f64 {
            radix::rounds(r.clamp(2, group.max(2)), group).len().max(1) as f64
        };
        let data = seg.phases.get(Phase::Data);
        match *kind {
            AlgoKind::SpreadOut | AlgoKind::OmpiLinear => data,
            AlgoKind::Scattered { block_count } => {
                data / batches(p.saturating_sub(1), block_count)
            }
            AlgoKind::Vendor => data / batches(p.saturating_sub(1), VENDOR_BLOCK_COUNT),
            AlgoKind::Pairwise => data / p.saturating_sub(1).max(1) as f64,
            AlgoKind::Bruck2 => data / log_rounds(2, p),
            AlgoKind::Tuna { radix } => data / log_rounds(radix, p),
            AlgoKind::TunaAuto => data / log_rounds(tuning::heuristic_radix(p, seg_mean), p),
            AlgoKind::Hier { global, .. } => {
                if n == 1 {
                    return data;
                }
                let inter = seg.phases.get(Phase::InterNode);
                match global {
                    GlobalAlgo::Bruck { radix } => inter / log_rounds(radix, n),
                    GlobalAlgo::Coalesced { block_count } => {
                        inter / batches(n - 1, block_count)
                    }
                    GlobalAlgo::Staggered { block_count } => {
                        inter / batches((n - 1) * q, block_count)
                    }
                    GlobalAlgo::Linear => inter,
                }
            }
        }
    }

    /// Sparse linear family: ~nnz structural messages (instead of P−1)
    /// of the structural mean size, batched by `block_count`.
    fn linear_sparse(&self, s_nz: f64, nnz: f64, block_count: usize, incast: bool) -> Estimate {
        let p = self.topo.p();
        let msgs = (nnz * (p.saturating_sub(1)) as f64 / p as f64).round() as usize;
        let bytes = s_nz.round() as u64;
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();
        let mut sent = 0usize;
        while sent < msgs {
            let batch = block_count.max(1).min(msgs - sent);
            let mut mirror: Vec<(f64, u64, Link)> = Vec::with_capacity(batch);
            let mut send_done = 0.0f64;
            for i in 0..batch {
                // Structural peers land on arbitrary offsets; spread the
                // link classes like the dense round-robin does.
                let dst = 1 + (sent + i) % (p - 1);
                let link = self.link_to(dst);
                let t = clock.post_send(self.profile, link, bytes, p);
                send_done = send_done.max(t.complete);
                mirror.push((t.arrive, bytes, link));
            }
            if incast {
                let first = mirror.iter().map(|m| m.0).fold(f64::INFINITY, f64::min);
                for m in mirror.iter_mut() {
                    m.0 = first;
                }
            }
            let completions = clock.drain_receives(self.profile, &mirror);
            let last = completions.iter().fold(send_done, |a, &b| a.max(b));
            clock.finish_wait(last);
            sent += batch;
        }
        phases.add(Phase::Data, clock.now);
        Estimate {
            makespan: clock.now,
            phases,
        }
    }

    /// Sparse hierarchical composition: the dense local phase (per-pair
    /// mean already dilutes volume), then a global phase shipping only
    /// the expectedly non-empty node buckets.
    fn hier_sparse(
        &self,
        s: f64,
        s_nz: f64,
        nnz: f64,
        local: LocalAlgo,
        global: GlobalAlgo,
    ) -> Estimate {
        let p = self.topo.p();
        let q = self.topo.q();
        let n = self.topo.nodes();
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();

        let t0 = clock.now;
        self.allreduce_cost(&mut clock);
        clock.charge_copy(self.profile, 4 * p as u64);
        phases.add(Phase::Prepare, clock.now - t0);

        match local {
            LocalAlgo::Tuna { radix } => {
                self.tuna_core_replay(
                    &mut clock,
                    &mut phases,
                    q,
                    radix.clamp(2, q.max(2)),
                    n,
                    s,
                    Some(Link::Local),
                    None,
                );
            }
            // Balanced posts the same Q−1 messages as Linear in a
            // different order; the per-rank expected cost is identical
            // under the mean-size model (the reorder only helps the
            // exact simulation's tail slots).
            LocalAlgo::Linear | LocalAlgo::Balanced => {
                let t1 = clock.now;
                let bytes = (n as f64 * s).round() as u64;
                let mut mirror = Vec::with_capacity(q - 1);
                let mut send_done = 0.0f64;
                for _ in 0..q.saturating_sub(1) {
                    let t = clock.post_send(self.profile, Link::Local, bytes, p);
                    send_done = send_done.max(t.complete);
                    mirror.push((t.arrive, bytes, Link::Local));
                }
                let completions = clock.drain_receives(self.profile, &mirror);
                let last = completions.iter().fold(send_done, |a, &b| a.max(b));
                clock.finish_wait(last);
                phases.add(Phase::Data, clock.now - t1);
            }
        }

        let t1 = clock.now;
        clock.charge_copy(self.profile, (q as f64 * s).round() as u64);
        phases.add(Phase::Replace, clock.now - t1);
        if n == 1 {
            return Estimate {
                makespan: clock.now,
                phases,
            };
        }

        // Expected non-empty foreign buckets per rank: each of the ~nnz
        // structural destinations of each of the node's Q rows lands on a
        // uniform node, so a bucket is empty with probability
        // (1 − 1/P·…)^Q ≈ (1 − nnz/P)^Q.
        let p_bucket = 1.0 - (1.0 - (nnz / p as f64).min(1.0)).powi(q as i32);
        let eff_buckets = (((n - 1) as f64) * p_bucket).ceil() as usize;
        let inter_total = ((n - 1) as f64 * q as f64 * s).round() as u64;

        match global {
            GlobalAlgo::Bruck { radix } => {
                self.tuna_core_replay(
                    &mut clock,
                    &mut phases,
                    n,
                    radix.clamp(2, n.max(2)),
                    q,
                    s,
                    Some(Link::Global),
                    Some(Phase::InterNode),
                );
            }
            GlobalAlgo::Coalesced { .. } | GlobalAlgo::Staggered { .. } | GlobalAlgo::Linear => {
                let (msg_bytes, total_msgs, block_count, rearrange) = match global {
                    GlobalAlgo::Coalesced { block_count } => {
                        let m = eff_buckets.max(usize::from(inter_total > 0));
                        ((inter_total as f64 / m.max(1) as f64).round() as u64, m, block_count, true)
                    }
                    GlobalAlgo::Staggered { block_count } => {
                        let m = ((n - 1) as f64 * q as f64 * (nnz / p as f64)).ceil() as usize;
                        (s_nz.round() as u64, m, block_count, false)
                    }
                    _ => {
                        let m = eff_buckets.max(usize::from(inter_total > 0));
                        ((inter_total as f64 / m.max(1) as f64).round() as u64, m, m.max(1), false)
                    }
                };
                if rearrange {
                    let t2 = clock.now;
                    clock.charge_copy(self.profile, inter_total);
                    phases.add(Phase::Rearrange, clock.now - t2);
                }
                let t3 = clock.now;
                let mut sent = 0usize;
                while sent < total_msgs {
                    let batch = block_count.max(1).min(total_msgs - sent);
                    let mut mirror = Vec::with_capacity(batch);
                    let mut send_done = 0.0f64;
                    for _ in 0..batch {
                        let t = clock.post_send(self.profile, Link::Global, msg_bytes, p);
                        send_done = send_done.max(t.complete);
                        mirror.push((t.arrive, msg_bytes, Link::Global));
                    }
                    let completions = clock.drain_receives(self.profile, &mirror);
                    let last = completions.iter().fold(send_done, |a, &b| a.max(b));
                    clock.finish_wait(last);
                    sent += batch;
                }
                phases.add(Phase::InterNode, clock.now - t3);
            }
        }

        Estimate {
            makespan: clock.now,
            phases,
        }
    }

    fn link_to(&self, dst: usize) -> Link {
        self.topo.link(0, dst % self.topo.p())
    }

    /// Cost of the recursive-doubling allreduce in the prepare phase.
    fn allreduce_cost(&self, clock: &mut Clock) {
        let p = self.topo.p();
        if p == 1 {
            return;
        }
        let rounds = (p as f64).log2().ceil() as usize;
        for k in 0..rounds {
            let partner = 1usize << k;
            let link = self.link_to(partner % p);
            let t = clock.post_send(self.profile, link, 8, p);
            let done = clock.drain_receives(self.profile, &[(t.arrive, 8, link)]);
            clock.finish_wait(done[0].max(t.complete));
        }
    }

    /// Linear family: P−1 destinations in round-robin order, batched by
    /// `block_count` (usize::MAX = single burst). `incast` mirrors the
    /// OpenMPI ascending-order pathology: all inbound messages of a batch
    /// arrive together at the earliest arrival instead of staggered.
    fn linear(&self, s: f64, block_count: usize, incast: bool) -> Estimate {
        let p = self.topo.p();
        let bytes = s.round() as u64;
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();
        let mut sent = 0usize;
        while sent < p - 1 {
            let batch = block_count.min(p - 1 - sent);
            let mut mirror: Vec<(f64, u64, Link)> = Vec::with_capacity(batch);
            let mut send_done = 0.0f64;
            for i in 0..batch {
                let dst = 1 + sent + i; // offsets 1..P-1 round-robin
                let link = self.link_to(dst);
                let t = clock.post_send(self.profile, link, bytes, p);
                send_done = send_done.max(t.complete);
                mirror.push((t.arrive, bytes, link));
            }
            if incast {
                let first = mirror.iter().map(|m| m.0).fold(f64::INFINITY, f64::min);
                for m in mirror.iter_mut() {
                    m.0 = first;
                }
            }
            let completions = clock.drain_receives(self.profile, &mirror);
            let last = completions.iter().fold(send_done, |a, &b| a.max(b));
            clock.finish_wait(last);
            sent += batch;
        }
        phases.add(Phase::Data, clock.now);
        Estimate {
            makespan: clock.now,
            phases,
        }
    }

    /// Pairwise: P−1 synchronized sendrecv rounds.
    fn pairwise(&self, s: f64) -> Estimate {
        let p = self.topo.p();
        let bytes = s.round() as u64;
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();
        for i in 1..p {
            let link = self.link_to(i);
            let t = clock.post_send(self.profile, link, bytes, p);
            let done = clock.drain_receives(self.profile, &[(t.arrive, bytes, link)]);
            clock.finish_wait(done[0].max(t.complete));
        }
        phases.add(Phase::Data, clock.now);
        Estimate {
            makespan: clock.now,
            phases,
        }
    }

    /// TuNA replay over a group of `q` ranks with `arity` sub-blocks of
    /// `s` bytes per slot. `fixed_link` pins the link class of every
    /// round (intra-node groups are all-local, inter-node Q-port groups
    /// all-global); `None` derives it from the round's rank distance (the
    /// flat communicator). `lap` overrides the per-round phase
    /// attribution exactly like the engine's slot core: the inter-node
    /// Bruck exchange charges everything to [`Phase::InterNode`].
    fn tuna_core_replay(
        &self,
        clock: &mut Clock,
        phases: &mut PhaseBreakdown,
        q: usize,
        r: usize,
        arity: usize,
        s: f64,
        fixed_link: Option<Link>,
        lap: Option<Phase>,
    ) {
        let p = self.topo.p();
        let (ph_meta, ph_data, ph_replace) = match lap {
            None => (Phase::Metadata, Phase::Data, Phase::Replace),
            Some(ph) => (ph, ph, ph),
        };
        for rd in radix::rounds(r, q) {
            let slots = radix::offsets_with_digit(rd.x, rd.z, r, q);
            let link = fixed_link.unwrap_or_else(|| self.link_to(rd.step));
            let meta_bytes = 8 * (slots * arity) as u64;
            let data_bytes = ((slots * arity) as f64 * s).round() as u64;

            // Metadata exchange.
            let t0 = clock.now;
            let tm = clock.post_send(self.profile, link, meta_bytes, p);
            let dm = clock.drain_receives(self.profile, &[(tm.arrive, meta_bytes, link)]);
            clock.finish_wait(dm[0].max(tm.complete));
            phases.add(ph_meta, clock.now - t0);

            // Pack, data exchange, unpack.
            let t1 = clock.now;
            clock.charge_copy(self.profile, data_bytes);
            phases.add(ph_replace, clock.now - t1);
            let t2 = clock.now;
            let td = clock.post_send(self.profile, link, data_bytes, p);
            let dd = clock.drain_receives(self.profile, &[(td.arrive, data_bytes, link)]);
            clock.finish_wait(dd[0].max(td.complete));
            phases.add(ph_data, clock.now - t2);
            let t3 = clock.now;
            clock.charge_copy(self.profile, data_bytes);
            phases.add(ph_replace, clock.now - t3);
        }
    }

    /// Flat TuNA (Algorithm 1).
    fn tuna(&self, s: f64, r: usize) -> Estimate {
        let p = self.topo.p();
        let r = r.clamp(2, p.max(2));
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();

        let t0 = clock.now;
        self.allreduce_cost(&mut clock);
        clock.charge_copy(self.profile, 4 * p as u64);
        phases.add(Phase::Prepare, clock.now - t0);

        self.tuna_core_replay(&mut clock, &mut phases, p, r, 1, s, None, None);

        let t1 = clock.now;
        clock.charge_copy(self.profile, s.round() as u64); // self block
        phases.add(Phase::Replace, clock.now - t1);
        Estimate {
            makespan: clock.now,
            phases,
        }
    }

    /// Composable TuNA_l^g: local-phase cost + rearrangement cost +
    /// global-phase cost, mirroring the engine's three-stage contract
    /// (`algos::hier`).
    fn hier(&self, s: f64, local: LocalAlgo, global: GlobalAlgo) -> Estimate {
        let p = self.topo.p();
        let q = self.topo.q();
        let n = self.topo.nodes();
        let mut clock = Clock::new();
        let mut phases = PhaseBreakdown::default();

        let t0 = clock.now;
        self.allreduce_cost(&mut clock);
        clock.charge_copy(self.profile, 4 * p as u64);
        phases.add(Phase::Prepare, clock.now - t0);

        // Local phase over Q ranks; slots carry N sub-blocks of s bytes.
        match local {
            LocalAlgo::Tuna { radix } => {
                self.tuna_core_replay(
                    &mut clock,
                    &mut phases,
                    q,
                    radix.clamp(2, q.max(2)),
                    n,
                    s,
                    Some(Link::Local),
                    None,
                );
            }
            // Balanced = the same burst in heavy-first order; identical
            // expected cost under the mean-size model.
            LocalAlgo::Linear | LocalAlgo::Balanced => {
                // Q-1 direct slot deliveries of N sub-blocks each, one
                // burst, one waitall — no metadata rounds, no T.
                let t1 = clock.now;
                let bytes = (n as f64 * s).round() as u64;
                let mut mirror = Vec::with_capacity(q - 1);
                let mut send_done = 0.0f64;
                for _ in 0..q.saturating_sub(1) {
                    let t = clock.post_send(self.profile, Link::Local, bytes, p);
                    send_done = send_done.max(t.complete);
                    mirror.push((t.arrive, bytes, Link::Local));
                }
                let completions = clock.drain_receives(self.profile, &mirror);
                let last = completions.iter().fold(send_done, |a, &b| a.max(b));
                clock.finish_wait(last);
                phases.add(Phase::Data, clock.now - t1);
            }
        }

        // Own-node bucket delivery.
        let t1 = clock.now;
        clock.charge_copy(self.profile, (q as f64 * s).round() as u64);
        phases.add(Phase::Replace, clock.now - t1);

        if n == 1 {
            return Estimate {
                makespan: clock.now,
                phases,
            };
        }

        // Global phase: batched node-message bursts or a node-level
        // log-radix slot exchange.
        match global {
            GlobalAlgo::Bruck { radix } => {
                self.tuna_core_replay(
                    &mut clock,
                    &mut phases,
                    n,
                    radix.clamp(2, n.max(2)),
                    q,
                    s,
                    Some(Link::Global),
                    Some(Phase::InterNode),
                );
            }
            GlobalAlgo::Coalesced { .. } | GlobalAlgo::Staggered { .. } | GlobalAlgo::Linear => {
                let (msg_bytes, total_msgs, block_count, rearrange) = match global {
                    GlobalAlgo::Coalesced { block_count } => {
                        ((q as f64 * s).round() as u64, n - 1, block_count, true)
                    }
                    GlobalAlgo::Staggered { block_count } => {
                        (s.round() as u64, (n - 1) * q, block_count, false)
                    }
                    // Linear = one full burst of coalesced messages.
                    _ => ((q as f64 * s).round() as u64, n - 1, n - 1, false),
                };
                if rearrange {
                    let t2 = clock.now;
                    let staged = ((n - 1) as f64 * q as f64 * s).round() as u64;
                    clock.charge_copy(self.profile, staged);
                    phases.add(Phase::Rearrange, clock.now - t2);
                }
                let t3 = clock.now;
                let mut sent = 0usize;
                while sent < total_msgs {
                    let batch = block_count.min(total_msgs - sent);
                    let mut mirror = Vec::with_capacity(batch);
                    let mut send_done = 0.0f64;
                    for _ in 0..batch {
                        let t = clock.post_send(self.profile, Link::Global, msg_bytes, p);
                        send_done = send_done.max(t.complete);
                        mirror.push((t.arrive, msg_bytes, Link::Global));
                    }
                    let completions = clock.drain_receives(self.profile, &mirror);
                    let last = completions.iter().fold(send_done, |a, &b| a.max(b));
                    clock.finish_wait(last);
                    sent += batch;
                }
                phases.add(Phase::InterNode, clock.now - t3);
            }
        }

        Estimate {
            makespan: clock.now,
            phases,
        }
    }
}

/// Convenience wrapper.
pub fn estimate(
    profile: &MachineProfile,
    topo: Topology,
    kind: &AlgoKind,
    mean_block: f64,
) -> Estimate {
    Estimator::new(profile, topo).estimate(kind, mean_block)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est(kind: AlgoKind, p: usize, q: usize, s: f64) -> f64 {
        estimate(&MachineProfile::fugaku(), Topology::new(p, q), &kind, s).makespan
    }

    #[test]
    fn estimates_positive_and_finite() {
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::OmpiLinear,
            AlgoKind::Pairwise,
            AlgoKind::Scattered { block_count: 8 },
            AlgoKind::Vendor,
            AlgoKind::Bruck2,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(4, 2),
            AlgoKind::hier_staggered(4, 8),
            AlgoKind::Hier {
                local: crate::algos::LocalAlgo::Linear,
                global: crate::algos::GlobalAlgo::Linear,
            },
            AlgoKind::Hier {
                local: crate::algos::LocalAlgo::Tuna { radix: 2 },
                global: crate::algos::GlobalAlgo::Bruck { radix: 2 },
            },
        ] {
            let t = est(kind, 64, 8, 512.0);
            assert!(t.is_finite() && t > 0.0, "{kind:?}: {t}");
        }
    }

    #[test]
    fn tuna_small_messages_beat_linear() {
        // Latency regime: log rounds must beat P-1 messages.
        let t_tuna = est(AlgoKind::Tuna { radix: 2 }, 4096, 32, 8.0);
        let t_lin = est(AlgoKind::SpreadOut, 4096, 32, 8.0);
        assert!(
            t_tuna < t_lin / 5.0,
            "tuna {t_tuna} should be well under spread-out {t_lin} at S=16"
        );
    }

    #[test]
    fn large_messages_favor_high_radix() {
        // Bandwidth regime: duplicate forwarding hurts radix 2.
        let lo = est(AlgoKind::Tuna { radix: 2 }, 1024, 32, 16384.0);
        let hi = est(AlgoKind::Tuna { radix: 1024 }, 1024, 32, 16384.0);
        assert!(hi < lo, "radix P ({hi}) must beat radix 2 ({lo}) at 16 KiB");
    }

    #[test]
    fn estimator_is_fast_at_paper_scale() {
        // The whole point: a 16,384-rank estimate in well under a second.
        let t0 = std::time::Instant::now();
        let v = est(AlgoKind::Tuna { radix: 128 }, 16384, 32, 512.0);
        assert!(v > 0.0);
        assert!(
            t0.elapsed().as_millis() < 500,
            "estimate took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn dense_shape_routes_to_the_exact_dense_estimator() {
        // WorkloadShape::dense must be bit-identical to estimate(): the
        // golden snapshots pin the dense numbers.
        let prof = MachineProfile::fugaku();
        let est = Estimator::new(&prof, Topology::new(256, 32));
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Pairwise,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(2, 2),
        ] {
            let a = est.estimate(&kind, 777.0).makespan;
            let b = est.estimate_shape(&kind, &WorkloadShape::dense(777.0)).makespan;
            assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}");
        }
    }

    #[test]
    fn sparse_shape_scales_linear_families_with_nnz_not_p() {
        let prof = MachineProfile::fugaku();
        let p = 1024;
        let est = Estimator::new(&prof, Topology::new(p, 32));
        let shape = |nnz: f64| WorkloadShape {
            mean_block: 512.0 * nnz / p as f64,
            mean_structural: 512.0,
            nnz_row: nnz,
            sparse: true,
        };
        let dense = est.estimate(&AlgoKind::SpreadOut, 512.0).makespan;
        let sp8 = est.estimate_shape(&AlgoKind::SpreadOut, &shape(8.0)).makespan;
        let sp64 = est.estimate_shape(&AlgoKind::SpreadOut, &shape(64.0)).makespan;
        assert!(sp8 > 0.0 && sp8.is_finite());
        assert!(
            sp8 < dense / 8.0,
            "8 structural messages ({sp8}) must be far under P-1 dense ({dense})"
        );
        assert!(sp8 < sp64, "estimate must grow with nnz: {sp8} vs {sp64}");
        // Pairwise and scattered take the same structural shrink.
        let pw = est.estimate_shape(&AlgoKind::Pairwise, &shape(8.0)).makespan;
        assert!(pw > 0.0 && pw < est.estimate(&AlgoKind::Pairwise, 512.0).makespan);
    }

    #[test]
    fn sparse_shape_hier_ships_fewer_node_buckets() {
        let prof = MachineProfile::fugaku();
        let (p, q) = (2048usize, 32usize);
        let est = Estimator::new(&prof, Topology::new(p, q));
        let kind = AlgoKind::hier_coalesced(4, 2);
        let shape = WorkloadShape {
            mean_block: 512.0 * 4.0 / p as f64,
            mean_structural: 512.0,
            nnz_row: 4.0,
            sparse: true,
        };
        let sp = est.estimate_shape(&kind, &shape).makespan;
        // Same total volume forced through the dense schedule (N-1
        // buckets per rank) must cost more than the sparse one.
        let dense_same_volume = est.estimate(&kind, shape.mean_block).makespan;
        assert!(sp > 0.0 && sp.is_finite());
        assert!(
            sp < dense_same_volume,
            "sparse hier {sp} must undercut dense-schedule {dense_same_volume}"
        );
        // Log-family estimates stay structural and finite.
        let tn = est
            .estimate_shape(&AlgoKind::Tuna { radix: 4 }, &shape)
            .makespan;
        assert!(tn > 0.0 && tn.is_finite());
    }

    #[test]
    fn faulted_estimate_scales_makespan_coarsely() {
        use crate::comm::{FaultModel, FaultSpec};
        let prof = MachineProfile::fugaku();
        let est = Estimator::new(&prof, Topology::new(256, 32));
        let shape = WorkloadShape::dense(512.0);
        let kind = AlgoKind::Tuna { radix: 4 };
        let healthy = est.estimate_shape(&kind, &shape);
        // None and the empty model are both exact no-ops.
        let same = est.estimate_shape_faulted(&kind, &shape, None);
        assert_eq!(healthy.makespan.to_bits(), same.makespan.to_bits());
        let empty = FaultModel::compile(&FaultSpec::default(), 32);
        let same = est.estimate_shape_faulted(&kind, &shape, Some(&empty));
        assert_eq!(healthy.makespan.to_bits(), same.makespan.to_bits());
        // A straggler multiplies; an outage adds its window on top.
        let slow = FaultModel::compile(&FaultSpec::parse("straggler:rank=0,slow=4").unwrap(), 32);
        let f = est.estimate_shape_faulted(&kind, &shape, Some(&slow));
        assert_eq!(f.makespan.to_bits(), (healthy.makespan * 4.0).to_bits());
        assert_eq!(f.phases, healthy.phases, "phases stay unscaled (documented coarse)");
        let out = FaultModel::compile(
            &FaultSpec::parse("outage:node=0,from=0.5,until=0.75").unwrap(),
            32,
        );
        let f = est.estimate_shape_faulted(&kind, &shape, Some(&out));
        assert!((f.makespan - (healthy.makespan + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn segmented_estimate_reduces_to_the_plain_one_at_k1() {
        let prof = MachineProfile::fugaku();
        let est = Estimator::new(&prof, Topology::new(256, 32));
        let shape = WorkloadShape::dense(1024.0);
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(4, 2),
        ] {
            let plain = est.estimate_shape(&kind, &shape).makespan;
            let blk = est.estimate_segmented(&kind, &shape, 1, false, 0.0).makespan;
            assert_eq!(plain.to_bits(), blk.to_bits(), "{kind:?}");
        }
    }

    #[test]
    fn pipelined_estimate_hides_compute_blocking_pays_it() {
        let prof = MachineProfile::fugaku();
        let est = Estimator::new(&prof, Topology::new(256, 32));
        let shape = WorkloadShape::dense(4096.0);
        let k = 4;
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(4, 2),
            AlgoKind::Pairwise,
        ] {
            // Size compute to the per-segment estimate so there is
            // something real to hide.
            let seg = est.estimate_segmented(&kind, &shape, k, false, 0.0).makespan / k as f64;
            let c = seg / 2.0;
            let blocking = est.estimate_segmented(&kind, &shape, k, false, c).makespan;
            let pipelined = est.estimate_segmented(&kind, &shape, k, true, c).makespan;
            assert!(
                pipelined < blocking,
                "{kind:?}: pipelined {pipelined} must undercut blocking {blocking}"
            );
            assert!(pipelined.is_finite() && pipelined > 0.0);
            // And compute shows up in the breakdown.
            let ph = est.estimate_segmented(&kind, &shape, k, true, c).phases;
            assert!((ph.get(Phase::Compute) - k as f64 * c).abs() < 1e-15);
        }
    }

    #[test]
    fn fully_overlappable_families_hide_more_than_round_bound_ones() {
        // Spread-out's single burst is fully overlappable; tuna can hide
        // only its final round. With per-segment compute sized at the
        // spread-out segment cost, spread-out's pipelined estimate drops
        // by a strictly larger fraction of its blocking cost.
        let prof = MachineProfile::fugaku();
        let est = Estimator::new(&prof, Topology::new(256, 32));
        let shape = WorkloadShape::dense(2048.0);
        let k = 4;
        let frac = |kind: &AlgoKind, c: f64| {
            let b = est.estimate_segmented(kind, &shape, k, false, c).makespan;
            let p = est.estimate_segmented(kind, &shape, k, true, c).makespan;
            (b - p) / b
        };
        let c = est
            .estimate_segmented(&AlgoKind::SpreadOut, &shape, k, false, 0.0)
            .makespan
            / k as f64;
        let so = frac(&AlgoKind::SpreadOut, c);
        let tn = frac(&AlgoKind::Tuna { radix: 4 }, c);
        assert!(so > tn, "spread-out hides {so:.3} of itself, tuna {tn:.3}");
    }

    #[test]
    fn incast_penalizes_ompi_linear() {
        let asc = est(AlgoKind::OmpiLinear, 2048, 32, 4096.0);
        let rr = est(AlgoKind::SpreadOut, 2048, 32, 4096.0);
        assert!(asc >= rr, "ascending {asc} must not beat round-robin {rr}");
    }

    #[test]
    fn hier_intra_cheaper_than_flat_at_small_s() {
        // Hierarchical decoupling pays off when most traffic can stay
        // on-node and inter-node messages coalesce.
        let flat = est(AlgoKind::Tuna { radix: 2 }, 2048, 32, 64.0);
        let hier = est(AlgoKind::hier_coalesced(2, 8), 2048, 32, 64.0);
        assert!(
            hier < flat,
            "hier coalesced {hier} should beat flat tuna {flat} at small S"
        );
    }
}
