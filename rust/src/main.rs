//! `tuna` — CLI for the TuNA / TuNA_l^g reproduction.
//!
//! Subcommands:
//!   run      one all-to-allv measurement (algo=... plus key=value config)
//!   figure   regenerate a paper figure (fig7..fig16 | all) [--full]
//!   tune     autotune TuNA radix / TuNA_l^g params for a workload
//!   tc       distributed transitive closure on a synthetic graph
//!   fft      distributed 4-step FFT through the PJRT runtime
//!   list     list algorithms, profiles and distributions
//!
//! Examples:
//!   tuna run algo=tuna:r=8 p=128 q=16 profile=fugaku dist=uniform:1024
//!   tuna figure fig8 --full
//!   tuna tune p=256 q=32 dist=uniform:512
//!   tuna tc p=8 q=4 algo=tuna-hier-coalesced:r=2,b=1
//!   tuna fft n1=64 n2=64 p=8 algo=tuna:r=4

use tuna::algos::{self, AlgoKind};
use tuna::apps;
use tuna::coordinator::{measure, RunConfig};
use tuna::harness::{self, FigOpts};
use tuna::util::stats::fmt_time;
use tuna::workload::graph::Graph;
use tuna::{Result, TunaError};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "run" => cmd_run(rest),
        "figure" => cmd_figure(rest),
        "tune" => cmd_tune(rest),
        "tc" => cmd_tc(rest),
        "fft" => cmd_fft(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(TunaError::config(format!(
            "unknown command `{other}` (see `tuna help`)"
        ))),
    }
}

const HELP: &str = "\
tuna — Configurable Non-uniform All-to-all Algorithms (TuNA / TuNA_l^g)

USAGE:
  tuna run algo=<spec> [key=value ...]     measure one algorithm
  tuna figure <fig7..fig16|all> [--full]   regenerate paper figures
  tuna tune [key=value ...]                autotune radix / block_count
  tuna tc [n=220] [algo=<spec>] [key=value ...]
  tuna fft [n1=64] [n2=64] [algo=<spec>] [key=value ...]
  tuna list                                list algorithms / profiles / dists

CONFIG KEYS: p, q, profile (polaris|fugaku|test-flat), dist
  (uniform:S|normal|powerlaw|const:S|fft-n1|fft-n2), seed, iters,
  real (true|false), limit-linear, limit-log
ALGO SPECS: spread-out | ompi-linear | pairwise | scattered:b=N | vendor |
  bruck2 | tuna:r=N | tuna-hier-coalesced:r=N,b=M | tuna-hier-staggered:r=N,b=M
";

/// Split `algo=` / figure-local keys from RunConfig keys.
fn split_args(args: &[String], keys: &[&str]) -> (Vec<(String, String)>, Vec<String>) {
    let mut special = Vec::new();
    let mut cfg = Vec::new();
    for a in args {
        match a.split_once('=') {
            Some((k, v)) if keys.contains(&k) => special.push((k.to_string(), v.to_string())),
            _ => cfg.push(a.clone()),
        }
    }
    (special, cfg)
}

fn get<'a>(special: &'a [(String, String)], key: &str) -> Option<&'a str> {
    special.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn parse_algo(spec: Option<&str>, default: AlgoKind) -> Result<AlgoKind> {
    match spec {
        None => Ok(default),
        Some(s) => {
            AlgoKind::parse(s).ok_or_else(|| TunaError::config(format!("bad algo spec `{s}`")))
        }
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let cfg = RunConfig::parse_args(&cfg_args)?;
    let m = measure(&cfg, &kind)?;
    println!(
        "{} on {} P={} Q={} dist={:?}",
        kind.name(),
        cfg.profile.name,
        cfg.p,
        cfg.q,
        cfg.dist
    );
    println!(
        "  median {}   (min {}, max {}, stddev {}, n={}, fidelity={})",
        fmt_time(m.summary.median),
        fmt_time(m.summary.min),
        fmt_time(m.summary.max),
        fmt_time(m.summary.stddev),
        m.summary.n,
        m.fidelity.name()
    );
    for ph in tuna::comm::PHASES {
        let t = m.phases.get(ph);
        if t > 0.0 {
            println!("  {:<12} {}", ph.name(), fmt_time(t));
        }
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let name = args
        .first()
        .ok_or_else(|| TunaError::config("usage: tuna figure <fig7..fig16|all> [--full]"))?;
    let full = args.iter().any(|a| a == "--full");
    let opts = FigOpts {
        full,
        iters: if full { 5 } else { 3 },
        ..FigOpts::default()
    };
    let names: Vec<&str> = if name == "all" {
        harness::ALL_FIGURES.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        eprintln!("[tuna] generating {n} (full={full}) ...");
        let t0 = std::time::Instant::now();
        for table in harness::run_figure(n, &opts)? {
            println!("{}", table.render());
        }
        eprintln!(
            "[tuna] {n} done in {:?}; artifacts in {:?}",
            t0.elapsed(),
            opts.out_dir
        );
    }
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let cfg = RunConfig::parse_args(args)?;
    let engine = tuna::comm::Engine::new(
        cfg.profile.clone(),
        tuna::comm::Topology::new(cfg.p, cfg.q),
    );
    let sizes = tuna::workload::BlockSizes::generate(cfg.p, cfg.dist, cfg.seed);
    println!(
        "autotuning on {} P={} Q={} dist={:?}",
        cfg.profile.name, cfg.p, cfg.q, cfg.dist
    );

    let tuna_res = algos::tuning::autotune_tuna(&engine, &sizes)?;
    println!(
        "  TuNA: best {} at {}",
        tuna_res.best.name(),
        fmt_time(tuna_res.best_time)
    );
    let heur = algos::tuning::heuristic_radix(cfg.p, sizes.mean_size());
    println!(
        "  heuristic suggests r={heur} (mean block {:.0} B)",
        sizes.mean_size()
    );

    if cfg.q >= 2 && cfg.p / cfg.q >= 2 {
        for coalesced in [true, false] {
            let res = algos::tuning::autotune_hier(&engine, &sizes, coalesced)?;
            println!(
                "  TuNA_l^g {}: best {} at {}",
                if coalesced { "coalesced" } else { "staggered" },
                res.best.name(),
                fmt_time(res.best_time)
            );
        }
    }
    Ok(())
}

fn cmd_tc(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo", "n", "m"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let n: usize = get(&special, "n")
        .unwrap_or("220")
        .parse()
        .map_err(|_| TunaError::config("bad n"))?;
    let m: usize = get(&special, "m")
        .unwrap_or("3")
        .parse()
        .map_err(|_| TunaError::config("bad m"))?;
    let mut cfg = RunConfig::parse_args(&cfg_args)?;
    if !cfg_args.iter().any(|a| a.starts_with("p=")) {
        cfg.p = 8;
        cfg.q = 4;
    }
    let graph = Graph::scale_free(n, m, cfg.seed);
    let engine = tuna::comm::Engine::new(
        cfg.profile.clone(),
        tuna::comm::Topology::new(cfg.p, cfg.q),
    );
    println!(
        "transitive closure: {} vertices, {} edges, P={} Q={} algo={}",
        graph.n,
        graph.edges.len(),
        cfg.p,
        cfg.q,
        kind.name()
    );
    let rep = apps::tc::run_tc(&engine, &kind, &graph, true)?;
    println!(
        "  |TC| = {} in {} iterations (validated against sequential oracle)",
        rep.paths, rep.iterations
    );
    println!(
        "  simulated: total {}  comm {}  | host wallclock {}",
        fmt_time(rep.makespan),
        fmt_time(rep.comm_time),
        fmt_time(rep.wall)
    );
    Ok(())
}

fn cmd_fft(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo", "n1", "n2"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let n1: usize = get(&special, "n1")
        .unwrap_or("64")
        .parse()
        .map_err(|_| TunaError::config("bad n1"))?;
    let n2: usize = get(&special, "n2")
        .unwrap_or("64")
        .parse()
        .map_err(|_| TunaError::config("bad n2"))?;
    let mut cfg = RunConfig::parse_args(&cfg_args)?;
    if !cfg_args.iter().any(|a| a.starts_with("p=")) {
        cfg.p = 8;
        cfg.q = 4;
    }
    let rep = apps::fft::run_distributed_fft(
        &cfg.profile,
        cfg.p,
        cfg.q,
        n1,
        n2,
        &kind,
        apps::fft::FftBackend::auto(),
    )?;
    println!(
        "distributed FFT N={n1}x{n2} P={} algo={}: max err {:.3e} (validated)",
        cfg.p,
        kind.name(),
        rep.max_err
    );
    println!(
        "  simulated total {}  comm {}  compute {}  | host wallclock {}",
        fmt_time(rep.makespan),
        fmt_time(rep.comm_time),
        fmt_time(rep.compute_time),
        fmt_time(rep.wall)
    );
    println!("  backend: {}", rep.backend);
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("algorithms:");
    for a in [
        "spread-out",
        "ompi-linear",
        "pairwise",
        "scattered:b=N",
        "vendor",
        "bruck2",
        "tuna:r=N",
        "tuna-hier-coalesced:r=N,b=M",
        "tuna-hier-staggered:r=N,b=M",
    ] {
        println!("  {a}");
    }
    println!("profiles: polaris, fugaku, test-flat");
    println!("distributions: uniform:S, normal, powerlaw, const:S, fft-n1, fft-n2");
    println!("figures: {}", harness::ALL_FIGURES.join(", "));
    Ok(())
}
