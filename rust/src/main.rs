//! `tuna` — CLI for the TuNA / TuNA_l^g reproduction.
//!
//! Subcommands:
//!   run      one all-to-allv measurement (algo=... plus key=value config)
//!   figure   regenerate a paper figure (fig7..fig16 | all) [--full]
//!   select   rank every algorithm family with the cost model, refine on
//!            the engine, persist a tuning table (TunaSelect)
//!   tune     table-backed autotune: answer from artifacts/tuning/ when a
//!            snapshot exists, full selection otherwise
//!   serve    multi-tenant serving: N tenants with persistent handles,
//!            Poisson traffic through one shared engine, p50/p95/p99
//!   chaos    fault-severity degradation sweeps (straggler / sick link)
//!            across algorithm families, with recommended crossovers
//!   tc       distributed transitive closure on a synthetic graph
//!   fft      distributed 4-step FFT through the PJRT runtime
//!   list     list algorithms, profiles and distributions
//!
//! Examples:
//!   tuna run algo=tuna:r=8 p=128 q=16 profile=fugaku dist=uniform:1024
//!   tuna run algo=tuna:r=8 p=256 q=16 persistent=true
//!   tuna figure fig8 --full
//!   tuna select p=256 q=32 dist=uniform:512 shortlist=8
//!   tuna select --write-golden
//!   tuna tune p=256 q=32 dist=uniform:512
//!   tuna serve tenants=4 p=1024 q=16 seconds=5 load=0.7
//!   tuna tc p=8 q=4 algo=hier:l=tuna:r=2,g=coalesced:b=1
//!   tuna fft n1=64 n2=64 p=8 algo=tuna:r=4

// Mirrors the lib's deliberate style allows (bin crates do not inherit
// the library's inner attributes); CI enforces `clippy -- -D warnings`.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::path::Path;

use tuna::algos::{self, select, tuning, AlgoKind};
use tuna::apps;
use tuna::coordinator::{measure, RunConfig, SelectConfig};
use tuna::harness::{self, FigOpts};
use tuna::util::stats::fmt_time;
use tuna::util::table::Table;
use tuna::workload::graph::Graph;
use tuna::{Result, TunaError};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "run" => cmd_run(rest),
        "figure" => cmd_figure(rest),
        "select" => cmd_select(rest),
        "tune" => cmd_tune(rest),
        "serve" => harness::serve::cmd(rest),
        "chaos" => harness::chaos::cmd(rest),
        // Hidden maintenance arm: hand-builds broken replay inputs so the
        // CLI's typed-error path is testable end to end (tests/cli_errors.rs).
        "debug-errors" => cmd_debug_errors(rest),
        "tc" => cmd_tc(rest),
        "fft" => cmd_fft(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(TunaError::config(format!(
            "unknown command `{other}` (see `tuna help`)"
        ))),
    }
}

const HELP: &str = "\
tuna — Configurable Non-uniform All-to-all Algorithms (TuNA / TuNA_l^g)

USAGE:
  tuna run algo=<spec> [key=value ...]     measure one algorithm
                                           (tuna:auto consults the tuning
                                           table under table-dir, default
                                           artifacts/tuning/)
  tuna figure <fig7..fig16|all> [--full]   regenerate paper figures
  tuna select [key=value ...]              rank all families (cost model +
                                           engine refinement), persist a
                                           tuning table under artifacts/tuning/
  tuna select --write-golden               regenerate tests/golden snapshots
  tuna tune [key=value ...]                table-backed autotune (force=true
                                           to ignore stored tables)
  tuna serve [--quick] [tenants=4] [p=1024] [q=16] [seconds=5] [load=0.7]
                                           [pace=0] [seed=N] [profile=..]
                                           [deadline=T] [retries=N]
                                           [plan-cache-cap=64]
                                           [out=BENCH_serve.json]
                                           multi-tenant serving: each tenant
                                           freezes its collective in a
                                           persistent handle, Poisson calls
                                           share one engine; reports per-tenant
                                           p50/p95/p99 and writes a JSON
                                           artifact with a pace (admission
                                           knob) sweep. --quick = CI smoke.
                                           deadline=T (secs) times out calls
                                           whose attempt exceeds T; retries=N
                                           re-issues each timed-out call up to
                                           N times with exponential backoff
                                           (deadline*2^k), then sheds it —
                                           reported as timeouts/retries/shed
                                           and goodput per tenant.
                                           plan-cache-cap=N bounds each
                                           tenant engine's retained compiled
                                           plans (LRU); hits/misses/evictions
                                           land in the table and artifact.
  tuna chaos [--quick] [p=256] [q=8] [s=1024] [iters=3] [seed=N]
                                           [profile=..] [out=BENCH_faults.json]
                                           fault-severity degradation sweep:
                                           straggler and sick-link faults at
                                           increasing severity across algorithm
                                           families (exact replay), reporting
                                           degradation curves, the recommended
                                           family per fault point, and the
                                           crossovers where the recommendation
                                           flips. --quick = CI smoke grid.
  tuna tc [n=220] [algo=<spec>] [key=value ...]
  tuna fft [n1=64] [n2=64] [algo=<spec>] [key=value ...]
  tuna list                                list algorithms / profiles / dists

CONFIG KEYS: p, q, profile (polaris|fugaku|test-flat), dist
  (uniform:S|normal|powerlaw|const:S|fft-n1|fft-n2|sparse:nnz=K[,max=S]),
  seed, iters, real (true|false), limit-linear, limit-log, limit-replay,
  limit-replay-sparse, replay-shards (N|auto: worker shards for the
  replay executor — bit-identical for every value, auto sizes from P
  and the host),
  compile-threads (N|auto: worker threads for plan compilation — the
  compiled plan is op-for-op identical for every value; auto is 1
  below P=4096, then sized from the host),
  plan-stats (true|false: print plan-IR statistics for replay points —
  total ops, distinct interned rank programs, arena bytes vs the
  legacy per-rank representation, e.g. `tuna run dist=sparse:nnz=16
  algo=tuna:r=4 p=262144 q=64 mode=replay replay-shards=4
  limit-replay-sparse=262144 plan-stats=true`),
  mode (auto|threaded|replay: auto replays phantom workloads on the
  plan executor — bit-identical to the threaded engine, and the way to
  run P=4096+ points, e.g. `tuna run algo=tuna:r=2 p=4096 q=32
  mode=replay`; structurally sparse workloads compile O(nnz)-op plans
  and shard the replay loop, so exact replay reaches P=65536+, e.g.
  `tuna run dist=sparse:nnz=16 algo=hier:l=tuna:r=4,g=coalesced:b=2
  p=65536 q=64 mode=replay replay-shards=4`),
  persistent (true|false: freeze the workload at `seed` and measure
  through one persistent handle — plan compilation, payload arenas and
  transposes are built once and reused by every iteration; also the only
  way to run the persistent-only hier local `balanced` schedule),
  faults (deterministic fault injection: '/'-separated clauses of
  straggler:rank=R,slow=X | link:node=A-B,bw=F,lat=F |
  jitter:sigma=S,seed=N | outage:node=N,from=T,until=T — pure
  seed-keyed perturbations of the virtual clocks, so threaded and
  sharded-replay runs stay bit-identical under any spec and any shard
  count; empty spec is provably zero perturbation, e.g. `tuna run
  algo=tuna:r=4 p=128 q=8 faults=straggler:rank=7,slow=8`),
  segments (K: split the collective into K chunk plans over exact
  per-destination byte ranges — phantom-only; segments=1 is the
  unsegmented run; blocks smaller than K bytes simply occupy fewer
  than K segments),
  overlap (true|false: nonblocking pipeline — each segment's compute is
  interleaved with the previous segment's in-flight exchange; requires
  segments >= 2; segmented runs print measured exposed/hidden comm),
  compute (secs: constant per-segment compute charged to every rank;
  with `tuna select`, segments/overlap/compute switch the ranking to
  the overlap-aware scoring mode, e.g. `tuna run
  algo=hier:l=tuna:r=4,g=coalesced:b=2 p=4096 q=32 mode=replay
  replay-shards=4 segments=4 overlap=true`; `tuna fft`/`tuna tc` with
  segments=K also time a pipelined twin of the validated app run;
  fig14/fig15 carry exposed-blk/exposed-pipe/overlap-x columns)
SELECT KEYS: shortlist (engine-refined candidates, default 6),
  refine (true|false), skewed (true|false: also stress the shortlist
  under a heavy-tailed companion workload), faulted=<spec> (re-measure
  the shortlist under the given fault spec — same grammar as faults= —
  and score each candidate by its worst case across healthy and
  faulted runs; requires refine=true), top (rows printed),
  table-dir, golden-dir
ALGO SPECS: spread-out | ompi-linear | pairwise | scattered:b=N | vendor |
  bruck2 | tuna:r=N | tuna:auto | hier:l=<local>,g=<global>
  hier locals:  tuna:r=N | linear (one-shot) | balanced (persistent-only:
                constructed through a persistent handle, e.g. `tuna run
                persistent=true`; never parseable in a one-shot spec)
  hier globals: coalesced:b=N | staggered:b=N | linear | bruck:r=N
  (legacy aliases: tuna-hier-coalesced:r=N,b=M = hier:l=tuna:r=N,g=coalesced:b=M,
   tuna-hier-staggered:r=N,b=M = hier:l=tuna:r=N,g=staggered:b=M)
";

/// Split `algo=` / figure-local keys from RunConfig keys.
fn split_args(args: &[String], keys: &[&str]) -> (Vec<(String, String)>, Vec<String>) {
    let mut special = Vec::new();
    let mut cfg = Vec::new();
    for a in args {
        match a.split_once('=') {
            Some((k, v)) if keys.contains(&k) => special.push((k.to_string(), v.to_string())),
            _ => cfg.push(a.clone()),
        }
    }
    (special, cfg)
}

fn get<'a>(special: &'a [(String, String)], key: &str) -> Option<&'a str> {
    special.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn parse_algo(spec: Option<&str>, default: AlgoKind) -> Result<AlgoKind> {
    match spec {
        None => Ok(default),
        Some(s) => AlgoKind::parse(s),
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo", "table-dir"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let mut cfg = RunConfig::parse_args(&cfg_args)?;
    // Only `tuna:auto` dispatch consults the persisted tuning table;
    // attach it (when present) so the engine can see it. A missing table
    // is the normal cold path; a present-but-unreadable one deserves a
    // warning, not a silent fallback to the heuristic.
    let table_dir_arg = get(&special, "table-dir");
    if kind != AlgoKind::TunaAuto && table_dir_arg.is_some() {
        return Err(TunaError::config(
            "table-dir only applies to algo=tuna:auto (tables feed auto radix dispatch)",
        ));
    }
    if kind == AlgoKind::TunaAuto {
        let table_dir = table_dir_arg.unwrap_or(tuning::DEFAULT_TABLE_DIR);
        let table_file = tuning::table_path(Path::new(table_dir), cfg.profile.name);
        match tuning::TuningTable::load(&table_file) {
            Ok(table) => {
                println!(
                    "using tuning table {} ({} entries)",
                    table_file.display(),
                    table.entries.len()
                );
                cfg.tuning = Some(std::sync::Arc::new(table));
            }
            Err(e) if table_file.exists() => {
                eprintln!(
                    "warning: ignoring unreadable tuning table {}: {e}",
                    table_file.display()
                );
            }
            Err(_) => {}
        }
    }
    let m = measure(&cfg, &kind)?;
    println!(
        "{} on {} P={} Q={} dist={:?}",
        kind.name(),
        cfg.profile.name,
        cfg.p,
        cfg.q,
        cfg.dist
    );
    println!(
        "  median {}   (min {}, max {}, stddev {}, n={}, fidelity={})",
        fmt_time(m.summary.median),
        fmt_time(m.summary.min),
        fmt_time(m.summary.max),
        fmt_time(m.summary.stddev),
        m.summary.n,
        m.fidelity.name()
    );
    for ph in tuna::comm::PHASES {
        let t = m.phases.get(ph);
        if t > 0.0 {
            println!("  {:<12} {}", ph.name(), fmt_time(t));
        }
    }
    if cfg.plan_stats {
        match &m.plan_stats {
            Some(st) => println!(
                "  plan: {} ops, {} distinct programs, {} B interned ({} B legacy, {:.1}% ratio)",
                st.total_ops,
                st.distinct_programs,
                st.plan_bytes,
                st.legacy_bytes,
                st.ratio() * 100.0,
            ),
            None => println!(
                "  plan: no stats (plan-stats=true reports the replay path's compiled plan; \
                 this point ran {})",
                m.fidelity.name()
            ),
        }
    }
    if cfg.segments > 1 {
        match &m.counters {
            Some(c) => println!(
                "  segments={} overlap={}: comm exposed {}  hidden {}  (window {})",
                cfg.segments,
                cfg.overlap,
                fmt_time(c.exposed_comm),
                fmt_time(c.hidden_comm),
                fmt_time(c.comm_window()),
            ),
            None => println!(
                "  segments={} overlap={}: analytic fidelity (no measured clocks; \
                 lower P or mode=replay for measured exposed/hidden comm)",
                cfg.segments, cfg.overlap
            ),
        }
    }
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let name = args
        .first()
        .ok_or_else(|| TunaError::config("usage: tuna figure <fig7..fig16|all> [--full]"))?;
    let full = args.iter().any(|a| a == "--full");
    let opts = FigOpts {
        full,
        iters: if full { 5 } else { 3 },
        ..FigOpts::default()
    };
    let names: Vec<&str> = if name == "all" {
        harness::ALL_FIGURES.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        eprintln!("[tuna] generating {n} (full={full}) ...");
        let t0 = std::time::Instant::now();
        for table in harness::run_figure(n, &opts)? {
            println!("{}", table.render());
        }
        eprintln!(
            "[tuna] {n} done in {:?}; artifacts in {:?}",
            t0.elapsed(),
            opts.out_dir
        );
    }
    Ok(())
}

fn cmd_select(args: &[String]) -> Result<()> {
    let mut write_golden = false;
    for a in args {
        match a.as_str() {
            "--write-golden" => write_golden = true,
            f if f.starts_with("--") => {
                return Err(TunaError::config(format!(
                    "unknown flag `{f}` (did you mean --write-golden?)"
                )));
            }
            _ => {}
        }
    }
    let kv: Vec<String> = args.iter().filter(|a| !a.starts_with("--")).cloned().collect();
    let (special, cfg_args) = split_args(&kv, &["table-dir", "top", "golden-dir"]);
    if write_golden {
        // Prefer the build-time source path when it still exists on this
        // host (the developer workflow); fall back to a cwd-relative
        // path for relocated binaries.
        let dir = match get(&special, "golden-dir") {
            Some(d) => std::path::PathBuf::from(d),
            None => {
                let built = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
                if built.exists() {
                    built
                } else {
                    std::path::PathBuf::from("tests/golden")
                }
            }
        };
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join("estimator.tsv"), select::golden_estimator_tsv())?;
        std::fs::write(dir.join("selector.tsv"), select::golden_selector_tsv())?;
        println!("golden snapshots regenerated under {}", dir.display());
        return Ok(());
    }
    let table_dir = get(&special, "table-dir")
        .unwrap_or(tuning::DEFAULT_TABLE_DIR)
        .to_string();
    let top: usize = get(&special, "top")
        .unwrap_or("10")
        .parse()
        .map_err(|_| TunaError::config("bad top"))?;
    let cfg = SelectConfig::parse_args(&cfg_args)?;

    let sel = select::select(&cfg)?;
    println!(
        "TunaSelect on {} P={} Q={} dist={:?} (mean block {:.0} B): {} candidates, {} engine-refined",
        sel.machine,
        sel.p,
        sel.q,
        cfg.run.dist,
        sel.mean_block,
        sel.ranked.len(),
        sel.refined
    );
    let mut t = Table::new(
        format!("TunaSelect ranking (top {top})"),
        &["rank", "algo", "model", "measured"],
    );
    for (i, sc) in sel.ranked.iter().take(top).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            sc.kind.name(),
            fmt_time(sc.model_time),
            sc.measured.map(fmt_time).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", t.render());

    let path = tuning::table_path(Path::new(&table_dir), &sel.machine);
    sel.to_table().save_merged(&path)?;
    println!("tuning table updated: {}", path.display());
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["table-dir", "force"]);
    let table_dir = get(&special, "table-dir")
        .unwrap_or(tuning::DEFAULT_TABLE_DIR)
        .to_string();
    let force = match get(&special, "force") {
        None => false,
        Some(v) => v
            .parse()
            .map_err(|_| TunaError::config(format!("bad bool for force: `{v}`")))?,
    };
    let cfg = RunConfig::parse_args(&cfg_args)?;
    let mean = tuna::workload::BlockSizes::generate(cfg.p, cfg.dist, cfg.seed).mean_size();

    // Table-backed fast path: answer from a persisted ranking when one
    // covers this scenario.
    let path = tuning::table_path(Path::new(&table_dir), cfg.profile.name);
    if !force {
        match tuning::TuningTable::load(&path) {
            Ok(table) => {
                if let Some(hit) = table.lookup(cfg.profile.name, cfg.p, cfg.q, mean) {
                    println!(
                        "tuning table hit ({}): best {} (model {}, measured {})",
                        path.display(),
                        hit.algo.name(),
                        fmt_time(hit.model_time),
                        hit.measured_time.map(fmt_time).unwrap_or_else(|| "-".into())
                    );
                    println!(
                        "  snapshot taken at mean block {:.0} B; pass force=true to re-sweep",
                        hit.mean_block
                    );
                    return Ok(());
                }
            }
            // A present-but-unreadable table is worth a warning (it will
            // be replaced on save); a missing one is the normal cold
            // path.
            Err(e) if path.exists() => {
                eprintln!(
                    "warning: ignoring unreadable tuning table {}: {e}",
                    path.display()
                );
            }
            Err(_) => {}
        }
    }

    // No snapshot: run the full selector, report per-family bests, and
    // persist the ranking for next time.
    println!(
        "autotuning on {} P={} Q={} dist={:?}",
        cfg.profile.name, cfg.p, cfg.q, cfg.dist
    );
    let sel = select::select(&SelectConfig {
        run: cfg.clone(),
        ..SelectConfig::default()
    })?;
    let mut seen: Vec<&str> = Vec::new();
    for sc in &sel.ranked {
        let family = sc.kind.family();
        if !seen.contains(&family) {
            seen.push(family);
            println!("  best {family:<20} {} at {}", sc.kind.name(), fmt_time(sc.time()));
        }
    }
    let heur = algos::tuning::heuristic_radix(cfg.p, mean);
    println!("  heuristic suggests r={heur} (mean block {mean:.0} B)");
    sel.to_table().save_merged(&path)?;
    println!("  ranking saved to {}", path.display());
    Ok(())
}

fn cmd_tc(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo", "n", "m"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let n: usize = get(&special, "n")
        .unwrap_or("220")
        .parse()
        .map_err(|_| TunaError::config("bad n"))?;
    let m: usize = get(&special, "m")
        .unwrap_or("3")
        .parse()
        .map_err(|_| TunaError::config("bad m"))?;
    let mut cfg = RunConfig::parse_args(&cfg_args)?;
    if !cfg_args.iter().any(|a| a.starts_with("p=")) {
        cfg.p = 8;
        cfg.q = 4;
    }
    let graph = Graph::scale_free(n, m, cfg.seed);
    let engine = tuna::comm::Engine::new(
        cfg.profile.clone(),
        tuna::comm::Topology::new(cfg.p, cfg.q),
    );
    println!(
        "transitive closure: {} vertices, {} edges, P={} Q={} algo={}",
        graph.n,
        graph.edges.len(),
        cfg.p,
        cfg.q,
        kind.name()
    );
    if cfg.segments > 1 {
        // Segmented twin: one validated mining run plus blocking vs
        // pipelined phantom replays of its aggregate shuffle traffic.
        let twin = apps::tc::run_tc_overlap(&engine, &kind, &graph, true, cfg.segments)?;
        let rep = &twin.base;
        println!(
            "  |TC| = {} in {} iterations (validated against sequential oracle)",
            rep.paths, rep.iterations
        );
        println!(
            "  simulated: total {}  comm {}  | host wallclock {}",
            fmt_time(rep.makespan),
            fmt_time(rep.comm_time),
            fmt_time(rep.wall)
        );
        println!(
            "  segmented twin (K={}): blocking {}  pipelined {}  ({:.2}x)",
            twin.segments,
            fmt_time(twin.blocking_makespan),
            fmt_time(twin.pipelined_makespan),
            twin.blocking_makespan / twin.pipelined_makespan
        );
        println!(
            "  exposed comm: blocking {}  pipelined {}  (hidden {})",
            fmt_time(twin.exposed_blocking),
            fmt_time(twin.exposed_pipelined),
            fmt_time(twin.hidden_pipelined)
        );
        return Ok(());
    }
    let rep = apps::tc::run_tc(&engine, &kind, &graph, true)?;
    println!(
        "  |TC| = {} in {} iterations (validated against sequential oracle)",
        rep.paths, rep.iterations
    );
    println!(
        "  simulated: total {}  comm {}  | host wallclock {}",
        fmt_time(rep.makespan),
        fmt_time(rep.comm_time),
        fmt_time(rep.wall)
    );
    Ok(())
}

fn cmd_fft(args: &[String]) -> Result<()> {
    let (special, cfg_args) = split_args(args, &["algo", "n1", "n2"]);
    let kind = parse_algo(get(&special, "algo"), AlgoKind::Tuna { radix: 2 })?;
    let n1: usize = get(&special, "n1")
        .unwrap_or("64")
        .parse()
        .map_err(|_| TunaError::config("bad n1"))?;
    let n2: usize = get(&special, "n2")
        .unwrap_or("64")
        .parse()
        .map_err(|_| TunaError::config("bad n2"))?;
    let mut cfg = RunConfig::parse_args(&cfg_args)?;
    if !cfg_args.iter().any(|a| a.starts_with("p=")) {
        cfg.p = 8;
        cfg.q = 4;
    }
    if cfg.segments > 1 {
        // Segmented twin: the validated FFT once, then blocking vs
        // pipelined phantom replays of its transpose with per-rank
        // stage-1 seconds split across segments.
        let twin = apps::fft::run_distributed_fft_overlap(
            &cfg.profile,
            cfg.p,
            cfg.q,
            n1,
            n2,
            &kind,
            apps::fft::FftBackend::auto(),
            cfg.segments,
        )?;
        let rep = &twin.base;
        println!(
            "distributed FFT N={n1}x{n2} P={} algo={}: max err {:.3e} (validated)",
            cfg.p,
            kind.name(),
            rep.max_err
        );
        println!(
            "  simulated total {}  comm {}  compute {}  | host wallclock {}",
            fmt_time(rep.makespan),
            fmt_time(rep.comm_time),
            fmt_time(rep.compute_time),
            fmt_time(rep.wall)
        );
        println!("  backend: {}", rep.backend);
        println!(
            "  segmented twin (K={}): blocking {}  pipelined {}  ({:.2}x)",
            twin.segments,
            fmt_time(twin.blocking_makespan),
            fmt_time(twin.pipelined_makespan),
            twin.blocking_makespan / twin.pipelined_makespan
        );
        println!(
            "  exposed comm: blocking {}  pipelined {}  (hidden {})",
            fmt_time(twin.exposed_blocking),
            fmt_time(twin.exposed_pipelined),
            fmt_time(twin.hidden_pipelined)
        );
        return Ok(());
    }
    let rep = apps::fft::run_distributed_fft(
        &cfg.profile,
        cfg.p,
        cfg.q,
        n1,
        n2,
        &kind,
        apps::fft::FftBackend::auto(),
    )?;
    println!(
        "distributed FFT N={n1}x{n2} P={} algo={}: max err {:.3e} (validated)",
        cfg.p,
        kind.name(),
        rep.max_err
    );
    println!(
        "  simulated total {}  comm {}  compute {}  | host wallclock {}",
        fmt_time(rep.makespan),
        fmt_time(rep.comm_time),
        fmt_time(rep.compute_time),
        fmt_time(rep.wall)
    );
    println!("  backend: {}", rep.backend);
    Ok(())
}

/// Hidden maintenance arm behind `tuna debug-errors case=<name>`: builds a
/// deliberately broken replay/persistent input in-process and feeds it to
/// the real executors, so `tests/cli_errors.rs` can assert that every
/// `ReplayError` variant (and the persistent stale-counts error) reaches
/// the user as a typed `error: ...` message with exit code 1 — never a
/// panic. Not listed in HELP: it exists only for the error-path tests.
fn cmd_debug_errors(args: &[String]) -> Result<()> {
    use tuna::comm::{CommPlan, Engine, PersistentColl, PlanBuilder, Topology};
    use tuna::model::MachineProfile;
    use tuna::workload::{BlockSizes, Dist};

    let (special, rest) = split_args(args, &["case"]);
    if let Some(extra) = rest.first() {
        return Err(TunaError::config(format!(
            "debug-errors takes only case=<name>, got `{extra}`"
        )));
    }
    let case = get(&special, "case").ok_or_else(|| {
        TunaError::config(
            "usage: tuna debug-errors case=<shape-mismatch|plan-deadlock|undrained|stale-counts>",
        )
    })?;
    let profile = MachineProfile::test_flat();
    // Two-rank plan with rank 0 swapped in per case; rank 1 stays empty so
    // the broken half is the whole story.
    let broken = |r0: PlanBuilder| {
        CommPlan::from_rank_plans(
            2,
            1,
            "debug".into(),
            vec![r0.finish(), PlanBuilder::new(1, 2).finish()],
            0,
            0,
        )
    };
    match case {
        "shape-mismatch" => {
            // Plan compiled for P=2 replayed on a P=4 topology.
            let plan = broken(PlanBuilder::new(0, 2));
            tuna::comm::replay::execute(&profile, Topology::flat(4), &plan)?;
        }
        "plan-deadlock" => {
            // Rank 0 waits on a receive no one ever sends.
            let mut b = PlanBuilder::new(0, 2);
            b.recv(1, 1);
            b.wait();
            tuna::comm::replay::execute(&profile, Topology::flat(2), &broken(b))?;
        }
        "undrained" => {
            // Rank 0 sends a message rank 1 never receives.
            let mut b = PlanBuilder::new(0, 2);
            b.send(1, 1, 64);
            b.wait();
            tuna::comm::replay::execute(&profile, Topology::flat(2), &broken(b))?;
        }
        "stale-counts" => {
            // Persistent handle frozen over one workload, started with
            // another: the content-identity check must fire.
            let engine = Engine::new(profile, Topology::flat(8));
            let sizes = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 1);
            let handle = PersistentColl::init(
                &engine,
                AlgoKind::SpreadOut,
                &sizes,
                false,
                tuna::algos::ExecMode::Auto,
            )?;
            let drifted = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 2);
            handle.start(&drifted)?;
        }
        other => {
            return Err(TunaError::config(format!(
                "unknown debug-errors case `{other}` \
                 (shape-mismatch|plan-deadlock|undrained|stale-counts)"
            )));
        }
    }
    // Every case above is constructed to fail; reaching here means the
    // executors accepted a broken input.
    Err(TunaError::validation(format!(
        "debug-errors case `{case}` unexpectedly succeeded"
    )))
}

fn cmd_list() -> Result<()> {
    println!("algorithms:");
    for a in [
        "spread-out",
        "ompi-linear",
        "pairwise",
        "scattered:b=N",
        "vendor",
        "bruck2",
        "tuna:r=N",
        "tuna:auto",
        "hier:l=<tuna:r=N|linear>,g=<coalesced:b=N|staggered:b=N|linear|bruck:r=N>",
        "hier local `balanced` (persistent-only: via `tuna run persistent=true` or `tuna serve`)",
        "tuna-hier-coalesced:r=N,b=M (alias for hier:l=tuna:r=N,g=coalesced:b=M)",
        "tuna-hier-staggered:r=N,b=M (alias for hier:l=tuna:r=N,g=staggered:b=M)",
    ] {
        println!("  {a}");
    }
    println!("profiles: polaris, fugaku, test-flat");
    println!(
        "distributions: uniform:S, normal, powerlaw, const:S, fft-n1, fft-n2, \
         sparse:nnz=K[,max=S]"
    );
    println!(
        "fault clauses (faults= on run, faulted= on select): \
         straggler:rank=R,slow=X, link:node=A-B,bw=F,lat=F, \
         jitter:sigma=S,seed=N, outage:node=N,from=T,until=T \
         ('/'-separated; deterministic, bit-identical across executors)"
    );
    println!(
        "segmented overlap (segments=K, overlap=true|false, compute=secs on run/select): \
         K chunk plans over exact byte ranges, pipelined compute/comm \
         when overlap=true, measured exposed/hidden comm; also `tuna \
         fft`/`tuna tc` pipelined twins, and fig14/fig15 carry \
         exposed-blk/exposed-pipe/overlap-x columns"
    );
    println!("figures: {}", harness::ALL_FIGURES.join(", "));
    Ok(())
}
