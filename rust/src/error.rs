//! Error taxonomy for the public API.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum TunaError {
    /// Invalid configuration (bad radix, block_count, topology, ...).
    #[error("configuration error: {0}")]
    Config(String),

    /// An algorithm produced an invalid result (failed validation).
    #[error("validation error: {0}")]
    Validation(String),

    /// PJRT / artifact runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, TunaError>;

impl TunaError {
    pub fn config(msg: impl Into<String>) -> TunaError {
        TunaError::Config(msg.into())
    }

    pub fn validation(msg: impl Into<String>) -> TunaError {
        TunaError::Validation(msg.into())
    }

    pub fn runtime(msg: impl Into<String>) -> TunaError {
        TunaError::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category() {
        assert!(TunaError::config("bad radix").to_string().contains("configuration"));
        assert!(TunaError::validation("x").to_string().contains("validation"));
        assert!(TunaError::runtime("x").to_string().contains("runtime"));
    }

    #[test]
    fn io_error_converts() {
        let e: TunaError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
    }
}
