//! Fig. 14 — FFT transpose workloads 𝒩₁ and 𝒩₂ (§VI-A): the all-to-allv
//! at the heart of FFTW's distributed transpose, with the paper's two
//! non-uniform decompositions. The full application (local Pallas/PJRT
//! FFT stages + transpose) runs in `examples/fft_e2e.rs`; this figure
//! isolates the communication component the paper's runtime is dominated
//! by.

use super::fig10::hier_candidates;
use super::boxplot::sweep_box;
use super::FigOpts;
use crate::algos::{run_alltoallv_segmented_replay, tuning, AlgoKind, SegmentCompute};
use crate::comm::{Engine, Topology};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};
use crate::workload::{BlockSizes, Dist};

/// Segments of the overlap columns.
const OVERLAP_SEGMENTS: usize = 4;

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 14 — FFT workloads N1/N2",
        &[
            "machine",
            "P",
            "workload",
            "vendor(ms)",
            "tuna*(ms)",
            "coalesced*(ms)",
            "staggered*(ms)",
            "best speedup",
            "exposed-blk(ms)",
            "exposed-pipe(ms)",
            "overlap-x",
            "fidelity",
        ],
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            let q = opts.q().min(p);
            let n = p / q;
            for dist in [Dist::FftN1, Dist::FftN2] {
                let mut cfg = opts.cfg(profile, p, 0);
                cfg.dist = dist;
                let vendor = measure(&cfg, &AlgoKind::Vendor)?;
                let tuna_c: Vec<AlgoKind> = tuning::radix_candidates(p)
                    .into_iter()
                    .map(|radix| AlgoKind::Tuna { radix })
                    .collect();
                let tuna = sweep_box(&cfg, &tuna_c)?;
                let (coal_t, stag_t) = if n >= 2 {
                    (
                        sweep_box(&cfg, &hier_candidates(q, n, true))?.best_time,
                        sweep_box(&cfg, &hier_candidates(q, n, false))?.best_time,
                    )
                } else {
                    (tuna.best_time, tuna.best_time)
                };
                let v = vendor.median();
                let best = tuna.best_time.min(coal_t).min(stag_t);
                // Overlap columns: the same transpose workload run as a
                // K-segment phantom collective on the replay executor,
                // with per-segment compute sized to the blocking run's
                // per-segment cost — the regime where a pipeline can at
                // best halve the critical path. `exposed` is measured by
                // the clocks, not inferred from the model.
                let engine = Engine::new(profile.clone(), Topology::new(p, q));
                let sizes = BlockSizes::generate(p, dist, opts.seed);
                let okind = AlgoKind::Tuna { radix: 4.min(p).max(2) };
                let probe = run_alltoallv_segmented_replay(
                    &engine,
                    &okind,
                    &sizes,
                    OVERLAP_SEGMENTS,
                    false,
                    &SegmentCompute::None,
                )?;
                let per_seg = probe.makespan / OVERLAP_SEGMENTS as f64;
                let compute = SegmentCompute::Uniform(per_seg);
                let blk = run_alltoallv_segmented_replay(
                    &engine,
                    &okind,
                    &sizes,
                    OVERLAP_SEGMENTS,
                    false,
                    &compute,
                )?;
                let pipe = run_alltoallv_segmented_replay(
                    &engine,
                    &okind,
                    &sizes,
                    OVERLAP_SEGMENTS,
                    true,
                    &compute,
                )?;
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    dist.name().into(),
                    cell_f(v * 1e3),
                    cell_f(tuna.best_time * 1e3),
                    cell_f(coal_t * 1e3),
                    cell_f(stag_t * 1e3),
                    format!("{:.2}x", v / best),
                    cell_f(blk.counters.exposed_comm * 1e3),
                    cell_f(pipe.counters.exposed_comm * 1e3),
                    format!("{:.2}x", blk.makespan / pipe.makespan),
                    tuna.fidelity.name().into(),
                ]);
            }
        }
    }
    table.note("paper: coalesced TuNA_l^g 9.42x (N1) / 4.01x (N2) over vendor at P=8192");
    opts.finish("fig14_fft_app", vec![table])
}
