//! Fig. 9 — ranges of radix where TuNA outperforms MPI_Alltoallv, per
//! (P, S), rendered as a textual heatmap: the winning sub-range of
//! [2, P], and the gain at the ideal radix (the paper's red intensity).

use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::Table;

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 9 — winning radix ranges (TuNA < vendor)",
        &[
            "machine", "P", "S(B)", "win range", "of range", "win frac", "ideal r", "gain",
        ],
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let vendor = measure(&cfg, &AlgoKind::Vendor)?.median();
                let radices = tuning::radix_candidates(p);
                let mut wins: Vec<usize> = Vec::new();
                let mut best = (0usize, f64::INFINITY);
                for &r in &radices {
                    let t = measure(&cfg, &AlgoKind::Tuna { radix: r })?.median();
                    if t < vendor {
                        wins.push(r);
                    }
                    if t < best.1 {
                        best = (r, t);
                    }
                }
                let win_range = if wins.is_empty() {
                    "none".to_string()
                } else {
                    format!("[{}..{}]", wins.iter().min().unwrap(), wins.iter().max().unwrap())
                };
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    s.to_string(),
                    win_range,
                    format!("[2..{p}]"),
                    format!("{:.0}%", 100.0 * wins.len() as f64 / radices.len() as f64),
                    best.0.to_string(),
                    format!("{:.2}x", vendor / best.1),
                ]);
            }
        }
    }
    table.note("gain = vendor / best TuNA; 'win frac' = fraction of sampled radices beating vendor");
    opts.finish("fig09_radix_heatmap", vec![table])
}
