//! Fig. 9 — ranges of radix where TuNA outperforms MPI_Alltoallv, per
//! (P, S), rendered as a textual heatmap: the winning sub-range of
//! [2, P], and the gain at the ideal radix (the paper's red intensity).
//! The "ideal r" cell comes from the selector's measured ranking and is
//! cross-checked against its analytic pick ("model r").

use super::FigOpts;
use crate::algos::{select, tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::Table;

fn radix_of(kind: &AlgoKind) -> usize {
    match kind {
        AlgoKind::Tuna { radix } => *radix,
        _ => unreachable!("fig9 ranks only tuna candidates"),
    }
}

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 9 — winning radix ranges (TuNA < vendor)",
        &[
            "machine", "P", "S(B)", "win range", "of range", "win frac", "ideal r", "model r",
            "gain",
        ],
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let vendor = measure(&cfg, &AlgoKind::Vendor)?.median();
                let candidates: Vec<AlgoKind> = tuning::radix_candidates(p)
                    .into_iter()
                    .map(|radix| AlgoKind::Tuna { radix })
                    .collect();
                let ranked = select::rank_measured(&cfg, &candidates)?;
                let best = ranked[0];
                let model_pick = ranked
                    .iter()
                    .min_by(|a, b| a.model_time.partial_cmp(&b.model_time).unwrap())
                    .unwrap();
                let wins: Vec<usize> = ranked
                    .iter()
                    .filter(|sc| sc.time() < vendor)
                    .map(|sc| radix_of(&sc.kind))
                    .collect();
                let win_range = if wins.is_empty() {
                    "none".to_string()
                } else {
                    format!(
                        "[{}..{}]",
                        wins.iter().min().unwrap(),
                        wins.iter().max().unwrap()
                    )
                };
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    s.to_string(),
                    win_range,
                    format!("[2..{p}]"),
                    format!("{:.0}%", 100.0 * wins.len() as f64 / ranked.len() as f64),
                    radix_of(&best.kind).to_string(),
                    radix_of(&model_pick.kind).to_string(),
                    format!("{:.2}x", vendor / best.time()),
                ]);
            }
        }
    }
    table.note(
        "gain = vendor / best TuNA; ideal r = selector's measured pick, model r = its analytic pick",
    );
    opts.finish("fig09_radix_heatmap", vec![table])
}
