//! Fig. 11 — six-component cost breakdown of coalesced (left bar) vs
//! staggered (right bar) TuNA_l^g at their ideal parameters: prepare,
//! metadata, data, replace (inter-buffer copying), rearrange (coalesced
//! only), inter-node communication.

use super::fig10::hier_candidates;
use super::boxplot::sweep_box;
use super::FigOpts;
use crate::comm::{Phase, PHASES};
use crate::util::table::{cell_f, Table};

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let phases: Vec<Phase> = PHASES
        .iter()
        .copied()
        .filter(|p| {
            matches!(
                p,
                Phase::Prepare
                    | Phase::Metadata
                    | Phase::Data
                    | Phase::Replace
                    | Phase::Rearrange
                    | Phase::InterNode
            )
        })
        .collect();
    let mut header: Vec<&str> = vec!["machine", "P", "S(B)", "variant", "params"];
    let phase_names: Vec<String> = phases.iter().map(|p| format!("{}(ms)", p.name())).collect();
    header.extend(phase_names.iter().map(|s| s.as_str()));
    header.push("total(ms)");
    let mut table = Table::new("Fig. 11 — TuNA_l^g cost breakdown", &header);

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            let q = opts.q().min(p);
            let n = p / q;
            if n < 2 {
                continue;
            }
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                for coalesced in [true, false] {
                    let sb = sweep_box(&cfg, &hier_candidates(q, n, coalesced))?;
                    let mut row = vec![
                        profile.name.to_string(),
                        p.to_string(),
                        s.to_string(),
                        if coalesced { "coalesced" } else { "staggered" }.to_string(),
                        sb.best.name(),
                    ];
                    for ph in &phases {
                        row.push(cell_f(sb.best_measure.phases.get(*ph) * 1e3));
                    }
                    row.push(cell_f(sb.best_measure.phases.total() * 1e3));
                    table.row(row);
                }
            }
        }
    }
    table.note("paper: staggered's inter-node cost dominates; rearrange applies to coalesced only");
    opts.finish("fig11_breakdown", vec![table])
}
