//! Figure/table regeneration harness.
//!
//! One module per evaluation figure (Fig. 7 — Fig. 16); each produces
//! [`Table`]s, printed by the CLI and written as `.txt` + `.csv` under
//! `results/`. Two grids exist per figure: the *quick* grid (default;
//! exact fidelity, minutes on a laptop-class host — used by `cargo
//! bench`) and the *full* paper-scale grid (`--full`; points up to
//! P = 4096 run exactly on the plan/replay executor, larger ones fall
//! back to the analytic model — recorded per row in the `fidelity`
//! column).

pub mod boxplot;
pub mod chaos;
pub mod fig07;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod serve;

use std::path::PathBuf;

use crate::coordinator::RunConfig;
use crate::model::MachineProfile;
use crate::util::table::Table;
use crate::workload::Dist;

/// Options shared by all figure generators.
#[derive(Clone, Debug)]
pub struct FigOpts {
    /// Paper-scale grids (up to P = 16,384) instead of the quick grids.
    pub full: bool,
    /// Machine profiles to evaluate (paper: Polaris and Fugaku).
    pub profiles: Vec<MachineProfile>,
    /// Output directory for `.txt`/`.csv` artifacts.
    pub out_dir: PathBuf,
    /// Iterations per measured point.
    pub iters: usize,
    pub seed: u64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            full: false,
            profiles: vec![MachineProfile::polaris(), MachineProfile::fugaku()],
            out_dir: PathBuf::from("results"),
            iters: 3,
            seed: 0xC0FFEE,
        }
    }
}

impl FigOpts {
    /// Quick single-profile options for `cargo bench`.
    pub fn bench() -> FigOpts {
        FigOpts {
            profiles: vec![MachineProfile::fugaku()],
            iters: 2,
            ..FigOpts::default()
        }
    }

    /// Process counts for scaling sweeps. The full grid's 512–4096
    /// points run exactly on the plan/replay executor — P counts that
    /// thread-per-rank simulation never attempted.
    pub fn ps(&self) -> Vec<usize> {
        if self.full {
            vec![512, 2048, 4096, 8192, 16384]
        } else {
            vec![64, 128, 256]
        }
    }

    /// Ranks per node (paper: 32 on both machines).
    pub fn q(&self) -> usize {
        if self.full {
            32
        } else {
            8
        }
    }

    /// Max block sizes S (bytes).
    pub fn ss(&self) -> Vec<u64> {
        if self.full {
            vec![16, 512, 2048, 16384]
        } else {
            vec![16, 512, 2048, 16384]
        }
    }

    /// Base run config for a (profile, P, S) point. Grids are phantom,
    /// so exact points run on the bit-identical plan/replay executor
    /// (no rank threads): the quick grids entirely, the full
    /// (paper-scale) grids up to the default replay budget of P = 4096
    /// for logarithmic families. Beyond that the analytic model takes
    /// over (recorded per row in the `fidelity` column) so the
    /// P <= 16,384 grids still finish in minutes on one core; the
    /// dedicated `analytic_vs_engine` and `replay_equivalence` suites
    /// provide the exactness cross-checks.
    pub fn cfg(&self, profile: &MachineProfile, p: usize, s: u64) -> RunConfig {
        let (lim_linear, lim_log) = if self.full { (0, 0) } else { (512, 2048) };
        RunConfig {
            p,
            q: self.q().min(p),
            profile: profile.clone(),
            dist: Dist::Uniform { max: s },
            seed: self.seed,
            iters: self.iters,
            engine_limit_linear: lim_linear,
            engine_limit_log: lim_log,
            ..RunConfig::default()
        }
    }

    /// Write and return tables.
    pub fn finish(&self, stem: &str, tables: Vec<Table>) -> crate::Result<Vec<Table>> {
        for (i, t) in tables.iter().enumerate() {
            let name = if tables.len() == 1 {
                stem.to_string()
            } else {
                format!("{stem}_{i}")
            };
            t.write_files(&self.out_dir, &name)?;
        }
        Ok(tables)
    }
}

/// Run a figure by name ("fig7" .. "fig16").
pub fn run_figure(name: &str, opts: &FigOpts) -> crate::Result<Vec<Table>> {
    match name {
        "fig7" => fig07::run(opts),
        "fig8" => fig08::run(opts),
        "fig9" => fig09::run(opts),
        "fig10" => fig10::run(opts),
        "fig11" => fig11::run(opts),
        "fig12" => fig12::run(opts),
        "fig13" => fig13::run(opts),
        "fig14" => fig14::run(opts),
        "fig15" => fig15::run(opts),
        "fig16" => fig16::run(opts),
        _ => Err(crate::TunaError::config(format!(
            "unknown figure `{name}` (fig7..fig16)"
        ))),
    }
}

pub const ALL_FIGURES: [&str; 10] = [
    "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_differ_between_quick_and_full() {
        let quick = FigOpts::default();
        let full = FigOpts {
            full: true,
            ..FigOpts::default()
        };
        assert!(quick.ps().iter().max() < full.ps().iter().max());
        assert_eq!(full.q(), 32);
        assert!(quick.ps().iter().all(|p| p % quick.q() == 0));
        assert!(full.ps().iter().all(|p| p % full.q() == 0));
        // The full grid exercises the replay-budget boundary: at least
        // one point at the default budget and one beyond it.
        let default_replay = crate::coordinator::RunConfig::default().engine_limit_replay;
        assert!(full.ps().contains(&default_replay));
        assert!(full.ps().iter().any(|&p| p > default_replay));
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(run_figure("fig99", &FigOpts::default()).is_err());
    }
}
