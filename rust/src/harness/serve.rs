//! `tuna serve` — the multi-tenant serving harness.
//!
//! Builds a heterogeneous tenant mix (cycling distributions, alternating
//! process counts, algorithms drawn from the persistent menu — the
//! balanced local schedule included where the topology allows it),
//! measures each tenant's per-call demand through its
//! [`PersistentColl`]-backed handle, simulates Poisson traffic through
//! the shared serving engine ([`crate::coordinator::serve`]), prints the
//! per-tenant p50/p95/p99 table, and writes `BENCH_serve.json` with the
//! same numbers plus a pace sweep of the admission knob.

use std::path::PathBuf;

use crate::algos::{AlgoKind, GlobalAlgo, LocalAlgo};
use crate::coordinator::serve::{
    measure_tenants_counters, simulate, PlanCacheCounters, ServeConfig, ServeReport, TenantSpec,
};
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::util::stats::fmt_time;
use crate::util::table::Table;
use crate::workload::Dist;

/// CLI arguments of `tuna serve`.
#[derive(Clone, Debug)]
pub struct ServeArgs {
    /// Tenant count.
    pub tenants: usize,
    /// Base process count (odd-indexed tenants run at P/2 when the
    /// topology allows, so the mix is heterogeneous in scale too).
    pub p: usize,
    pub q: usize,
    /// Arrival horizon, simulated seconds.
    pub seconds: f64,
    /// Target offered load Σ rate·demand (each tenant gets an equal
    /// share: its rate is `load / (tenants · demand)`).
    pub load: f64,
    /// Admission-control knob: max concurrently admitted calls
    /// (0 = unlimited processor sharing).
    pub pace: usize,
    /// Per-attempt deadline applied to every tenant, simulated seconds
    /// (0 = none): timed-out calls are retried with exponential backoff
    /// and shed when the budget runs out.
    pub deadline: f64,
    /// Retry budget per call (requires a deadline).
    pub retries: u32,
    /// Retained-plan bound per tenant engine (`plan-cache-cap=N`, LRU).
    /// Generous by default — the knob exists so long-lived serving
    /// deployments can bound plan memory; evictions are reported next
    /// to hits/misses.
    pub plan_cache_cap: usize,
    pub seed: u64,
    pub profile: MachineProfile,
    /// Output path for the JSON artifact.
    pub out: PathBuf,
    /// Smoke mode: lighter default load and a shorter pace sweep.
    pub quick: bool,
}

impl Default for ServeArgs {
    fn default() -> Self {
        ServeArgs {
            tenants: 4,
            p: 1024,
            q: 16,
            seconds: 5.0,
            load: 0.7,
            pace: 0,
            deadline: 0.0,
            retries: 0,
            plan_cache_cap: 64,
            seed: 0xC0FFEE,
            profile: MachineProfile::fugaku(),
            out: PathBuf::from("BENCH_serve.json"),
            quick: false,
        }
    }
}

impl ServeArgs {
    /// Parse `tenants=4 p=1024 q=16 seconds=2 load=0.7 pace=0
    /// deadline=0.01 retries=2 seed=7 profile=fugaku
    /// out=BENCH_serve.json` plus the `--quick` flag.
    pub fn parse(args: &[String]) -> Result<ServeArgs> {
        let mut a = ServeArgs::default();
        let mut load_given = false;
        for arg in args {
            if arg == "--quick" {
                a.quick = true;
                continue;
            }
            let (k, v) = arg
                .split_once('=')
                .ok_or_else(|| TunaError::config(format!("expected key=value, got `{arg}`")))?;
            let num = |v: &str| -> Result<usize> {
                v.parse()
                    .map_err(|_| TunaError::config(format!("bad number for {k}: `{v}`")))
            };
            let fnum = |v: &str| -> Result<f64> {
                v.parse()
                    .map_err(|_| TunaError::config(format!("bad number for {k}: `{v}`")))
            };
            match k {
                "tenants" => a.tenants = num(v)?,
                "p" => a.p = num(v)?,
                "q" => a.q = num(v)?,
                "seconds" => a.seconds = fnum(v)?,
                "load" => {
                    a.load = fnum(v)?;
                    load_given = true;
                }
                "pace" => a.pace = num(v)?,
                "deadline" => a.deadline = fnum(v)?,
                "retries" => a.retries = num(v)? as u32,
                "plan-cache-cap" => {
                    a.plan_cache_cap = num(v)?;
                    if a.plan_cache_cap == 0 {
                        return Err(TunaError::config("serve: plan-cache-cap must be >= 1"));
                    }
                }
                "seed" => a.seed = num(v)? as u64,
                "profile" => {
                    a.profile = MachineProfile::by_name(v).ok_or_else(|| {
                        TunaError::config(format!(
                            "unknown profile `{v}` (try polaris, fugaku, test-flat)"
                        ))
                    })?
                }
                "out" => a.out = PathBuf::from(v),
                _ => return Err(TunaError::config(format!("unknown serve key `{k}`"))),
            }
        }
        if a.quick && !load_given {
            a.load = 0.5;
        }
        if a.tenants == 0 {
            return Err(TunaError::config("serve: tenants must be >= 1"));
        }
        if !(a.load > 0.0) {
            return Err(TunaError::config("serve: load must be > 0"));
        }
        crate::comm::Topology::try_new(a.p, a.q)?;
        Ok(a)
    }
}

/// The algorithm menu tenants cycle through: the persistent-only
/// balanced composition deliberately included (the serving engine runs
/// everything through persistent handles, which is the only path that
/// admits it), filtered to what this (P, Q) topology can run.
fn algo_menu(p: usize, q: usize) -> Vec<AlgoKind> {
    let menu = [
        AlgoKind::Tuna { radix: 4 },
        AlgoKind::Hier { local: LocalAlgo::Balanced, global: GlobalAlgo::Linear },
        AlgoKind::SpreadOut,
        AlgoKind::Hier {
            local: LocalAlgo::Tuna { radix: 2 },
            global: GlobalAlgo::Coalesced { block_count: 1 },
        },
        AlgoKind::Bruck2,
        AlgoKind::Pairwise,
    ];
    let mut out: Vec<AlgoKind> = menu.into_iter().filter(|k| k.check(p, q).is_ok()).collect();
    if out.is_empty() {
        out.push(AlgoKind::SpreadOut);
    }
    out
}

/// Build the heterogeneous tenant mix: distributions cycle, odd tenants
/// drop to P/2 where the topology allows, algorithms cycle through
/// [`algo_menu`]. Rates are provisional (1.0) — [`run`] rebalances them
/// to the target offered load once demands are measured.
pub fn default_tenants(a: &ServeArgs) -> Vec<TenantSpec> {
    let dists = [
        Dist::Uniform { max: 1024 },
        Dist::normal_default(),
        Dist::powerlaw_default(),
        Dist::Sparse { nnz: 8, max: 1024 },
    ];
    (0..a.tenants)
        .map(|i| {
            let half = a.p / 2;
            let p = if i % 2 == 1 && half >= a.q && half % a.q == 0 && half >= 2 {
                half
            } else {
                a.p
            };
            let menu = algo_menu(p, a.q);
            TenantSpec {
                name: format!("t{i}"),
                p,
                q: a.q,
                dist: dists[i % dists.len()],
                algo: menu[i % menu.len()],
                rate: 1.0,
                seed: a.seed.wrapping_add(i as u64),
                deadline: a.deadline,
                retries: a.retries,
            }
        })
        .collect()
}

/// Pace values the JSON artifact sweeps (reusing the measured demands)
/// so the admission knob's effect is visible without re-running.
fn pace_sweep(quick: bool) -> Vec<usize> {
    if quick {
        vec![0, 4]
    } else {
        vec![0, 1, 2, 4, 8]
    }
}

/// Run the serving harness: measure demands, balance rates to the target
/// offered load, simulate, and return the report with its table and JSON.
pub fn run(a: &ServeArgs) -> Result<(ServeReport, Table, String)> {
    let mut cfg = ServeConfig {
        tenants: default_tenants(a),
        profile: a.profile.clone(),
        seconds: a.seconds,
        pace: a.pace,
        seed: a.seed,
        plan_cache_cap: a.plan_cache_cap,
    };
    let (demands, cache) = measure_tenants_counters(&cfg)?;
    // Equal offered-load share per tenant: Σ rate·demand == a.load.
    for (t, &d) in cfg.tenants.iter_mut().zip(&demands) {
        t.rate = a.load / (a.tenants as f64 * d.max(1e-30));
    }
    let report = simulate(&cfg, &demands);

    let mut table = Table::new(
        format!(
            "tuna serve — {} tenants on {} (load {:.2}, pace {})",
            a.tenants,
            a.profile.name,
            a.load,
            if a.pace == 0 { "unlimited".to_string() } else { a.pace.to_string() },
        ),
        &[
            "tenant", "algo", "P", "Q", "dist", "calls", "demand", "p50", "p95", "p99", "shed",
            "goodput",
        ],
    );
    for t in &report.tenants {
        table.row(vec![
            t.name.clone(),
            t.algo.clone(),
            t.p.to_string(),
            t.q.to_string(),
            t.dist.clone(),
            t.calls.to_string(),
            fmt_time(t.demand),
            fmt_time(t.p50),
            fmt_time(t.p95),
            fmt_time(t.p99),
            t.shed.to_string(),
            format!("{:.3}", t.goodput),
        ]);
    }
    table.note(format!(
        "offered load {:.3}; {} calls over {:.1}s horizon, drained at {:.3}s",
        report.offered_load, report.total_calls, report.seconds, report.drain
    ));
    table.note(
        "demands measured once per tenant through a persistent handle; \
         latencies include queueing under processor-sharing contention",
    );
    table.note(format!(
        "plan cache (LRU, cap {} per engine): {} hits, {} misses, {} evictions",
        cache.capacity, cache.hits, cache.misses, cache.evictions
    ));

    let json = to_json(a, &cfg, &demands, &cache, &report);
    Ok((report, table, json))
}

fn fmt_f(v: f64) -> String {
    format!("{v:.9e}")
}

/// Hand-rolled JSON (the crate deliberately has no serde dependency).
fn to_json(
    a: &ServeArgs,
    cfg: &ServeConfig,
    demands: &[f64],
    cache: &PlanCacheCounters,
    report: &ServeReport,
) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!(
        "  \"config\": {{\"tenants\": {}, \"p\": {}, \"q\": {}, \"seconds\": {}, \
         \"load\": {}, \"pace\": {}, \"seed\": {}, \"profile\": \"{}\", \"quick\": {}}},\n",
        a.tenants, a.p, a.q, a.seconds, a.load, a.pace, a.seed, a.profile.name, a.quick
    ));
    s.push_str(&format!(
        "  \"degradation\": {{\"deadline_s\": {}, \"retries\": {}}},\n",
        fmt_f(a.deadline),
        a.retries
    ));
    s.push_str(&format!(
        "  \"plan_cache\": {{\"capacity\": {}, \"hits\": {}, \"misses\": {}, \
         \"evictions\": {}}},\n",
        cache.capacity, cache.hits, cache.misses, cache.evictions
    ));
    s.push_str(&format!("  \"offered_load\": {},\n", fmt_f(report.offered_load)));
    s.push_str(&format!("  \"total_calls\": {},\n", report.total_calls));
    s.push_str(&format!("  \"drain_s\": {},\n", fmt_f(report.drain)));
    s.push_str("  \"tenants\": [\n");
    for (i, t) in report.tenants.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"algo\": \"{}\", \"p\": {}, \"q\": {}, \
             \"dist\": \"{}\", \"rate_hz\": {}, \"demand_s\": {}, \"calls\": {}, \
             \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \"mean_s\": {}, \"max_s\": {}, \
             \"timeouts\": {}, \"retries\": {}, \"shed\": {}, \"goodput\": {}}}{}\n",
            t.name,
            t.algo,
            t.p,
            t.q,
            t.dist,
            fmt_f(t.rate),
            fmt_f(t.demand),
            t.calls,
            fmt_f(t.p50),
            fmt_f(t.p95),
            fmt_f(t.p99),
            fmt_f(t.mean),
            fmt_f(t.max),
            t.timeouts,
            t.retries,
            t.shed,
            fmt_f(t.goodput),
            if i + 1 < report.tenants.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    // The admission knob, swept over the same arrivals and demands: the
    // aggregate worst p99 per pace value shows what pacing buys (or
    // costs) without re-measuring anything.
    s.push_str("  \"pace_sweep\": [\n");
    let paces = pace_sweep(a.quick);
    for (i, &pace) in paces.iter().enumerate() {
        let r = simulate(&ServeConfig { pace, ..cfg.clone() }, demands);
        let worst_p99 = r.tenants.iter().map(|t| t.p99).fold(0.0, f64::max);
        let worst_p50 = r.tenants.iter().map(|t| t.p50).fold(0.0, f64::max);
        s.push_str(&format!(
            "    {{\"pace\": {}, \"worst_p50_s\": {}, \"worst_p99_s\": {}, \"drain_s\": {}}}{}\n",
            pace,
            fmt_f(worst_p50),
            fmt_f(worst_p99),
            fmt_f(r.drain),
            if i + 1 < paces.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// CLI entry: parse, run, print the table, write the JSON artifact.
pub fn cmd(args: &[String]) -> Result<()> {
    let a = ServeArgs::parse(args)?;
    let (report, table, json) = run(&a)?;
    println!("{}", table.render());
    std::fs::write(&a.out, &json)?;
    println!(
        "serve: {} calls, offered load {:.3}, artifact {}",
        report.total_calls,
        report.offered_load,
        a.out.display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_serve_args() {
        let a = ServeArgs::parse(&args("tenants=4 p=64 q=8 seconds=2 load=0.6 pace=2 seed=9"))
            .unwrap();
        assert_eq!(a.tenants, 4);
        assert_eq!((a.p, a.q), (64, 8));
        assert_eq!(a.seconds, 2.0);
        assert_eq!(a.load, 0.6);
        assert_eq!(a.pace, 2);
        assert!(!a.quick);
        let q = ServeArgs::parse(&args("--quick tenants=2 p=16 q=4")).unwrap();
        assert!(q.quick);
        assert_eq!(q.load, 0.5, "quick lowers the default load");
        assert!(ServeArgs::parse(&args("tenants=0")).is_err());
        assert!(ServeArgs::parse(&args("p=10 q=4")).is_err());
        assert!(ServeArgs::parse(&args("pace=lots")).is_err());
        assert!(ServeArgs::parse(&args("bogus=1")).is_err());
        let d = ServeArgs::parse(&args("deadline=0.01 retries=2")).unwrap();
        assert_eq!(d.deadline, 0.01);
        assert_eq!(d.retries, 2);
        assert!(ServeArgs::parse(&args("deadline=soon")).is_err());
        assert_eq!(ServeArgs::default().plan_cache_cap, 64, "generous default");
        let c = ServeArgs::parse(&args("plan-cache-cap=2")).unwrap();
        assert_eq!(c.plan_cache_cap, 2);
        assert!(ServeArgs::parse(&args("plan-cache-cap=0")).is_err());
        assert!(ServeArgs::parse(&args("plan-cache-cap=big")).is_err());
    }

    #[test]
    fn degraded_serve_harness_reports_shedding() {
        // A deadline far below any demand sheds every call: goodput 0,
        // and the artifact carries the degradation columns.
        let a = ServeArgs {
            tenants: 2,
            p: 16,
            q: 4,
            seconds: 0.05,
            load: 0.5,
            deadline: 1e-9,
            retries: 1,
            profile: MachineProfile::test_flat(),
            quick: true,
            ..ServeArgs::default()
        };
        let (report, table, json) = run(&a).unwrap();
        assert!(report.tenants.iter().all(|t| t.goodput == 0.0));
        assert!(report.tenants.iter().all(|t| t.shed > 0 && t.retries > 0));
        assert!(json.contains("\"degradation\""));
        assert!(json.contains("\"goodput\""));
        assert!(table.rows.iter().all(|r| r.last().unwrap().as_str() == "0.000"));
        // Deterministic under degradation too.
        let (_, _, json2) = run(&a).unwrap();
        assert_eq!(json, json2);
    }

    #[test]
    fn tenant_mix_is_heterogeneous() {
        let a = ServeArgs {
            tenants: 4,
            p: 32,
            q: 4,
            ..ServeArgs::default()
        };
        let ts = default_tenants(&a);
        assert_eq!(ts.len(), 4);
        // Odd tenants drop to P/2; distributions cycle; every algo is
        // runnable on its tenant's topology.
        assert_eq!(ts[0].p, 32);
        assert_eq!(ts[1].p, 16);
        let dists: std::collections::HashSet<&str> =
            ts.iter().map(|t| t.dist.name()).collect();
        assert!(dists.len() >= 3, "distribution mix too homogeneous");
        for t in &ts {
            t.algo.check(t.p, t.q).unwrap();
        }
        // The persistent-only balanced composition is in the mix.
        assert!(
            ts.iter().any(|t| t.algo.persistent_only()),
            "balanced composition missing from the tenant mix"
        );
    }

    #[test]
    fn serve_harness_end_to_end() {
        let a = ServeArgs {
            tenants: 3,
            p: 16,
            q: 4,
            seconds: 0.2,
            load: 0.5,
            profile: MachineProfile::test_flat(),
            quick: true,
            ..ServeArgs::default()
        };
        let (report, table, json) = run(&a).unwrap();
        assert_eq!(report.tenants.len(), 3);
        assert!(report.total_calls > 0);
        // Rates were balanced to the target offered load exactly:
        // Σ (load / (n·dᵢ)) · dᵢ == load up to rounding.
        assert!((report.offered_load - 0.5).abs() < 1e-9, "load {}", report.offered_load);
        assert_eq!(table.rows.len(), 3);
        assert!(json.contains("\"pace_sweep\""));
        assert!(json.contains("\"p99_s\""));
        assert!(json.contains("\"plan_cache\""));
        assert!(json.contains("\"evictions\""));
        // Deterministic end to end.
        let (_, _, json2) = run(&a).unwrap();
        assert_eq!(json, json2);
    }
}
