//! Fig. 8 — TuNA (box over radices) vs vendor MPI_Alltoallv across P and
//! S on both machines. The paper's headline single-level result: TuNA
//! wins decisively for S ≤ 2 KiB (Polaris) / 16 KiB (Fugaku), e.g. 29x /
//! 70x at P=8192, S=16.

use super::boxplot::{box_cells, sweep_box, BOX_HEADER};
use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut header = vec!["machine", "P", "S(B)"];
    header.extend_from_slice(&BOX_HEADER);
    header.extend_from_slice(&["ideal r", "vendor(ms)", "speedup", "fidelity"]);
    let mut table = Table::new("Fig. 8 — TuNA vs MPI_Alltoallv", &header);

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let candidates: Vec<AlgoKind> = tuning::radix_candidates(p)
                    .into_iter()
                    .map(|radix| AlgoKind::Tuna { radix })
                    .collect();
                let sb = sweep_box(&cfg, &candidates)?;
                let vendor = measure(&cfg, &AlgoKind::Vendor)?;
                let ideal_r = match sb.best {
                    AlgoKind::Tuna { radix } => radix,
                    _ => unreachable!(),
                };
                let mut row = vec![profile.name.to_string(), p.to_string(), s.to_string()];
                row.extend(box_cells(&sb.box_stats));
                row.push(ideal_r.to_string());
                row.push(cell_f(vendor.median() * 1e3));
                row.push(format!("{:.2}x", vendor.median() / sb.best_time));
                row.push(sb.fidelity.name().into());
                table.row(row);
            }
        }
    }
    table.note("speedup = vendor / TuNA-with-ideal-radix; paper reports up to 70x (Fugaku, small S)");
    opts.finish("fig08_tuna_vs_vendor", vec![table])
}
