//! Parameter-sweep box plots: the paper presents tunable algorithms as a
//! box over the parameter range with the ideal value annotated beneath
//! (Figs. 8, 10, 12). The ideal comes from the selector's measured
//! ranking ([`select::rank_measured`]) rather than a local argmin.

use crate::algos::{select, AlgoKind};
use crate::coordinator::{Fidelity, Measurement, RunConfig};
use crate::util::stats::Summary;

/// Result of sweeping one tunable algorithm over its parameter range.
#[derive(Clone, Debug)]
pub struct SweepBox {
    /// Distribution of median times across the parameter range.
    pub box_stats: Summary,
    /// Best candidate and its median time.
    pub best: AlgoKind,
    pub best_time: f64,
    pub best_measure: Measurement,
    pub fidelity: Fidelity,
}

/// Measure every candidate through the selector, box the medians, and
/// take the ideal from its ranking.
pub fn sweep_box(cfg: &RunConfig, candidates: &[AlgoKind]) -> crate::Result<SweepBox> {
    assert!(!candidates.is_empty());
    let mut ranked = select::rank_measured_detailed(cfg, candidates)?;
    let medians: Vec<f64> = ranked.iter().map(|(sc, _)| sc.time()).collect();
    let (best, best_measure) = ranked.swap_remove(0);
    let fidelity = best_measure.fidelity;
    Ok(SweepBox {
        box_stats: Summary::of(&medians),
        best: best.kind,
        best_time: best.time(),
        best_measure,
        fidelity,
    })
}

/// Render a box as the compact `min/q1/med/q3/max` cell set.
pub fn box_cells(s: &Summary) -> Vec<String> {
    [s.min, s.q1, s.median, s.q3, s.max]
        .iter()
        .map(|v| format!("{:.4}", v * 1e3))
        .collect()
}

pub const BOX_HEADER: [&str; 5] = ["min(ms)", "q1(ms)", "med(ms)", "q3(ms)", "max(ms)"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dist;

    #[test]
    fn sweep_finds_minimum() {
        let cfg = RunConfig {
            p: 16,
            q: 4,
            dist: Dist::Uniform { max: 128 },
            iters: 2,
            ..RunConfig::default()
        };
        let candidates: Vec<AlgoKind> = [2usize, 4, 16]
            .iter()
            .map(|&radix| AlgoKind::Tuna { radix })
            .collect();
        let sb = sweep_box(&cfg, &candidates).unwrap();
        assert_eq!(sb.box_stats.n, 3);
        assert_eq!(sb.best_time, sb.box_stats.min);
        assert!(candidates.contains(&sb.best));
    }

    #[test]
    fn box_cells_are_ms() {
        let s = Summary::of(&[0.001, 0.002, 0.003]);
        let cells = box_cells(&s);
        assert_eq!(cells.len(), 5);
        assert_eq!(cells[2], "2.0000");
    }
}
