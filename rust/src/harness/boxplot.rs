//! Parameter-sweep box plots: the paper presents tunable algorithms as a
//! box over the parameter range with the ideal value annotated beneath
//! (Figs. 8, 10, 12).

use crate::algos::AlgoKind;
use crate::coordinator::{measure, Fidelity, Measurement, RunConfig};
use crate::util::stats::Summary;

/// Result of sweeping one tunable algorithm over its parameter range.
#[derive(Clone, Debug)]
pub struct SweepBox {
    /// Distribution of median times across the parameter range.
    pub box_stats: Summary,
    /// Best candidate and its median time.
    pub best: AlgoKind,
    pub best_time: f64,
    pub best_measure: Measurement,
    pub fidelity: Fidelity,
}

/// Measure every candidate, box the medians, find the ideal.
pub fn sweep_box(cfg: &RunConfig, candidates: &[AlgoKind]) -> crate::Result<SweepBox> {
    assert!(!candidates.is_empty());
    let mut medians = Vec::with_capacity(candidates.len());
    let mut best: Option<(AlgoKind, f64, Measurement)> = None;
    let mut fidelity = Fidelity::Engine;
    for kind in candidates {
        let m = measure(cfg, kind)?;
        fidelity = m.fidelity;
        let t = m.median();
        medians.push(t);
        if best.as_ref().map(|b| t < b.1).unwrap_or(true) {
            best = Some((*kind, t, m));
        }
    }
    let (best, best_time, best_measure) = best.unwrap();
    Ok(SweepBox {
        box_stats: Summary::of(&medians),
        best,
        best_time,
        best_measure,
        fidelity,
    })
}

/// Render a box as the compact `min/q1/med/q3/max` cell set.
pub fn box_cells(s: &Summary) -> Vec<String> {
    [s.min, s.q1, s.median, s.q3, s.max]
        .iter()
        .map(|v| format!("{:.4}", v * 1e3))
        .collect()
}

pub const BOX_HEADER: [&str; 5] = ["min(ms)", "q1(ms)", "med(ms)", "q3(ms)", "max(ms)"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dist;

    #[test]
    fn sweep_finds_minimum() {
        let cfg = RunConfig {
            p: 16,
            q: 4,
            dist: Dist::Uniform { max: 128 },
            iters: 2,
            ..RunConfig::default()
        };
        let candidates: Vec<AlgoKind> = [2usize, 4, 16]
            .iter()
            .map(|&radix| AlgoKind::Tuna { radix })
            .collect();
        let sb = sweep_box(&cfg, &candidates).unwrap();
        assert_eq!(sb.box_stats.n, 3);
        assert_eq!(sb.best_time, sb.box_stats.min);
        assert!(candidates.contains(&sb.best));
    }

    #[test]
    fn box_cells_are_ms() {
        let s = Summary::of(&[0.001, 0.002, 0.003]);
        let cells = box_cells(&s);
        assert_eq!(cells.len(), 5);
        assert_eq!(cells[2], "2.0000");
    }
}
