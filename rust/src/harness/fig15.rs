//! Fig. 15 — graph mining: transitive closure (path finding) strong
//! scaling (§VI-B). The fixed-point loop calls all-to-allv thousands of
//! times with small, skewed payloads; our algorithms drop in behind the
//! same interface. Bars = communication overhead, line = total execution
//! (here: columns).

use super::FigOpts;
use crate::algos::AlgoKind;
use crate::apps::tc::{run_tc_overlap, sequential_tc};
use crate::comm::{Engine, Topology};
use crate::util::table::{cell_f, Table};
use crate::workload::graph::Graph;

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    // Engine-only figure (the app moves real tuples); scaled-down graph.
    let (n_vertices, m_per_v) = if opts.full { (1200, 3) } else { (220, 3) };
    let ps: Vec<usize> = if opts.full {
        vec![8, 16, 32, 64]
    } else {
        vec![4, 8, 16]
    };
    let graph = Graph::scale_free(n_vertices, m_per_v, opts.seed);
    let expect = sequential_tc(&graph);

    let mut table = Table::new(
        format!(
            "Fig. 15 — transitive closure strong scaling ({} vertices, {} edges, |TC|={})",
            graph.n,
            graph.edges.len(),
            expect
        ),
        &[
            "machine",
            "P",
            "algo",
            "iters",
            "comm(ms)",
            "total(ms)",
            "speedup vs vendor",
            "exposed-blk(ms)",
            "exposed-pipe(ms)",
            "overlap-x",
        ],
    );

    for profile in &opts.profiles {
        for &p in &ps {
            let q = if p >= 8 { 4 } else { 2 };
            let engine = Engine::new(profile.clone(), Topology::new(p, q));
            let algos = [
                AlgoKind::Vendor,
                AlgoKind::Tuna { radix: 4.min(p) },
                AlgoKind::hier_coalesced(2, 1),
            ];
            let mut vendor_comm = None;
            for kind in algos {
                // One validated mining run plus its segmented timing
                // twin: the overlap columns replay the run's aggregate
                // shuffle traffic blocking vs pipelined, charging each
                // rank's measured join/dedup seconds across segments.
                let twin = run_tc_overlap(&engine, &kind, &graph, true, 4)?;
                let rep = &twin.base;
                assert_eq!(rep.paths, expect, "TC validation");
                let speedup = vendor_comm
                    .map(|v: f64| format!("{:.2}x", v / rep.comm_time))
                    .unwrap_or_else(|| "1.00x".into());
                if matches!(kind, AlgoKind::Vendor) {
                    vendor_comm = Some(rep.comm_time);
                }
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    kind.name(),
                    rep.iterations.to_string(),
                    cell_f(rep.comm_time * 1e3),
                    cell_f(rep.makespan * 1e3),
                    speedup,
                    cell_f(twin.exposed_blocking * 1e3),
                    cell_f(twin.exposed_pipelined * 1e3),
                    format!("{:.2}x", twin.blocking_makespan / twin.pipelined_makespan),
                ]);
            }
        }
    }
    table.note("paper: TuNA 5.98x / TuNA_l^g 7.96x over vendor at P=8192 on Polaris");
    opts.finish("fig15_pathfinding", vec![table])
}
