//! Fig. 13 — the headline comparison: TuNA, coalesced and staggered
//! TuNA_l^g (each ideally configured) against the best-tuned scattered
//! baseline and the vendor MPI_Alltoallv. Paper: up to 60.6x (TuNA) and
//! 138.6x (coalesced) over the vendor on Fugaku at small S; coalesced
//! wins everywhere.

use super::fig10::hier_candidates;
use super::boxplot::sweep_box;
use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 13 — proposed algorithms vs top baselines (ideal params)",
        &[
            "machine",
            "P",
            "S(B)",
            "vendor(ms)",
            "scattered*(ms)",
            "tuna*(ms)",
            "coalesced*(ms)",
            "staggered*(ms)",
            "tuna speedup",
            "coalesced speedup",
            "staggered speedup",
            "fidelity",
        ],
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            let q = opts.q().min(p);
            let n = p / q;
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let vendor = measure(&cfg, &AlgoKind::Vendor)?;

                let scat: Vec<AlgoKind> = tuning::block_count_candidates(p - 1)
                    .into_iter()
                    .map(|b| AlgoKind::Scattered { block_count: b })
                    .collect();
                let scattered = sweep_box(&cfg, &scat)?;

                let tuna_c: Vec<AlgoKind> = tuning::radix_candidates(p)
                    .into_iter()
                    .map(|radix| AlgoKind::Tuna { radix })
                    .collect();
                let tuna = sweep_box(&cfg, &tuna_c)?;

                let (coal_t, stag_t) = if n >= 2 {
                    let coal = sweep_box(&cfg, &hier_candidates(q, n, true))?;
                    let stag = sweep_box(&cfg, &hier_candidates(q, n, false))?;
                    (coal.best_time, stag.best_time)
                } else {
                    (tuna.best_time, tuna.best_time)
                };

                let v = vendor.median();
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    s.to_string(),
                    cell_f(v * 1e3),
                    cell_f(scattered.best_time * 1e3),
                    cell_f(tuna.best_time * 1e3),
                    cell_f(coal_t * 1e3),
                    cell_f(stag_t * 1e3),
                    format!("{:.2}x", v / tuna.best_time),
                    format!("{:.2}x", v / coal_t),
                    format!("{:.2}x", v / stag_t),
                    tuna.fidelity.name().into(),
                ]);
            }
        }
    }
    table.note("* = ideally tuned; paper headline: 60.6x (TuNA) / 138.6x (coalesced) on Fugaku small S");
    opts.finish("fig13_headline", vec![table])
}
