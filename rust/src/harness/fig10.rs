//! Fig. 10 — coalesced vs staggered TuNA_l^g parameter study (Fugaku in
//! the paper): intra-node radix and inter-node block_count sweeps, with
//! ideal parameters annotated. Intra/inter components are reported
//! separately from the phase breakdown, matching the paper's paired box
//! plots.

use super::boxplot::{box_cells, sweep_box, BOX_HEADER};
use super::FigOpts;
use crate::algos::{select, tuning, AlgoKind, GlobalAlgo, LocalAlgo};
use crate::comm::{Phase, Topology};
use crate::util::table::{cell_f, Table};
use crate::workload::BlockSizes;

/// Candidate (local radix, block_count) grid for one of the paper's two
/// TuNA-local hierarchy pairings (coalesced = Alg. 3, staggered =
/// Alg. 2).
pub fn hier_candidates(q: usize, n: usize, coalesced: bool) -> Vec<AlgoKind> {
    let bc_max = if coalesced {
        (n - 1).max(1)
    } else {
        ((n - 1) * q).max(1)
    };
    let mut out = Vec::new();
    for radix in tuning::radix_candidates(q).into_iter().filter(|&r| r <= q) {
        for bc in tuning::block_count_candidates(bc_max) {
            out.push(if coalesced {
                AlgoKind::hier_coalesced(radix, bc)
            } else {
                AlgoKind::hier_staggered(radix, bc)
            });
        }
    }
    out
}

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut header = vec!["machine", "P", "S(B)", "variant"];
    header.extend_from_slice(&BOX_HEADER);
    header.extend_from_slice(&[
        "ideal r", "ideal bc", "model r", "model bc", "intra(ms)", "inter(ms)", "fidelity",
    ]);
    let mut table = Table::new(
        "Fig. 10 — coalesced vs staggered TuNA_l^g parameter study",
        &header,
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            let q = opts.q().min(p);
            let n = p / q;
            if n < 2 {
                continue;
            }
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let mean = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed).mean_size();
                for coalesced in [true, false] {
                    let candidates = hier_candidates(q, n, coalesced);
                    let sb = sweep_box(&cfg, &candidates)?;
                    let params = |kind: &AlgoKind| match *kind {
                        AlgoKind::Hier {
                            local: LocalAlgo::Tuna { radix },
                            global:
                                GlobalAlgo::Coalesced { block_count }
                                | GlobalAlgo::Staggered { block_count },
                        } => (radix, block_count),
                        _ => unreachable!(),
                    };
                    let (ideal_r, ideal_bc) = params(&sb.best);
                    // The selector's analytic pick, as a cross-check on
                    // the measured ideal.
                    let model_ranked = select::model_rank(
                        &cfg.profile,
                        Topology::new(cfg.p, cfg.q),
                        mean,
                        &candidates,
                    );
                    let (model_r, model_bc) = params(&model_ranked[0].kind);
                    let ph = &sb.best_measure.phases;
                    let intra = ph.get(Phase::Prepare)
                        + ph.get(Phase::Metadata)
                        + ph.get(Phase::Data)
                        + ph.get(Phase::Replace);
                    let inter = ph.get(Phase::Rearrange) + ph.get(Phase::InterNode);
                    let mut row = vec![
                        profile.name.to_string(),
                        p.to_string(),
                        s.to_string(),
                        if coalesced { "coalesced" } else { "staggered" }.to_string(),
                    ];
                    row.extend(box_cells(&sb.box_stats));
                    row.push(ideal_r.to_string());
                    row.push(ideal_bc.to_string());
                    row.push(model_r.to_string());
                    row.push(model_bc.to_string());
                    row.push(cell_f(intra * 1e3));
                    row.push(cell_f(inter * 1e3));
                    row.push(sb.fidelity.name().into());
                    table.row(row);
                }
            }
        }
    }
    table.note("paper trends: larger S favors smaller block_count; ideal bc shrinks as P grows");
    opts.finish("fig10_hier_params", vec![table])
}
