//! Fig. 12 — benchmarking the standard non-uniform all-to-all
//! implementations from OpenMPI and MPICH: ascending linear, pairwise,
//! spread-out, the vendor default, and the scattered algorithm as a box
//! over its tunable block_count. The paper finds OpenMPI's blocking
//! linear worst at scale and ideally-tuned scattered best.

use super::boxplot::{box_cells, sweep_box, BOX_HEADER};
use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 12 — MPI baseline algorithms",
        &[
            "machine",
            "P",
            "S(B)",
            "ompi-linear(ms)",
            "pairwise(ms)",
            "spread-out(ms)",
            "vendor(ms)",
            "scattered ideal b",
            "scattered best(ms)",
            "fidelity",
        ],
    );
    let mut scat_header = vec!["machine", "P", "S(B)"];
    scat_header.extend_from_slice(&BOX_HEADER);
    let mut scattered_box = Table::new("Fig. 12 — scattered block_count box", &scat_header);

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            for &s in &opts.ss() {
                let cfg = opts.cfg(profile, p, s);
                let ompi = measure(&cfg, &AlgoKind::OmpiLinear)?;
                let pair = measure(&cfg, &AlgoKind::Pairwise)?;
                let spread = measure(&cfg, &AlgoKind::SpreadOut)?;
                let vendor = measure(&cfg, &AlgoKind::Vendor)?;
                let candidates: Vec<AlgoKind> = tuning::block_count_candidates(p - 1)
                    .into_iter()
                    .map(|b| AlgoKind::Scattered { block_count: b })
                    .collect();
                let sb = sweep_box(&cfg, &candidates)?;
                let ideal_b = match sb.best {
                    AlgoKind::Scattered { block_count } => block_count,
                    _ => unreachable!(),
                };
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    s.to_string(),
                    cell_f(ompi.median() * 1e3),
                    cell_f(pair.median() * 1e3),
                    cell_f(spread.median() * 1e3),
                    cell_f(vendor.median() * 1e3),
                    ideal_b.to_string(),
                    cell_f(sb.best_time * 1e3),
                    sb.fidelity.name().into(),
                ]);
                let mut row = vec![profile.name.to_string(), p.to_string(), s.to_string()];
                row.extend(box_cells(&sb.box_stats));
                scattered_box.row(row);
            }
        }
    }
    table.note("paper: ompi-linear worst at scale; ideally-tuned scattered best among baselines");
    opts.finish("fig12_mpi_baselines", vec![table, scattered_box])
}
