//! Fig. 7 — the three radix trends of TuNA.
//!
//! For a fixed P, sweeping the radix at different max block sizes S shows:
//! increasing performance with r for small S would be *wrong* — the paper
//! observes (1) small S: best near r=2 (latency regime), (2) medium S:
//! U-shape with the minimum near √P, (3) large S: decreasing time as r
//! grows (bandwidth regime, r≈P ideal). The table reports the time per
//! radix and classifies the observed trend per (machine, S).

use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let p = if opts.full { 2048 } else { 256 };
    let mut table = Table::new(
        format!("Fig. 7 — TuNA radix trends (P={p})"),
        &["machine", "S(B)", "radix", "time(ms)", "fidelity"],
    );
    let mut summary = Table::new(
        "Fig. 7 summary — ideal radix per regime",
        &["machine", "S(B)", "ideal r", "sqrt(P)", "regime"],
    );

    for profile in &opts.profiles {
        for &s in &opts.ss() {
            let cfg = opts.cfg(profile, p, s);
            let radices = tuning::radix_candidates(p);
            let mut best = (0usize, f64::INFINITY);
            for &r in &radices {
                let m = measure(&cfg, &AlgoKind::Tuna { radix: r })?;
                let t = m.median();
                if t < best.1 {
                    best = (r, t);
                }
                table.row(vec![
                    profile.name.into(),
                    s.to_string(),
                    r.to_string(),
                    cell_f(t * 1e3),
                    m.fidelity.name().into(),
                ]);
            }
            let sqrt_p = (p as f64).sqrt().round() as usize;
            let regime = if best.0 <= 4 {
                "latency (small r)"
            } else if best.0 <= 4 * sqrt_p {
                "balanced (U-shape, r~sqrt(P))"
            } else {
                "bandwidth (large r)"
            };
            summary.row(vec![
                profile.name.into(),
                s.to_string(),
                best.0.to_string(),
                sqrt_p.to_string(),
                regime.into(),
            ]);
        }
    }
    table.note("paper: ideal r grows with S — 2 for small S, ~sqrt(P) mid, ~P large");
    opts.finish("fig07_trends", vec![table, summary])
}
