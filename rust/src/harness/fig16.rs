//! Fig. 16 — standard distributions (§VI-C): normal (mean 1000, stddev
//! 240) and power-law block sizes, weak-scaling comparison of the
//! proposed algorithms against the vendor MPI_Alltoallv.

use super::fig10::hier_candidates;
use super::boxplot::sweep_box;
use super::FigOpts;
use crate::algos::{tuning, AlgoKind};
use crate::coordinator::measure;
use crate::util::table::{cell_f, Table};
use crate::workload::Dist;

pub fn run(opts: &FigOpts) -> crate::Result<Vec<Table>> {
    let mut table = Table::new(
        "Fig. 16 — normal and power-law distributions",
        &[
            "machine",
            "P",
            "dist",
            "vendor(ms)",
            "tuna*(ms)",
            "coalesced*(ms)",
            "staggered*(ms)",
            "tuna speedup",
            "coalesced speedup",
            "fidelity",
        ],
    );

    for profile in &opts.profiles {
        for &p in &opts.ps() {
            let q = opts.q().min(p);
            let n = p / q;
            for dist in [Dist::normal_default(), Dist::powerlaw_default()] {
                let mut cfg = opts.cfg(profile, p, 0);
                cfg.dist = dist;
                let vendor = measure(&cfg, &AlgoKind::Vendor)?;
                let tuna_c: Vec<AlgoKind> = tuning::radix_candidates(p)
                    .into_iter()
                    .map(|radix| AlgoKind::Tuna { radix })
                    .collect();
                let tuna = sweep_box(&cfg, &tuna_c)?;
                let (coal_t, stag_t) = if n >= 2 {
                    (
                        sweep_box(&cfg, &hier_candidates(q, n, true))?.best_time,
                        sweep_box(&cfg, &hier_candidates(q, n, false))?.best_time,
                    )
                } else {
                    (tuna.best_time, tuna.best_time)
                };
                let v = vendor.median();
                table.row(vec![
                    profile.name.into(),
                    p.to_string(),
                    dist.name().into(),
                    cell_f(v * 1e3),
                    cell_f(tuna.best_time * 1e3),
                    cell_f(coal_t * 1e3),
                    cell_f(stag_t * 1e3),
                    format!("{:.2}x", v / tuna.best_time),
                    format!("{:.2}x", v / coal_t),
                    tuna.fidelity.name().into(),
                ]);
            }
        }
    }
    table.note("paper (P=4096, Fugaku): tuna 3.21x, coalesced 3.63x, staggered 1.57x over vendor");
    opts.finish("fig16_distributions", vec![table])
}
