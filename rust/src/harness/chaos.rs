//! `tuna chaos` — fault-severity degradation sweeps.
//!
//! Sweeps deterministic fault severity (a straggler's CPU slowdown, then
//! a sick link's bandwidth loss) against the algorithm families on a
//! fixed topology, measuring every point exactly on the plan/replay
//! executor through [`crate::coordinator::measure`] with the fault spec
//! injected. The output is a set of *degradation curves* — faulted
//! makespan over the family's healthy makespan — plus, per severity, the
//! recommended (fastest-under-fault) family and the crossover points
//! where the recommendation changes. Everything is a pure function of
//! the config: two runs produce byte-identical `BENCH_faults.json`.

use std::path::PathBuf;

use crate::algos::{AlgoKind, ExecMode};
use crate::comm::FaultSpec;
use crate::coordinator::{measure, RunConfig};
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::util::stats::fmt_time;
use crate::util::table::Table;
use crate::workload::Dist;

/// CLI arguments of `tuna chaos`.
#[derive(Clone, Debug)]
pub struct ChaosArgs {
    pub p: usize,
    pub q: usize,
    /// Max block size of the uniform workload, bytes.
    pub s: u64,
    pub iters: usize,
    pub seed: u64,
    pub profile: MachineProfile,
    /// Output path for the JSON artifact.
    pub out: PathBuf,
    /// Smoke mode: smaller topology and a coarser severity grid.
    pub quick: bool,
}

impl Default for ChaosArgs {
    fn default() -> Self {
        ChaosArgs {
            p: 256,
            q: 8,
            s: 1024,
            iters: 3,
            seed: 0xC0FFEE,
            profile: MachineProfile::fugaku(),
            out: PathBuf::from("BENCH_faults.json"),
            quick: false,
        }
    }
}

impl ChaosArgs {
    /// Parse `p=256 q=8 s=1024 iters=3 seed=7 profile=fugaku
    /// out=BENCH_faults.json` plus the `--quick` flag.
    pub fn parse(args: &[String]) -> Result<ChaosArgs> {
        let mut a = ChaosArgs::default();
        for arg in args {
            if arg == "--quick" {
                a.quick = true;
                continue;
            }
            let (k, v) = arg
                .split_once('=')
                .ok_or_else(|| TunaError::config(format!("expected key=value, got `{arg}`")))?;
            let num = |v: &str| -> Result<usize> {
                v.parse()
                    .map_err(|_| TunaError::config(format!("bad number for {k}: `{v}`")))
            };
            match k {
                "p" => a.p = num(v)?,
                "q" => a.q = num(v)?,
                "s" => a.s = num(v)? as u64,
                "iters" => a.iters = num(v)?,
                "seed" => a.seed = num(v)? as u64,
                "profile" => {
                    a.profile = MachineProfile::by_name(v).ok_or_else(|| {
                        TunaError::config(format!(
                            "unknown profile `{v}` (try polaris, fugaku, test-flat)"
                        ))
                    })?
                }
                "out" => a.out = PathBuf::from(v),
                _ => return Err(TunaError::config(format!("unknown chaos key `{k}`"))),
            }
        }
        if a.quick {
            a.p = a.p.min(64);
            a.q = a.q.min(8);
            a.iters = a.iters.min(2);
        }
        if a.iters == 0 {
            return Err(TunaError::config("chaos: iters must be >= 1"));
        }
        crate::comm::Topology::try_new(a.p, a.q)?;
        Ok(a)
    }
}

/// One measured sweep point.
#[derive(Clone, Debug)]
pub struct ChaosRow {
    /// Fault dimension: "straggler" or "link".
    pub fault: &'static str,
    /// Severity knob: the straggler's `slow` factor, or `1/bw` for the
    /// sick link (both read "1 = healthy, larger = sicker").
    pub severity: f64,
    pub algo: String,
    pub makespan: f64,
    /// `makespan / healthy makespan` of the same family.
    pub degradation: f64,
}

/// The family menu the sweep ranks (flat log, hierarchical, linear).
fn algo_menu(p: usize, q: usize) -> Vec<AlgoKind> {
    let menu = [
        AlgoKind::Tuna { radix: 4 },
        AlgoKind::hier_coalesced(2, 2),
        AlgoKind::SpreadOut,
        AlgoKind::Pairwise,
    ];
    menu.into_iter().filter(|k| k.check(p, q).is_ok()).collect()
}

/// Severity grids: 1.0 (healthy anchor) first, then increasingly sick.
fn severities(quick: bool) -> Vec<f64> {
    if quick {
        vec![1.0, 2.0, 8.0]
    } else {
        vec![1.0, 1.5, 2.0, 4.0, 8.0, 16.0]
    }
}

/// The fault spec for one (dimension, severity) cell. Severity 1.0 is
/// the healthy anchor: an empty spec (provably zero-perturbation).
fn spec_for(fault: &str, severity: f64) -> Result<FaultSpec> {
    if severity <= 1.0 {
        return Ok(FaultSpec::default());
    }
    let spec = match fault {
        // The straggler sits mid-fleet; the sick link joins the first
        // two nodes (both always exist: chaos topologies have >= 2
        // nodes or the link dimension is skipped).
        "straggler" => format!("straggler:rank=1,slow={severity}"),
        "link" => format!("link:node=0-1,bw={}", 1.0 / severity),
        other => return Err(TunaError::config(format!("unknown fault dimension `{other}`"))),
    };
    FaultSpec::parse(&spec)
}

/// Run the sweep: measure every (dimension, severity, family) cell in
/// replay mode, derive degradation ratios, recommended families and
/// crossovers. Returns the rows, the printed table, and the JSON.
pub fn run(a: &ChaosArgs) -> Result<(Vec<ChaosRow>, Table, String)> {
    let menu = algo_menu(a.p, a.q);
    if menu.is_empty() {
        return Err(TunaError::config("chaos: no runnable algorithm family"));
    }
    let dims: Vec<&'static str> = if a.p / a.q >= 2 {
        vec!["straggler", "link"]
    } else {
        vec!["straggler"]
    };
    let base = RunConfig {
        p: a.p,
        q: a.q,
        profile: a.profile.clone(),
        dist: Dist::Uniform { max: a.s },
        seed: a.seed,
        iters: a.iters,
        mode: ExecMode::Replay,
        ..RunConfig::default()
    };
    let grid = severities(a.quick);
    let mut rows: Vec<ChaosRow> = Vec::new();
    for &fault in &dims {
        // Healthy anchors per family, measured once per dimension (the
        // empty spec is bit-identical to no fault injection at all).
        let mut healthy: Vec<f64> = Vec::with_capacity(menu.len());
        for kind in &menu {
            let cfg = RunConfig {
                faults: FaultSpec::default(),
                ..base.clone()
            };
            healthy.push(measure(&cfg, kind)?.median());
        }
        for &sev in &grid {
            for (kind, &h) in menu.iter().zip(&healthy) {
                let cfg = RunConfig {
                    faults: spec_for(fault, sev)?,
                    ..base.clone()
                };
                let m = measure(&cfg, kind)?.median();
                rows.push(ChaosRow {
                    fault,
                    severity: sev,
                    algo: kind.name(),
                    makespan: m,
                    degradation: m / h,
                });
            }
        }
    }

    let mut table = Table::new(
        format!(
            "tuna chaos — degradation on {} P={} Q={} S={}",
            a.profile.name, a.p, a.q, a.s
        ),
        &["fault", "severity", "algo", "makespan", "degradation", "recommended"],
    );
    for &fault in &dims {
        for &sev in &grid {
            let best = recommended(&rows, fault, sev)
                .map(|r| r.algo.clone())
                .unwrap_or_default();
            for r in rows.iter().filter(|r| r.fault == fault && r.severity == sev) {
                table.row(vec![
                    r.fault.to_string(),
                    format!("{sev}"),
                    r.algo.clone(),
                    fmt_time(r.makespan),
                    format!("{:.3}", r.degradation),
                    if r.algo == best { "*".into() } else { String::new() },
                ]);
            }
        }
    }
    table.note(
        "severity 1 = healthy (empty fault spec, zero-perturbation); straggler = \
         CPU slowdown of rank 1, link = bandwidth loss on the node 0-1 pair; every \
         point measured exactly on the plan/replay executor with faults injected",
    );

    let json = to_json(a, &dims, &grid, &rows);
    Ok((rows, table, json))
}

/// The fastest family at one (dimension, severity) cell.
fn recommended<'a>(rows: &'a [ChaosRow], fault: &str, sev: f64) -> Option<&'a ChaosRow> {
    rows.iter()
        .filter(|r| r.fault == fault && r.severity == sev)
        .min_by(|a, b| a.makespan.total_cmp(&b.makespan))
}

fn fmt_f(v: f64) -> String {
    format!("{v:.9e}")
}

/// Hand-rolled JSON (the crate deliberately has no serde dependency).
fn to_json(a: &ChaosArgs, dims: &[&'static str], grid: &[f64], rows: &[ChaosRow]) -> String {
    let mut s = String::from("{\n");
    s.push_str(&format!(
        "  \"config\": {{\"p\": {}, \"q\": {}, \"s\": {}, \"iters\": {}, \"seed\": {}, \
         \"profile\": \"{}\", \"quick\": {}}},\n",
        a.p, a.q, a.s, a.iters, a.seed, a.profile.name, a.quick
    ));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"fault\": \"{}\", \"severity\": {}, \"algo\": \"{}\", \
             \"makespan_s\": {}, \"degradation\": {}}}{}\n",
            r.fault,
            r.severity,
            r.algo,
            fmt_f(r.makespan),
            fmt_f(r.degradation),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    // Per (dimension, severity): the recommended family, plus crossover
    // points — severities where the recommendation changes from the
    // previous grid step (the actionable output: "past slow=4, switch").
    s.push_str("  \"recommended\": [\n");
    let mut rec_lines: Vec<String> = Vec::new();
    let mut crossovers: Vec<String> = Vec::new();
    for &fault in dims {
        let mut prev: Option<String> = None;
        for &sev in grid {
            if let Some(best) = recommended(rows, fault, sev) {
                rec_lines.push(format!(
                    "    {{\"fault\": \"{}\", \"severity\": {}, \"algo\": \"{}\", \
                     \"makespan_s\": {}}}",
                    fault,
                    sev,
                    best.algo,
                    fmt_f(best.makespan)
                ));
                if let Some(p) = &prev {
                    if *p != best.algo {
                        crossovers.push(format!(
                            "    {{\"fault\": \"{}\", \"severity\": {}, \"from\": \"{}\", \
                             \"to\": \"{}\"}}",
                            fault, sev, p, best.algo
                        ));
                    }
                }
                prev = Some(best.algo.clone());
            }
        }
    }
    s.push_str(&rec_lines.join(",\n"));
    s.push_str("\n  ],\n  \"crossovers\": [\n");
    s.push_str(&crossovers.join(",\n"));
    if crossovers.is_empty() {
        s.push_str("  ]\n}\n");
    } else {
        s.push_str("\n  ]\n}\n");
    }
    s
}

/// CLI entry: parse, run, print the table, write the JSON artifact.
pub fn cmd(args: &[String]) -> Result<()> {
    let a = ChaosArgs::parse(args)?;
    let (rows, table, json) = run(&a)?;
    println!("{}", table.render());
    std::fs::write(&a.out, &json)?;
    println!("chaos: {} sweep points, artifact {}", rows.len(), a.out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_chaos_args() {
        let a = ChaosArgs::parse(&args("p=64 q=8 s=512 iters=2 seed=9")).unwrap();
        assert_eq!((a.p, a.q), (64, 8));
        assert_eq!(a.s, 512);
        assert_eq!(a.iters, 2);
        assert!(!a.quick);
        let q = ChaosArgs::parse(&args("--quick")).unwrap();
        assert!(q.quick);
        assert!(q.p <= 64, "quick shrinks the topology");
        assert!(ChaosArgs::parse(&args("p=10 q=4")).is_err());
        assert!(ChaosArgs::parse(&args("iters=0")).is_err());
        assert!(ChaosArgs::parse(&args("bogus=1")).is_err());
    }

    #[test]
    fn severity_one_anchors_degradation_at_exactly_one() {
        assert!(spec_for("straggler", 1.0).unwrap().is_empty());
        assert!(spec_for("link", 1.0).unwrap().is_empty());
        assert_eq!(spec_for("straggler", 8.0).unwrap().spec(), "straggler:rank=1,slow=8");
        assert_eq!(spec_for("link", 2.0).unwrap().spec(), "link:node=0-1,bw=0.5");
        assert!(spec_for("cosmic-rays", 2.0).is_err());
    }

    #[test]
    fn chaos_harness_end_to_end() {
        let a = ChaosArgs {
            p: 16,
            q: 4,
            s: 256,
            iters: 2,
            profile: MachineProfile::test_flat(),
            quick: true,
            ..ChaosArgs::default()
        };
        let (rows, table, json) = run(&a).unwrap();
        assert!(!rows.is_empty());
        assert!(!table.rows.is_empty());
        // The healthy anchor is exact: empty spec is zero-perturbation,
        // so severity 1.0 rows have degradation == 1 bit for bit.
        for r in rows.iter().filter(|r| r.severity == 1.0) {
            assert_eq!(r.degradation.to_bits(), 1.0f64.to_bits(), "{} {}", r.fault, r.algo);
        }
        // Sicker is never faster: degradation is monotone per family.
        let algos: std::collections::BTreeSet<String> =
            rows.iter().map(|r| r.algo.clone()).collect();
        for fault in ["straggler", "link"] {
            for algo in &algos {
                let degs: Vec<f64> = rows
                    .iter()
                    .filter(|r| r.fault == fault && r.algo == *algo)
                    .map(|r| r.degradation)
                    .collect();
                assert!(
                    degs.windows(2).all(|w| w[1] >= w[0] * (1.0 - 1e-12)),
                    "{fault}/{algo}: {degs:?}"
                );
            }
        }
        assert!(json.contains("\"recommended\""));
        assert!(json.contains("\"crossovers\""));
        // Byte-identical on re-run.
        let (_, _, json2) = run(&a).unwrap();
        assert_eq!(json, json2);
    }
}
