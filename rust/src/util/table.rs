//! Plain-text and CSV table rendering for the figure/table harness.
//!
//! Every harness module produces a `Table`; the CLI prints it and writes a
//! CSV next to it under `results/` so figures can be re-plotted elsewhere.

use std::fmt::Write as _;

/// A simple column-aligned table with a title and optional notes.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:>width$}  ", cell, width = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        for n in &self.notes {
            let _ = writeln!(out, "# {n}");
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write both the text rendering and the CSV under `dir` using `stem`.
    pub fn write_files(&self, dir: &std::path::Path, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.txt")), self.render())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format an f64 cell with sensible precision.
pub fn cell_f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.01 {
        format!("{v:.4}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long_header"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn cell_formatting() {
        assert_eq!(cell_f(0.0), "0");
        assert_eq!(cell_f(123.456), "123.5");
        assert_eq!(cell_f(0.5), "0.5000");
        assert!(cell_f(1e-6).contains('e'));
    }

    #[test]
    fn write_files_creates_txt_and_csv() {
        let dir = std::env::temp_dir().join("tuna_table_test");
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into()]);
        t.write_files(&dir, "t").unwrap();
        assert!(dir.join("t.txt").exists());
        assert!(dir.join("t.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
