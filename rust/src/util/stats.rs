//! Descriptive statistics for experiment reporting: median, quantiles,
//! standard deviation — the paper reports medians with error bars and
//! box plots over parameter sweeps.

/// Five-number-ish summary of a sample (plus mean/stddev), used for the
/// box-plot style figures (Fig. 8, 10, 12).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty sample (an experiment with no
    /// measurements is a harness bug, not a runtime condition).
    pub fn of(sample: &[f64]) -> Summary {
        assert!(!sample.is_empty(), "Summary::of on empty sample");
        let mut xs: Vec<f64> = sample.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            min: xs[0],
            q1: quantile_sorted(&xs, 0.25),
            median: quantile_sorted(&xs, 0.5),
            q3: quantile_sorted(&xs, 0.75),
            max: xs[n - 1],
            mean,
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolation quantile of an already-sorted slice, `q` in [0, 1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Median convenience for unsorted data.
pub fn median(sample: &[f64]) -> f64 {
    let mut xs = sample.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    quantile_sorted(&xs, 0.5)
}

/// Nearest-rank percentile of an unsorted sample (0.0 on empty input),
/// `pct` in [0, 100]. The one shared implementation behind serving-
/// latency and chaos-sweep reporting. Sorted with [`f64::total_cmp`]: a
/// NaN sample (impossible from the simulator, possible from hand-fed
/// data) sorts last instead of panicking mid-report.
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = ((pct / 100.0) * s.len() as f64).ceil() as usize;
    s[rank.clamp(1, s.len()) - 1]
}

/// Geometric mean — used when aggregating speedups across scenarios.
pub fn geomean(sample: &[f64]) -> f64 {
    assert!(!sample.is_empty());
    let log_sum: f64 = sample
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / sample.len() as f64).exp()
}

/// Format seconds in a human-friendly unit (the tables mix ns..s scales).
pub fn fmt_time(seconds: f64) -> String {
    let abs = seconds.abs();
    if abs >= 1.0 {
        format!("{seconds:.3} s")
    } else if abs >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.3} us", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.q1, 7.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile_sorted(&xs, 0.5), 5.0);
        assert_eq!(quantile_sorted(&xs, 0.25), 2.5);
    }

    #[test]
    fn median_unsorted() {
        assert_eq!(median(&[9.0, 1.0, 5.0]), 5.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn percentile_is_nearest_rank_and_nan_safe() {
        let s = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 95.0), 4.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        // Degenerate pct values stay in range instead of indexing out.
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
        // total_cmp sorts NaN last instead of panicking mid-report.
        let n = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&n, 50.0), 2.0);
        assert!(percentile(&n, 99.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn time_formatting_units() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.002), "2.000 ms");
        assert_eq!(fmt_time(3e-6), "3.000 us");
        assert_eq!(fmt_time(5e-9), "5.0 ns");
    }

    #[test]
    fn byte_formatting_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
