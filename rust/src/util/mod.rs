//! Small self-contained utilities: seeded PRNG, statistics, a property-test
//! harness, and plain-text table rendering.
//!
//! The build environment is offline, so we carry our own implementations of
//! what `rand`, `proptest` and `prettytable` would normally provide.

pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;

pub use prng::Pcg64;
pub use stats::Summary;
