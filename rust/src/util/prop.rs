//! A tiny property-testing harness (proptest is unavailable offline).
//!
//! `forall` runs a predicate over many seeded random cases and reports the
//! first failing seed so failures are reproducible; `Shrink`-style
//! minimization is intentionally out of scope — cases are parameterized by
//! small generated values, so failures are already small.

use super::prng::Pcg64;

/// Run `cases` random trials of `body`. `body` gets a fresh deterministic
/// RNG per case; a panic or an `Err(msg)` fails the property with the case
/// index and seed embedded in the panic message.
pub fn forall<F>(name: &str, cases: usize, mut body: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9e37_79b9_7f4a_7c15u64 ^ (case as u64).wrapping_mul(0xff51_afd7_ed55_8ccd);
        let mut rng = Pcg64::new(seed, case as u64);
        if let Err(msg) = body(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two f64s are close in absolute + relative terms.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let tol = abs + rel * a.abs().max(b.abs());
    if diff <= tol {
        Ok(())
    } else {
        Err(format!("{a} vs {b}: |diff| {diff} > tol {tol}"))
    }
}

/// Generate a "interesting" process count: mixes powers of two, primes and
/// composites, since the algorithms special-case none of them.
pub fn gen_proc_count(rng: &mut Pcg64, max: usize) -> usize {
    const INTERESTING: [usize; 12] = [2, 3, 4, 5, 7, 8, 9, 12, 16, 17, 24, 32];
    let pick = rng.next_below(INTERESTING.len() as u64 + 2) as usize;
    let p = if pick < INTERESTING.len() {
        INTERESTING[pick]
    } else {
        2 + rng.next_below(max as u64 - 1) as usize
    };
    p.min(max).max(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |rng| {
            let v = rng.next_below(100);
            if v < 100 {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn forall_reports_failures() {
        forall("failing", 10, |rng| {
            if rng.next_below(2) < 2 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-3, 0.0).is_err());
        assert!(close(0.0, 1e-12, 0.0, 1e-9).is_ok());
    }

    #[test]
    fn proc_counts_in_range() {
        let mut rng = Pcg64::new(0, 0);
        for _ in 0..1000 {
            let p = gen_proc_count(&mut rng, 64);
            assert!((2..=64).contains(&p));
        }
    }
}
