//! Deterministic, seedable PRNG (PCG-XSL-RR 128/64) plus distribution
//! helpers used by the workload generators.
//!
//! Determinism matters more than statistical perfection here: every
//! experiment in the harness is reproducible from `(seed, rank)` so that a
//! rank can regenerate any other rank's block-size row without storing the
//! full P x P matrix.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0x5851_f42d_4c95_7f2d) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        // A few warm-up rounds to decorrelate similar seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)`. Uses the widening-multiply trick; the tiny
    /// modulo bias is irrelevant for workload generation.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > f64::MIN_POSITIVE {
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "streams should be decorrelated, {same} collisions");
    }

    #[test]
    fn next_below_respects_bound() {
        let mut rng = Pcg64::new(1, 2);
        for _ in 0..10_000 {
            assert!(rng.next_below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut rng = Pcg64::new(3, 4);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match rng.range_inclusive(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                v => assert!((5..=8).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(9, 0);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut rng = Pcg64::new(11, 0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5, 5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
