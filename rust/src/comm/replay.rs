//! Single-threaded discrete-event replay of a [`CommPlan`].
//!
//! [`execute`] advances every rank's [`Clock`] through its compiled op
//! sequence in dependency order: a rank runs until a `Wait` whose
//! messages have not all been "sent" yet, then parks; the send that
//! clears its last deficit re-queues it. No OS threads, no mutexes, no
//! condvars — a P = 16,384 phantom simulation is ordinary single-core
//! arithmetic instead of 16k spawned threads.
//!
//! **Bit-identity.** Every clock call made here replicates the threaded
//! engine exactly: sends charge `Clock::post_send` in sender program
//! order, receive posts charge `Clock::post_recv`, and each `Wait` drains
//! its matched messages in the same deterministic order as
//! `RankCtx::waitall` — stable-sorted by `(arrival, src, tag)` with FIFO
//! matching per `(src, tag)` channel. Virtual time is a pure function of
//! the per-rank op sequences, so makespans, phase breakdowns and counters
//! are bit-identical to a threaded phantom run of the same algorithm
//! (asserted with zero tolerance by `tests/replay_equivalence.rs`).
//!
//! The threaded engine stays the golden oracle for real payloads; replay
//! never materializes payload bytes, so `Counters::copied_bytes` is zero,
//! exactly as in threaded phantom mode.

use std::collections::{HashMap, VecDeque};
use std::hash::BuildHasherDefault;

use super::clock::Clock;
use super::engine::{ChanHasher, EngineResult, RankResult};
use super::plan::{CommPlan, PlanOp};
use super::topology::Topology;
use super::PhaseBreakdown;
use crate::model::{Link, MachineProfile};

/// A message in flight: what the receiver's drain needs, nothing more.
#[derive(Clone, Copy, Debug)]
struct InMsg {
    arrive: f64,
    bytes: u64,
    link: Link,
}

type ChanMap = HashMap<(u32, u32), VecDeque<InMsg>, BuildHasherDefault<ChanHasher>>;
type MissingMap = HashMap<(u32, u32), usize, BuildHasherDefault<ChanHasher>>;

/// One rank's execution state.
struct ReplayRank {
    /// Index of the next op to execute.
    pc: usize,
    clock: Clock,
    phases: PhaseBreakdown,
    mark: f64,
    /// Completion times of sends posted since the last `Wait`.
    pending_sends: Vec<f64>,
    /// `(src, tag)` of receives posted since the last `Wait`, in request
    /// order (the order `waitall` matches and returns them in).
    pending_recvs: Vec<(u32, u32)>,
    /// Parked at a `Wait` with messages still missing.
    blocked: bool,
    /// Outstanding per-channel message deficits while blocked.
    missing: MissingMap,
    missing_total: usize,
    done: bool,
}

impl ReplayRank {
    fn new() -> ReplayRank {
        ReplayRank {
            pc: 0,
            clock: Clock::new(),
            phases: PhaseBreakdown::default(),
            mark: 0.0,
            pending_sends: Vec::new(),
            pending_recvs: Vec::new(),
            blocked: false,
            missing: MissingMap::default(),
            missing_total: 0,
            done: false,
        }
    }
}

/// Execute `plan` and return per-rank results plus the simulated makespan
/// — the same shape [`Engine::run`](super::Engine::run) produces, so
/// `phase_critical_path` / `total_counters` aggregation is shared.
///
/// Panics on a deadlocked plan (a `Wait` whose messages are never sent)
/// and on undrained mailboxes (messages sent but never received) — both
/// are compiler bugs, reported like the engine's undrained-mailbox check.
pub fn execute(profile: &MachineProfile, topo: Topology, plan: &CommPlan) -> EngineResult<()> {
    let p = topo.p();
    assert_eq!(plan.p, p, "plan is for P={} but topology has P={p}", plan.p);
    assert_eq!(
        plan.q,
        topo.q(),
        "plan is for Q={} but topology has Q={}",
        plan.q,
        topo.q()
    );

    let mut mailboxes: Vec<ChanMap> = (0..p).map(|_| ChanMap::default()).collect();
    let mut states: Vec<ReplayRank> = (0..p).map(|_| ReplayRank::new()).collect();
    let mut ready: VecDeque<usize> = (0..p).collect();
    let mut in_queue = vec![true; p];

    while let Some(me) = ready.pop_front() {
        in_queue[me] = false;
        let ops = &plan.ranks[me].ops;
        loop {
            if states[me].pc == ops.len() {
                states[me].done = true;
                break;
            }
            match ops[states[me].pc] {
                PlanOp::Send { dst, tag, bytes } => {
                    let d = dst as usize;
                    let link = topo.link(me, d);
                    let st = &mut states[me];
                    let timing = st.clock.post_send(profile, link, bytes, p);
                    st.pending_sends.push(timing.complete);
                    mailboxes[d].entry((me as u32, tag)).or_default().push_back(InMsg {
                        arrive: timing.arrive,
                        bytes,
                        link,
                    });
                    // Wake the receiver if this send clears its last
                    // deficit. (A self-send needs no wake: we are the
                    // running rank.)
                    if d != me && states[d].blocked {
                        if let Some(n) = states[d].missing.get_mut(&(me as u32, tag)) {
                            if *n > 0 {
                                *n -= 1;
                                states[d].missing_total -= 1;
                                if states[d].missing_total == 0 {
                                    states[d].blocked = false;
                                    if !in_queue[d] {
                                        in_queue[d] = true;
                                        ready.push_back(d);
                                    }
                                }
                            }
                        }
                    }
                }
                PlanOp::Recv { src, tag } => {
                    let link = topo.link(me, src as usize);
                    let st = &mut states[me];
                    st.clock.post_recv(profile, link);
                    st.pending_recvs.push((src, tag));
                }
                PlanOp::Wait => {
                    let (missing, missing_total) =
                        channel_deficits(&states[me].pending_recvs, &mailboxes[me]);
                    if missing_total > 0 {
                        let st = &mut states[me];
                        st.missing = missing;
                        st.missing_total = missing_total;
                        st.blocked = true;
                        // pc stays on this Wait; resumed once the
                        // deficits drain.
                        break;
                    }
                    perform_wait(&mut states[me], &mut mailboxes[me], profile);
                }
                PlanOp::Copy { bytes } => {
                    states[me].clock.charge_copy(profile, bytes);
                }
                PlanOp::Compute { secs } => {
                    states[me].clock.charge_compute(secs);
                }
                PlanOp::Mark => {
                    let st = &mut states[me];
                    st.mark = st.clock.now;
                }
                PlanOp::Lap { phase } => {
                    let st = &mut states[me];
                    let now = st.clock.now;
                    st.phases.add(phase, now - st.mark);
                    st.mark = now;
                }
            }
            states[me].pc += 1;
        }
    }

    for (rank, st) in states.iter().enumerate() {
        assert!(
            st.done,
            "replay deadlock: rank {rank} parked at op {}/{} of {} ({} messages missing)",
            st.pc,
            plan.ranks[rank].ops.len(),
            plan.algo,
            st.missing_total
        );
    }
    for (rank, mb) in mailboxes.iter().enumerate() {
        assert!(
            mb.is_empty(),
            "rank {rank} mailbox not drained — plan left unreceived messages"
        );
    }

    let ranks: Vec<RankResult<()>> = states
        .into_iter()
        .enumerate()
        .map(|(rank, st)| RankResult {
            rank,
            value: (),
            finish: st.clock.now,
            phases: st.phases,
            counters: st.clock.counters,
        })
        .collect();
    let makespan = ranks.iter().fold(0.0f64, |m, r| m.max(r.finish));
    EngineResult { ranks, makespan }
}

/// Per-channel message deficits of a pending receive set against a
/// mailbox: which `(src, tag)` channels still owe how many messages.
fn channel_deficits(pending: &[(u32, u32)], mb: &ChanMap) -> (MissingMap, usize) {
    let mut needed = MissingMap::default();
    for &key in pending {
        *needed.entry(key).or_insert(0) += 1;
    }
    let mut missing = MissingMap::default();
    let mut total = 0usize;
    for (key, need) in needed {
        let avail = mb.get(&key).map_or(0, VecDeque::len);
        if avail < need {
            missing.insert(key, need - avail);
            total += need - avail;
        }
    }
    (missing, total)
}

/// Complete a `Wait` whose messages are all present — the mirror of
/// `RankCtx::waitall`: FIFO-match per channel in request order, drain in
/// deterministic `(arrival, src, tag)` order, then advance program order
/// past sends and receive completions.
fn perform_wait(st: &mut ReplayRank, mb: &mut ChanMap, profile: &MachineProfile) {
    let n = st.pending_recvs.len();
    let mut msgs: Vec<InMsg> = Vec::with_capacity(n);
    for &key in &st.pending_recvs {
        let q = mb.get_mut(&key).expect("readiness check guaranteed a message");
        let m = q.pop_front().expect("readiness check guaranteed a message");
        if q.is_empty() {
            mb.remove(&key);
        }
        msgs.push(m);
    }

    // Deterministic drain order, identical to the engine: by (arrive,
    // src, tag), stable in request order.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        msgs[a]
            .arrive
            .partial_cmp(&msgs[b].arrive)
            .unwrap()
            .then(st.pending_recvs[a].0.cmp(&st.pending_recvs[b].0))
            .then(st.pending_recvs[a].1.cmp(&st.pending_recvs[b].1))
    });
    let sorted: Vec<(f64, u64, Link)> = order
        .iter()
        .map(|&i| (msgs[i].arrive, msgs[i].bytes, msgs[i].link))
        .collect();
    let completions = st.clock.drain_receives(profile, &sorted);

    let mut t = 0.0f64;
    for &s in &st.pending_sends {
        t = t.max(s);
    }
    for &c in &completions {
        t = t.max(c);
    }
    st.clock.finish_wait(t);
    st.pending_sends.clear();
    st.pending_recvs.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::buffer::DataBuf;
    use crate::comm::plan::PlanBuilder;
    use crate::comm::{Engine, Payload, Phase};

    fn ring_plan(p: usize, bytes: u64) -> CommPlan {
        let ranks = (0..p)
            .map(|me| {
                let mut b = PlanBuilder::new(me, p);
                b.mark();
                b.sendrecv((me + 1) % p, 7, bytes, (me + p - 1) % p, 7);
                b.lap(Phase::Data);
                b.finish()
            })
            .collect();
        CommPlan {
            p,
            q: 2,
            algo: "ring".into(),
            ranks,
            t_peak: 0,
            rounds: 1,
        }
    }

    #[test]
    fn ring_replay_matches_threaded_engine_bitwise() {
        let profile = MachineProfile::test_flat();
        let topo = Topology::new(4, 2);
        let plan = ring_plan(4, 1024);
        let replayed = execute(&profile, topo, &plan);

        let engine = Engine::new(profile, topo);
        let threaded = engine.run(|ctx| {
            let p = ctx.size();
            let me = ctx.rank();
            ctx.phase_mark();
            let _ = ctx.sendrecv(
                (me + 1) % p,
                7,
                Payload::Raw(DataBuf::Phantom(1024)),
                (me + p - 1) % p,
                7,
            );
            ctx.phase_lap(Phase::Data);
        });

        assert_eq!(replayed.makespan.to_bits(), threaded.makespan.to_bits());
        for (a, b) in replayed.ranks.iter().zip(threaded.ranks.iter()) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "rank {}", a.rank);
            assert_eq!(a.phases, b.phases, "rank {}", a.rank);
            assert_eq!(a.counters, b.counters, "rank {}", a.rank);
        }
    }

    #[test]
    fn self_send_and_out_of_order_arrivals_resolve() {
        // Rank 0 waits for rank 1's message and its own self-send in one
        // wait; rank 1 depends on rank 0's reply afterwards.
        let profile = MachineProfile::test_flat();
        let topo = Topology::flat(2);
        let mut b0 = PlanBuilder::new(0, 2);
        b0.send(0, 3, 8);
        b0.recv(0, 3);
        b0.recv(1, 4);
        b0.wait();
        b0.send(1, 5, 16);
        b0.wait();
        let mut b1 = PlanBuilder::new(1, 2);
        b1.send(0, 4, 8);
        b1.wait();
        b1.recv(0, 5);
        b1.wait();
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        let res = execute(&profile, topo, &plan);
        assert!(res.makespan > 0.0);
        assert_eq!(res.ranks.len(), 2);
    }

    #[test]
    #[should_panic(expected = "replay deadlock")]
    fn missing_sender_deadlocks_loudly() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.recv(1, 1);
        b0.wait();
        let b1 = PlanBuilder::new(1, 2);
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        execute(&MachineProfile::test_flat(), Topology::flat(2), &plan);
    }

    #[test]
    #[should_panic(expected = "not drained")]
    fn unreceived_message_detected() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.send(1, 9, 8);
        b0.wait();
        let b1 = PlanBuilder::new(1, 2);
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        execute(&MachineProfile::test_flat(), Topology::flat(2), &plan);
    }

    #[test]
    fn fifo_per_channel_preserved_under_duplicate_requests() {
        // Two messages on one (src, tag) channel received by duplicate
        // requests in one wait — must match FIFO like the engine.
        let profile = MachineProfile::test_flat();
        let mut b0 = PlanBuilder::new(0, 2);
        b0.recv(1, 3);
        b0.recv(1, 3);
        b0.wait();
        let mut b1 = PlanBuilder::new(1, 2);
        b1.send(0, 3, 64);
        b1.send(0, 3, 128);
        b1.wait();
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        let res = execute(&profile, Topology::flat(2), &plan);
        // 64 + 128 wire bytes on the global link, both counted at rank 1.
        assert_eq!(res.total_counters().bytes_global, 192);
        assert_eq!(res.total_counters().msgs_global, 2);
    }
}
