//! Discrete-event replay of a [`CommPlan`] — single-threaded or sharded
//! across worker threads, always bit-identical.
//!
//! [`execute`] advances every rank's [`Clock`] through its compiled op
//! sequence in dependency order: a rank runs until a `Wait` whose
//! messages have not all been "sent" yet, then parks; the send that
//! clears its last deficit re-queues it. No OS threads, no mutexes, no
//! condvars — a P = 16,384 phantom simulation is ordinary single-core
//! arithmetic instead of 16k spawned threads.
//!
//! [`execute_sharded`] partitions the ranks into contiguous shards and
//! runs the same event loop on each shard concurrently, synchronized by
//! conservative time windows: within a window every shard advances its
//! own ranks until they are all parked or done, buffering cross-shard
//! sends in a per-shard boundary queue; at the window barrier the
//! coordinator drains every boundary queue into the destination shards'
//! mailboxes (waking receivers whose deficits clear) and opens the next
//! window. The loop ends when a barrier delivers nothing and no rank is
//! runnable.
//!
//! **Why window barriers preserve the drain order (shard-count
//! independence).** Virtual time is a pure function of the per-rank op
//! sequences; the only cross-rank interaction is a send depositing its
//! `(arrive, bytes, link)` tuple into the receiver's `(src, tag)`
//! channel. Three facts make the schedule independent of sharding:
//!
//! 1. **Channels are single-writer.** A mailbox channel is keyed by
//!    `(src, tag)`, so every message in it comes from one rank, which
//!    executes serially inside exactly one shard. Boundary queues are
//!    appended in sender program order and drained in order at the
//!    barrier, so FIFO-per-channel is sender program order under any
//!    shard count — exactly what the threaded engine's mailbox yields.
//! 2. **Matching is by count, not by time.** A `Wait` matches the
//!    channel-FIFO prefix of its posted receives; a barrier only changes
//!    *when* (in wallclock) the deficit clears, never *which* messages
//!    match. Arrival timestamps are computed on the sender's clock and
//!    travel with the message, unchanged by the delivery delay.
//! 3. **The drain sort is over the matched set.** Each completed `Wait`
//!    stable-sorts its matched messages by `(arrive, src, tag)` — a
//!    deterministic function of facts fixed by 1 and 2.
//!
//! Hence every clock advance sees identical inputs regardless of shard
//! count, and makespans, phase breakdowns and counters are bit-identical
//! to the single-threaded replay and to a threaded phantom run
//! (asserted with zero tolerance by `tests/replay_equivalence.rs` across
//! 1/2/4/8 shards).
//!
//! **Fault injection preserves all three facts.** A
//! [`FaultModel`](super::faults::FaultModel) perturbs only the *times* a
//! clock computes — multiplicatively, keyed on `(rank, peer, event
//! index)` — never which messages are sent, matched or drained. Each
//! rank's event indices count its own program order (tx) and its own
//! deterministic drain order (rx), both of which are shard-count- and
//! executor-independent by facts 1-3, so a faulted replay is still
//! bit-identical to a faulted threaded run at any shard count
//! (`tests/replay_equivalence.rs`, faulted grid).
//!
//! Invalid inputs surface as typed [`ReplayError`]s, never panics:
//! plan/topology shape mismatches ([`ReplayError::ShapeMismatch`]), plans
//! that park a rank forever ([`ReplayError::PlanDeadlock`]) and plans
//! that leave sent messages unreceived
//! ([`ReplayError::UndrainedMailbox`]) — the latter two are compiler
//! bugs, reported with the rank/op context needed to debug one.
//!
//! The threaded engine stays the golden oracle for real payloads; replay
//! never materializes payload bytes, so `Counters::copied_bytes` is zero,
//! exactly as in threaded phantom mode.

use std::collections::{HashMap, VecDeque};
use std::hash::BuildHasherDefault;

use thiserror::Error;

use super::clock::Clock;
use super::engine::{ChanHasher, EngineResult, RankResult};
use super::faults::{FaultLens, FaultModel};
use super::plan::{CommPlan, PlanOp};
use super::topology::Topology;
use super::PhaseBreakdown;
use crate::model::{Link, MachineProfile};

/// Typed replay failures. `ShapeMismatch` is a configuration error (the
/// caller handed a plan to the wrong topology); the other two mean the
/// plan itself is broken — a compiler bug — and carry the context a
/// compiler author needs. Converted into [`crate::TunaError`] where the
/// public API surfaces them (`algos::run_alltoallv_replay`).
#[derive(Clone, Debug, Error, PartialEq)]
pub enum ReplayError {
    /// The plan was compiled for a different process layout.
    #[error(
        "plan/topology mismatch: plan is for P={plan_p}, Q={plan_q} \
         but topology has P={topo_p}, Q={topo_q}"
    )]
    ShapeMismatch {
        plan_p: usize,
        plan_q: usize,
        topo_p: usize,
        topo_q: usize,
    },
    /// A `Wait` whose messages are never sent: `rank` stays parked at op
    /// `pc` (of `ops` total) with `missing` messages outstanding.
    #[error(
        "replay deadlock: rank {rank} parked at op {pc}/{ops} of {algo} \
         ({missing} messages missing)"
    )]
    PlanDeadlock {
        rank: usize,
        pc: usize,
        ops: usize,
        algo: String,
        missing: usize,
    },
    /// Messages were sent to `rank` but never received — the plan ended
    /// with `messages` undrained messages on `channels` channels.
    #[error(
        "rank {rank} mailbox not drained — plan left {messages} unreceived \
         messages on {channels} (src, tag) channels"
    )]
    UndrainedMailbox {
        rank: usize,
        messages: usize,
        channels: usize,
    },
}

impl From<ReplayError> for crate::TunaError {
    fn from(e: ReplayError) -> crate::TunaError {
        match e {
            ReplayError::ShapeMismatch { .. } => crate::TunaError::Config(e.to_string()),
            _ => crate::TunaError::Validation(e.to_string()),
        }
    }
}

/// A message in flight: what the receiver's drain needs, nothing more.
#[derive(Clone, Copy, Debug)]
struct InMsg {
    arrive: f64,
    bytes: u64,
    link: Link,
}

/// A cross-shard send buffered until the next window barrier.
#[derive(Clone, Copy, Debug)]
struct BoundaryMsg {
    dst: u32,
    src: u32,
    tag: u32,
    msg: InMsg,
}

type ChanMap = HashMap<(u32, u32), VecDeque<InMsg>, BuildHasherDefault<ChanHasher>>;
type MissingMap = HashMap<(u32, u32), usize, BuildHasherDefault<ChanHasher>>;

/// One rank's execution state.
struct ReplayRank {
    /// Index of the next op to execute.
    pc: usize,
    clock: Clock,
    phases: PhaseBreakdown,
    mark: f64,
    /// Completion times of sends posted since the last `Wait`.
    pending_sends: Vec<f64>,
    /// `(src, tag)` of receives posted since the last `Wait`, in request
    /// order (the order `waitall` matches and returns them in).
    pending_recvs: Vec<(u32, u32)>,
    /// Parked at a `Wait` with messages still missing.
    blocked: bool,
    /// Outstanding per-channel message deficits while blocked.
    missing: MissingMap,
    missing_total: usize,
    done: bool,
}

impl ReplayRank {
    fn new(faults: Option<FaultLens>) -> ReplayRank {
        ReplayRank {
            pc: 0,
            clock: Clock::with_faults(faults),
            phases: PhaseBreakdown::default(),
            mark: 0.0,
            pending_sends: Vec::new(),
            pending_recvs: Vec::new(),
            blocked: false,
            missing: MissingMap::default(),
            missing_total: 0,
            done: false,
        }
    }
}

/// Reusable per-shard scratch for `Wait` resolution: the deficit
/// counting map and the match/sort buffers of `perform_wait`. One
/// allocation set per shard for the whole replay instead of one per
/// completed `Wait` — at P = 262144 that is hundreds of millions of
/// avoided transient allocations on the hot loop.
#[derive(Default)]
struct WaitScratch {
    needed: MissingMap,
    msgs: Vec<InMsg>,
    order: Vec<usize>,
    sorted: Vec<(f64, u64, Link, usize)>,
}

/// One worker shard: a contiguous range of ranks plus their mailboxes,
/// ready queue and the boundary queue of cross-shard sends produced in
/// the current window. Shards share nothing during a window, so the
/// parallel phase needs no locks.
struct Shard {
    /// First global rank owned by this shard.
    start: usize,
    states: Vec<ReplayRank>,
    mailboxes: Vec<ChanMap>,
    /// Runnable ranks, as local indices.
    ready: VecDeque<usize>,
    in_queue: Vec<bool>,
    /// Cross-shard sends of the current window, in sender program order
    /// (per sender; senders within a shard are interleaved by the event
    /// loop, which is fine — FIFO only matters per `(src, tag)` channel).
    outbox: Vec<BoundaryMsg>,
    scratch: WaitScratch,
}

impl Shard {
    fn new(start: usize, len: usize, faults: Option<&FaultModel>) -> Shard {
        Shard {
            start,
            states: (0..len)
                .map(|i| ReplayRank::new(faults.map(|m| m.lens(start + i))))
                .collect(),
            mailboxes: (0..len).map(|_| ChanMap::default()).collect(),
            ready: (0..len).collect(),
            in_queue: vec![true; len],
            outbox: Vec::new(),
            scratch: WaitScratch::default(),
        }
    }

    #[inline]
    fn owns(&self, rank: usize) -> bool {
        rank >= self.start && rank < self.start + self.states.len()
    }

    /// Deposit a message into local rank `dl`'s mailbox and wake it if
    /// this clears its last deficit. The running rank is never `blocked`,
    /// so self-sends skip the wake branch naturally.
    fn deposit(&mut self, dl: usize, src: u32, tag: u32, msg: InMsg) {
        self.mailboxes[dl].entry((src, tag)).or_default().push_back(msg);
        let st = &mut self.states[dl];
        if st.blocked {
            if let Some(n) = st.missing.get_mut(&(src, tag)) {
                if *n > 0 {
                    *n -= 1;
                    st.missing_total -= 1;
                    if st.missing_total == 0 {
                        st.blocked = false;
                        if !self.in_queue[dl] {
                            self.in_queue[dl] = true;
                            self.ready.push_back(dl);
                        }
                    }
                }
            }
        }
    }

    /// Run this shard's event loop until every owned rank is parked or
    /// done — one conservative window. Cross-shard sends accumulate in
    /// `self.outbox` for the barrier to deliver.
    fn run_window(&mut self, profile: &MachineProfile, topo: Topology, plan: &CommPlan) {
        while let Some(li) = self.ready.pop_front() {
            self.in_queue[li] = false;
            let me = self.start + li;
            // Resolve the rank's interned program window once; ops decode
            // in place from the SoA columns (no materialized Vec<PlanOp>).
            let prog = plan.prog(me);
            loop {
                if self.states[li].pc == prog.len() {
                    self.states[li].done = true;
                    break;
                }
                match prog.op(self.states[li].pc) {
                    PlanOp::Send { dst, tag, bytes } => {
                        let d = dst as usize;
                        let link = topo.link(me, d);
                        let st = &mut self.states[li];
                        let timing = st.clock.post_send_to(profile, link, bytes, plan.p, d);
                        st.pending_sends.push(timing.complete);
                        let msg = InMsg {
                            arrive: timing.arrive,
                            bytes,
                            link,
                        };
                        if self.owns(d) {
                            self.deposit(d - self.start, me as u32, tag, msg);
                        } else {
                            self.outbox.push(BoundaryMsg {
                                dst,
                                src: me as u32,
                                tag,
                                msg,
                            });
                        }
                    }
                    PlanOp::Recv { src, tag } => {
                        let link = topo.link(me, src as usize);
                        let st = &mut self.states[li];
                        st.clock.post_recv(profile, link);
                        st.pending_recvs.push((src, tag));
                    }
                    PlanOp::Wait => {
                        let st = &mut self.states[li];
                        let missing_total = channel_deficits(
                            &st.pending_recvs,
                            &self.mailboxes[li],
                            &mut self.scratch.needed,
                            &mut st.missing,
                        );
                        if missing_total > 0 {
                            st.missing_total = missing_total;
                            st.blocked = true;
                            // pc stays on this Wait; resumed once the
                            // deficits drain (locally or at a barrier).
                            break;
                        }
                        perform_wait(st, &mut self.mailboxes[li], profile, &mut self.scratch);
                    }
                    PlanOp::Copy { bytes } => {
                        self.states[li].clock.charge_copy(profile, bytes);
                    }
                    PlanOp::Compute { secs } => {
                        self.states[li].clock.charge_compute(secs);
                    }
                    PlanOp::Mark => {
                        let st = &mut self.states[li];
                        st.mark = st.clock.now;
                    }
                    PlanOp::Lap { phase } => {
                        let st = &mut self.states[li];
                        let now = st.clock.now;
                        st.phases.add(phase, now - st.mark);
                        st.mark = now;
                    }
                }
                self.states[li].pc += 1;
            }
        }
    }
}

/// Default shard count for a `p`-rank replay when `replay-shards=auto`:
/// 1 below the scale where window-barrier overhead pays for itself, then
/// scaling with both the host's cores and the rank count. Any value is
/// correct — shard count is purely a wallclock knob; results are
/// bit-identical for every choice.
pub fn auto_shards(p: usize) -> usize {
    if p < 8192 {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min(p / 2048).clamp(1, 16)
}

/// Execute `plan` on the single-threaded event loop (the `shards = 1`
/// case of [`execute_sharded`]) and return per-rank results plus the
/// simulated makespan — the same shape
/// [`Engine::run`](super::Engine::run) produces, so
/// `phase_critical_path` / `total_counters` aggregation is shared.
pub fn execute(
    profile: &MachineProfile,
    topo: Topology,
    plan: &CommPlan,
) -> Result<EngineResult<()>, ReplayError> {
    execute_faulted(profile, topo, plan, 1, None)
}

/// Execute `plan` across `shards` worker shards with conservative
/// time-window synchronization (see the module header for the
/// determinism argument). `shards` is clamped to `[1, P]`; with one
/// shard no threads are spawned and this is exactly the classic
/// single-threaded replay.
pub fn execute_sharded(
    profile: &MachineProfile,
    topo: Topology,
    plan: &CommPlan,
    shards: usize,
) -> Result<EngineResult<()>, ReplayError> {
    execute_faulted(profile, topo, plan, shards, None)
}

/// [`execute_sharded`] under a deterministic fault model. Each rank's
/// clock carries the model's per-rank lens; `None` is exactly the
/// healthy replay. Perturbations never change what a plan sends or
/// matches, so shape/deadlock/drain validation is identical.
pub fn execute_faulted(
    profile: &MachineProfile,
    topo: Topology,
    plan: &CommPlan,
    shards: usize,
    faults: Option<&FaultModel>,
) -> Result<EngineResult<()>, ReplayError> {
    let p = topo.p();
    if plan.p != p || plan.q != topo.q() {
        return Err(ReplayError::ShapeMismatch {
            plan_p: plan.p,
            plan_q: plan.q,
            topo_p: p,
            topo_q: topo.q(),
        });
    }

    // Near-equal contiguous partition: the first `rem` shards own one
    // extra rank. Contiguity keeps node-local traffic (ranks on a node
    // are contiguous) mostly intra-shard.
    let shards = shards.clamp(1, p);
    let base = p / shards;
    let rem = p % shards;
    let shard_of = |rank: usize| -> usize {
        let cut = rem * (base + 1);
        if rank < cut {
            rank / (base + 1)
        } else {
            rem + (rank - cut) / base
        }
    };
    let mut parts: Vec<Shard> = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < rem);
        parts.push(Shard::new(start, len, faults));
        start += len;
    }

    // Window loop: run every shard with runnable ranks to quiescence
    // (in parallel), then deliver the boundary queues at the barrier.
    // Each popped rank advances at least one op, so the loop terminates;
    // it exits when a barrier wakes nobody.
    loop {
        let mut active: Vec<&mut Shard> =
            parts.iter_mut().filter(|s| !s.ready.is_empty()).collect();
        match active.len() {
            0 => break,
            1 => active[0].run_window(profile, topo, plan),
            _ => {
                std::thread::scope(|scope| {
                    for shard in active {
                        scope.spawn(move || shard.run_window(profile, topo, plan));
                    }
                });
            }
        }
        // Barrier: drain every outbox in shard order. Per-channel FIFO is
        // preserved because a channel's messages come from one sender,
        // whose shard appended them in program order.
        let batches: Vec<Vec<BoundaryMsg>> = parts
            .iter_mut()
            .map(|s| std::mem::take(&mut s.outbox))
            .collect();
        for bm in batches.into_iter().flatten() {
            let t = shard_of(bm.dst as usize);
            let dl = bm.dst as usize - parts[t].start;
            parts[t].deposit(dl, bm.src, bm.tag, bm.msg);
        }
    }

    let mut states: Vec<ReplayRank> = Vec::with_capacity(p);
    let mut mailboxes: Vec<ChanMap> = Vec::with_capacity(p);
    for shard in parts {
        states.extend(shard.states);
        mailboxes.extend(shard.mailboxes);
    }
    for (rank, st) in states.iter().enumerate() {
        if !st.done {
            return Err(ReplayError::PlanDeadlock {
                rank,
                pc: st.pc,
                ops: plan.rank_len(rank),
                algo: plan.algo.clone(),
                missing: st.missing_total,
            });
        }
    }
    for (rank, mb) in mailboxes.iter().enumerate() {
        if !mb.is_empty() {
            return Err(ReplayError::UndrainedMailbox {
                rank,
                messages: mb.values().map(VecDeque::len).sum(),
                channels: mb.len(),
            });
        }
    }

    let ranks: Vec<RankResult<()>> = states
        .into_iter()
        .enumerate()
        .map(|(rank, st)| RankResult {
            rank,
            value: (),
            finish: st.clock.now,
            phases: st.phases,
            counters: st.clock.counters,
        })
        .collect();
    let makespan = ranks.iter().fold(0.0f64, |m, r| m.max(r.finish));
    Ok(EngineResult { ranks, makespan })
}

/// Per-channel message deficits of a pending receive set against a
/// mailbox: which `(src, tag)` channels still owe how many messages.
/// `needed` is counting scratch; the deficits land in `missing` (the
/// blocked rank's own map, reused across waits). Returns the total.
fn channel_deficits(
    pending: &[(u32, u32)],
    mb: &ChanMap,
    needed: &mut MissingMap,
    missing: &mut MissingMap,
) -> usize {
    needed.clear();
    for &key in pending {
        *needed.entry(key).or_insert(0) += 1;
    }
    missing.clear();
    let mut total = 0usize;
    for (&key, &need) in needed.iter() {
        let avail = mb.get(&key).map_or(0, VecDeque::len);
        if avail < need {
            missing.insert(key, need - avail);
            total += need - avail;
        }
    }
    total
}

/// Complete a `Wait` whose messages are all present — the mirror of
/// `RankCtx::waitall`: FIFO-match per channel in request order, drain in
/// deterministic `(arrival, src, tag)` order, then advance program order
/// past sends and receive completions. Match/sort buffers come from the
/// shard's [`WaitScratch`].
fn perform_wait(
    st: &mut ReplayRank,
    mb: &mut ChanMap,
    profile: &MachineProfile,
    scratch: &mut WaitScratch,
) {
    let n = st.pending_recvs.len();
    let msgs = &mut scratch.msgs;
    msgs.clear();
    for &key in &st.pending_recvs {
        let q = mb.get_mut(&key).expect("readiness check guaranteed a message");
        let m = q.pop_front().expect("readiness check guaranteed a message");
        if q.is_empty() {
            mb.remove(&key);
        }
        msgs.push(m);
    }

    // Deterministic drain order, identical to the engine: by (arrive,
    // src, tag), stable in request order.
    let order = &mut scratch.order;
    order.clear();
    order.extend(0..n);
    let pending = &st.pending_recvs;
    order.sort_by(|&a, &b| {
        msgs[a]
            .arrive
            .partial_cmp(&msgs[b].arrive)
            .unwrap()
            .then(pending[a].0.cmp(&pending[b].0))
            .then(pending[a].1.cmp(&pending[b].1))
    });
    let sorted = &mut scratch.sorted;
    sorted.clear();
    sorted.extend(order.iter().map(|&i| {
        (
            msgs[i].arrive,
            msgs[i].bytes,
            msgs[i].link,
            pending[i].0 as usize,
        )
    }));
    let completions = st.clock.drain_receives_from(profile, sorted);

    let mut t = 0.0f64;
    for &s in &st.pending_sends {
        t = t.max(s);
    }
    for &c in &completions {
        t = t.max(c);
    }
    st.clock.finish_wait(t);
    st.pending_sends.clear();
    st.pending_recvs.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::buffer::DataBuf;
    use crate::comm::plan::PlanBuilder;
    use crate::comm::{Engine, Payload, Phase};

    fn ring_plan(p: usize, bytes: u64) -> CommPlan {
        let ranks = (0..p)
            .map(|me| {
                let mut b = PlanBuilder::new(me, p);
                b.mark();
                b.sendrecv((me + 1) % p, 7, bytes, (me + p - 1) % p, 7);
                b.lap(Phase::Data);
                b.finish()
            })
            .collect();
        CommPlan::from_rank_plans(p, 2, "ring".into(), ranks, 0, 1)
    }

    #[test]
    fn ring_replay_matches_threaded_engine_bitwise() {
        let profile = MachineProfile::test_flat();
        let topo = Topology::new(4, 2);
        let plan = ring_plan(4, 1024);
        let replayed = execute(&profile, topo, &plan).unwrap();

        let engine = Engine::new(profile, topo);
        let threaded = engine.run(|ctx| {
            let p = ctx.size();
            let me = ctx.rank();
            ctx.phase_mark();
            let _ = ctx.sendrecv(
                (me + 1) % p,
                7,
                Payload::Raw(DataBuf::Phantom(1024)),
                (me + p - 1) % p,
                7,
            );
            ctx.phase_lap(Phase::Data);
        });

        assert_eq!(replayed.makespan.to_bits(), threaded.makespan.to_bits());
        for (a, b) in replayed.ranks.iter().zip(threaded.ranks.iter()) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "rank {}", a.rank);
            assert_eq!(a.phases, b.phases, "rank {}", a.rank);
            assert_eq!(a.counters, b.counters, "rank {}", a.rank);
        }
    }

    #[test]
    fn sharded_ring_is_bit_identical_for_every_shard_count() {
        let profile = MachineProfile::test_flat();
        let topo = Topology::new(8, 2);
        let plan = ring_plan(8, 512);
        let single = execute(&profile, topo, &plan).unwrap();
        for shards in [2usize, 3, 4, 8, 64] {
            let sharded = execute_sharded(&profile, topo, &plan, shards).unwrap();
            assert_eq!(
                single.makespan.to_bits(),
                sharded.makespan.to_bits(),
                "{shards} shards"
            );
            for (a, b) in single.ranks.iter().zip(sharded.ranks.iter()) {
                assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "rank {}", a.rank);
                assert_eq!(a.phases, b.phases, "rank {}", a.rank);
                assert_eq!(a.counters, b.counters, "rank {}", a.rank);
            }
        }
    }

    #[test]
    fn self_send_and_out_of_order_arrivals_resolve() {
        // Rank 0 waits for rank 1's message and its own self-send in one
        // wait; rank 1 depends on rank 0's reply afterwards.
        let profile = MachineProfile::test_flat();
        let topo = Topology::flat(2);
        let mut b0 = PlanBuilder::new(0, 2);
        b0.send(0, 3, 8);
        b0.recv(0, 3);
        b0.recv(1, 4);
        b0.wait();
        b0.send(1, 5, 16);
        b0.wait();
        let mut b1 = PlanBuilder::new(1, 2);
        b1.send(0, 4, 8);
        b1.wait();
        b1.recv(0, 5);
        b1.wait();
        let plan =
            CommPlan::from_rank_plans(2, 1, "x".into(), vec![b0.finish(), b1.finish()], 0, 0);
        let res = execute(&profile, topo, &plan).unwrap();
        assert!(res.makespan > 0.0);
        assert_eq!(res.ranks.len(), 2);
        // The cross-shard dependency chain (0 -> barrier -> 1 -> barrier
        // -> 0) resolves identically with every rank on its own shard.
        let sharded = execute_sharded(&profile, topo, &plan, 2).unwrap();
        assert_eq!(res.makespan.to_bits(), sharded.makespan.to_bits());
    }

    #[test]
    fn missing_sender_surfaces_typed_deadlock_error() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.recv(1, 1);
        b0.wait();
        let b1 = PlanBuilder::new(1, 2);
        let plan =
            CommPlan::from_rank_plans(2, 1, "x".into(), vec![b0.finish(), b1.finish()], 0, 0);
        let err = execute(&MachineProfile::test_flat(), Topology::flat(2), &plan).unwrap_err();
        assert_eq!(
            err,
            ReplayError::PlanDeadlock {
                rank: 0,
                pc: 1,
                ops: 2,
                algo: "x".into(),
                missing: 1,
            }
        );
        assert!(err.to_string().contains("replay deadlock"), "{err}");
        // The sharded scheduler detects the same deadlock, identically.
        let sharded =
            execute_sharded(&MachineProfile::test_flat(), Topology::flat(2), &plan, 2).unwrap_err();
        assert_eq!(err, sharded);
        // And it converts to a validation-class TunaError for the public
        // API (`run_alltoallv_replay` surfaces it via `?`).
        let typed: crate::TunaError = err.into();
        assert!(typed.to_string().contains("validation"), "{typed}");
    }

    #[test]
    fn unreceived_message_surfaces_typed_undrained_error() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.send(1, 9, 8);
        b0.wait();
        let b1 = PlanBuilder::new(1, 2);
        let plan =
            CommPlan::from_rank_plans(2, 1, "x".into(), vec![b0.finish(), b1.finish()], 0, 0);
        let err = execute(&MachineProfile::test_flat(), Topology::flat(2), &plan).unwrap_err();
        assert_eq!(
            err,
            ReplayError::UndrainedMailbox {
                rank: 1,
                messages: 1,
                channels: 1,
            }
        );
        assert!(err.to_string().contains("not drained"), "{err}");
    }

    #[test]
    fn shape_mismatch_surfaces_typed_config_error() {
        let plan = ring_plan(4, 64); // compiled for P=4, Q=2
        let profile = MachineProfile::test_flat();
        let err = execute(&profile, Topology::new(8, 2), &plan).unwrap_err();
        assert_eq!(
            err,
            ReplayError::ShapeMismatch {
                plan_p: 4,
                plan_q: 2,
                topo_p: 8,
                topo_q: 2,
            }
        );
        let err = execute(&profile, Topology::flat(4), &plan).unwrap_err();
        assert!(matches!(err, ReplayError::ShapeMismatch { plan_q: 2, topo_q: 1, .. }));
        let typed: crate::TunaError = err.into();
        assert!(typed.to_string().contains("configuration"), "{typed}");
    }

    #[test]
    fn fifo_per_channel_preserved_under_duplicate_requests() {
        // Two messages on one (src, tag) channel received by duplicate
        // requests in one wait — must match FIFO like the engine, on the
        // single-threaded path and through a shard boundary queue.
        let profile = MachineProfile::test_flat();
        let mut b0 = PlanBuilder::new(0, 2);
        b0.recv(1, 3);
        b0.recv(1, 3);
        b0.wait();
        let mut b1 = PlanBuilder::new(1, 2);
        b1.send(0, 3, 64);
        b1.send(0, 3, 128);
        b1.wait();
        let plan =
            CommPlan::from_rank_plans(2, 1, "x".into(), vec![b0.finish(), b1.finish()], 0, 0);
        let res = execute(&profile, Topology::flat(2), &plan).unwrap();
        // 64 + 128 wire bytes on the global link, both counted at rank 1.
        assert_eq!(res.total_counters().bytes_global, 192);
        assert_eq!(res.total_counters().msgs_global, 2);
        let sharded = execute_sharded(&profile, Topology::flat(2), &plan, 2).unwrap();
        assert_eq!(res.makespan.to_bits(), sharded.makespan.to_bits());
        assert_eq!(res.total_counters(), sharded.total_counters());
    }

    #[test]
    fn faulted_ring_replay_matches_faulted_threaded_engine_bitwise() {
        use crate::comm::faults::FaultSpec;
        let profile = MachineProfile::test_flat();
        let topo = Topology::new(4, 2);
        let plan = ring_plan(4, 1024);
        let spec = FaultSpec::parse(
            "straggler:rank=1,slow=4/link:node=0-1,bw=0.5,lat=2/jitter:sigma=0.2,seed=7",
        )
        .unwrap();
        let model = FaultModel::compile(&spec, 2);
        let faulted = execute_faulted(&profile, topo, &plan, 1, Some(&model)).unwrap();

        let engine = Engine::new(profile, topo).with_faults(&spec);
        let threaded = engine.run(|ctx| {
            let p = ctx.size();
            let me = ctx.rank();
            ctx.phase_mark();
            let _ = ctx.sendrecv(
                (me + 1) % p,
                7,
                Payload::Raw(DataBuf::Phantom(1024)),
                (me + p - 1) % p,
                7,
            );
            ctx.phase_lap(Phase::Data);
        });

        assert_eq!(faulted.makespan.to_bits(), threaded.makespan.to_bits());
        for (a, b) in faulted.ranks.iter().zip(threaded.ranks.iter()) {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "rank {}", a.rank);
            assert_eq!(a.phases, b.phases, "rank {}", a.rank);
            assert_eq!(a.counters, b.counters, "rank {}", a.rank);
        }
        // The perturbation is real: a healthy replay differs.
        let healthy = execute(&profile, topo, &plan).unwrap();
        assert_ne!(healthy.makespan.to_bits(), faulted.makespan.to_bits());
        // And shard-count-independent.
        for shards in [2usize, 4] {
            let sharded = execute_faulted(&profile, topo, &plan, shards, Some(&model)).unwrap();
            assert_eq!(faulted.makespan.to_bits(), sharded.makespan.to_bits(), "{shards}");
        }
    }

    #[test]
    fn auto_shards_scales_with_p() {
        assert_eq!(auto_shards(2), 1);
        assert_eq!(auto_shards(4096), 1);
        assert!(auto_shards(8192) >= 1);
        assert!(auto_shards(1 << 18) >= auto_shards(8192));
        assert!(auto_shards(1 << 18) <= 16);
    }
}
