//! Threaded rank engine with virtual time.
//!
//! `Engine::run(p, f)` spawns one OS thread per rank, each owning a
//! [`RankCtx`] that exposes MPI-like operations. Message *matching* uses
//! OS-level mailboxes (mutex + condvar, FIFO per `(src, tag)` channel, like
//! MPI's non-overtaking rule); message *timing* is purely virtual, so the
//! simulated makespan is independent of host scheduling.
//!
//! Tags below [`RESERVED_TAG_BASE`] are free for algorithms; the engine's
//! built-in collectives use the reserved space.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::{Arc, Condvar, Mutex};

use super::buffer::Payload;
use super::clock::{Clock, Counters};
use super::topology::Topology;
use super::{Phase, PhaseBreakdown};
use crate::algos::tuning::TuningTable;
use crate::model::{Link, MachineProfile};

/// Tags at or above this value are reserved for engine collectives. The
/// allreduce tags are shared with the plan compiler (`super::plan`),
/// which emits the identical butterfly schedule.
pub const RESERVED_TAG_BASE: u32 = 0x8000_0000;
pub(crate) const TAG_AR_FOLD: u32 = RESERVED_TAG_BASE;
pub(crate) const TAG_AR_UNFOLD: u32 = RESERVED_TAG_BASE + 1;
pub(crate) const TAG_AR_ROUND: u32 = RESERVED_TAG_BASE + 2; // + k per butterfly round

/// A message in flight: payload plus its virtual arrival time at the
/// receiver's rx port.
struct Msg {
    payload: Payload,
    arrive: f64,
    link: Link,
}

/// Fast hasher for `(src, tag)` channel keys — the mailbox map is on the
/// per-message hot path and SipHash costs show up at P = 16k ranks.
/// Shared with the replay executor's single-threaded mailboxes.
#[derive(Default)]
pub(crate) struct ChanHasher(u64);

impl Hasher for ChanHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        }
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.0 = (self.0 ^ v as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        self.0 ^= self.0 >> 29;
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

type ChanMap = HashMap<(u32, u32), VecDeque<Msg>, BuildHasherDefault<ChanHasher>>;

/// One mailbox per destination rank; channels keyed by `(src, tag)`.
struct Mailbox {
    inner: Mutex<ChanMap>,
    cv: Condvar,
    /// True while the owner rank is blocked in `pop_many` — lets senders
    /// skip the notify syscall in the common already-delivered case.
    waiting: std::sync::atomic::AtomicBool,
}

impl Mailbox {
    fn new() -> Mailbox {
        Mailbox {
            inner: Mutex::new(ChanMap::default()),
            cv: Condvar::new(),
            waiting: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn push(&self, src: u32, tag: u32, msg: Msg) {
        let mut map = self.inner.lock().unwrap();
        map.entry((src, tag)).or_default().push_back(msg);
        // `waiting` is only mutated under this same mutex, so Relaxed is
        // sufficient — the lock provides the ordering.
        if self.waiting.load(std::sync::atomic::Ordering::Relaxed) {
            // Only the mailbox owner ever waits on this condvar.
            self.cv.notify_one();
        }
    }

    /// Blocking pop of one message per request, in request order, under a
    /// single lock session — one lock/unlock per *wait*, not per message.
    /// Duplicate `(src, tag)` requests drain their channel FIFO in request
    /// order.
    fn pop_many(&self, reqs: &[(u32, u32)]) -> Vec<Msg> {
        use std::sync::atomic::Ordering;
        let mut out: Vec<Option<Msg>> = reqs.iter().map(|_| None).collect();
        let mut missing = reqs.len();
        let mut map = self.inner.lock().unwrap();
        loop {
            for (i, key) in reqs.iter().enumerate() {
                if out[i].is_none() {
                    if let Some(q) = map.get_mut(key) {
                        if let Some(m) = q.pop_front() {
                            if q.is_empty() {
                                map.remove(key);
                            }
                            out[i] = Some(m);
                            missing -= 1;
                        }
                    }
                }
            }
            if missing == 0 {
                break;
            }
            self.waiting.store(true, Ordering::Relaxed);
            map = self.cv.wait(map).unwrap();
            self.waiting.store(false, Ordering::Relaxed);
        }
        drop(map);
        out.into_iter().map(|m| m.unwrap()).collect()
    }

    /// Blocking pop of exactly one message from one channel — the
    /// `waitall` fast path for the single-receive case. Identical
    /// matching semantics to [`Mailbox::pop_many`] with one request,
    /// without the per-request bookkeeping vectors.
    fn pop_one(&self, key: (u32, u32)) -> Msg {
        use std::sync::atomic::Ordering;
        let mut map = self.inner.lock().unwrap();
        loop {
            if let Some(q) = map.get_mut(&key) {
                if let Some(m) = q.pop_front() {
                    if q.is_empty() {
                        map.remove(&key);
                    }
                    return m;
                }
            }
            self.waiting.store(true, Ordering::Relaxed);
            map = self.cv.wait(map).unwrap();
            self.waiting.store(false, Ordering::Relaxed);
        }
    }

    fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }
}

/// Handle for a posted non-blocking send.
#[derive(Clone, Copy, Debug)]
pub struct SendReq {
    /// Virtual time at which the send is locally complete.
    pub complete: f64,
}

/// Handle for a posted non-blocking receive.
#[derive(Clone, Copy, Debug)]
pub struct RecvReq {
    src: u32,
    tag: u32,
}

/// Per-rank execution context handed to algorithm code.
pub struct RankCtx<'e> {
    rank: usize,
    topo: Topology,
    profile: &'e MachineProfile,
    mailboxes: &'e [Mailbox],
    tuning: Option<&'e TuningTable>,
    clock: Clock,
    phases: PhaseBreakdown,
    mark: f64,
}

impl<'e> RankCtx<'e> {
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    #[inline]
    pub fn size(&self) -> usize {
        self.topo.p()
    }

    #[inline]
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    #[inline]
    pub fn profile(&self) -> &MachineProfile {
        self.profile
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> f64 {
        self.clock.now
    }

    #[inline]
    pub fn counters(&self) -> &Counters {
        &self.clock.counters
    }

    /// The persisted tuning table attached to the engine, if any —
    /// consulted by `tuna:auto` dispatch before falling back to the §V-A
    /// heuristic.
    #[inline]
    pub fn tuning_table(&self) -> Option<&TuningTable> {
        self.tuning
    }

    /// Post a non-blocking send. The payload travels by value — ropes
    /// move their segment views, never payload bytes — so enqueueing
    /// never clones block data. Its virtual arrival time is computed here
    /// from the sender's clock and the link cost model.
    pub fn isend(&mut self, dst: usize, tag: u32, payload: Payload) -> SendReq {
        debug_assert!(dst < self.size(), "isend to rank {dst} of {}", self.size());
        debug_assert!(tag < RESERVED_TAG_BASE, "tag {tag:#x} is reserved");
        self.isend_impl(dst, tag, payload)
    }

    fn isend_impl(&mut self, dst: usize, tag: u32, payload: Payload) -> SendReq {
        let link = self.topo.link(self.rank, dst);
        let bytes = payload.wire_bytes();
        let timing = self.clock.post_send_to(self.profile, link, bytes, self.size(), dst);
        self.mailboxes[dst].push(
            self.rank as u32,
            tag,
            Msg {
                payload,
                arrive: timing.arrive,
                link,
            },
        );
        SendReq {
            complete: timing.complete,
        }
    }

    /// Post a non-blocking receive for `(src, tag)`.
    pub fn irecv(&mut self, src: usize, tag: u32) -> RecvReq {
        debug_assert!(src < self.size());
        let link = self.topo.link(self.rank, src);
        self.clock.post_recv(self.profile, link);
        RecvReq {
            src: src as u32,
            tag,
        }
    }

    /// Wait for all given sends and receives. Returns the received
    /// payloads in *request order*. Receive drain order (and thus timing)
    /// is deterministic: sorted by virtual arrival, tie-broken by source.
    pub fn waitall(&mut self, sends: &[SendReq], recvs: &[RecvReq]) -> Vec<Payload> {
        let mut t = 0.0f64;
        for s in sends {
            t = t.max(s.complete);
        }
        if recvs.is_empty() {
            self.clock.finish_wait(t);
            return Vec::new();
        }
        // Fast path: a single receive (the common case for the
        // sendrecv-heavy linear/pairwise algorithms) needs no arrival
        // sort and none of the general path's per-call scratch vectors
        // (request keys, popped-message, order and sorted-drain buffers).
        if let [r] = recvs {
            let msg = self.mailboxes[self.rank].pop_one((r.src, r.tag));
            let bytes = msg.payload.wire_bytes();
            let done =
                self.clock
                    .drain_one_from(self.profile, msg.arrive, bytes, msg.link, r.src as usize);
            self.clock.finish_wait(t.max(done));
            return vec![msg.payload];
        }

        // Block (OS level) for every message to materialize — one lock
        // session for the whole batch.
        let keys: Vec<(u32, u32)> = recvs.iter().map(|r| (r.src, r.tag)).collect();
        let mut msgs: Vec<(usize, Msg)> = self.mailboxes[self.rank]
            .pop_many(&keys)
            .into_iter()
            .enumerate()
            .collect();

        // Deterministic drain order: by (arrive, src, tag).
        let mut order: Vec<usize> = (0..msgs.len()).collect();
        order.sort_by(|&a, &b| {
            let (ia, ma) = (&msgs[a].0, &msgs[a].1);
            let (ib, mb) = (&msgs[b].0, &msgs[b].1);
            ma.arrive
                .partial_cmp(&mb.arrive)
                .unwrap()
                .then(recvs[*ia].src.cmp(&recvs[*ib].src))
                .then(recvs[*ia].tag.cmp(&recvs[*ib].tag))
        });
        let sorted: Vec<(f64, u64, Link, usize)> = order
            .iter()
            .map(|&i| {
                (
                    msgs[i].1.arrive,
                    msgs[i].1.payload.wire_bytes(),
                    msgs[i].1.link,
                    recvs[msgs[i].0].src as usize,
                )
            })
            .collect();
        let completions = self.clock.drain_receives_from(self.profile, &sorted);

        for c in &completions {
            t = t.max(*c);
        }
        self.clock.finish_wait(t);

        // Return payloads in request order.
        let mut out: Vec<Option<Payload>> = (0..msgs.len()).map(|_| None).collect();
        for (slot, &i) in order.iter().enumerate() {
            let _ = slot;
            let (req_idx, _) = msgs[i];
            let payload = std::mem::replace(&mut msgs[i].1.payload, Payload::Scalar(0));
            out[req_idx] = Some(payload);
        }
        out.into_iter().map(|p| p.unwrap()).collect()
    }

    /// Blocking send.
    pub fn send(&mut self, dst: usize, tag: u32, payload: Payload) {
        let req = self.isend(dst, tag, payload);
        self.clock.finish_wait(req.complete);
    }

    /// Blocking receive.
    pub fn recv(&mut self, src: usize, tag: u32) -> Payload {
        let r = self.irecv(src, tag);
        let mut p = self.waitall(&[], &[r]);
        p.pop().unwrap()
    }

    /// Combined send + receive (MPI_Sendrecv).
    pub fn sendrecv(
        &mut self,
        dst: usize,
        stag: u32,
        payload: Payload,
        src: usize,
        rtag: u32,
    ) -> Payload {
        let s = self.isend(dst, stag, payload);
        let r = self.irecv(src, rtag);
        let mut p = self.waitall(&[s], &[r]);
        p.pop().unwrap()
    }

    /// Charge a local memory copy of `bytes`.
    pub fn copy(&mut self, bytes: u64) {
        self.clock.charge_copy(self.profile, bytes);
    }

    /// Charge local compute time.
    pub fn compute(&mut self, seconds: f64) {
        self.clock.charge_compute(seconds);
    }

    // ---- phase accounting ------------------------------------------------

    /// Start (or restart) the phase stopwatch.
    pub fn phase_mark(&mut self) {
        self.mark = self.clock.now;
    }

    /// Attribute virtual time since the last mark to `phase` and re-mark.
    pub fn phase_lap(&mut self, phase: Phase) {
        let now = self.clock.now;
        self.phases.add(phase, now - self.mark);
        self.mark = now;
    }

    pub fn phases(&self) -> &PhaseBreakdown {
        &self.phases
    }

    // ---- built-in collectives ---------------------------------------------

    /// Max-allreduce of a u64 via recursive doubling (with pre/post folding
    /// for non-power-of-two P), timed like any other traffic.
    pub fn allreduce_max(&mut self, v: u64) -> u64 {
        self.allreduce(v, |a, b| a.max(b))
    }

    /// Sum-allreduce of a u64.
    pub fn allreduce_sum(&mut self, v: u64) -> u64 {
        self.allreduce(v, |a, b| a.wrapping_add(b))
    }

    fn allreduce(&mut self, mut v: u64, op: fn(u64, u64) -> u64) -> u64 {
        let p = self.size();
        if p == 1 {
            return v;
        }
        let p2 = prev_pow2(p);
        let extra = p - p2;
        let rank = self.rank;

        if rank >= p2 {
            // Fold into the power-of-two core, then wait for the result.
            let peer = rank - p2;
            let s = self.isend_impl(peer, TAG_AR_FOLD, Payload::Scalar(v));
            self.clock.finish_wait(s.complete);
            return self
                .recv_reserved(peer, TAG_AR_UNFOLD)
                .into_scalar();
        }
        if rank < extra {
            let theirs = self.recv_reserved(rank + p2, TAG_AR_FOLD).into_scalar();
            v = op(v, theirs);
        }
        let rounds = p2.trailing_zeros();
        for k in 0..rounds {
            let partner = rank ^ (1usize << k);
            let s = self.isend_impl(partner, TAG_AR_ROUND + k, Payload::Scalar(v));
            let r = RecvReq {
                src: partner as u32,
                tag: TAG_AR_ROUND + k,
            };
            let link = self.topo.link(self.rank, partner);
            self.clock.post_recv(self.profile, link);
            let mut got = self.waitall(&[s], &[r]);
            v = op(v, got.pop().unwrap().into_scalar());
        }
        if rank < extra {
            let s = self.isend_impl(rank + p2, TAG_AR_UNFOLD, Payload::Scalar(v));
            self.clock.finish_wait(s.complete);
        }
        v
    }

    fn recv_reserved(&mut self, src: usize, tag: u32) -> Payload {
        let link = self.topo.link(self.rank, src);
        self.clock.post_recv(self.profile, link);
        let r = RecvReq {
            src: src as u32,
            tag,
        };
        let mut p = self.waitall(&[], &[r]);
        p.pop().unwrap()
    }

    /// Barrier = zero-valued max-allreduce.
    pub fn barrier(&mut self) {
        self.allreduce_max(0);
    }

    // ---- plan interpretation ----------------------------------------------

    /// Interpret one compiled rank plan op-for-op on this context — the
    /// threaded twin of the replay executor's loop, used by the segmented
    /// overlap driver so both executors run the identical stitched
    /// schedule. Sends carry phantom payloads (plans model sizes, never
    /// bytes) and go through `isend_impl` directly: compiled plans
    /// legitimately carry reserved allreduce tags (`TAG_AR_*`), which the
    /// public `isend` rejects. `Wait` resolves exactly the sends/recvs
    /// posted since the previous `Wait`, matching `PlanOp::Wait`
    /// semantics and the replay executor's pending-set handling.
    pub fn run_plan(&mut self, plan: &super::plan::RankPlan) {
        use super::buffer::DataBuf;
        use super::plan::PlanOp;
        let mut sends: Vec<SendReq> = Vec::new();
        let mut recvs: Vec<RecvReq> = Vec::new();
        for op in &plan.ops {
            match *op {
                PlanOp::Send { dst, tag, bytes } => {
                    let req =
                        self.isend_impl(dst as usize, tag, Payload::Raw(DataBuf::Phantom(bytes)));
                    sends.push(req);
                }
                PlanOp::Recv { src, tag } => {
                    recvs.push(self.irecv(src as usize, tag));
                }
                PlanOp::Wait => {
                    let _ = self.waitall(&sends, &recvs);
                    sends.clear();
                    recvs.clear();
                }
                PlanOp::Copy { bytes } => self.copy(bytes),
                PlanOp::Compute { secs } => self.compute(secs),
                PlanOp::Mark => self.phase_mark(),
                PlanOp::Lap { phase } => self.phase_lap(phase),
            }
        }
        debug_assert!(
            sends.is_empty() && recvs.is_empty(),
            "rank {} plan ended with posted ops and no closing Wait",
            self.rank
        );
    }
}

pub(crate) fn prev_pow2(n: usize) -> usize {
    debug_assert!(n >= 1);
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// Result of one rank's execution.
#[derive(Clone, Debug)]
pub struct RankResult<R> {
    pub rank: usize,
    pub value: R,
    /// The rank's final virtual time.
    pub finish: f64,
    pub phases: PhaseBreakdown,
    pub counters: Counters,
}

/// Result of a whole engine run.
#[derive(Clone, Debug)]
pub struct EngineResult<R> {
    pub ranks: Vec<RankResult<R>>,
    /// Simulated completion time: max over ranks' final clocks.
    pub makespan: f64,
}

impl<R> EngineResult<R> {
    /// Per-phase critical path (element-wise max over ranks) — what the
    /// paper's breakdown bars show.
    pub fn phase_critical_path(&self) -> PhaseBreakdown {
        let mut agg = PhaseBreakdown::default();
        for r in &self.ranks {
            agg.max_with(&r.phases);
        }
        agg
    }

    /// Aggregate communication counters over all ranks.
    pub fn total_counters(&self) -> Counters {
        let mut c = Counters::default();
        for r in &self.ranks {
            c.merge(&r.counters);
        }
        c
    }

    pub fn values(self) -> Vec<R> {
        self.ranks.into_iter().map(|r| r.value).collect()
    }
}

/// The engine: a machine profile plus a topology.
pub struct Engine {
    pub profile: MachineProfile,
    pub topo: Topology,
    /// Stack size per rank thread (algorithms are iterative; small stacks
    /// let large-P simulations fit comfortably).
    pub stack_size: usize,
    /// Optional persisted tuning table, exposed to rank code through
    /// [`RankCtx::tuning_table`] (used by `tuna:auto` dispatch).
    pub tuning: Option<Arc<TuningTable>>,
    /// Compiled-plan cache for the replay executor, keyed by
    /// `(algo spec, counts-matrix identity)` — repeated collectives on
    /// one engine replay without re-compiling (`algos::plan_for`).
    pub plan_cache: super::plan::PlanCache,
    /// Worker-shard count for the replay executor; `None` picks
    /// [`super::replay::auto_shards`] from P and the host. Purely a
    /// wallclock knob — replay results are bit-identical for every value.
    pub replay_shards: Option<usize>,
    /// Deterministic fault model (`None` = healthy). Threaded runs hand
    /// each rank clock its per-rank lens; replay runs thread the model
    /// through `replay::execute_faulted`. The plan cache is *not* keyed
    /// on faults: perturbations scale execution times, never schedules.
    pub faults: Option<Arc<super::faults::FaultModel>>,
    /// Plan-compile worker count; `None` = auto (serial below
    /// [`Engine::COMPILE_PAR_MIN_P`] ranks, else up to 16 host threads).
    /// Purely a wallclock knob — compiled plans are
    /// representation-identical for every value (the parallel-compile
    /// determinism contract of `comm::plan`).
    pub compile_threads: Option<usize>,
}

impl Engine {
    /// Below this many ranks the auto `compile-threads` policy stays
    /// serial: a plan this small compiles in well under a worker
    /// spawn's worth of time.
    pub const COMPILE_PAR_MIN_P: usize = 4096;

    pub fn new(profile: MachineProfile, topo: Topology) -> Engine {
        Engine {
            profile,
            topo,
            stack_size: 1 << 20,
            tuning: None,
            plan_cache: super::plan::PlanCache::default(),
            replay_shards: None,
            faults: None,
            compile_threads: None,
        }
    }

    /// Attach (or detach) a persisted tuning table for `tuna:auto`. The
    /// plan cache is reset: `tuna:auto` plans resolve their radix
    /// against the attached table at compile time, so plans compiled
    /// under the old table would silently replay a stale radix.
    pub fn with_tuning(mut self, table: Option<Arc<TuningTable>>) -> Engine {
        self.tuning = table;
        self.plan_cache = super::plan::PlanCache::default();
        self
    }

    /// Pin the replay executor's worker-shard count (`Some(n)`) or
    /// restore auto-sizing (`None`). The plan cache is untouched: shard
    /// count never changes what a plan computes, only how fast.
    pub fn with_replay_shards(mut self, shards: Option<usize>) -> Engine {
        self.replay_shards = shards;
        self
    }

    /// Pin the plan-compile worker count (`Some(n)`, clamped to >= 1) or
    /// restore the auto policy (`None`). The plan cache is untouched —
    /// compiled plans are representation-identical for every value.
    pub fn with_compile_threads(mut self, threads: Option<usize>) -> Engine {
        self.compile_threads = threads;
        self
    }

    /// Replace the plan cache with one bounded at `cap` entries (LRU) —
    /// the `plan-cache-cap` serving knob. Existing entries are dropped.
    pub fn with_plan_cache_capacity(mut self, cap: usize) -> Engine {
        self.plan_cache = super::plan::PlanCache::with_capacity(cap);
        self
    }

    /// Resolve the compile worker count for a `p`-rank plan: the pinned
    /// value when set, else serial below [`Engine::COMPILE_PAR_MIN_P`]
    /// and up to 16 host threads beyond it.
    pub fn compile_threads_for(&self, p: usize) -> usize {
        match self.compile_threads {
            Some(n) => n.max(1),
            None => {
                if p < Self::COMPILE_PAR_MIN_P {
                    1
                } else {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(1)
                        .min(16)
                }
            }
        }
    }

    /// Attach a fault specification, compiled against this engine's
    /// topology. The empty spec compiles to no model at all, so healthy
    /// engines stay provably zero-perturbation. The plan cache is
    /// untouched — faults perturb execution, not compiled schedules.
    pub fn with_faults(mut self, spec: &super::faults::FaultSpec) -> Engine {
        self.faults = if spec.is_empty() {
            None
        } else {
            Some(Arc::new(super::faults::FaultModel::compile(
                spec,
                self.topo.q(),
            )))
        };
        self
    }

    /// Run `f` on every rank concurrently; returns per-rank results sorted
    /// by rank plus the simulated makespan. Panics in rank code propagate.
    pub fn run<R, F>(&self, f: F) -> EngineResult<R>
    where
        R: Send,
        F: Fn(&mut RankCtx) -> R + Send + Sync,
    {
        let p = self.topo.p();
        let mailboxes: Vec<Mailbox> = (0..p).map(|_| Mailbox::new()).collect();
        let mut results: Vec<Option<RankResult<R>>> = (0..p).map(|_| None).collect();

        let tuning = self.tuning.as_deref();
        let faults = self.faults.as_deref();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            for rank in 0..p {
                let f = &f;
                let mailboxes = &mailboxes;
                let profile = &self.profile;
                let topo = self.topo;
                let h = std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .stack_size(self.stack_size)
                    .spawn_scoped(scope, move || {
                        // Each rank owns an OS thread, so the host-copy
                        // counter (rope materialization / sink reads) is
                        // tracked thread-locally and harvested below.
                        super::buffer::reset_host_copied();
                        let mut ctx = RankCtx {
                            rank,
                            topo,
                            profile,
                            mailboxes,
                            tuning,
                            clock: Clock::with_faults(faults.map(|m| m.lens(rank))),
                            phases: PhaseBreakdown::default(),
                            mark: 0.0,
                        };
                        let value = f(&mut ctx);
                        let mut counters = ctx.clock.counters;
                        counters.copied_bytes = super::buffer::host_copied();
                        RankResult {
                            rank,
                            value,
                            finish: ctx.clock.now,
                            phases: ctx.phases,
                            counters,
                        }
                    })
                    .expect("failed to spawn rank thread");
                handles.push(h);
            }
            for (rank, h) in handles.into_iter().enumerate() {
                results[rank] = Some(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
        });

        for (rank, mb) in mailboxes.iter().enumerate() {
            assert!(
                mb.is_empty(),
                "rank {rank} mailbox not drained — algorithm left unreceived messages"
            );
        }

        let ranks: Vec<RankResult<R>> = results.into_iter().map(|r| r.unwrap()).collect();
        let makespan = ranks.iter().fold(0.0f64, |m, r| m.max(r.finish));
        EngineResult { ranks, makespan }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::buffer::DataBuf;

    fn engine(p: usize, q: usize) -> Engine {
        Engine::new(MachineProfile::test_flat(), Topology::new(p, q))
    }

    #[test]
    fn ring_exchange_delivers_payloads() {
        let e = engine(4, 2);
        let res = e.run(|ctx| {
            let p = ctx.size();
            let me = ctx.rank();
            let dst = (me + 1) % p;
            let src = (me + p - 1) % p;
            let payload = Payload::Raw(DataBuf::pattern(me, dst, 64));
            let got = ctx.sendrecv(dst, 7, payload, src, 7).into_raw();
            got.check_pattern(src, me).is_ok()
        });
        assert!(res.ranks.iter().all(|r| r.value));
        assert!(res.makespan > 0.0);
    }

    #[test]
    fn virtual_time_deterministic_across_runs() {
        // Same program, two runs: identical virtual makespans and per-rank
        // finish times even though OS scheduling differs.
        let run = || {
            let e = engine(8, 4);
            let res = e.run(|ctx| {
                let p = ctx.size();
                let me = ctx.rank();
                for i in 1..p {
                    let dst = (me + i) % p;
                    let src = (me + p - i) % p;
                    let _ = ctx.sendrecv(
                        dst,
                        i as u32,
                        Payload::Raw(DataBuf::Phantom(1024)),
                        src,
                        i as u32,
                    );
                }
                ctx.now()
            });
            (res.makespan, res.ranks.iter().map(|r| r.finish).collect::<Vec<_>>())
        };
        let (m1, f1) = run();
        let (m2, f2) = run();
        assert_eq!(m1, m2);
        assert_eq!(f1, f2);
    }

    #[test]
    fn waitall_returns_request_order() {
        let e = engine(3, 1);
        let res = e.run(|ctx| {
            let me = ctx.rank();
            if me == 0 {
                // Receive from 2 then 1 in request order regardless of
                // which message arrives first.
                let r2 = ctx.irecv(2, 5);
                let r1 = ctx.irecv(1, 5);
                let got = ctx.waitall(&[], &[r2, r1]);
                let a = got[0].clone().into_scalar();
                let b = got[1].clone().into_scalar();
                (a, b)
            } else {
                ctx.send(0, 5, Payload::Scalar(me as u64 * 100));
                (0, 0)
            }
        });
        assert_eq!(res.ranks[0].value, (200, 100));
    }

    #[test]
    fn fifo_per_channel_preserved() {
        let e = engine(2, 1);
        let res = e.run(|ctx| {
            if ctx.rank() == 0 {
                for i in 0..10u64 {
                    ctx.send(1, 3, Payload::Scalar(i));
                }
                Vec::new()
            } else {
                (0..10)
                    .map(|_| ctx.recv(0, 3).into_scalar())
                    .collect::<Vec<u64>>()
            }
        });
        assert_eq!(res.ranks[1].value, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn allreduce_max_and_sum_all_sizes() {
        for p in [1usize, 2, 3, 4, 5, 7, 8, 12, 16] {
            let e = engine(p, 1);
            let res = e.run(|ctx| {
                let v = (ctx.rank() as u64) * 10 + 1;
                (ctx.allreduce_max(v), ctx.allreduce_sum(ctx.rank() as u64))
            });
            let expect_max = (p as u64 - 1) * 10 + 1;
            let expect_sum: u64 = (0..p as u64).sum();
            for r in &res.ranks {
                assert_eq!(r.value.0, expect_max, "max at P={p}");
                assert_eq!(r.value.1, expect_sum, "sum at P={p}");
            }
        }
    }

    #[test]
    fn barrier_completes() {
        let e = engine(6, 3);
        let res = e.run(|ctx| {
            ctx.barrier();
            true
        });
        assert!(res.ranks.iter().all(|r| r.value));
    }

    #[test]
    fn phase_accounting_tracks_time() {
        let e = engine(2, 1);
        let res = e.run(|ctx| {
            ctx.phase_mark();
            ctx.compute(1e-3);
            ctx.phase_lap(Phase::Compute);
            ctx.compute(2e-3);
            ctx.phase_lap(Phase::Other);
            (ctx.phases().get(Phase::Compute), ctx.phases().get(Phase::Other))
        });
        for r in &res.ranks {
            assert!((r.value.0 - 1e-3).abs() < 1e-12);
            assert!((r.value.1 - 2e-3).abs() < 1e-12);
        }
    }

    #[test]
    fn counters_track_links() {
        let e = engine(4, 2); // nodes {0,1}, {2,3}
        let res = e.run(|ctx| {
            let me = ctx.rank();
            // Everyone sends 100 B to the next rank; 0->1 and 2->3 are
            // local, 1->2 and 3->0 are global.
            let dst = (me + 1) % 4;
            let src = (me + 3) % 4;
            let _ = ctx.sendrecv(dst, 1, Payload::Raw(DataBuf::Phantom(100)), src, 1);
        });
        let c = res.total_counters();
        assert_eq!(c.msgs_local, 2);
        assert_eq!(c.msgs_global, 2);
        assert_eq!(c.bytes_local, 200);
        assert_eq!(c.bytes_global, 200);
    }

    #[test]
    fn host_copied_bytes_harvested_per_rank() {
        // Each rank writes a 64 B pattern once (source) and verifies the
        // received pattern once (sink): forwarding in between moves views
        // only, so the harvested copied_bytes is exactly 128 per rank.
        let e = engine(4, 2);
        let res = e.run(|ctx| {
            let p = ctx.size();
            let me = ctx.rank();
            let dst = (me + 1) % p;
            let src = (me + p - 1) % p;
            let payload = Payload::Raw(DataBuf::pattern(me, dst, 64));
            let got = ctx.sendrecv(dst, 7, payload, src, 7).into_raw();
            got.check_pattern(src, me).unwrap();
        });
        for r in &res.ranks {
            assert_eq!(r.counters.copied_bytes, 128, "rank {}", r.rank);
        }
        assert_eq!(res.total_counters().copied_bytes, 4 * 128);
    }

    #[test]
    #[should_panic(expected = "not drained")]
    fn undrained_mailbox_detected() {
        let e = engine(2, 1);
        e.run(|ctx| {
            if ctx.rank() == 0 {
                ctx.send(1, 9, Payload::Scalar(1));
            }
            // rank 1 never receives.
        });
    }

    #[test]
    fn self_send_works() {
        let e = engine(2, 1);
        let res = e.run(|ctx| {
            let me = ctx.rank();
            let s = ctx.isend(me, 4, Payload::Scalar(me as u64 + 7));
            let r = ctx.irecv(me, 4);
            let got = ctx.waitall(&[s], &[r]);
            got[0].clone().into_scalar()
        });
        assert_eq!(res.ranks[0].value, 7);
        assert_eq!(res.ranks[1].value, 8);
    }

    #[test]
    fn prev_pow2_values() {
        assert_eq!(prev_pow2(1), 1);
        assert_eq!(prev_pow2(2), 2);
        assert_eq!(prev_pow2(3), 2);
        assert_eq!(prev_pow2(8), 8);
        assert_eq!(prev_pow2(9), 8);
        assert_eq!(prev_pow2(1023), 512);
    }
}
