//! Deterministic fault injection.
//!
//! A [`FaultSpec`] describes machine degradations — stragglers, degraded
//! links, serialization jitter, node outages — parsed from the CLI
//! (`faults=<clause>/<clause>/...`) and round-tripping through
//! [`FaultSpec::spec`] exactly like `AlgoKind`. Compiling a spec against
//! a topology yields a [`FaultModel`]; each rank's clock holds a
//! [`FaultLens`] (the per-rank projection) and consults it inside
//! `Clock::post_send_to` / `drain_*` / `charge_compute`.
//!
//! # Determinism contract
//!
//! Every perturbation is a **pure function of (spec, rank, peer,
//! event index)** — never wall-clock time, never an RNG whose state is
//! shared across ranks or threads:
//!
//! * **seed-keyed** — jitter draws come from a stateless splitmix64
//!   hash of `(seed, rank, peer, direction, event index)` pushed through
//!   Box-Muller; re-running the same spec reproduces every draw.
//! * **event-indexed** — each clock counts its own tx and rx events in
//!   program order. Both executors replay the same per-rank program
//!   order and the same deterministic drain order `(arrive, src, tag)`,
//!   so the event indices — and therefore every perturbation — agree.
//! * **executor-independent** — the threaded engine and the sharded
//!   plan/replay executor apply identical multiplier sequences, so
//!   makespans stay bit-identical under any fault spec and any shard
//!   count (`tests/replay_equivalence.rs`, faulted grid). Faults scale
//!   *times*, never counts or matching, so the message-matching
//!   argument in `comm/replay.rs` is unaffected; compiled plans are
//!   fault-independent and the plan cache needs no fault key.
//!
//! The empty spec is **provably zero-perturbation**: a clock without a
//! lens multiplies nothing (the `None` arm uses the constant `1.0`, and
//! IEEE-754 multiplication by `1.0` returns the operand unchanged), so
//! healthy makespans are bit-identical to a build without this module —
//! asserted against the golden snapshots.
//!
//! # Clause semantics
//!
//! * `straggler:rank=R,slow=X` — rank `R`'s CPU-side costs (send/recv
//!   overheads, local copies, compute) are multiplied by `X`.
//! * `link:node=A-B,bw=F,lat=F2` — traffic between the unordered node
//!   pair `{A, B}` sees its bandwidth scaled by `F` (serialization time
//!   x 1/F, charged at both NICs) and its wire latency scaled by `F2`.
//!   `node=A-A` degrades node A's intra-node fabric.
//! * `jitter:sigma=S,seed=N` — every serialization is multiplied by a
//!   lognormal factor `exp(S * z)`, `z` a hashed standard normal.
//! * `outage:node=N,from=T,until=T2` — node `N`'s ports are down during
//!   `[T, T2)` (virtual seconds): any serialization that would start in
//!   the window is deferred to `T2`.

use crate::error::{Result, TunaError};

/// Sentinel peer for call sites with no counterpart rank (the analytic
/// estimator's probe clocks). Link and jitter perturbations are skipped;
/// the rank-local CPU multiplier still applies.
pub const NO_PEER: usize = usize::MAX;

/// One parsed fault clause. See the module header for semantics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultClause {
    Straggler { rank: usize, slow: f64 },
    Link { a: usize, b: usize, bw: f64, lat: f64 },
    Jitter { sigma: f64, seed: u64 },
    Outage { node: usize, from: f64, until: f64 },
}

/// A parsed, validated fault specification. Empty means healthy.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub clauses: Vec<FaultClause>,
}

fn bad(msg: impl std::fmt::Display) -> TunaError {
    TunaError::config(format!("faults: {msg}"))
}

fn parse_usize(clause: &str, key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>()
        .map_err(|_| bad(format!("{clause}: {key}={v} is not a non-negative integer")))
}

fn parse_u64(clause: &str, key: &str, v: &str) -> Result<u64> {
    v.parse::<u64>()
        .map_err(|_| bad(format!("{clause}: {key}={v} is not a non-negative integer")))
}

fn parse_f64(clause: &str, key: &str, v: &str) -> Result<f64> {
    let x: f64 = v
        .parse()
        .map_err(|_| bad(format!("{clause}: {key}={v} is not a number")))?;
    if !x.is_finite() {
        return Err(bad(format!("{clause}: {key}={v} must be finite")));
    }
    Ok(x)
}

fn parse_pos(clause: &str, key: &str, v: &str) -> Result<f64> {
    let x = parse_f64(clause, key, v)?;
    if x <= 0.0 {
        return Err(bad(format!("{clause}: {key}={v} must be > 0")));
    }
    Ok(x)
}

impl FaultSpec {
    /// Parse a CLI spec: clauses separated by `/`, fields by `,`, the
    /// clause kind before `:`. The empty string is the healthy spec.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut clauses = Vec::new();
        for part in s.split('/') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, fields) = part
                .split_once(':')
                .ok_or_else(|| bad(format!("clause `{part}` needs `<kind>:<k>=<v>,...`")))?;
            let mut kv = Vec::new();
            for field in fields.split(',') {
                let (k, v) = field
                    .split_once('=')
                    .ok_or_else(|| bad(format!("{kind}: field `{field}` needs `<k>=<v>`")))?;
                kv.push((k.trim(), v.trim()));
            }
            let get = |key: &str| kv.iter().find(|(k, _)| *k == key).map(|&(_, v)| v);
            let known = |keys: &[&str]| -> Result<()> {
                for (k, _) in &kv {
                    if !keys.contains(k) {
                        return Err(bad(format!("{kind}: unknown field `{k}`")));
                    }
                }
                Ok(())
            };
            let clause = match kind {
                "straggler" => {
                    known(&["rank", "slow"])?;
                    let rank = get("rank").ok_or_else(|| bad("straggler: needs rank="))?;
                    let slow = get("slow").ok_or_else(|| bad("straggler: needs slow="))?;
                    FaultClause::Straggler {
                        rank: parse_usize(kind, "rank", rank)?,
                        slow: parse_pos(kind, "slow", slow)?,
                    }
                }
                "link" => {
                    known(&["node", "bw", "lat"])?;
                    let pair = get("node").ok_or_else(|| bad("link: needs node=A-B"))?;
                    let (a, b) = pair
                        .split_once('-')
                        .ok_or_else(|| bad(format!("link: node={pair} needs `A-B`")))?;
                    let a = parse_usize(kind, "node", a)?;
                    let b = parse_usize(kind, "node", b)?;
                    FaultClause::Link {
                        a: a.min(b),
                        b: a.max(b),
                        bw: match get("bw") {
                            Some(v) => parse_pos(kind, "bw", v)?,
                            None => 1.0,
                        },
                        lat: match get("lat") {
                            Some(v) => parse_pos(kind, "lat", v)?,
                            None => 1.0,
                        },
                    }
                }
                "jitter" => {
                    known(&["sigma", "seed"])?;
                    let sigma = get("sigma").ok_or_else(|| bad("jitter: needs sigma="))?;
                    let sigma = parse_f64(kind, "sigma", sigma)?;
                    if sigma < 0.0 {
                        return Err(bad("jitter: sigma must be >= 0"));
                    }
                    FaultClause::Jitter {
                        sigma,
                        seed: match get("seed") {
                            Some(v) => parse_u64(kind, "seed", v)?,
                            None => 0,
                        },
                    }
                }
                "outage" => {
                    known(&["node", "from", "until"])?;
                    let node = get("node").ok_or_else(|| bad("outage: needs node="))?;
                    let until = get("until").ok_or_else(|| bad("outage: needs until="))?;
                    let from = match get("from") {
                        Some(v) => parse_f64(kind, "from", v)?,
                        None => 0.0,
                    };
                    let until = parse_f64(kind, "until", until)?;
                    if from < 0.0 {
                        return Err(bad("outage: from must be >= 0"));
                    }
                    if until < from {
                        return Err(bad(format!(
                            "outage: until ({until}) must be >= from ({from})"
                        )));
                    }
                    FaultClause::Outage {
                        node: parse_usize(kind, "node", node)?,
                        from,
                        until,
                    }
                }
                other => {
                    return Err(bad(format!(
                        "unknown clause `{other}` (expected straggler | link | jitter | outage)"
                    )))
                }
            };
            clauses.push(clause);
        }
        Ok(FaultSpec { clauses })
    }

    /// The canonical spec string; `parse(spec())` reproduces the value
    /// exactly (floats print in shortest round-trip form).
    pub fn spec(&self) -> String {
        let parts: Vec<String> = self
            .clauses
            .iter()
            .map(|c| match *c {
                FaultClause::Straggler { rank, slow } => {
                    format!("straggler:rank={rank},slow={slow}")
                }
                FaultClause::Link { a, b, bw, lat } => {
                    format!("link:node={a}-{b},bw={bw},lat={lat}")
                }
                FaultClause::Jitter { sigma, seed } => format!("jitter:sigma={sigma},seed={seed}"),
                FaultClause::Outage { node, from, until } => {
                    format!("outage:node={node},from={from},until={until}")
                }
            })
            .collect();
        parts.join("/")
    }

    /// True for the healthy (zero-perturbation) spec.
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Range-check clause targets against a concrete (P, Q) topology.
    pub fn check(&self, p: usize, q: usize) -> Result<()> {
        let nodes = if q >= 1 { p / q } else { 0 };
        for c in &self.clauses {
            match *c {
                FaultClause::Straggler { rank, .. } if rank >= p => {
                    return Err(bad(format!("straggler: rank={rank} out of range (P={p})")));
                }
                FaultClause::Link { a, b, .. } if a >= nodes || b >= nodes => {
                    return Err(bad(format!(
                        "link: node={a}-{b} out of range ({nodes} nodes)"
                    )));
                }
                FaultClause::Outage { node, .. } if node >= nodes => {
                    return Err(bad(format!(
                        "outage: node={node} out of range ({nodes} nodes)"
                    )));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// A spec compiled against a topology's ranks-per-node. Shared by every
/// rank of an engine; hands out per-rank [`FaultLens`] projections.
#[derive(Clone, Debug)]
pub struct FaultModel {
    spec: FaultSpec,
    q: usize,
}

impl FaultModel {
    pub fn compile(spec: &FaultSpec, q: usize) -> FaultModel {
        debug_assert!(q >= 1);
        FaultModel { spec: spec.clone(), q }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    pub fn is_empty(&self) -> bool {
        self.spec.is_empty()
    }

    /// The per-rank projection consulted by that rank's clock.
    pub fn lens(&self, rank: usize) -> FaultLens {
        let node = rank / self.q;
        let mut cpu = 1.0;
        let mut jitters = Vec::new();
        let mut links = Vec::new();
        let mut outages = Vec::new();
        for c in &self.spec.clauses {
            match *c {
                FaultClause::Straggler { rank: r, slow } => {
                    if r == rank {
                        cpu *= slow;
                    }
                }
                FaultClause::Link { a, b, bw, lat } => {
                    if a == node || b == node {
                        links.push((a, b, bw, lat));
                    }
                }
                FaultClause::Jitter { sigma, seed } => jitters.push((sigma, seed)),
                FaultClause::Outage { node: n, from, until } => {
                    if n == node && until > from {
                        outages.push((from, until));
                    }
                }
            }
        }
        outages.sort_by(|x, y| x.0.total_cmp(&y.0));
        FaultLens { rank, node, q: self.q, cpu, jitters, links, outages }
    }

    /// Coarse degradation summary for the analytic estimator's degraded
    /// arm: a worst-case multiplicative slowdown plus an additive stall
    /// (total outage duration). Deliberately pessimistic — the model's
    /// job under faults is ranking, not absolute accuracy.
    pub fn analytic_slowdown(&self) -> (f64, f64) {
        let mut mult = 1.0_f64;
        let mut add = 0.0_f64;
        for c in &self.spec.clauses {
            match *c {
                FaultClause::Straggler { slow, .. } => mult = mult.max(slow),
                FaultClause::Link { bw, lat, .. } => mult = mult.max((1.0 / bw).max(lat)),
                // Mean of the lognormal factor exp(sigma * z).
                FaultClause::Jitter { sigma, .. } => mult = mult.max((sigma * sigma / 2.0).exp()),
                FaultClause::Outage { from, until, .. } => add += until - from,
            }
        }
        (mult, add)
    }
}

/// One rank's view of a [`FaultModel`]: everything its clock needs,
/// precomputed. Cheap to clone into each rank thread / replay shard.
#[derive(Clone, Debug)]
pub struct FaultLens {
    rank: usize,
    node: usize,
    q: usize,
    /// Straggler multiplier on this rank's CPU-side costs.
    cpu: f64,
    /// All jitter clauses (global: every rank draws, keyed by itself).
    jitters: Vec<(f64, u64)>,
    /// Link clauses touching this rank's node.
    links: Vec<(usize, usize, f64, f64)>,
    /// Outage windows for this rank's node, sorted by start.
    outages: Vec<(f64, f64)>,
}

impl FaultLens {
    /// Multiplier on CPU-side costs (overheads, copies, compute).
    #[inline]
    pub fn cpu(&self) -> f64 {
        self.cpu
    }

    /// (serialization multiplier, latency multiplier) for link clauses
    /// on the unordered node pair {this rank's node, peer's node}.
    fn link_mults(&self, peer: usize) -> (f64, f64) {
        if peer == NO_PEER || self.links.is_empty() {
            return (1.0, 1.0);
        }
        let pn = peer / self.q;
        let (lo, hi) = if self.node <= pn { (self.node, pn) } else { (pn, self.node) };
        let mut ser = 1.0;
        let mut lat = 1.0;
        for &(a, b, bw, l) in &self.links {
            if a == lo && b == hi {
                ser *= 1.0 / bw;
                lat *= l;
            }
        }
        (ser, lat)
    }

    fn jitter_mult(&self, peer: usize, dir: u64, idx: u64) -> f64 {
        if peer == NO_PEER || self.jitters.is_empty() {
            return 1.0;
        }
        let mut m = 1.0;
        for &(sigma, seed) in &self.jitters {
            let h = hash5(seed, self.rank as u64, peer as u64, dir, idx);
            m *= (sigma * gauss(h)).exp();
        }
        m
    }

    /// Perturbations for the `idx`-th send to `peer`:
    /// (serialization multiplier, wire-latency multiplier).
    pub fn tx(&self, peer: usize, idx: u64) -> (f64, f64) {
        let (ser, lat) = self.link_mults(peer);
        (ser * self.jitter_mult(peer, 0, idx), lat)
    }

    /// Serialization multiplier for the `idx`-th drained receive from
    /// `peer`.
    pub fn rx(&self, peer: usize, idx: u64) -> f64 {
        let (ser, _) = self.link_mults(peer);
        ser * self.jitter_mult(peer, 1, idx)
    }

    /// Defer a port start time out of any outage window it lands in.
    #[inline]
    pub fn defer(&self, start: f64) -> f64 {
        let mut s = start;
        for &(from, until) in &self.outages {
            if s >= from && s < until {
                s = until;
            }
        }
        s
    }
}

/// splitmix64 finalizer — the stateless mixing primitive.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

fn hash5(seed: u64, a: u64, b: u64, c: u64, d: u64) -> u64 {
    let mut h = mix64(seed ^ 0x9E37_79B9_7F4A_7C15);
    h = mix64(h ^ a);
    h = mix64(h ^ b);
    h = mix64(h ^ c);
    h = mix64(h ^ d);
    h
}

/// A standard normal from one hash word via Box-Muller. Pure f64
/// arithmetic on deterministic inputs — identical across executors.
fn gauss(h: u64) -> f64 {
    const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
    let u1 = ((h >> 11) as f64) * SCALE; // in [0, 1)
    let u2 = ((mix64(h ^ 0xD1B5_4A32_D192_ED03) >> 11) as f64) * SCALE;
    // 1 - u1 is in (0, 1], so the log is finite.
    let r = (-2.0 * (1.0 - u1).ln()).sqrt();
    r * (std::f64::consts::TAU * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spec_round_trips_every_clause() {
        let specs = [
            "straggler:rank=7,slow=8",
            "link:node=0-3,bw=0.25,lat=4",
            "jitter:sigma=0.2,seed=42",
            "outage:node=1,from=0.001,until=0.002",
            "straggler:rank=0,slow=2.5/link:node=1-2,bw=0.5,lat=1/jitter:sigma=0.1,seed=9/outage:node=0,from=0,until=0.5",
        ];
        for s in specs {
            let parsed = FaultSpec::parse(s).unwrap();
            let rendered = parsed.spec();
            let reparsed = FaultSpec::parse(&rendered).unwrap();
            assert_eq!(parsed, reparsed, "round trip failed for `{s}` -> `{rendered}`");
        }
    }

    #[test]
    fn parse_normalizes_and_defaults() {
        // Node pairs are stored unordered (low-high).
        let a = FaultSpec::parse("link:node=5-2,bw=0.5").unwrap();
        let b = FaultSpec::parse("link:node=2-5,bw=0.5,lat=1").unwrap();
        assert_eq!(a, b);
        // outage from defaults to 0, jitter seed to 0.
        let o = FaultSpec::parse("outage:node=0,until=1").unwrap();
        assert_eq!(o.clauses, vec![FaultClause::Outage { node: 0, from: 0.0, until: 1.0 }]);
        let j = FaultSpec::parse("jitter:sigma=0.1").unwrap();
        assert_eq!(j.clauses, vec![FaultClause::Jitter { sigma: 0.1, seed: 0 }]);
        // Empty string is the healthy spec.
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert_eq!(FaultSpec::default().spec(), "");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for s in [
            "meteor:rank=1",                 // unknown clause
            "straggler:rank=1",              // missing slow
            "straggler:rank=1,slow=0",       // non-positive multiplier
            "straggler:rank=1,slow=-2",      // negative multiplier
            "straggler:rank=1,slow=inf",     // non-finite
            "straggler:rank=1,slow=nan",     // non-finite
            "straggler:rank=x,slow=2",       // bad integer
            "straggler:rank=1,slow=2,hat=3", // unknown field
            "link:node=3,bw=0.5",            // pair needs A-B
            "link:node=0-1,bw=0",            // non-positive bandwidth
            "jitter:sigma=-0.1",             // negative sigma
            "outage:node=0,from=2,until=1",  // until < from
            "outage:node=0,from=-1,until=1", // negative window
            "slowpoke",                      // no kind separator
            "straggler:rank",                // field without value
        ] {
            let e = FaultSpec::parse(s);
            assert!(e.is_err(), "`{s}` should be rejected");
            let msg = e.unwrap_err().to_string();
            assert!(msg.contains("configuration error"), "{msg}");
            assert!(msg.contains("faults:"), "{msg}");
        }
    }

    #[test]
    fn check_ranges_against_topology() {
        let s = FaultSpec::parse("straggler:rank=7,slow=2").unwrap();
        assert!(s.check(8, 2).is_ok());
        assert!(s.check(4, 2).is_err());
        let l = FaultSpec::parse("link:node=0-3,bw=0.5").unwrap();
        assert!(l.check(8, 2).is_ok()); // 4 nodes
        assert!(l.check(8, 4).is_err()); // 2 nodes
        let o = FaultSpec::parse("outage:node=2,until=1").unwrap();
        assert!(o.check(12, 4).is_ok());
        assert!(o.check(8, 4).is_err());
    }

    #[test]
    fn lens_projects_per_rank() {
        let spec = FaultSpec::parse(
            "straggler:rank=3,slow=4/link:node=0-1,bw=0.5,lat=2/outage:node=1,from=1,until=2",
        )
        .unwrap();
        let model = FaultModel::compile(&spec, 2);
        // Rank 3 lives on node 1: straggler applies, link 0-1 touches it,
        // and the outage window defers starts inside [1, 2).
        let lens = model.lens(3);
        assert_eq!(lens.cpu(), 4.0);
        let (ser, lat) = lens.tx(0, 0); // peer rank 0 is on node 0
        assert_eq!(ser, 2.0); // 1 / bw
        assert_eq!(lat, 2.0);
        let (ser, lat) = lens.tx(2, 0); // node 1 -> node 1: no link clause
        assert_eq!((ser, lat), (1.0, 1.0));
        assert_eq!(lens.defer(1.5), 2.0);
        assert_eq!(lens.defer(0.5), 0.5);
        assert_eq!(lens.defer(2.0), 2.0);
        // Rank 0 on node 0: healthy CPU, same link clause, no outage.
        let lens0 = model.lens(0);
        assert_eq!(lens0.cpu(), 1.0);
        assert_eq!(lens0.rx(3, 7), 2.0);
        assert_eq!(lens0.defer(1.5), 1.5);
        // A rank on an untouched node sees nothing.
        let spec2 = FaultSpec::parse("link:node=0-1,bw=0.5").unwrap();
        let lens4 = FaultModel::compile(&spec2, 2).lens(4); // node 2
        assert_eq!(lens4.tx(0, 0), (1.0, 1.0));
    }

    #[test]
    fn jitter_is_a_pure_function_of_its_key() {
        let spec = FaultSpec::parse("jitter:sigma=0.3,seed=11").unwrap();
        let model = FaultModel::compile(&spec, 4);
        let lens = model.lens(5);
        let (a, _) = lens.tx(9, 0);
        let (b, _) = lens.tx(9, 0);
        assert_eq!(a.to_bits(), b.to_bits(), "same key must give same draw");
        let (c, _) = lens.tx(9, 1);
        assert_ne!(a.to_bits(), c.to_bits(), "event index must vary the draw");
        let (d, _) = lens.tx(10, 0);
        assert_ne!(a.to_bits(), d.to_bits(), "peer must vary the draw");
        // tx and rx draws are decorrelated (direction is keyed).
        assert_ne!(a.to_bits(), lens.rx(9, 0).to_bits());
        // A different seed re-keys everything.
        let spec2 = FaultSpec::parse("jitter:sigma=0.3,seed=12").unwrap();
        let (e, _) = FaultModel::compile(&spec2, 4).lens(5).tx(9, 0);
        assert_ne!(a.to_bits(), e.to_bits());
        // Multipliers are positive and finite.
        for idx in 0..256 {
            let (m, _) = lens.tx(1, idx);
            assert!(m.is_finite() && m > 0.0, "bad jitter multiplier {m}");
        }
    }

    #[test]
    fn no_peer_sentinel_skips_link_and_jitter() {
        let spec =
            FaultSpec::parse("straggler:rank=0,slow=3/link:node=0-1,bw=0.5/jitter:sigma=0.5")
                .unwrap();
        let lens = FaultModel::compile(&spec, 1).lens(0);
        assert_eq!(lens.tx(NO_PEER, 0), (1.0, 1.0));
        assert_eq!(lens.rx(NO_PEER, 0), 1.0);
        assert_eq!(lens.cpu(), 3.0);
    }

    #[test]
    fn analytic_slowdown_is_coarse_but_ordered() {
        let healthy = FaultModel::compile(&FaultSpec::default(), 2);
        assert_eq!(healthy.analytic_slowdown(), (1.0, 0.0));
        let spec = FaultSpec::parse(
            "straggler:rank=0,slow=8/link:node=0-1,bw=0.25,lat=2/outage:node=0,from=0.5,until=0.75",
        )
        .unwrap();
        let (mult, add) = FaultModel::compile(&spec, 2).analytic_slowdown();
        assert_eq!(mult, 8.0); // straggler dominates 1/bw = 4 and lat = 2
        assert!((add - 0.25).abs() < 1e-12);
        // Chained outage windows defer across both.
        let spec = FaultSpec::parse("outage:node=0,from=1,until=2/outage:node=0,from=2,until=3")
            .unwrap();
        let lens = FaultModel::compile(&spec, 1).lens(0);
        assert_eq!(lens.defer(1.5), 3.0);
    }
}
