//! Data buffers, payload ropes and routed blocks.
//!
//! One implementation of every algorithm serves both correctness testing
//! and large-scale simulation: payloads are [`DataBuf`]s that either carry
//! real bytes (`Real`, validated against the gold all-to-all result) or
//! just a length (`Phantom`, so a P = 16,384 simulation fits in memory).
//! A run must be homogeneous — mixing modes in one message is a bug.
//!
//! # Payload ownership: ropes of shared views (PR 2)
//!
//! Real payloads are **ropes**: a [`Rope`] is an ordered list of
//! [`ByteView`] segments, each an `(Arc<buffer>, offset, len)` window into
//! immutable shared storage. The contract every layer relies on:
//!
//! * **Write once.** Bytes are materialized exactly once, at the source —
//!   [`Rope::from_vec`] adopts a freshly written buffer without copying it
//!   (the `Arc` wraps the `Vec` itself). A whole send row is typically one
//!   arena adopted once and handed out as per-destination views
//!   ([`DataBuf::pattern_row`]).
//! * **Move by view.** Slicing ([`Rope::slice`]) and store-and-forward
//!   hops (engine enqueue/dequeue, TuNA slot replacement, hierarchical
//!   slot batches) are O(segments) metadata operations that bump `Arc`
//!   refcounts — never payload memcpys. The shipped algorithms keep
//!   blocks whole and move them by value; payload-level merging
//!   ([`Rope::append`], [`DataBuf::concat`]) follows the same
//!   no-byte-movement rule for consumers that need it and is covered by
//!   this module's tests.
//! * **Read once.** Bytes leave rope storage at the sink: pattern
//!   verification ([`DataBuf::check_pattern`]) reads them in place;
//!   [`DataBuf::to_contiguous`] borrows single-segment ropes and copies
//!   only when a rope is genuinely fragmented.
//!
//! Three operations — and only those three — are charged to a
//! thread-local host-copy counter that the engine harvests into
//! [`Counters::copied_bytes`](super::clock::Counters) per rank: arena
//! writes ([`Rope::from_vec`]), pattern-verification reads
//! ([`DataBuf::check_pattern`]), and forced compaction
//! ([`Rope::to_contiguous`] on a fragmented rope). In-place borrows
//! (`bytes()`, the contiguous `to_contiguous` path) move nothing and
//! charge nothing. For a real-mode all-to-allv, whose sinks verify every
//! block, that yields the end-to-end invariant `copied_bytes == bytes
//! written at sources + bytes read at sinks`, with no per-round
//! amplification (`tests/zero_copy.rs`).
//!
//! Note this is *host* accounting, distinct from the virtual-time copy
//! charges (`RankCtx::copy` → `Counters::bytes_copied`) that model what a
//! real MPI implementation's packing would cost on the simulated machine.

use std::borrow::Cow;
use std::cell::Cell;
use std::sync::Arc;

thread_local! {
    /// Host-side payload bytes physically moved on this thread (each rank
    /// of the engine runs on its own OS thread, so this is per-rank).
    static HOST_COPIED: Cell<u64> = Cell::new(0);
}

#[inline]
fn note_host_copy(bytes: u64) {
    HOST_COPIED.with(|c| c.set(c.get() + bytes));
}

/// Reset this thread's host-copy counter (engine calls this when a rank
/// thread starts).
pub(crate) fn reset_host_copied() {
    HOST_COPIED.with(|c| c.set(0));
}

/// Read this thread's host-copy counter (engine harvests it into the
/// rank's `Counters` when the rank finishes).
pub(crate) fn host_copied() -> u64 {
    HOST_COPIED.with(|c| c.get())
}

/// An immutable window into shared byte storage: `(Arc<buffer>, offset,
/// len)`. Cloning bumps a refcount; the underlying bytes are never moved.
///
/// The buffer is an `Arc<Vec<u8>>` rather than an `Arc<[u8]>` on purpose:
/// `Arc<[u8]>::from(Vec<u8>)` must reallocate and memcpy the bytes into
/// the Arc's own allocation, which would silently reintroduce the copy
/// this type exists to eliminate.
#[derive(Clone, Debug)]
pub struct ByteView {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl ByteView {
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Borrow the viewed bytes in place.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }
}

/// A payload rope: ordered [`ByteView`] segments. Zero-length segments
/// are never stored, so segment iteration yields non-empty slices.
#[derive(Clone, Debug, Default)]
pub struct Rope {
    segs: Vec<ByteView>,
    len: u64,
}

impl Rope {
    pub fn new() -> Rope {
        Rope::default()
    }

    /// Adopt freshly written bytes as a single-segment rope without
    /// copying them. This is the one place payload bytes enter rope
    /// storage, so the write is charged to the host-copy counter here.
    pub fn from_vec(v: Vec<u8>) -> Rope {
        let len = v.len() as u64;
        note_host_copy(len);
        if len == 0 {
            return Rope::default();
        }
        Rope {
            segs: vec![ByteView {
                buf: Arc::new(v),
                off: 0,
                len: len as usize,
            }],
            len,
        }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn segment_count(&self) -> usize {
        self.segs.len()
    }

    /// Iterate the rope's segments as byte slices (all non-empty).
    pub fn segments(&self) -> impl Iterator<Item = &[u8]> + '_ {
        self.segs.iter().map(ByteView::as_slice)
    }

    /// O(segments) zero-copy subrange view `[start, start + len)`.
    pub fn slice(&self, start: u64, len: u64) -> Rope {
        assert!(
            start.checked_add(len).is_some() && start + len <= self.len,
            "slice [{start}, {start}+{len}) out of rope of len {}",
            self.len
        );
        let mut out = Rope::default();
        if len == 0 {
            return out;
        }
        let mut skip = start;
        let mut remaining = len;
        for seg in &self.segs {
            let sl = seg.len as u64;
            if skip >= sl {
                skip -= sl;
                continue;
            }
            let take = (sl - skip).min(remaining);
            out.segs.push(ByteView {
                buf: seg.buf.clone(),
                off: seg.off + skip as usize,
                len: take as usize,
            });
            out.len += take;
            remaining -= take;
            skip = 0;
            if remaining == 0 {
                break;
            }
        }
        debug_assert_eq!(out.len, len);
        out
    }

    /// Append `other`'s segments — O(1) per segment, no byte movement.
    pub fn append(&mut self, other: &Rope) {
        self.segs.extend(other.segs.iter().cloned());
        self.len += other.len;
    }

    /// The rope's bytes as one slice, when it is already contiguous
    /// (zero or one segments). `None` for fragmented ropes.
    pub fn as_contiguous(&self) -> Option<&[u8]> {
        match self.segs.len() {
            0 => Some(&[]),
            1 => Some(self.segs[0].as_slice()),
            _ => None,
        }
    }

    /// Materialize the rope's bytes: borrows in place when contiguous,
    /// copies (charged as a host copy) only when fragmented.
    pub fn to_contiguous(&self) -> Cow<'_, [u8]> {
        if let Some(s) = self.as_contiguous() {
            return Cow::Borrowed(s);
        }
        note_host_copy(self.len);
        let mut v = Vec::with_capacity(self.len as usize);
        for seg in &self.segs {
            v.extend_from_slice(seg.as_slice());
        }
        Cow::Owned(v)
    }
}

/// Logical byte equality, independent of segmentation.
impl PartialEq for Rope {
    fn eq(&self, other: &Rope) -> bool {
        if self.len != other.len {
            return false;
        }
        let mut a = self.segs.iter().map(ByteView::as_slice);
        let mut b = other.segs.iter().map(ByteView::as_slice);
        let mut ca: &[u8] = &[];
        let mut cb: &[u8] = &[];
        loop {
            if ca.is_empty() {
                match a.next() {
                    Some(s) => ca = s,
                    // Equal totals + lockstep consumption: b is spent too.
                    None => return true,
                }
            }
            if cb.is_empty() {
                match b.next() {
                    Some(s) => cb = s,
                    None => return true,
                }
            }
            let n = ca.len().min(cb.len());
            if ca[..n] != cb[..n] {
                return false;
            }
            ca = &ca[n..];
            cb = &cb[n..];
        }
    }
}

impl Eq for Rope {}

/// A payload: a real byte rope or a phantom (size-only) stand-in.
#[derive(Clone, Debug)]
pub enum DataBuf {
    Real(Rope),
    Phantom(u64),
}

/// Logical equality: ropes compare by content, never by segmentation;
/// real and phantom payloads are never equal (mode is part of identity).
impl PartialEq for DataBuf {
    fn eq(&self, other: &DataBuf) -> bool {
        match (self, other) {
            (DataBuf::Real(a), DataBuf::Real(b)) => a == b,
            (DataBuf::Phantom(a), DataBuf::Phantom(b)) => a == b,
            _ => false,
        }
    }
}

impl Eq for DataBuf {}

impl DataBuf {
    #[inline]
    pub fn len(&self) -> u64 {
        match self {
            DataBuf::Real(r) => r.len(),
            DataBuf::Phantom(n) => *n,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn is_real(&self) -> bool {
        matches!(self, DataBuf::Real(_))
    }

    /// Adopt freshly written bytes as a real payload (no copy).
    pub fn from_vec(v: Vec<u8>) -> DataBuf {
        DataBuf::Real(Rope::from_vec(v))
    }

    /// Borrow the real bytes; panics on a phantom buffer (callers that need
    /// bytes are correctness paths which always run in real mode) and on a
    /// fragmented rope (use [`DataBuf::to_contiguous`] when aggregation may
    /// have occurred).
    pub fn bytes(&self) -> &[u8] {
        match self {
            DataBuf::Real(r) => r
                .as_contiguous()
                .expect("bytes() on a fragmented rope — use to_contiguous()"),
            DataBuf::Phantom(_) => panic!("bytes() on a phantom DataBuf"),
        }
    }

    /// Materialize the payload bytes: borrowed in place for contiguous
    /// ropes, copied only when fragmented. Panics on phantom buffers.
    pub fn to_contiguous(&self) -> Cow<'_, [u8]> {
        match self {
            DataBuf::Real(r) => r.to_contiguous(),
            DataBuf::Phantom(_) => panic!("to_contiguous() on a phantom DataBuf"),
        }
    }

    /// The underlying rope of a real payload.
    pub fn rope(&self) -> &Rope {
        match self {
            DataBuf::Real(r) => r,
            DataBuf::Phantom(_) => panic!("rope() on a phantom DataBuf"),
        }
    }

    /// An empty buffer in the given mode.
    pub fn empty(real: bool) -> DataBuf {
        if real {
            DataBuf::Real(Rope::new())
        } else {
            DataBuf::Phantom(0)
        }
    }

    /// O(segments) zero-copy subrange `[start, start + len)`; phantom
    /// buffers slice to phantoms.
    pub fn slice(&self, start: u64, len: u64) -> DataBuf {
        match self {
            DataBuf::Real(r) => DataBuf::Real(r.slice(start, len)),
            DataBuf::Phantom(n) => {
                assert!(
                    start.checked_add(len).map(|end| end <= *n).unwrap_or(false),
                    "slice [{start}, {start}+{len}) out of phantom of len {n}"
                );
                DataBuf::Phantom(len)
            }
        }
    }

    /// Concatenate payloads as a segment concat — no byte movement in
    /// real mode, a length sum in phantom mode. `real` fixes the mode of
    /// the (possibly empty) result; a part of the other mode is a bug per
    /// the module contract.
    pub fn concat<I: IntoIterator<Item = DataBuf>>(real: bool, parts: I) -> DataBuf {
        if real {
            let mut rope = Rope::new();
            for p in parts {
                match p {
                    DataBuf::Real(r) => rope.append(&r),
                    DataBuf::Phantom(_) => panic!("concat: phantom part in a real concat"),
                }
            }
            DataBuf::Real(rope)
        } else {
            let mut n = 0u64;
            for p in parts {
                match p {
                    DataBuf::Phantom(m) => n += m,
                    DataBuf::Real(_) => panic!("concat: real part in a phantom concat"),
                }
            }
            DataBuf::Phantom(n)
        }
    }

    /// Deterministic pattern payload for (origin, dest): byte `i` is drawn
    /// from a hash of `(origin, dest, i / 8)` — generated a word at a time
    /// — so any misrouting or mis-slicing in an algorithm corrupts the
    /// pattern and is caught by [`DataBuf::check_pattern`].
    pub fn pattern(origin: usize, dest: usize, len: u64) -> DataBuf {
        let mut v = Vec::with_capacity(len as usize);
        append_pattern(&mut v, origin, dest, len);
        DataBuf::from_vec(v)
    }

    /// Pattern payloads for a whole dense send row (index =
    /// destination), written once into a shared arena and handed out as
    /// zero-copy per-destination views — one allocation and one
    /// host-copy charge per rank instead of one per destination.
    pub fn pattern_row(origin: usize, sizes: &[u64]) -> Vec<DataBuf> {
        let total: u64 = sizes.iter().sum();
        DataBuf::pattern_views(origin, sizes.iter().copied().enumerate(), sizes.len(), total)
    }

    /// [`DataBuf::pattern_row`] over the *structural* `(dest, len)`
    /// entries of a sparse send row: the arena holds only structural
    /// bytes, absent destinations get no buffer and no rope segment, and
    /// the returned views align with `entries` positionally.
    pub fn pattern_row_entries(origin: usize, entries: &[(usize, u64)]) -> Vec<DataBuf> {
        let total: u64 = entries.iter().map(|&(_, len)| len).sum();
        DataBuf::pattern_views(origin, entries.iter().copied(), entries.len(), total)
    }

    /// Shared arena writer behind the two `pattern_row*` adapters —
    /// streams the `(dest, len)` entries without materializing them.
    fn pattern_views(
        origin: usize,
        entries: impl Iterator<Item = (usize, u64)>,
        count: usize,
        total: u64,
    ) -> Vec<DataBuf> {
        let mut arena = Vec::with_capacity(total as usize);
        let mut bounds = Vec::with_capacity(count);
        for (dest, len) in entries {
            let start = arena.len() as u64;
            append_pattern(&mut arena, origin, dest, len);
            bounds.push((start, len));
        }
        let master = DataBuf::from_vec(arena);
        bounds
            .into_iter()
            .map(|(off, len)| master.slice(off, len))
            .collect()
    }

    /// Verify a pattern payload in place (a sink read, charged to the
    /// host-copy counter); returns the first mismatching index. Compares
    /// a word at a time on aligned stretches.
    pub fn check_pattern(&self, origin: usize, dest: usize) -> Result<(), u64> {
        let rope = match self {
            DataBuf::Real(r) => r,
            DataBuf::Phantom(_) => panic!("check_pattern() on a phantom DataBuf"),
        };
        note_host_copy(rope.len());
        let mut i = 0u64; // logical byte index within the payload
        for seg in rope.segments() {
            let mut k = 0usize;
            while k < seg.len() {
                if i % 8 == 0 && seg.len() - k >= 8 {
                    let expect = pattern_word(origin, dest, i / 8).to_le_bytes();
                    let got = &seg[k..k + 8];
                    if got != &expect[..] {
                        for (j, (&g, &e)) in got.iter().zip(expect.iter()).enumerate() {
                            if g != e {
                                return Err(i + j as u64);
                            }
                        }
                    }
                    i += 8;
                    k += 8;
                } else {
                    if seg[k] != pattern_byte(origin, dest, i) {
                        return Err(i);
                    }
                    i += 1;
                    k += 1;
                }
            }
        }
        Ok(())
    }
}

/// One 64-bit word of the (origin, dest) pattern stream.
#[inline]
fn pattern_word(origin: usize, dest: usize, w: u64) -> u64 {
    let mut h = (origin as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add((dest as u64).wrapping_mul(0xff51_afd7_ed55_8ccd))
        .wrapping_add(w.wrapping_mul(0xc4ce_b9fe_1a85_ec53));
    h ^= h >> 33;
    h = h.wrapping_mul(0xc2b2_ae3d_27d4_eb4f);
    h ^= h >> 29;
    h
}

/// Byte `i` of the pattern stream — byte `i % 8` (little-endian) of word
/// `i / 8`, so byte- and word-wise generation agree.
#[inline]
fn pattern_byte(origin: usize, dest: usize, i: u64) -> u8 {
    (pattern_word(origin, dest, i / 8) >> ((i % 8) * 8)) as u8
}

/// Append `len` pattern bytes for (origin, dest), a word at a time.
fn append_pattern(v: &mut Vec<u8>, origin: usize, dest: usize, len: u64) {
    let words = len / 8;
    for w in 0..words {
        v.extend_from_slice(&pattern_word(origin, dest, w).to_le_bytes());
    }
    let rem = (len % 8) as usize;
    if rem > 0 {
        let tail = pattern_word(origin, dest, words).to_le_bytes();
        v.extend_from_slice(&tail[..rem]);
    }
}

/// A routed data block: payload from `origin`, ultimately destined to
/// `dest`. Store-and-forward algorithms move blocks through intermediate
/// ranks; linear algorithms ship them directly. Cloning a block clones
/// payload *views*, never payload bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Block {
    pub origin: u32,
    pub dest: u32,
    pub data: DataBuf,
}

impl Block {
    pub fn new(origin: usize, dest: usize, data: DataBuf) -> Block {
        Block {
            origin: origin as u32,
            dest: dest as u32,
            data,
        }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// What actually travels in a message. Payloads are moved (views and
/// counts), never deep-copied, on enqueue and dequeue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Payload {
    /// Metadata phase of the two-phase scheme: block sizes (8 B each on
    /// the wire, like the `MPI_LONG` arrays the paper exchanges).
    Meta(Vec<u64>),
    /// The data phase: a batch of routed blocks. Wire size is the payload
    /// bytes only — block headers were already conveyed by the metadata.
    Blocks(Vec<Block>),
    /// An unstructured buffer (linear algorithms ship one block per
    /// message and need no routing header).
    Raw(DataBuf),
    /// A single value (allreduce / barrier internals).
    Scalar(u64),
}

impl Payload {
    /// Wire size in bytes under the cost model.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Meta(v) => 8 * v.len() as u64,
            Payload::Blocks(bs) => bs.iter().map(|b| b.len()).sum(),
            Payload::Raw(d) => d.len(),
            Payload::Scalar(_) => 8,
        }
    }

    pub fn into_meta(self) -> Vec<u64> {
        match self {
            Payload::Meta(v) => v,
            other => panic!("expected Meta payload, got {other:?}"),
        }
    }

    pub fn into_blocks(self) -> Vec<Block> {
        match self {
            Payload::Blocks(v) => v,
            other => panic!("expected Blocks payload, got {other:?}"),
        }
    }

    pub fn into_raw(self) -> DataBuf {
        match self {
            Payload::Raw(d) => d,
            other => panic!("expected Raw payload, got {other:?}"),
        }
    }

    pub fn into_scalar(self) -> u64 {
        match self {
            Payload::Scalar(v) => v,
            other => panic!("expected Scalar payload, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths() {
        assert_eq!(DataBuf::from_vec(vec![1, 2, 3]).len(), 3);
        assert_eq!(DataBuf::Phantom(77).len(), 77);
        assert!(DataBuf::empty(true).is_empty());
        assert!(DataBuf::empty(false).is_empty());
    }

    #[test]
    fn pattern_roundtrip() {
        let d = DataBuf::pattern(3, 9, 256);
        assert_eq!(d.len(), 256);
        assert!(d.check_pattern(3, 9).is_ok());
        // Wrong origin/dest must be detected quickly.
        assert!(d.check_pattern(9, 3).is_err());
        // Non-multiple-of-8 lengths exercise the word/byte tail path.
        for len in [0u64, 1, 7, 8, 9, 63, 65] {
            let d = DataBuf::pattern(1, 2, len);
            assert_eq!(d.len(), len);
            assert!(d.check_pattern(1, 2).is_ok(), "len {len}");
        }
    }

    #[test]
    fn word_and_byte_pattern_agree() {
        for i in 0..64u64 {
            let w = pattern_word(4, 5, i / 8).to_le_bytes()[(i % 8) as usize];
            assert_eq!(w, pattern_byte(4, 5, i), "byte {i}");
        }
    }

    #[test]
    fn pattern_detects_corruption() {
        let mut v = DataBuf::pattern(1, 2, 64).to_contiguous().into_owned();
        v[10] ^= 0xff;
        let d = DataBuf::from_vec(v);
        assert_eq!(d.check_pattern(1, 2), Err(10));
    }

    #[test]
    #[should_panic(expected = "phantom")]
    fn phantom_has_no_bytes() {
        DataBuf::Phantom(4).bytes();
    }

    #[test]
    fn slice_is_zero_copy_and_pattern_checked() {
        reset_host_copied();
        let row = DataBuf::pattern_row(2, &[16, 0, 40, 8]);
        assert_eq!(host_copied(), 64, "one arena write for the whole row");
        assert_eq!(row.len(), 4);
        assert_eq!(row[0].len(), 16);
        assert_eq!(row[1].len(), 0);
        assert_eq!(row[2].len(), 40);
        assert_eq!(row[3].len(), 8);
        for (dest, d) in row.iter().enumerate() {
            d.check_pattern(2, dest).unwrap();
        }
        // The four checks read 64 bytes total on top of the 64 written.
        assert_eq!(host_copied(), 128);
    }

    #[test]
    fn pattern_row_entries_skips_absent_destinations() {
        reset_host_copied();
        // Structural entries only: dests 1 and 5 of an 8-wide row.
        let bufs = DataBuf::pattern_row_entries(3, &[(1, 24), (5, 40)]);
        assert_eq!(host_copied(), 64, "one arena write, structural bytes only");
        assert_eq!(bufs.len(), 2);
        assert_eq!(bufs[0].len(), 24);
        assert_eq!(bufs[1].len(), 40);
        bufs[0].check_pattern(3, 1).unwrap();
        bufs[1].check_pattern(3, 5).unwrap();
        // A zero-size entry yields an empty buffer with no rope segment.
        let z = DataBuf::pattern_row_entries(3, &[(2, 0)]);
        assert_eq!(z[0].len(), 0);
        assert_eq!(z[0].rope().segment_count(), 0);
    }

    #[test]
    fn subslice_of_slice_shares_storage() {
        let d = DataBuf::pattern(0, 1, 100);
        let a = d.slice(8, 64);
        let b = a.slice(8, 8);
        // b is bytes [16, 24) of the original pattern.
        assert_eq!(
            b.to_contiguous().as_ref(),
            &d.to_contiguous().as_ref()[16..24]
        );
        assert_eq!(b.rope().segment_count(), 1);
    }

    #[test]
    fn concat_is_segment_concat_and_eq_ignores_segmentation() {
        reset_host_copied();
        let whole = DataBuf::pattern(3, 4, 48);
        let written = host_copied();
        let parts = DataBuf::concat(
            true,
            vec![whole.slice(0, 10), whole.slice(10, 30), whole.slice(40, 8)],
        );
        // Re-slicing + concat moved no bytes.
        assert_eq!(host_copied(), written);
        assert_eq!(parts.rope().segment_count(), 3);
        assert_eq!(parts, whole, "equality is content, not segmentation");
        parts.check_pattern(3, 4).unwrap();
        // Fragmented materialization is the only copy.
        let flat = parts.to_contiguous();
        assert_eq!(flat.as_ref(), whole.bytes());
        assert_eq!(host_copied(), written + 48 + 48); // 1 check + 1 flatten
    }

    #[test]
    fn phantom_concat_and_slice_track_lengths() {
        let c = DataBuf::concat(
            false,
            vec![DataBuf::Phantom(5), DataBuf::Phantom(0), DataBuf::Phantom(7)],
        );
        assert_eq!(c, DataBuf::Phantom(12));
        assert_eq!(c.slice(3, 6), DataBuf::Phantom(6));
    }

    #[test]
    fn real_never_equals_phantom() {
        assert_ne!(DataBuf::from_vec(vec![0, 0]), DataBuf::Phantom(2));
        assert_eq!(DataBuf::empty(true).len(), DataBuf::empty(false).len());
        assert_ne!(DataBuf::empty(true), DataBuf::empty(false));
    }

    #[test]
    fn wire_bytes_per_payload_kind() {
        assert_eq!(Payload::Meta(vec![1, 2, 3]).wire_bytes(), 24);
        let blocks = vec![
            Block::new(0, 1, DataBuf::Phantom(10)),
            Block::new(0, 2, DataBuf::Phantom(5)),
        ];
        assert_eq!(Payload::Blocks(blocks).wire_bytes(), 15);
        assert_eq!(Payload::Raw(DataBuf::Phantom(9)).wire_bytes(), 9);
        assert_eq!(Payload::Scalar(1).wire_bytes(), 8);
    }

    #[test]
    #[should_panic(expected = "expected Blocks")]
    fn payload_downcast_checked() {
        Payload::Scalar(3).into_blocks();
    }
}
