//! Data buffers and routed blocks.
//!
//! One implementation of every algorithm serves both correctness testing
//! and large-scale simulation: payloads are [`DataBuf`]s that either carry
//! real bytes (`Real`, validated against the gold all-to-all result) or
//! just a length (`Phantom`, so a P = 16,384 simulation fits in memory).
//! A run must be homogeneous — mixing modes in one message is a bug.

/// A payload: real bytes or a phantom (size-only) stand-in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DataBuf {
    Real(Vec<u8>),
    Phantom(u64),
}

impl DataBuf {
    #[inline]
    pub fn len(&self) -> u64 {
        match self {
            DataBuf::Real(v) => v.len() as u64,
            DataBuf::Phantom(n) => *n,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn is_real(&self) -> bool {
        matches!(self, DataBuf::Real(_))
    }

    /// Borrow the real bytes; panics on a phantom buffer (callers that need
    /// bytes are correctness paths which always run in real mode).
    pub fn bytes(&self) -> &[u8] {
        match self {
            DataBuf::Real(v) => v,
            DataBuf::Phantom(_) => panic!("bytes() on a phantom DataBuf"),
        }
    }

    /// An empty buffer in the given mode.
    pub fn empty(real: bool) -> DataBuf {
        if real {
            DataBuf::Real(Vec::new())
        } else {
            DataBuf::Phantom(0)
        }
    }

    /// Deterministic pattern payload for (origin, dest): byte `i` is a hash
    /// of `(origin, dest, i)`, so any misrouting or mis-slicing in an
    /// algorithm corrupts the pattern and is caught by [`DataBuf::check_pattern`].
    pub fn pattern(origin: usize, dest: usize, len: u64) -> DataBuf {
        let mut v = Vec::with_capacity(len as usize);
        for i in 0..len {
            v.push(pattern_byte(origin, dest, i));
        }
        DataBuf::Real(v)
    }

    /// Verify a pattern payload; returns the first mismatching index.
    pub fn check_pattern(&self, origin: usize, dest: usize) -> Result<(), u64> {
        let bytes = self.bytes();
        for (i, b) in bytes.iter().enumerate() {
            if *b != pattern_byte(origin, dest, i as u64) {
                return Err(i as u64);
            }
        }
        Ok(())
    }
}

#[inline]
fn pattern_byte(origin: usize, dest: usize, i: u64) -> u8 {
    let mut h = (origin as u64)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add((dest as u64).wrapping_mul(0xff51_afd7_ed55_8ccd))
        .wrapping_add(i.wrapping_mul(0xc4ce_b9fe_1a85_ec53));
    h ^= h >> 33;
    (h & 0xff) as u8
}

/// A routed data block: payload from `origin`, ultimately destined to
/// `dest`. Store-and-forward algorithms move blocks through intermediate
/// ranks; linear algorithms ship them directly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Block {
    pub origin: u32,
    pub dest: u32,
    pub data: DataBuf,
}

impl Block {
    pub fn new(origin: usize, dest: usize, data: DataBuf) -> Block {
        Block {
            origin: origin as u32,
            dest: dest as u32,
            data,
        }
    }

    #[inline]
    pub fn len(&self) -> u64 {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// What actually travels in a message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Payload {
    /// Metadata phase of the two-phase scheme: block sizes (8 B each on
    /// the wire, like the `MPI_LONG` arrays the paper exchanges).
    Meta(Vec<u64>),
    /// The data phase: a batch of routed blocks. Wire size is the payload
    /// bytes only — block headers were already conveyed by the metadata.
    Blocks(Vec<Block>),
    /// An unstructured buffer (linear algorithms ship one block per
    /// message and need no routing header).
    Raw(DataBuf),
    /// A single value (allreduce / barrier internals).
    Scalar(u64),
}

impl Payload {
    /// Wire size in bytes under the cost model.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Meta(v) => 8 * v.len() as u64,
            Payload::Blocks(bs) => bs.iter().map(|b| b.len()).sum(),
            Payload::Raw(d) => d.len(),
            Payload::Scalar(_) => 8,
        }
    }

    pub fn into_meta(self) -> Vec<u64> {
        match self {
            Payload::Meta(v) => v,
            other => panic!("expected Meta payload, got {other:?}"),
        }
    }

    pub fn into_blocks(self) -> Vec<Block> {
        match self {
            Payload::Blocks(v) => v,
            other => panic!("expected Blocks payload, got {other:?}"),
        }
    }

    pub fn into_raw(self) -> DataBuf {
        match self {
            Payload::Raw(d) => d,
            other => panic!("expected Raw payload, got {other:?}"),
        }
    }

    pub fn into_scalar(self) -> u64 {
        match self {
            Payload::Scalar(v) => v,
            other => panic!("expected Scalar payload, got {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths() {
        assert_eq!(DataBuf::Real(vec![1, 2, 3]).len(), 3);
        assert_eq!(DataBuf::Phantom(77).len(), 77);
        assert!(DataBuf::empty(true).is_empty());
        assert!(DataBuf::empty(false).is_empty());
    }

    #[test]
    fn pattern_roundtrip() {
        let d = DataBuf::pattern(3, 9, 256);
        assert_eq!(d.len(), 256);
        assert!(d.check_pattern(3, 9).is_ok());
        // Wrong origin/dest must be detected quickly.
        assert!(d.check_pattern(9, 3).is_err());
    }

    #[test]
    fn pattern_detects_corruption() {
        let mut d = DataBuf::pattern(1, 2, 64);
        if let DataBuf::Real(v) = &mut d {
            v[10] ^= 0xff;
        }
        assert_eq!(d.check_pattern(1, 2), Err(10));
    }

    #[test]
    #[should_panic(expected = "phantom")]
    fn phantom_has_no_bytes() {
        DataBuf::Phantom(4).bytes();
    }

    #[test]
    fn wire_bytes_per_payload_kind() {
        assert_eq!(Payload::Meta(vec![1, 2, 3]).wire_bytes(), 24);
        let blocks = vec![
            Block::new(0, 1, DataBuf::Phantom(10)),
            Block::new(0, 2, DataBuf::Phantom(5)),
        ];
        assert_eq!(Payload::Blocks(blocks).wire_bytes(), 15);
        assert_eq!(Payload::Raw(DataBuf::Phantom(9)).wire_bytes(), 9);
        assert_eq!(Payload::Scalar(1).wire_bytes(), 8);
    }

    #[test]
    #[should_panic(expected = "expected Blocks")]
    fn payload_downcast_checked() {
        Payload::Scalar(3).into_blocks();
    }
}
