//! Process-to-node layout.
//!
//! The paper runs `P = Q * N` ranks: `Q` ranks per node, `N` nodes, with
//! rank `p` living on node `p / Q` and having in-node (group) rank
//! `g = p % Q` — the same block mapping MPI launchers use by default and
//! the one Algorithms 2/3 assume.

use crate::error::{Result, TunaError};
use crate::model::Link;

/// Rank layout: `p` total ranks, `q` per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    p: usize,
    q: usize,
}

impl Topology {
    /// Create a layout, surfacing invalid shapes (no ranks, `q = 0`,
    /// `q ∤ p`) as typed configuration errors instead of panics — this is
    /// what `RunConfig::validate` and the programmatic entry points call,
    /// so a bad topology fails at config validation rather than killing
    /// rank threads mid-run. `q` must divide `p` (the paper always runs
    /// full nodes; partial nodes would change the Q-port math of
    /// TuNA_l^g).
    pub fn try_new(p: usize, q: usize) -> Result<Topology> {
        if p < 1 {
            return Err(TunaError::config("topology: need at least one rank"));
        }
        if q < 1 {
            return Err(TunaError::config(
                "topology: need at least one rank per node (q >= 1)",
            ));
        }
        if p % q != 0 {
            return Err(TunaError::config(format!(
                "topology: ranks per node ({q}) must divide total ranks ({p})"
            )));
        }
        Ok(Topology { p, q })
    }

    /// Infallible constructor for call sites whose shape is already
    /// validated (tests, fixed grids). Panics with the
    /// [`Topology::try_new`] error message on an invalid shape.
    pub fn new(p: usize, q: usize) -> Topology {
        match Topology::try_new(p, q) {
            Ok(t) => t,
            Err(e) => panic!("{e}"),
        }
    }

    /// Every rank on its own node (all communication inter-node).
    pub fn flat(p: usize) -> Topology {
        Topology::new(p, 1)
    }

    /// All ranks on one node (all communication intra-node).
    pub fn single_node(p: usize) -> Topology {
        Topology::new(p, p)
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Ranks per node (the paper's Q).
    #[inline]
    pub fn q(&self) -> usize {
        self.q
    }

    /// Number of nodes (the paper's N).
    #[inline]
    pub fn nodes(&self) -> usize {
        self.p / self.q
    }

    /// Node hosting `rank`.
    #[inline]
    pub fn node_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.p);
        rank / self.q
    }

    /// In-node (group) rank, the paper's `g = p % Q`.
    #[inline]
    pub fn group_rank(&self, rank: usize) -> usize {
        debug_assert!(rank < self.p);
        rank % self.q
    }

    /// Global rank of group-rank `g` on node `n`.
    #[inline]
    pub fn rank_of(&self, node: usize, g: usize) -> usize {
        debug_assert!(node < self.nodes() && g < self.q);
        node * self.q + g
    }

    /// Link class between two ranks.
    #[inline]
    pub fn link(&self, a: usize, b: usize) -> Link {
        if self.node_of(a) == self.node_of(b) {
            Link::Local
        } else {
            Link::Global
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_math() {
        let t = Topology::new(12, 4);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 1);
        assert_eq!(t.group_rank(7), 3);
        assert_eq!(t.rank_of(1, 3), 7);
        for r in 0..12 {
            assert_eq!(t.rank_of(t.node_of(r), t.group_rank(r)), r);
        }
    }

    #[test]
    fn link_classes() {
        let t = Topology::new(8, 4);
        assert_eq!(t.link(0, 3), Link::Local);
        assert_eq!(t.link(0, 4), Link::Global);
        assert_eq!(t.link(5, 6), Link::Local);
    }

    #[test]
    fn flat_and_single_node() {
        let f = Topology::flat(6);
        assert_eq!(f.nodes(), 6);
        assert_eq!(f.link(1, 2), Link::Global);
        let s = Topology::single_node(6);
        assert_eq!(s.nodes(), 1);
        assert_eq!(s.link(1, 2), Link::Local);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn rejects_partial_nodes() {
        Topology::new(10, 4);
    }

    #[test]
    fn try_new_surfaces_typed_config_errors() {
        let e = Topology::try_new(10, 4).unwrap_err().to_string();
        assert!(e.contains("configuration") && e.contains("must divide"), "{e}");
        let e = Topology::try_new(8, 0).unwrap_err().to_string();
        assert!(e.contains("rank per node"), "{e}");
        let e = Topology::try_new(0, 1).unwrap_err().to_string();
        assert!(e.contains("at least one rank"), "{e}");
        assert_eq!(Topology::try_new(8, 4).unwrap(), Topology::new(8, 4));
    }
}
