//! Per-rank virtual clock.
//!
//! Every rank thread owns one `Clock`. All costs are charged in *virtual*
//! seconds from the [`MachineProfile`]; wallclock never enters the model,
//! so results are independent of host scheduling and fully deterministic
//! (receive processing is ordered by virtual arrival time, not OS arrival
//! order — see `Engine::waitall`).

use crate::comm::faults::{FaultLens, NO_PEER};
use crate::model::{Link, MachineProfile};

/// Communication counters, kept per rank and merged by the harness.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Counters {
    pub msgs_local: u64,
    pub msgs_global: u64,
    pub bytes_local: u64,
    pub bytes_global: u64,
    /// Bytes moved by *modeled* local copies (packing / rearrangement):
    /// the virtual-clock charge from `RankCtx::copy`, identical in real
    /// and phantom mode.
    pub bytes_copied: u64,
    /// Payload bytes *physically* moved by the host: rope materialization
    /// at sources, pattern-verification reads at sinks, and forced
    /// compaction of fragmented ropes (see `comm::buffer`). Zero in
    /// phantom mode. Store-and-forward hops move Arc views, so for a
    /// real-mode all-to-allv this equals bytes written at sources plus
    /// bytes read at sinks exactly — the zero-copy invariant asserted by
    /// `tests/zero_copy.rs`.
    pub copied_bytes: u64,
    /// Virtual seconds of communication the rank's program order
    /// actually stalled on: the tail of each comm window (first
    /// post-since-wait → wait completion) past the point program order
    /// had already reached when the wait resolved. Measured, not
    /// inferred — segmented overlap drivers shrink this without
    /// changing `hidden_comm + exposed_comm`.
    pub exposed_comm: f64,
    /// Virtual seconds of communication hidden behind host progress
    /// (posting overhead, copies, interleaved `Compute` ops) inside the
    /// same windows. `exposed_comm + hidden_comm` is the total comm
    /// window time by construction (each window contributes
    /// `exposed` and `total - exposed`).
    pub hidden_comm: f64,
}

impl Counters {
    pub fn merge(&mut self, other: &Counters) {
        self.msgs_local += other.msgs_local;
        self.msgs_global += other.msgs_global;
        self.bytes_local += other.bytes_local;
        self.bytes_global += other.bytes_global;
        self.bytes_copied += other.bytes_copied;
        self.copied_bytes += other.copied_bytes;
        self.exposed_comm += other.exposed_comm;
        self.hidden_comm += other.hidden_comm;
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs_local + self.msgs_global
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_local + self.bytes_global
    }

    /// Total communication window time: the sum both exposure counters
    /// partition. (Each wait contributes `exposed` and `total - exposed`,
    /// so the identity is exact by construction.)
    pub fn comm_window(&self) -> f64 {
        self.exposed_comm + self.hidden_comm
    }
}

/// The clock itself. `now` only moves forward.
#[derive(Clone, Debug)]
pub struct Clock {
    /// Current virtual time of the rank's program order.
    pub now: f64,
    /// Time at which the tx port becomes free.
    tx_free: f64,
    /// Time at which the rx port becomes free.
    rx_free: f64,
    /// Sends posted since the last wait — the burst size the congestion
    /// model keys on.
    outstanding_tx: u32,
    /// Deterministic fault perturbations for this rank (`None` =
    /// healthy; see `comm::faults` for the zero-perturbation argument).
    faults: Option<FaultLens>,
    /// Sends posted over this clock's lifetime — the tx event index the
    /// fault model keys jitter on. Counts in program order, so both
    /// executors see identical indices.
    tx_events: u64,
    /// Receives drained over this clock's lifetime — the rx event
    /// index. Drain order is deterministic (`(arrive, src, tag)`), so
    /// the sequence is executor-independent too.
    rx_events: u64,
    /// Program-order time at which the currently open comm window
    /// started: set by the first send/recv posted since the last wait,
    /// resolved (into `exposed_comm`/`hidden_comm`) by `finish_wait`.
    comm_open: Option<f64>,
    pub counters: Counters,
}

/// Outcome of posting a send.
#[derive(Clone, Copy, Debug)]
pub struct SendTiming {
    /// When the send is locally complete (buffer reusable / waitable).
    pub complete: f64,
    /// When the message arrives at the receiver's rx port.
    pub arrive: f64,
}

impl Clock {
    pub fn new() -> Clock {
        Clock::with_faults(None)
    }

    /// A clock carrying one rank's fault projection. `None` is exactly
    /// [`Clock::new`]: the healthy arms multiply by the constant `1.0`,
    /// which IEEE-754 guarantees returns the operand unchanged, so an
    /// empty fault spec is bit-identical to a lens-free build.
    pub fn with_faults(faults: Option<FaultLens>) -> Clock {
        Clock {
            now: 0.0,
            tx_free: 0.0,
            rx_free: 0.0,
            outstanding_tx: 0,
            faults,
            tx_events: 0,
            rx_events: 0,
            comm_open: None,
            counters: Counters::default(),
        }
    }

    /// Post a send of `bytes` over `link` in a job of `p` ranks.
    ///
    /// Peer-less convenience for call sites that never carry a lens
    /// (the analytic estimator's probe clocks).
    pub fn post_send(&mut self, prof: &MachineProfile, link: Link, bytes: u64, p: usize) -> SendTiming {
        self.post_send_to(prof, link, bytes, p, NO_PEER)
    }

    /// Post a send of `bytes` over `link` to `peer` in a job of `p`
    /// ranks.
    ///
    /// Charges the per-message software overhead to program order, then
    /// serializes the payload on the tx port with the burst congestion
    /// factor applied. With a fault lens, the overhead is scaled by the
    /// rank's CPU multiplier, serialization and wire latency by the
    /// link/jitter multipliers keyed on `(peer, tx event index)`, and
    /// the port start is deferred out of outage windows.
    pub fn post_send_to(
        &mut self,
        prof: &MachineProfile,
        link: Link,
        bytes: u64,
        p: usize,
        peer: usize,
    ) -> SendTiming {
        let (cpu, ser, lat) = match &self.faults {
            Some(f) => {
                let (ser, lat) = f.tx(peer, self.tx_events);
                (f.cpu(), ser, lat)
            }
            None => (1.0, 1.0, 1.0),
        };
        self.tx_events += 1;
        if self.comm_open.is_none() {
            self.comm_open = Some(self.now);
        }
        self.now += prof.o_send(link) * cpu;
        let factor = match link {
            Link::Local => 1.0,
            Link::Global => prof.congestion.tx_factor(self.outstanding_tx, p as u32),
        };
        self.outstanding_tx += 1;
        let mut start = self.now.max(self.tx_free);
        if let Some(f) = &self.faults {
            start = f.defer(start);
        }
        self.tx_free = start + bytes as f64 * prof.beta(link) * factor * ser;
        match link {
            Link::Local => {
                self.counters.msgs_local += 1;
                self.counters.bytes_local += bytes;
            }
            Link::Global => {
                self.counters.msgs_global += 1;
                self.counters.bytes_global += bytes;
            }
        }
        SendTiming {
            complete: self.tx_free,
            arrive: self.tx_free + prof.alpha(link) * lat,
        }
    }

    /// Charge the posting overhead of a receive request (cheap, but real).
    pub fn post_recv(&mut self, prof: &MachineProfile, link: Link) {
        let cpu = match &self.faults {
            Some(f) => f.cpu(),
            None => 1.0,
        };
        if self.comm_open.is_none() {
            self.comm_open = Some(self.now);
        }
        // Posting an irecv costs a fraction of a full receive overhead.
        self.now += 0.25 * prof.o_recv(link) * cpu;
    }

    /// Drain a batch of matched receives through the rx port.
    ///
    /// Peer-less convenience; must not be used on a faulted clock (the
    /// rx perturbations are keyed on the sender).
    pub fn drain_receives(
        &mut self,
        prof: &MachineProfile,
        msgs: &[(f64, u64, Link)],
    ) -> Vec<f64> {
        debug_assert!(self.faults.is_none(), "faulted clocks must use drain_receives_from");
        let from: Vec<(f64, u64, Link, usize)> =
            msgs.iter().map(|&(a, b, l)| (a, b, l, NO_PEER)).collect();
        self.drain_receives_from(prof, &from)
    }

    /// Drain a batch of matched receives through the rx port.
    ///
    /// `msgs` is `(arrive_time, bytes, link, src)` and MUST be sorted by
    /// `(arrive_time, tiebreak)` by the caller — the deterministic order.
    /// Returns per-message completion times. Applies the incast factor
    /// based on instantaneous queue depth. With a fault lens, each
    /// message's serialization is scaled by the link/jitter multipliers
    /// keyed on `(src, rx event index)`, the receive overhead by the
    /// rank's CPU multiplier, and the port start is deferred out of
    /// outage windows.
    pub fn drain_receives_from(
        &mut self,
        prof: &MachineProfile,
        msgs: &[(f64, u64, Link, usize)],
    ) -> Vec<f64> {
        let mut completions = Vec::with_capacity(msgs.len());
        for (i, &(arrive, bytes, link, src)) in msgs.iter().enumerate() {
            let (cpu, ser) = match &self.faults {
                Some(f) => (f.cpu(), f.rx(src, self.rx_events)),
                None => (1.0, 1.0),
            };
            self.rx_events += 1;
            let mut start = arrive.max(self.rx_free);
            if let Some(f) = &self.faults {
                start = f.defer(start);
            }
            // Queue depth: messages already arrived but not yet drained.
            let mut depth = 1u32;
            for &(a2, _, _, _) in msgs[i + 1..].iter() {
                if a2 <= start {
                    depth += 1;
                } else {
                    break;
                }
            }
            let factor = match link {
                Link::Local => 1.0,
                Link::Global => prof.congestion.rx_factor(depth),
            };
            self.rx_free = start + bytes as f64 * prof.beta(link) * factor * ser;
            completions.push(self.rx_free + prof.o_recv(link) * cpu);
        }
        completions
    }

    /// Drain exactly one matched receive — `waitall`'s single-receive
    /// fast path. Peer-less convenience; must not be used on a faulted
    /// clock.
    pub fn drain_one(&mut self, prof: &MachineProfile, arrive: f64, bytes: u64, link: Link) -> f64 {
        debug_assert!(self.faults.is_none(), "faulted clocks must use drain_one_from");
        self.drain_one_from(prof, arrive, bytes, link, NO_PEER)
    }

    /// Drain exactly one matched receive from `src`. The arithmetic is
    /// bit-identical to [`Clock::drain_receives_from`] on a one-message
    /// batch (queue depth is necessarily 1), without the completion
    /// vector.
    pub fn drain_one_from(
        &mut self,
        prof: &MachineProfile,
        arrive: f64,
        bytes: u64,
        link: Link,
        src: usize,
    ) -> f64 {
        let (cpu, ser) = match &self.faults {
            Some(f) => (f.cpu(), f.rx(src, self.rx_events)),
            None => (1.0, 1.0),
        };
        self.rx_events += 1;
        let mut start = arrive.max(self.rx_free);
        if let Some(f) = &self.faults {
            start = f.defer(start);
        }
        let factor = match link {
            Link::Local => 1.0,
            Link::Global => prof.congestion.rx_factor(1),
        };
        self.rx_free = start + bytes as f64 * prof.beta(link) * factor * ser;
        self.rx_free + prof.o_recv(link) * cpu
    }

    /// A wait completed at `t`: advance program order and close the
    /// burst. Resolves the open comm window (if any) into the exposure
    /// counters: the window runs from the first post since the previous
    /// wait to the wait's completion; the part past the rank's current
    /// program-order time was *exposed* (the rank stalled on it), the
    /// rest was *hidden* behind whatever the rank did meanwhile
    /// (posting overhead, copies, interleaved compute).
    pub fn finish_wait(&mut self, t: f64) {
        if let Some(start) = self.comm_open.take() {
            let end = t.max(self.now);
            let total = (end - start).max(0.0);
            let exposed = (end - self.now).max(0.0).min(total);
            self.counters.exposed_comm += exposed;
            self.counters.hidden_comm += total - exposed;
        }
        self.now = self.now.max(t);
        self.outstanding_tx = 0;
    }

    /// Charge a local memory copy (scaled by the straggler multiplier
    /// when a fault lens is present).
    pub fn charge_copy(&mut self, prof: &MachineProfile, bytes: u64) {
        let cpu = match &self.faults {
            Some(f) => f.cpu(),
            None => 1.0,
        };
        self.now += prof.copy_cost(bytes) * cpu;
        self.counters.bytes_copied += bytes;
    }

    /// Charge arbitrary local compute time (scaled by the straggler
    /// multiplier when a fault lens is present).
    pub fn charge_compute(&mut self, seconds: f64) {
        debug_assert!(seconds >= 0.0);
        let cpu = match &self.faults {
            Some(f) => f.cpu(),
            None => 1.0,
        };
        self.now += seconds * cpu;
    }
}

impl Default for Clock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof() -> MachineProfile {
        MachineProfile::test_flat()
    }

    #[test]
    fn send_charges_overhead_and_serializes() {
        let p = prof();
        let mut c = Clock::new();
        let t1 = c.post_send(&p, Link::Global, 1000, 64);
        // o_send = 1e-7; 1000 B * 1e-9 = 1e-6 serialization; alpha = 1e-6.
        assert!((c.now - 1e-7).abs() < 1e-15);
        assert!((t1.complete - (1e-7 + 1e-6)).abs() < 1e-15);
        assert!((t1.arrive - (1e-7 + 1e-6 + 1e-6)).abs() < 1e-15);
        // Second send serializes behind the first on the tx port.
        let t2 = c.post_send(&p, Link::Global, 1000, 64);
        assert!(t2.complete > t1.complete);
        assert!((t2.complete - (t1.complete + 1e-6)).abs() < 1e-12);
    }

    #[test]
    fn counters_split_by_link() {
        let p = prof();
        let mut c = Clock::new();
        c.post_send(&p, Link::Local, 10, 8);
        c.post_send(&p, Link::Global, 20, 8);
        c.post_send(&p, Link::Global, 30, 8);
        assert_eq!(c.counters.msgs_local, 1);
        assert_eq!(c.counters.msgs_global, 2);
        assert_eq!(c.counters.bytes_local, 10);
        assert_eq!(c.counters.bytes_global, 50);
    }

    #[test]
    fn merge_sums_every_field() {
        let mut a = Counters {
            msgs_local: 1,
            msgs_global: 2,
            bytes_local: 3,
            bytes_global: 4,
            bytes_copied: 5,
            copied_bytes: 6,
            exposed_comm: 0.5,
            hidden_comm: 0.25,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.msgs_local, 2);
        assert_eq!(a.msgs_global, 4);
        assert_eq!(a.bytes_local, 6);
        assert_eq!(a.bytes_global, 8);
        assert_eq!(a.bytes_copied, 10);
        assert_eq!(a.copied_bytes, 12);
        assert_eq!(a.exposed_comm, 1.0);
        assert_eq!(a.hidden_comm, 0.5);
        assert_eq!(a.comm_window(), 1.5);
    }

    #[test]
    fn exposure_partitions_each_comm_window_exactly() {
        let p = prof();
        // Window opens at the first post; program order then advances
        // (as if the rank computed); the wait's tail past `now` is
        // exposed, the covered part hidden. Dyadic values make every
        // operation exact, so the partition is asserted bitwise:
        // exposed + hidden == window total.
        let mut c = Clock::new();
        c.post_send(&p, Link::Global, 1000, 64); // window starts at 0.0
        c.now = 3.0; // host progress inside the window
        c.finish_wait(5.0);
        assert_eq!(c.counters.exposed_comm.to_bits(), 2.0f64.to_bits());
        assert_eq!(c.counters.hidden_comm.to_bits(), 3.0f64.to_bits());
        assert_eq!(c.counters.comm_window().to_bits(), 5.0f64.to_bits());
        // The window closed: a wait with nothing posted adds nothing.
        c.finish_wait(9.0);
        assert_eq!(c.counters.comm_window().to_bits(), 5.0f64.to_bits());

        // A wait that resolves behind program order is fully hidden.
        let mut h = Clock::new();
        h.post_send(&p, Link::Global, 1000, 64);
        h.now = 8.0;
        h.finish_wait(2.0);
        assert_eq!(h.counters.exposed_comm.to_bits(), 0.0f64.to_bits());
        assert_eq!(h.counters.hidden_comm.to_bits(), 8.0f64.to_bits());
        assert_eq!(h.now, 8.0);

        // Receive-only windows open at the recv post too.
        let mut r = Clock::new();
        r.now = 1.0;
        r.post_recv(&p, Link::Global);
        r.now = 1.5;
        r.finish_wait(3.5);
        assert_eq!(r.counters.exposed_comm.to_bits(), 2.0f64.to_bits());
        assert_eq!(r.counters.hidden_comm.to_bits(), 0.5f64.to_bits());

        // No window, no exposure.
        let mut n = Clock::new();
        n.finish_wait(5.0);
        assert_eq!(n.counters.comm_window(), 0.0);
    }

    #[test]
    fn drain_orders_and_serializes() {
        let p = prof();
        let mut c = Clock::new();
        let msgs = vec![
            (1e-3, 1000u64, Link::Global),
            (1e-3, 1000u64, Link::Global),
        ];
        let done = c.drain_receives(&p, &msgs);
        // Second message waits for the first to drain (1 us each).
        assert!(done[1] > done[0]);
        assert!((done[1] - done[0] - 1e-6).abs() < 1e-12);
    }

    #[test]
    fn wait_advances_now_monotonically() {
        let mut c = Clock::new();
        c.finish_wait(5.0);
        assert_eq!(c.now, 5.0);
        c.finish_wait(1.0); // must not go backwards
        assert_eq!(c.now, 5.0);
    }

    #[test]
    fn copy_and_compute_charge_program_order() {
        let p = prof();
        let mut c = Clock::new();
        c.charge_copy(&p, 1_000_000); // 1 MB at 1 GB/s = 1 ms
        assert!((c.now - 1e-3).abs() < 1e-12);
        c.charge_compute(2e-3);
        assert!((c.now - 3e-3).abs() < 1e-12);
        assert_eq!(c.counters.bytes_copied, 1_000_000);
    }

    #[test]
    fn lens_free_peer_calls_match_legacy_bit_for_bit() {
        let p = prof();
        let mut legacy = Clock::new();
        let mut peered = Clock::with_faults(None);
        let a = legacy.post_send(&p, Link::Global, 1000, 64);
        let b = peered.post_send_to(&p, Link::Global, 1000, 64, 17);
        assert_eq!(a.complete.to_bits(), b.complete.to_bits());
        assert_eq!(a.arrive.to_bits(), b.arrive.to_bits());
        let da = legacy.drain_one(&p, 1e-3, 500, Link::Global);
        let db = peered.drain_one_from(&p, 1e-3, 500, Link::Global, 17);
        assert_eq!(da.to_bits(), db.to_bits());
        let msgs = [(1e-3, 100u64, Link::Global), (1e-3, 100u64, Link::Global)];
        let from: Vec<_> = msgs.iter().map(|&(a, b, l)| (a, b, l, 3usize)).collect();
        let va = legacy.drain_receives(&p, &msgs);
        let vb = peered.drain_receives_from(&p, &from);
        for (x, y) in va.iter().zip(vb.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(legacy.now.to_bits(), peered.now.to_bits());
    }

    #[test]
    fn straggler_lens_scales_cpu_costs() {
        use crate::comm::faults::{FaultModel, FaultSpec};
        let p = prof();
        let spec = FaultSpec::parse("straggler:rank=0,slow=3").unwrap();
        let model = FaultModel::compile(&spec, 1);
        let mut c = Clock::with_faults(Some(model.lens(0)));
        c.post_send_to(&p, Link::Global, 0, 4, 1);
        // o_send = 1e-7, tripled.
        assert!((c.now - 3e-7).abs() < 1e-15, "{}", c.now);
        c.charge_compute(1e-3);
        assert!((c.now - (3e-7 + 3e-3)).abs() < 1e-12, "{}", c.now);
        // An unaffected rank is bit-identical to a healthy clock.
        let mut healthy = Clock::new();
        let mut other = Clock::with_faults(Some(model.lens(1)));
        let a = healthy.post_send(&p, Link::Global, 4096, 4);
        let b = other.post_send_to(&p, Link::Global, 4096, 4, 0);
        assert_eq!(a.arrive.to_bits(), b.arrive.to_bits());
    }

    #[test]
    fn link_lens_scales_serialization_and_latency() {
        use crate::comm::faults::{FaultModel, FaultSpec};
        let p = prof();
        // Nodes of one rank each; degrade the 0-1 link to 1/4 bandwidth
        // and 2x latency.
        let spec = FaultSpec::parse("link:node=0-1,bw=0.25,lat=2").unwrap();
        let model = FaultModel::compile(&spec, 1);
        let mut c = Clock::with_faults(Some(model.lens(0)));
        let t = c.post_send_to(&p, Link::Global, 1000, 4, 1);
        // o_send 1e-7 + 1000 B * 1e-9 * 4 = 4.1e-6 complete; + 2e-6 arrive.
        assert!((t.complete - 4.1e-6).abs() < 1e-14, "{}", t.complete);
        assert!((t.arrive - 6.1e-6).abs() < 1e-14, "{}", t.arrive);
        // A send to an untouched node is unperturbed.
        let mut c2 = Clock::with_faults(Some(model.lens(0)));
        let t2 = c2.post_send_to(&p, Link::Global, 1000, 4, 2);
        assert!((t2.complete - 1.1e-6).abs() < 1e-14, "{}", t2.complete);
    }

    #[test]
    fn outage_defers_port_starts() {
        use crate::comm::faults::{FaultModel, FaultSpec};
        let p = prof();
        let spec = FaultSpec::parse("outage:node=0,from=0,until=0.5").unwrap();
        let model = FaultModel::compile(&spec, 1);
        let mut c = Clock::with_faults(Some(model.lens(0)));
        let t = c.post_send_to(&p, Link::Global, 1000, 4, 1);
        // Serialization starts at 0.5, not at o_send.
        assert!((t.complete - (0.5 + 1e-6)).abs() < 1e-12, "{}", t.complete);
        let done = c.drain_one_from(&p, 0.1, 1000, Link::Global, 1);
        // rx start deferred from max(0.1, rx_free=0) to 0.5.
        assert!((done - (0.5 + 1e-6 + 1e-7)).abs() < 1e-12, "{done}");
    }

    #[test]
    fn burst_resets_after_wait() {
        // With congestion ON, a long burst must cost more than separated
        // sends; waiting resets the outstanding counter.
        let mut p = prof();
        p.congestion = crate::model::congestion::CongestionParams::fugaku();
        let mut burst = Clock::new();
        for _ in 0..64 {
            burst.post_send(&p, Link::Global, 4096, 4096);
        }
        let burst_total = burst.tx_free;

        let mut paced = Clock::new();
        for _ in 0..64 {
            let t = paced.post_send(&p, Link::Global, 4096, 4096);
            paced.finish_wait(t.complete);
        }
        let paced_total = paced.tx_free;
        assert!(
            burst_total > paced_total,
            "burst {burst_total} should exceed paced {paced_total} under congestion"
        );
    }
}
