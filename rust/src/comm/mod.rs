//! MPI-like message-passing substrate with virtual time.
//!
//! Algorithms are written once against [`engine::RankCtx`] (non-blocking
//! `isend`/`irecv` + `waitall`, blocking conveniences, `allreduce`,
//! `barrier`) and run unchanged in two modes:
//!
//! * **real payloads** — bytes actually move between rank threads and are
//!   validated against the gold all-to-all result (correctness);
//! * **phantom payloads** — only sizes move, so paper-scale process counts
//!   fit in memory (simulation).
//!
//! Timing comes from per-rank virtual clocks ([`clock::Clock`]); the
//! engine's simulated makespan is the max clock over ranks at exit.
//!
//! Phantom collectives additionally run in a second execution mode:
//! algorithms compile their schedule into a [`plan::CommPlan`] (pure
//! data, derived from the counts matrix alone) which the single-threaded
//! discrete-event executor in [`replay`] advances with bit-identical
//! timing — no rank threads, so paper-scale P is cheap. The threaded
//! engine stays the golden oracle for real payloads.

pub mod buffer;
pub mod clock;
pub mod engine;
pub mod faults;
pub mod persist;
pub mod plan;
pub mod replay;
pub mod topology;

pub use buffer::{Block, ByteView, DataBuf, Payload, Rope};
pub use clock::{Clock, Counters};
pub use faults::{FaultModel, FaultSpec};
pub use engine::{Engine, EngineResult, RankCtx, RankResult};
pub use persist::PersistentColl;
pub use plan::{CommPlan, PlanBuilder, PlanCache, PlanOp, PlanStats, RankPlan};
pub use topology::Topology;

/// Cost-breakdown phases, matching the six components of the paper's
/// Fig. 11 plus compute/other for the applications.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Preparatory steps: allreduce for M, rotation/index setup (Alg. 3
    /// lines 1-5, 9-13).
    Prepare,
    /// Metadata exchanges of the two-phase scheme.
    Metadata,
    /// Actual data exchanges of the intra-node / single-level algorithm.
    Data,
    /// Inter-buffer copying each round (T and R management).
    Replace,
    /// Local rearrangement before coalesced inter-node exchange.
    Rearrange,
    /// Inter-node communication of TuNA_l^g.
    InterNode,
    /// Application compute (FFT stages, joins).
    Compute,
    /// Anything else.
    Other,
}

pub const PHASES: [Phase; 8] = [
    Phase::Prepare,
    Phase::Metadata,
    Phase::Data,
    Phase::Replace,
    Phase::Rearrange,
    Phase::InterNode,
    Phase::Compute,
    Phase::Other,
];

impl Phase {
    pub fn index(self) -> usize {
        PHASES.iter().position(|p| *p == self).unwrap()
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Prepare => "prepare",
            Phase::Metadata => "metadata",
            Phase::Data => "data",
            Phase::Replace => "replace",
            Phase::Rearrange => "rearrange",
            Phase::InterNode => "inter-node",
            Phase::Compute => "compute",
            Phase::Other => "other",
        }
    }
}

/// Per-rank virtual seconds attributed to each phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub secs: [f64; PHASES.len()],
}

impl PhaseBreakdown {
    pub fn add(&mut self, phase: Phase, dt: f64) {
        debug_assert!(dt >= -1e-12, "negative phase time {dt}");
        self.secs[phase.index()] += dt.max(0.0);
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.secs[phase.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Element-wise max — used to aggregate the per-rank breakdowns into
    /// the per-phase critical path the paper plots.
    pub fn max_with(&mut self, other: &PhaseBreakdown) {
        for i in 0..self.secs.len() {
            self.secs[i] = self.secs[i].max(other.secs[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_indices_unique() {
        let mut seen = std::collections::HashSet::new();
        for p in PHASES {
            assert!(seen.insert(p.index()));
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn breakdown_accumulates() {
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Metadata, 1.0);
        b.add(Phase::Metadata, 0.5);
        b.add(Phase::Data, 2.0);
        assert_eq!(b.get(Phase::Metadata), 1.5);
        assert_eq!(b.total(), 3.5);
    }

    #[test]
    fn breakdown_max_elementwise() {
        let mut a = PhaseBreakdown::default();
        a.add(Phase::Data, 1.0);
        let mut b = PhaseBreakdown::default();
        b.add(Phase::Data, 0.5);
        b.add(Phase::Metadata, 2.0);
        a.max_with(&b);
        assert_eq!(a.get(Phase::Data), 1.0);
        assert_eq!(a.get(Phase::Metadata), 2.0);
    }
}
