//! Compiled communication plans: the schedule of an all-to-all collective
//! as pure data, separated from its execution.
//!
//! A [`CommPlan`] holds, for every rank, the exact sequence of engine
//! operations ([`PlanOp`]) the algorithm would issue against a
//! [`RankCtx`](super::engine::RankCtx): sends/recvs as `(peer, tag,
//! bytes)`, wait points, modeled copy/compute charges, and phase
//! stopwatch marks. Each algorithm family compiles its plan from the
//! counts matrix alone (see `algos::compile_plan`), and the single
//! threaded replay executor ([`super::replay`]) then advances the
//! per-rank [`Clock`](super::clock::Clock)s through the plan without
//! spawning any rank threads — producing makespans, phase breakdowns and
//! counters **bit-identical** to the threaded engine's phantom mode
//! (`tests/replay_equivalence.rs`).
//!
//! # Plan-determinism contract
//!
//! A plan depends only on
//!
//! 1. the **counts matrix** (the P x P block-size matrix of the
//!    workload), and
//! 2. **resolved parameters**: P, Q, the algorithm spec, and — for
//!    `tuna:auto` — the radix resolved at compile time from the attached
//!    tuning table or the §V-A heuristic;
//!
//! and **never on payload bytes**. Compilation must not inspect, move or
//! fabricate payload data: every algorithm's control flow (round
//! schedules, moving-slot sets, metadata contents, batch boundaries) is a
//! function of block *sizes* only. This is what makes a plan reusable —
//! the same collective issued repeatedly (FFT transposes, selector
//! refinement sweeps) replays a cached plan without re-compilation, keyed
//! by `(algo spec, counts-matrix identity)` in a [`PlanCache`].
//!
//! The threaded engine remains the golden oracle: it is the only executor
//! that moves and validates real payload bytes. Replay is the phantom
//! (size-only) fast path for large-P model sweeps.
//!
//! # Compact interned plan IR
//!
//! Internally a plan is **one arena in structure-of-arrays layout**, not
//! a `Vec<PlanOp>` per rank. Four parallel columns hold the ops of every
//! *distinct* rank program exactly once:
//!
//! * `kinds: Vec<u8>` — the op-kind byte stream (7 codes),
//! * `peers: Vec<u32>` — send/recv peers, stored **rotation-canonical**
//!   (`(peer + P − me) mod P`, i.e. relative to the owning rank),
//! * `tags: Vec<u32>` — message tags (and the phase index of a `Lap`),
//! * `args: Vec<u64>` — byte counts (and the `f64` bit pattern of a
//!   `Compute` charge).
//!
//! A rank's program is an `(offset, len)` window into those columns
//! (`windows`), and `prog_of[r]` maps each rank to its window. Because
//! peers are stored relative to the owner, two ranks whose schedules are
//! equal **up to peer rotation** — every rank of a uniform spread-out
//! plan, for example — canonicalize to byte-identical windows and are
//! **interned** into one shared program; the rotation base needs no
//! storage, it *is* the rank index. Decoding rank `r`'s op at `pc` is a
//! window lookup plus one add-and-conditional-subtract per peer.
//!
//! ## Memory envelope
//!
//! Arena cost per stored op: 1 B kind + 4 B peer + 4 B tag + 8 B arg =
//! **17 B/op**, vs the 24 B of a materialized `PlanOp` (tagged union).
//! Whole-plan footprint:
//!
//! ```text
//! plan_bytes   = 17 · Σ(ops of distinct programs) + 16 · #programs + 4 · P
//! legacy_bytes = 24 · Σ(ops of all ranks)
//! ratio        = plan_bytes / legacy_bytes
//!              ≈ (17 / 24) · (#distinct programs / P)     for large plans
//! ```
//!
//! so plan bytes scale with *distinct* programs, not P: a P-rank uniform
//! linear plan (one canonical program) stores O(P) ops instead of O(P²).
//! Schedules with rank-asymmetric structure (e.g. the recursive-doubling
//! allreduce preamble of `tuna`, whose butterfly partner `me ^ 2^k` is
//! not a rotation) intern nothing and pay only the 17/24 SoA discount.
//!
//! # Parallel compile determinism
//!
//! Compilers emit rank programs in contiguous rank chunks on
//! `std::thread::scope` workers ([`CommPlan::build_parallel`]); each
//! worker packs its chunk into a private [`PlanPack`] and the packs are
//! merged **in ascending rank order** with cross-pack dedup. Interned
//! program indices are therefore assigned in first-encounter rank order
//! — exactly the order the serial single-pack build assigns them — and
//! every column byte, window, and `prog_of` entry is identical whatever
//! the worker count. Two facts make this sound: (1) each rank's op
//! sequence is a pure function of the counts matrix (no emission-order
//! coupling between ranks), and (2) dedup compares canonical column
//! bytes exactly (the 64-bit FNV prefilter only narrows candidates), so
//! merge order cannot change which program is canonical. `compile-threads
//! ∈ {1, 2, 4, 8}` equality is pinned by `tests/plan_ir.rs`.

use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::engine::{prev_pow2, TAG_AR_FOLD, TAG_AR_ROUND, TAG_AR_UNFOLD};
use super::{Phase, PHASES};

/// One engine operation of a compiled plan. Mirrors the `RankCtx` calls an
/// algorithm makes, in program order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanOp {
    /// Non-blocking send (`RankCtx::isend`): `bytes` on the wire to `dst`.
    Send { dst: u32, tag: u32, bytes: u64 },
    /// Non-blocking receive post (`RankCtx::irecv`).
    Recv { src: u32, tag: u32 },
    /// Wait for every send/recv posted since the previous `Wait`
    /// (`RankCtx::waitall` over exactly that pending set).
    Wait,
    /// Modeled local copy charge (`RankCtx::copy`).
    Copy { bytes: u64 },
    /// Modeled local compute charge (`RankCtx::compute`).
    Compute { secs: f64 },
    /// Phase stopwatch restart (`RankCtx::phase_mark`).
    Mark,
    /// Attribute time since the last mark to `phase` and re-mark
    /// (`RankCtx::phase_lap`).
    Lap { phase: Phase },
}

/// One rank's compiled op sequence, materialized. The interned arena is
/// the storage format; `RankPlan` is the builder/patching currency — what
/// compilers emit and what [`CommPlan::rank_plan`] decodes back out.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankPlan {
    pub ops: Vec<PlanOp>,
}

impl RankPlan {
    /// Split this rank's ops at the final `Wait`: `(prefix, suffix)`
    /// where the suffix starts with the last `Wait` (and carries any
    /// trailing ops, e.g. the closing `Lap`). The segmented overlap
    /// driver stitches chunk plans by deferring each chunk's suffix
    /// until after the next chunk's compute — the prefix posts the
    /// chunk's communication, the suffix is the completion point that
    /// user compute can hide. A plan with no `Wait` at all is all
    /// prefix (nothing in flight to hide).
    pub fn split_at_last_wait(&self) -> (&[PlanOp], &[PlanOp]) {
        match self.ops.iter().rposition(|op| matches!(op, PlanOp::Wait)) {
            Some(i) => self.ops.split_at(i),
            None => (&self.ops[..], &[]),
        }
    }
}

// ---- op-kind codes of the arena's byte stream ------------------------------

const OP_SEND: u8 = 0;
const OP_RECV: u8 = 1;
const OP_WAIT: u8 = 2;
const OP_COPY: u8 = 3;
const OP_COMPUTE: u8 = 4;
const OP_MARK: u8 = 5;
const OP_LAP: u8 = 6;

/// Rotate an absolute peer into the owner-relative canonical form:
/// `(peer + p − me) mod p`, branch instead of modulo.
#[inline]
fn rot_out(peer: u32, me: usize, p: usize) -> u32 {
    let pe = peer as usize;
    (if pe >= me { pe - me } else { pe + p - me }) as u32
}

/// Rotate a canonical peer back to absolute for rank `me`.
#[inline]
fn rot_in(canon: u32, me: usize, p: usize) -> u32 {
    let mut v = canon as usize + me;
    if v >= p {
        v -= p;
    }
    v as u32
}

/// Canonicalize one op for rank `me` into its four column cells.
#[inline]
fn canon_op(op: &PlanOp, me: usize, p: usize) -> (u8, u32, u32, u64) {
    match *op {
        PlanOp::Send { dst, tag, bytes } => (OP_SEND, rot_out(dst, me, p), tag, bytes),
        PlanOp::Recv { src, tag } => (OP_RECV, rot_out(src, me, p), tag, 0),
        PlanOp::Wait => (OP_WAIT, 0, 0, 0),
        PlanOp::Copy { bytes } => (OP_COPY, 0, 0, bytes),
        PlanOp::Compute { secs } => (OP_COMPUTE, 0, 0, secs.to_bits()),
        PlanOp::Mark => (OP_MARK, 0, 0, 0),
        PlanOp::Lap { phase } => (OP_LAP, 0, phase.index() as u32, 0),
    }
}

/// Decode one column cell back into the absolute-peer op for rank `me`.
#[inline]
fn decode_op(kind: u8, peer: u32, tag: u32, arg: u64, me: usize, p: usize) -> PlanOp {
    match kind {
        OP_SEND => PlanOp::Send {
            dst: rot_in(peer, me, p),
            tag,
            bytes: arg,
        },
        OP_RECV => PlanOp::Recv {
            src: rot_in(peer, me, p),
            tag,
        },
        OP_WAIT => PlanOp::Wait,
        OP_COPY => PlanOp::Copy { bytes: arg },
        OP_COMPUTE => PlanOp::Compute {
            secs: f64::from_bits(arg),
        },
        OP_MARK => PlanOp::Mark,
        _ => PlanOp::Lap {
            phase: PHASES[tag as usize],
        },
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(FNV_PRIME)
}

/// A compiled collective: the interned SoA arena of every distinct rank
/// program, the rank → program map, and the schedule stats the run
/// report carries (identical on every rank for the shipped algorithms,
/// so they are stored once). See the module header for the IR layout.
#[derive(Clone, Debug, PartialEq)]
pub struct CommPlan {
    /// Total ranks the plan was compiled for.
    pub p: usize,
    /// Ranks per node the plan was compiled for.
    pub q: usize,
    /// Human-readable algorithm name (`AlgoKind::name`).
    pub algo: String,
    /// Peak temporary-buffer occupancy of the compiled schedule.
    pub t_peak: usize,
    /// Communication rounds of the compiled schedule.
    pub rounds: usize,
    /// `prog_of[r]` — index into `windows` of rank `r`'s program.
    prog_of: Vec<u32>,
    /// `(offset, len)` window into the columns, one per distinct program.
    windows: Vec<(usize, usize)>,
    /// Op-kind byte stream of all distinct programs, concatenated.
    kinds: Vec<u8>,
    /// Rotation-canonical peers (`(peer + P − me) mod P`).
    peers: Vec<u32>,
    /// Tags (send/recv) and phase indices (lap).
    tags: Vec<u32>,
    /// Byte counts (send/copy) and `f64` bits (compute).
    args: Vec<u64>,
    /// Cached `Σ rank_len(r)` over all ranks.
    total_ops: usize,
    /// Cached `max rank_len(r)` over all ranks.
    peak_ops: usize,
}

/// Telemetry snapshot of a plan's interned footprint (the `plan-stats`
/// CLI knob and the bench `plan_bytes` column).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanStats {
    /// Σ ops over all ranks (what replay executes).
    pub total_ops: usize,
    /// Distinct interned programs actually stored.
    pub distinct_programs: usize,
    /// Actual arena + table footprint in bytes.
    pub plan_bytes: usize,
    /// What a `Vec<PlanOp>`-per-rank representation would hold.
    pub legacy_bytes: usize,
}

impl PlanStats {
    /// `plan_bytes / legacy_bytes` — the interning ratio (< 1 is a win).
    pub fn ratio(&self) -> f64 {
        if self.legacy_bytes == 0 {
            1.0
        } else {
            self.plan_bytes as f64 / self.legacy_bytes as f64
        }
    }
}

/// Borrowed window of one rank's interned program: the replay hot loop
/// resolves this once per scheduled rank and decodes ops in place.
#[derive(Clone, Copy)]
pub struct ProgView<'a> {
    kinds: &'a [u8],
    peers: &'a [u32],
    tags: &'a [u32],
    args: &'a [u64],
    me: usize,
    p: usize,
}

impl ProgView<'_> {
    #[inline]
    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Decode the op at `pc` for the owning rank.
    #[inline]
    pub fn op(&self, pc: usize) -> PlanOp {
        decode_op(
            self.kinds[pc],
            self.peers[pc],
            self.tags[pc],
            self.args[pc],
            self.me,
            self.p,
        )
    }
}

impl CommPlan {
    /// Pack materialized per-rank op sequences into the interned IR.
    /// `ranks.len()` must equal `p`. This is the serial reference build;
    /// [`CommPlan::build_parallel`] produces bit-identical plans from
    /// chunked workers.
    pub fn from_rank_plans(
        p: usize,
        q: usize,
        algo: String,
        ranks: Vec<RankPlan>,
        t_peak: usize,
        rounds: usize,
    ) -> CommPlan {
        debug_assert_eq!(ranks.len(), p, "one rank plan per rank");
        let mut pack = PlanPack::new(p);
        for (me, rp) in ranks.iter().enumerate() {
            pack.push_rank(me, &rp.ops);
        }
        pack.finish(q, algo, t_peak, rounds)
    }

    /// Build a plan by emitting rank programs on `threads` scoped
    /// workers over contiguous rank chunks, packing incrementally (one
    /// rank's `Vec<PlanOp>` is alive at a time per worker — dense P²-op
    /// plans never materialize wholesale). `emit(r)` must be a pure
    /// function of `r`; the result is identical for every thread count
    /// (see the module header's determinism argument).
    pub(crate) fn build_parallel<F>(
        p: usize,
        q: usize,
        algo: String,
        t_peak: usize,
        rounds: usize,
        threads: usize,
        emit: F,
    ) -> CommPlan
    where
        F: Fn(usize) -> Vec<PlanOp> + Sync,
    {
        let threads = threads.max(1).min(p.max(1));
        if threads <= 1 {
            let mut pack = PlanPack::new(p);
            for me in 0..p {
                let ops = emit(me);
                pack.push_rank(me, &ops);
            }
            return pack.finish(q, algo, t_peak, rounds);
        }
        let emit = &emit;
        let packs: Vec<PlanPack> = std::thread::scope(|s| {
            let handles: Vec<_> = chunk_ranges(p, threads)
                .into_iter()
                .map(|range| {
                    s.spawn(move || {
                        let mut pack = PlanPack::new(p);
                        for me in range {
                            let ops = emit(me);
                            pack.push_rank(me, &ops);
                        }
                        pack
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("plan compile worker panicked"))
                .collect()
        });
        let mut packs = packs.into_iter();
        let mut merged = packs.next().expect("at least one chunk");
        for pk in packs {
            merged.absorb(pk);
        }
        merged.finish(q, algo, t_peak, rounds)
    }

    /// Total op count across all ranks (O(1), cached at build).
    pub fn total_ops(&self) -> usize {
        self.total_ops
    }

    /// Largest single-rank op list (O(1), cached at build).
    pub fn peak_rank_ops(&self) -> usize {
        self.peak_ops
    }

    /// Peak per-rank plan memory in bytes — what `perf_engine` records
    /// as the per-row plan envelope. Kept in materialized-`PlanOp` units
    /// so the envelope stays comparable across plan-IR generations.
    pub fn peak_rank_bytes(&self) -> usize {
        self.peak_ops * std::mem::size_of::<PlanOp>()
    }

    /// Op count of rank `r`'s program (O(1)).
    pub fn rank_len(&self, r: usize) -> usize {
        self.windows[self.prog_of[r] as usize].1
    }

    /// Distinct interned programs stored in the arena.
    pub fn distinct_programs(&self) -> usize {
        self.windows.len()
    }

    /// Actual footprint of the interned IR: column bytes + window table
    /// + the rank → program map.
    pub fn plan_bytes(&self) -> usize {
        self.kinds.len() * (1 + 4 + 4 + 8)
            + self.windows.len() * std::mem::size_of::<(usize, usize)>()
            + self.prog_of.len() * 4
    }

    /// Footprint of the legacy `Vec<PlanOp>`-per-rank representation.
    pub fn legacy_bytes(&self) -> usize {
        self.total_ops * std::mem::size_of::<PlanOp>()
    }

    /// Telemetry snapshot (plan-stats knob, bench columns).
    pub fn stats(&self) -> PlanStats {
        PlanStats {
            total_ops: self.total_ops,
            distinct_programs: self.windows.len(),
            plan_bytes: self.plan_bytes(),
            legacy_bytes: self.legacy_bytes(),
        }
    }

    /// Borrow rank `r`'s program window for in-place decoding — the
    /// replay executor resolves this once per scheduled rank.
    pub fn prog(&self, r: usize) -> ProgView<'_> {
        let (off, len) = self.windows[self.prog_of[r] as usize];
        ProgView {
            kinds: &self.kinds[off..off + len],
            peers: &self.peers[off..off + len],
            tags: &self.tags[off..off + len],
            args: &self.args[off..off + len],
            me: r,
            p: self.p,
        }
    }

    /// Decode rank `r`'s full op sequence back out of the arena —
    /// lossless (rotation canonicalization round-trips exactly). Used by
    /// the threaded segmented driver, plan patching, and tests; the
    /// replay hot loop uses [`CommPlan::prog`] instead.
    pub fn rank_plan(&self, r: usize) -> RankPlan {
        let view = self.prog(r);
        let mut ops = Vec::with_capacity(view.len());
        for pc in 0..view.len() {
            ops.push(view.op(pc));
        }
        RankPlan { ops }
    }

    /// A copy of this plan with the listed ranks' op sequences replaced —
    /// the incremental-patch primitive: when a row diff shows only a few
    /// ranks' schedules changed, `algos::patch_plan` recompiles just those
    /// ranks and splices them in here instead of recompiling O(nnz).
    /// Schedule stats (`t_peak`, `rounds`) carry over; they are 0 for the
    /// linear families patching supports.
    ///
    /// Implemented as a full **repack** (decode every rank, splice,
    /// re-intern): the packed representation stays the canonical one a
    /// fresh compile of the patched workload would build, so patched ==
    /// fresh holds bit-for-bit under `PartialEq`.
    pub fn with_rank_plans(&self, replacements: Vec<(usize, RankPlan)>) -> CommPlan {
        let mut ranks: Vec<RankPlan> = (0..self.p).map(|r| self.rank_plan(r)).collect();
        for (rank, rp) in replacements {
            ranks[rank] = rp;
        }
        CommPlan::from_rank_plans(
            self.p,
            self.q,
            self.algo.clone(),
            ranks,
            self.t_peak,
            self.rounds,
        )
    }
}

/// Contiguous near-equal partition of `0..n` into at most `workers`
/// non-empty ranges (the same split rule the replay sharder uses).
pub(crate) fn chunk_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let workers = workers.max(1).min(n.max(1));
    let base = n / workers;
    let extra = n % workers;
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0usize;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        if len == 0 {
            continue;
        }
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// Incremental interning packer: rank programs are pushed **in ascending
/// rank order**, canonicalized, hashed, and either matched to an
/// existing program (exact column compare; the hash only prefilters) or
/// appended to the arena. Workers pack disjoint rank chunks into private
/// packs; [`PlanPack::absorb`] merges them in chunk order with the same
/// dedup rule, so the merged arena is identical to a serial pack.
#[derive(Debug)]
pub(crate) struct PlanPack {
    p: usize,
    kinds: Vec<u8>,
    peers: Vec<u32>,
    tags: Vec<u32>,
    args: Vec<u64>,
    windows: Vec<(usize, usize)>,
    /// Canonical hash per stored program (carried for cross-pack merge).
    hashes: Vec<u64>,
    by_hash: HashMap<u64, Vec<u32>>,
    prog_of: Vec<u32>,
    total_ops: usize,
    peak_ops: usize,
    // One rank's canonical columns, reused across pushes.
    ck: Vec<u8>,
    cp: Vec<u32>,
    ct: Vec<u32>,
    ca: Vec<u64>,
}

impl PlanPack {
    pub(crate) fn new(p: usize) -> PlanPack {
        PlanPack {
            p,
            kinds: Vec::new(),
            peers: Vec::new(),
            tags: Vec::new(),
            args: Vec::new(),
            windows: Vec::new(),
            hashes: Vec::new(),
            by_hash: HashMap::new(),
            prog_of: Vec::new(),
            total_ops: 0,
            peak_ops: 0,
            ck: Vec::new(),
            cp: Vec::new(),
            ct: Vec::new(),
            ca: Vec::new(),
        }
    }

    /// Canonicalize and intern rank `me`'s op sequence. Must be called
    /// once per rank, ranks ascending.
    pub(crate) fn push_rank(&mut self, me: usize, ops: &[PlanOp]) {
        self.ck.clear();
        self.cp.clear();
        self.ct.clear();
        self.ca.clear();
        let mut h = FNV_OFFSET;
        for op in ops {
            let (k, pe, t, a) = canon_op(op, me, self.p);
            self.ck.push(k);
            self.cp.push(pe);
            self.ct.push(t);
            self.ca.push(a);
            h = mix(h, k as u64 | ((pe as u64) << 8));
            h = mix(h, t as u64);
            h = mix(h, a);
        }
        h = mix(h, ops.len() as u64);

        let pid = match self.find_local(h) {
            Some(pid) => pid,
            None => {
                let off = self.kinds.len();
                let len = self.ck.len();
                self.kinds.extend_from_slice(&self.ck);
                self.peers.extend_from_slice(&self.cp);
                self.tags.extend_from_slice(&self.ct);
                self.args.extend_from_slice(&self.ca);
                let pid = self.windows.len() as u32;
                self.windows.push((off, len));
                self.hashes.push(h);
                self.by_hash.entry(h).or_default().push(pid);
                pid
            }
        };
        self.prog_of.push(pid);
        self.total_ops += ops.len();
        self.peak_ops = self.peak_ops.max(ops.len());
    }

    /// Existing program equal to the scratch columns, if any.
    fn find_local(&self, h: u64) -> Option<u32> {
        let cands = self.by_hash.get(&h)?;
        cands
            .iter()
            .copied()
            .find(|&pid| self.window_matches(pid, &self.ck, &self.cp, &self.ct, &self.ca))
    }

    /// Exact column compare of stored program `pid` against candidate
    /// canonical columns.
    fn window_matches(&self, pid: u32, k: &[u8], pe: &[u32], t: &[u32], a: &[u64]) -> bool {
        let (off, len) = self.windows[pid as usize];
        len == k.len()
            && self.kinds[off..off + len] == *k
            && self.peers[off..off + len] == *pe
            && self.tags[off..off + len] == *t
            && self.args[off..off + len] == *a
    }

    /// Merge `other` (the pack of the next contiguous rank chunk) after
    /// this one: dedup its programs against ours, append the novel ones,
    /// and extend the rank map. Chunk order == rank order keeps the
    /// first-encounter program numbering identical to a serial pack.
    pub(crate) fn absorb(&mut self, other: PlanPack) {
        debug_assert_eq!(self.p, other.p);
        let mut remap: Vec<u32> = Vec::with_capacity(other.windows.len());
        for (pid, &(off, len)) in other.windows.iter().enumerate() {
            let h = other.hashes[pid];
            let k = &other.kinds[off..off + len];
            let pe = &other.peers[off..off + len];
            let t = &other.tags[off..off + len];
            let a = &other.args[off..off + len];
            let existing = self
                .by_hash
                .get(&h)
                .and_then(|c| c.iter().copied().find(|&x| self.window_matches(x, k, pe, t, a)));
            match existing {
                Some(x) => remap.push(x),
                None => {
                    let noff = self.kinds.len();
                    self.kinds.extend_from_slice(k);
                    self.peers.extend_from_slice(pe);
                    self.tags.extend_from_slice(t);
                    self.args.extend_from_slice(a);
                    let npid = self.windows.len() as u32;
                    self.windows.push((noff, len));
                    self.hashes.push(h);
                    self.by_hash.entry(h).or_default().push(npid);
                    remap.push(npid);
                }
            }
        }
        for lp in other.prog_of {
            self.prog_of.push(remap[lp as usize]);
        }
        self.total_ops += other.total_ops;
        self.peak_ops = self.peak_ops.max(other.peak_ops);
    }

    /// Seal the pack into a plan.
    pub(crate) fn finish(self, q: usize, algo: String, t_peak: usize, rounds: usize) -> CommPlan {
        debug_assert_eq!(self.prog_of.len(), self.p, "one program per rank");
        CommPlan {
            p: self.p,
            q,
            algo,
            t_peak,
            rounds,
            prog_of: self.prog_of,
            windows: self.windows,
            kinds: self.kinds,
            peers: self.peers,
            tags: self.tags,
            args: self.args,
            total_ops: self.total_ops,
            peak_ops: self.peak_ops,
        }
    }
}

/// Per-rank plan emitter. Compilers drive one builder per rank with the
/// same call sequence the algorithm would make against a `RankCtx`.
#[derive(Debug)]
pub struct PlanBuilder {
    me: usize,
    p: usize,
    ops: Vec<PlanOp>,
}

impl PlanBuilder {
    pub fn new(me: usize, p: usize) -> PlanBuilder {
        PlanBuilder {
            me,
            p,
            ops: Vec::new(),
        }
    }

    #[inline]
    pub fn send(&mut self, dst: usize, tag: u32, bytes: u64) {
        debug_assert!(dst < self.p);
        self.ops.push(PlanOp::Send {
            dst: dst as u32,
            tag,
            bytes,
        });
    }

    #[inline]
    pub fn recv(&mut self, src: usize, tag: u32) {
        debug_assert!(src < self.p);
        self.ops.push(PlanOp::Recv {
            src: src as u32,
            tag,
        });
    }

    #[inline]
    pub fn wait(&mut self) {
        self.ops.push(PlanOp::Wait);
    }

    #[inline]
    pub fn copy(&mut self, bytes: u64) {
        self.ops.push(PlanOp::Copy { bytes });
    }

    #[inline]
    pub fn compute(&mut self, secs: f64) {
        self.ops.push(PlanOp::Compute { secs });
    }

    #[inline]
    pub fn mark(&mut self) {
        self.ops.push(PlanOp::Mark);
    }

    #[inline]
    pub fn lap(&mut self, phase: Phase) {
        self.ops.push(PlanOp::Lap { phase });
    }

    /// `RankCtx::sendrecv`: send, then recv, then wait on both.
    pub fn sendrecv(&mut self, dst: usize, stag: u32, bytes: u64, src: usize, rtag: u32) {
        self.send(dst, stag, bytes);
        self.recv(src, rtag);
        self.wait();
    }

    /// Emit this rank's op sequence for one scalar allreduce (or barrier)
    /// — the same recursive-doubling schedule with pre/post folding that
    /// `RankCtx::allreduce` executes, 8 wire bytes per message. The
    /// reduced *value* never affects the schedule, so the op kind is
    /// irrelevant here; compilers that need the value (e.g. `tuna:auto`'s
    /// mean) compute it directly from the counts matrix.
    pub fn allreduce(&mut self) {
        let p = self.p;
        if p == 1 {
            return;
        }
        let p2 = prev_pow2(p);
        let extra = p - p2;
        let me = self.me;
        if me >= p2 {
            // Fold into the power-of-two core, then wait for the result.
            self.send(me - p2, TAG_AR_FOLD, 8);
            self.wait();
            self.recv(me - p2, TAG_AR_UNFOLD);
            self.wait();
            return;
        }
        if me < extra {
            self.recv(me + p2, TAG_AR_FOLD);
            self.wait();
        }
        for k in 0..p2.trailing_zeros() {
            let partner = me ^ (1usize << k);
            self.send(partner, TAG_AR_ROUND + k, 8);
            self.recv(partner, TAG_AR_ROUND + k);
            self.wait();
        }
        if me < extra {
            self.send(me + p2, TAG_AR_UNFOLD, 8);
            self.wait();
        }
    }

    pub fn finish(self) -> RankPlan {
        RankPlan { ops: self.ops }
    }
}

/// Keyed cache of compiled plans: `(algo spec, counts-matrix identity)`
/// → shared [`CommPlan`]. Attached to every [`Engine`](super::Engine), so
/// repeated collectives (FFT-style apps, bench iterations, selector
/// refinement) replay without re-compiling. Thread-safe: refinement
/// measures candidates concurrently on one shared engine.
///
/// Capacity is bounded (default [`PlanCache::MAX_PLANS`], configurable
/// via [`PlanCache::with_capacity`] / the `plan-cache-cap` knob) with
/// **LRU** eviction: a hit refreshes the entry's recency, so long-lived
/// serving engines cycling through many tenants keep their hot plans and
/// shed the cold ones. Evictions are counted next to hits/misses.
#[derive(Debug, Default)]
pub struct PlanCache {
    inner: Mutex<CacheInner>,
}

#[derive(Debug)]
struct CacheInner {
    map: HashMap<(String, u64), Arc<CommPlan>>,
    /// Recency order: front = least recently used, back = most recent.
    order: VecDeque<(String, u64)>,
    hits: u64,
    misses: u64,
    evictions: u64,
    cap: usize,
}

impl Default for CacheInner {
    fn default() -> CacheInner {
        CacheInner {
            map: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
            cap: PlanCache::MAX_PLANS,
        }
    }
}

impl PlanCache {
    /// Default retained-plan bound. Large enough for the repeat patterns
    /// that matter (one collective re-issued, a small radix sweep over
    /// one workload); small enough that even worst-case linear plans
    /// stay in the hundreds of MB.
    pub const MAX_PLANS: usize = 8;

    /// A cache bounded at `cap` entries (clamped to >= 1) — the
    /// `plan-cache-cap` serving knob.
    pub fn with_capacity(cap: usize) -> PlanCache {
        PlanCache {
            inner: Mutex::new(CacheInner {
                cap: cap.max(1),
                ..CacheInner::default()
            }),
        }
    }

    /// Acquire the cache lock, recovering from poisoning. Cache
    /// operations never leave `CacheInner` torn mid-update (map and order
    /// are mutated only after all fallible work), so a panic on another
    /// thread holding the lock — e.g. a builder assertion during a
    /// concurrent refinement sweep — must not brick every subsequent run
    /// in-process: we take the inner value and continue, parking_lot
    /// style.
    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Look `key` up, compiling (outside the lock) and inserting on a
    /// miss. Concurrent misses on the same key may both compile; the
    /// first insert wins and the duplicate is dropped — plans are pure
    /// data, so this is only wasted work, never an inconsistency.
    ///
    /// `(p, q)` is the shape the caller is about to execute against. A
    /// key hit whose cached plan was compiled for a different shape is a
    /// hash collision (the 64-bit identity hash is not injective) — the
    /// stale entry is dropped and the plan recompiled, instead of handing
    /// a wrong-shape plan to the replay executor.
    pub fn get_or_try_insert<E>(
        &self,
        key: (String, u64),
        p: usize,
        q: usize,
        build: impl FnOnce() -> Result<CommPlan, E>,
    ) -> Result<Arc<CommPlan>, E> {
        {
            let mut inner = self.lock();
            match inner.map.get(&key).cloned() {
                Some(hit) if hit.p == p && hit.q == q => {
                    inner.hits += 1;
                    Self::touch(&mut inner, &key);
                    return Ok(hit);
                }
                Some(_) => {
                    // Collision: same (spec, hash), different shape.
                    inner.map.remove(&key);
                    inner.order.retain(|k| k != &key);
                }
                None => {}
            }
        }
        let plan = Arc::new(build()?);
        let mut inner = self.lock();
        inner.misses += 1;
        match inner.map.get(&key).cloned() {
            Some(existing) if existing.p == p && existing.q == q => return Ok(existing),
            Some(_) => {
                inner.map.remove(&key);
                inner.order.retain(|k| k != &key);
            }
            None => {}
        }
        Self::insert_locked(&mut inner, key, plan.clone());
        Ok(plan)
    }

    /// Insert (or replace) `plan` under `key` without touching the
    /// hit/miss counters — the path patched plans take, so bench rows
    /// still read `(hits, misses)` as (replays, compiles).
    pub fn insert(&self, key: (String, u64), plan: Arc<CommPlan>) {
        let mut inner = self.lock();
        if inner.map.contains_key(&key) {
            inner.map.insert(key, plan);
            return;
        }
        Self::insert_locked(&mut inner, key, plan);
    }

    /// Refresh `key`'s recency: move it to the back of the LRU order.
    fn touch(inner: &mut CacheInner, key: &(String, u64)) {
        if inner.order.back() == Some(key) {
            return;
        }
        if let Some(pos) = inner.order.iter().position(|k| k == key) {
            inner.order.remove(pos);
            inner.order.push_back(key.clone());
        }
    }

    /// LRU-evict at capacity, then insert a key not currently present.
    fn insert_locked(inner: &mut CacheInner, key: (String, u64), plan: Arc<CommPlan>) {
        while inner.map.len() >= inner.cap {
            match inner.order.pop_front() {
                Some(lru) => {
                    inner.map.remove(&lru);
                    inner.evictions += 1;
                }
                None => break,
            }
        }
        inner.order.push_back(key.clone());
        inner.map.insert(key, plan);
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.lock();
        (inner.hits, inner.misses)
    }

    /// Entries evicted at capacity since construction.
    pub fn evictions(&self) -> u64 {
        self.lock().evictions
    }

    /// The configured retained-plan bound.
    pub fn capacity(&self) -> usize {
        self.lock().cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_from(p: usize, q: usize, builders: Vec<PlanBuilder>) -> CommPlan {
        CommPlan::from_rank_plans(
            p,
            q,
            "x".into(),
            builders.into_iter().map(PlanBuilder::finish).collect(),
            0,
            0,
        )
    }

    #[test]
    fn sendrecv_emits_canonical_triple() {
        let mut b = PlanBuilder::new(0, 4);
        b.sendrecv(1, 7, 100, 3, 7);
        let plan = b.finish();
        assert_eq!(
            plan.ops,
            vec![
                PlanOp::Send {
                    dst: 1,
                    tag: 7,
                    bytes: 100
                },
                PlanOp::Recv { src: 3, tag: 7 },
                PlanOp::Wait,
            ]
        );
    }

    #[test]
    fn allreduce_shapes_by_rank_role() {
        // P = 1: nothing.
        let mut b = PlanBuilder::new(0, 1);
        b.allreduce();
        assert!(b.finish().ops.is_empty());

        // P = 3 (p2 = 2, extra = 1): rank 2 folds into rank 0.
        let ops_of = |me: usize| {
            let mut b = PlanBuilder::new(me, 3);
            b.allreduce();
            b.finish().ops
        };
        let folder = ops_of(2);
        assert_eq!(
            folder[0],
            PlanOp::Send {
                dst: 0,
                tag: TAG_AR_FOLD,
                bytes: 8
            }
        );
        assert_eq!(folder.iter().filter(|o| matches!(o, PlanOp::Wait)).count(), 2);
        // Rank 0 absorbs the fold, runs 1 butterfly round, unfolds back.
        let core = ops_of(0);
        let sends = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Send { .. }))
            .count();
        assert_eq!(sends, 2); // round + unfold
        let recvs = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Recv { .. }))
            .count();
        assert_eq!(recvs, 2); // fold + round
        // Rank 1 runs only the butterfly round.
        let plain = ops_of(1);
        assert_eq!(plain.len(), 3); // send + recv + wait
    }

    #[test]
    fn arena_roundtrip_decodes_every_op_kind() {
        // Every PlanOp variant survives canonicalize → intern → decode.
        let mut b0 = PlanBuilder::new(0, 3);
        b0.mark();
        b0.send(1, 9, 64);
        b0.recv(2, 9);
        b0.wait();
        b0.copy(17);
        b0.compute(0.125);
        b0.lap(Phase::Data);
        let mut b1 = PlanBuilder::new(1, 3);
        b1.copy(1);
        let b2 = PlanBuilder::new(2, 3);
        let want0 = {
            let mut c = PlanBuilder::new(0, 3);
            c.mark();
            c.send(1, 9, 64);
            c.recv(2, 9);
            c.wait();
            c.copy(17);
            c.compute(0.125);
            c.lap(Phase::Data);
            c.finish()
        };
        let plan = plan_from(3, 1, vec![b0, b1, b2]);
        assert_eq!(plan.rank_plan(0), want0);
        assert_eq!(plan.rank_plan(1).ops, vec![PlanOp::Copy { bytes: 1 }]);
        assert!(plan.rank_plan(2).ops.is_empty());
        assert_eq!(plan.total_ops(), 8);
        assert_eq!(plan.peak_rank_ops(), 7);
        // ProgView decodes identically to rank_plan.
        let view = plan.prog(0);
        assert_eq!(view.len(), 7);
        for pc in 0..view.len() {
            assert_eq!(view.op(pc), plan.rank_plan(0).ops[pc]);
        }
    }

    #[test]
    fn rotation_identical_programs_intern_to_one() {
        // A ring schedule (send to me+1, recv from me-1, same sizes) is
        // rotation-identical on every rank → one stored program.
        let p = 16;
        let builders: Vec<PlanBuilder> = (0..p)
            .map(|me| {
                let mut b = PlanBuilder::new(me, p);
                b.mark();
                b.recv((me + p - 1) % p, 1);
                b.send((me + 1) % p, 1, 4096);
                b.wait();
                b.lap(Phase::Data);
                b
            })
            .collect();
        let plan = plan_from(p, 1, builders);
        assert_eq!(plan.distinct_programs(), 1);
        assert!(plan.plan_bytes() * 2 <= plan.legacy_bytes());
        assert!(plan.stats().ratio() < 0.5);
        // Decode stays per-rank absolute.
        for me in 0..p {
            assert_eq!(
                plan.rank_plan(me).ops[2],
                PlanOp::Send {
                    dst: ((me + 1) % p) as u32,
                    tag: 1,
                    bytes: 4096
                }
            );
        }
    }

    #[test]
    fn distinct_programs_stay_distinct() {
        // Different sizes per rank defeat interning; the arena must keep
        // every program and still decode each correctly.
        let p = 8;
        let builders: Vec<PlanBuilder> = (0..p)
            .map(|me| {
                let mut b = PlanBuilder::new(me, p);
                b.send((me + 1) % p, 0, 100 + me as u64);
                b.wait();
                b
            })
            .collect();
        let plan = plan_from(p, 1, builders);
        assert_eq!(plan.distinct_programs(), p);
        for me in 0..p {
            assert_eq!(
                plan.rank_plan(me).ops[0],
                PlanOp::Send {
                    dst: ((me + 1) % p) as u32,
                    tag: 0,
                    bytes: 100 + me as u64
                }
            );
        }
    }

    #[test]
    fn cached_peaks_match_on_demand_scan() {
        // The O(1) cached peak/total equal the old per-call scan over
        // materialized rank plans.
        let p = 9;
        let builders: Vec<PlanBuilder> = (0..p)
            .map(|me| {
                let mut b = PlanBuilder::new(me, p);
                for i in 0..=me {
                    b.copy(i as u64);
                }
                if me % 2 == 0 {
                    b.wait();
                }
                b
            })
            .collect();
        let plan = plan_from(p, 3, builders);
        let scan_total: usize = (0..p).map(|r| plan.rank_plan(r).ops.len()).sum();
        let scan_peak: usize = (0..p).map(|r| plan.rank_plan(r).ops.len()).max().unwrap();
        assert_eq!(plan.total_ops(), scan_total);
        assert_eq!(plan.peak_rank_ops(), scan_peak);
        assert_eq!(
            plan.peak_rank_bytes(),
            scan_peak * std::mem::size_of::<PlanOp>()
        );
        for r in 0..p {
            assert_eq!(plan.rank_len(r), plan.rank_plan(r).ops.len());
        }
    }

    #[test]
    fn build_parallel_matches_serial_for_every_thread_count() {
        let p = 37;
        let emit = |me: usize| {
            let mut b = PlanBuilder::new(me, p);
            b.mark();
            // Half the ranks share a rotation-canonical program.
            if me % 2 == 0 {
                b.send((me + 1) % p, 3, 512);
            } else {
                b.send((me + 2) % p, 4, 100 + me as u64);
            }
            b.wait();
            b.lap(Phase::Data);
            b.finish().ops
        };
        let serial = CommPlan::build_parallel(p, 1, "x".into(), 0, 0, 1, emit);
        for threads in [2usize, 3, 4, 8, 64] {
            let par = CommPlan::build_parallel(p, 1, "x".into(), 0, 0, threads, emit);
            assert_eq!(par, serial, "threads={threads}");
        }
        // And the serial build equals from_rank_plans over the same ops.
        let ranks: Vec<RankPlan> = (0..p).map(|me| RankPlan { ops: emit(me) }).collect();
        assert_eq!(
            CommPlan::from_rank_plans(p, 1, "x".into(), ranks, 0, 0),
            serial
        );
    }

    #[test]
    fn chunk_ranges_cover_contiguously() {
        for (n, w) in [(10usize, 3usize), (4, 8), (1, 1), (16, 4), (7, 7)] {
            let ranges = chunk_ranges(n, w);
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n, "n={n} w={w}");
        }
    }

    #[test]
    fn cache_hits_share_one_plan() {
        let cache = PlanCache::default();
        let key = ("tuna:r=2".to_string(), 42u64);
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan::from_rank_plans(
                2,
                1,
                "tuna(r=2)".into(),
                vec![RankPlan::default(), RankPlan::default()],
                0,
                1,
            ))
        };
        let a = cache.get_or_try_insert(key.clone(), 2, 1, build).unwrap();
        let b = cache.get_or_try_insert(key, 2, 1, build).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (1, 1));
        // A different key compiles fresh.
        let c = cache
            .get_or_try_insert(("tuna:r=2".to_string(), 43u64), 2, 1, build)
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats(), (1, 2));
    }

    #[test]
    fn cache_evicts_oldest_at_capacity() {
        let cache = PlanCache::default();
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan::from_rank_plans(
                1,
                1,
                "x".into(),
                vec![RankPlan::default()],
                0,
                0,
            ))
        };
        for i in 0..PlanCache::MAX_PLANS as u64 + 3 {
            cache
                .get_or_try_insert(("a".to_string(), i), 1, 1, build)
                .unwrap();
        }
        assert_eq!(cache.len(), PlanCache::MAX_PLANS);
        assert_eq!(cache.evictions(), 3);
        // The first keys were evicted; the newest are retained.
        let (hits_before, _) = cache.stats();
        cache
            .get_or_try_insert(("a".to_string(), 0), 1, 1, build)
            .unwrap();
        let (hits_after_old, _) = cache.stats();
        assert_eq!(hits_after_old, hits_before, "evicted key must recompile");
        let newest = PlanCache::MAX_PLANS as u64 + 2;
        cache
            .get_or_try_insert(("a".to_string(), newest), 1, 1, build)
            .unwrap();
        let (hits_after_new, _) = cache.stats();
        assert_eq!(hits_after_new, hits_before + 1, "retained key must hit");
    }

    #[test]
    fn lru_hit_refreshes_recency() {
        // Fill a capacity-2 cache, hit the older key, insert a third:
        // the *unhit* key is the one evicted — LRU, not FIFO.
        let cache = PlanCache::with_capacity(2);
        assert_eq!(cache.capacity(), 2);
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan::from_rank_plans(
                1,
                1,
                "x".into(),
                vec![RankPlan::default()],
                0,
                0,
            ))
        };
        cache.get_or_try_insert(("k".to_string(), 1), 1, 1, build).unwrap();
        cache.get_or_try_insert(("k".to_string(), 2), 1, 1, build).unwrap();
        // Touch key 1: it becomes most recent.
        cache.get_or_try_insert(("k".to_string(), 1), 1, 1, build).unwrap();
        assert_eq!(cache.stats(), (1, 2));
        // Key 3 evicts key 2 (the LRU), not key 1.
        cache.get_or_try_insert(("k".to_string(), 3), 1, 1, build).unwrap();
        assert_eq!(cache.evictions(), 1);
        let (hits, _) = cache.stats();
        cache.get_or_try_insert(("k".to_string(), 1), 1, 1, build).unwrap();
        assert_eq!(cache.stats().0, hits + 1, "touched key must survive");
        let (_, misses) = cache.stats();
        cache.get_or_try_insert(("k".to_string(), 2), 1, 1, build).unwrap();
        assert_eq!(cache.stats().1, misses + 1, "LRU key must have been evicted");
    }

    fn plan_of_shape(p: usize, q: usize) -> CommPlan {
        CommPlan::from_rank_plans(p, q, "x".into(), vec![RankPlan::default(); p], 0, 0)
    }

    #[test]
    fn key_collision_with_different_shape_recompiles() {
        // Two workloads whose (spec, identity_hash) keys collide but that
        // were compiled for different (p, q) must never share a plan.
        let cache = PlanCache::default();
        let key = ("so".to_string(), 7u64);
        let small = cache
            .get_or_try_insert(key.clone(), 2, 1, || Ok::<_, ()>(plan_of_shape(2, 1)))
            .unwrap();
        // Same key, different shape: the stale entry is dropped and the
        // correct-shape plan compiled and returned.
        let big = cache
            .get_or_try_insert(key.clone(), 4, 2, || Ok::<_, ()>(plan_of_shape(4, 2)))
            .unwrap();
        assert!(!Arc::ptr_eq(&small, &big));
        assert_eq!((big.p, big.q), (4, 2));
        assert_eq!(cache.stats(), (0, 2), "a collision is a miss, not a hit");
        // The replacement is now the cached entry for the key.
        let again = cache
            .get_or_try_insert(key, 4, 2, || Ok::<_, ()>(plan_of_shape(4, 2)))
            .unwrap();
        assert!(Arc::ptr_eq(&big, &again));
    }

    #[test]
    fn poisoned_lock_recovers_instead_of_bricking_the_cache() {
        let cache = PlanCache::default();
        cache
            .get_or_try_insert(("k".to_string(), 1), 1, 1, || {
                Ok::<_, ()>(plan_of_shape(1, 1))
            })
            .unwrap();
        // Poison the mutex: panic on another thread while holding it.
        let result = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = cache.inner.lock().unwrap();
                    panic!("boom while holding the cache lock");
                })
                .join()
        });
        assert!(result.is_err(), "the poisoning thread must have panicked");
        // Every cache entry point still works — the poisoned state is
        // taken over, parking_lot style, not propagated as a panic.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 1));
        let hit = cache
            .get_or_try_insert(("k".to_string(), 1), 1, 1, || {
                Ok::<_, ()>(plan_of_shape(1, 1))
            })
            .unwrap();
        assert_eq!((hit.p, hit.q), (1, 1));
        assert_eq!(cache.stats(), (1, 1));
        cache.insert(("k".to_string(), 2), Arc::new(plan_of_shape(1, 1)));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn insert_replaces_in_place_without_counter_bumps() {
        let cache = PlanCache::default();
        let key = ("p".to_string(), 9u64);
        let first = Arc::new(plan_of_shape(2, 1));
        cache.insert(key.clone(), first.clone());
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 0));
        let second = Arc::new(plan_of_shape(2, 1));
        cache.insert(key.clone(), second.clone());
        assert_eq!(cache.len(), 1, "replace in place, no duplicate order entry");
        let got = cache
            .get_or_try_insert(key, 2, 1, || Ok::<_, ()>(plan_of_shape(2, 1)))
            .unwrap();
        assert!(Arc::ptr_eq(&got, &second));
    }

    #[test]
    fn with_rank_plans_splices_only_the_named_ranks() {
        let base = {
            let mut b0 = PlanBuilder::new(0, 3);
            b0.copy(8);
            let mut b1 = PlanBuilder::new(1, 3);
            b1.copy(16);
            let mut b2 = PlanBuilder::new(2, 3);
            b2.copy(24);
            let mut plan = plan_from(3, 1, vec![b0, b1, b2]);
            plan.t_peak = 5;
            plan.rounds = 7;
            plan
        };
        let mut nb = PlanBuilder::new(1, 3);
        nb.copy(999);
        let patched = base.with_rank_plans(vec![(1, nb.finish())]);
        assert_eq!(patched.rank_plan(0), base.rank_plan(0));
        assert_eq!(patched.rank_plan(2), base.rank_plan(2));
        assert_eq!(patched.rank_plan(1).ops, vec![PlanOp::Copy { bytes: 999 }]);
        assert_eq!((patched.t_peak, patched.rounds), (5, 7));
        assert_eq!(patched.algo, base.algo);
        // A repack of the patched rank set is bit-identical to building
        // the patched plan fresh — the patched == fresh contract.
        let fresh = {
            let mut b0 = PlanBuilder::new(0, 3);
            b0.copy(8);
            let mut b1 = PlanBuilder::new(1, 3);
            b1.copy(999);
            let mut b2 = PlanBuilder::new(2, 3);
            b2.copy(24);
            let mut plan = plan_from(3, 1, vec![b0, b1, b2]);
            plan.t_peak = 5;
            plan.rounds = 7;
            plan
        };
        assert_eq!(patched, fresh);
    }

    #[test]
    fn split_at_last_wait_keeps_trailing_ops_with_the_suffix() {
        let mut b = PlanBuilder::new(0, 4);
        b.mark();
        b.send(1, 0, 64);
        b.recv(2, 0);
        b.wait();
        b.send(3, 1, 32);
        b.recv(3, 1);
        b.wait();
        b.lap(Phase::Data);
        let rp = b.finish();
        let (prefix, suffix) = rp.split_at_last_wait();
        assert_eq!(prefix.len(), 6, "prefix ends just before the last Wait");
        assert_eq!(suffix[0], PlanOp::Wait);
        assert_eq!(suffix.len(), 2, "trailing Lap rides with the suffix");
        // Reassembly is the original sequence.
        let mut joined = prefix.to_vec();
        joined.extend_from_slice(suffix);
        assert_eq!(joined, rp.ops);
        // No Wait at all: everything is prefix.
        let mut c = PlanBuilder::new(0, 2);
        c.copy(8);
        let rp = c.finish();
        let (pre, suf) = rp.split_at_last_wait();
        assert_eq!(pre.len(), 1);
        assert!(suf.is_empty());
    }

    #[test]
    fn total_ops_sums_ranks() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.copy(8);
        let mut b1 = PlanBuilder::new(1, 2);
        b1.sendrecv(0, 1, 8, 0, 1);
        let plan = plan_from(2, 1, vec![b0, b1]);
        assert_eq!(plan.total_ops(), 4);
    }
}
