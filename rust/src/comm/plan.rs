//! Compiled communication plans: the schedule of an all-to-all collective
//! as pure data, separated from its execution.
//!
//! A [`CommPlan`] holds, for every rank, the exact sequence of engine
//! operations ([`PlanOp`]) the algorithm would issue against a
//! [`RankCtx`](super::engine::RankCtx): sends/recvs as `(peer, tag,
//! bytes)`, wait points, modeled copy/compute charges, and phase
//! stopwatch marks. Each algorithm family compiles its plan from the
//! counts matrix alone (see `algos::compile_plan`), and the single
//! threaded replay executor ([`super::replay`]) then advances the
//! per-rank [`Clock`](super::clock::Clock)s through the plan without
//! spawning any rank threads — producing makespans, phase breakdowns and
//! counters **bit-identical** to the threaded engine's phantom mode
//! (`tests/replay_equivalence.rs`).
//!
//! # Plan-determinism contract
//!
//! A plan depends only on
//!
//! 1. the **counts matrix** (the P x P block-size matrix of the
//!    workload), and
//! 2. **resolved parameters**: P, Q, the algorithm spec, and — for
//!    `tuna:auto` — the radix resolved at compile time from the attached
//!    tuning table or the §V-A heuristic;
//!
//! and **never on payload bytes**. Compilation must not inspect, move or
//! fabricate payload data: every algorithm's control flow (round
//! schedules, moving-slot sets, metadata contents, batch boundaries) is a
//! function of block *sizes* only. This is what makes a plan reusable —
//! the same collective issued repeatedly (FFT transposes, selector
//! refinement sweeps) replays a cached plan without re-compilation, keyed
//! by `(algo spec, counts-matrix identity)` in a [`PlanCache`].
//!
//! The threaded engine remains the golden oracle: it is the only executor
//! that moves and validates real payload bytes. Replay is the phantom
//! (size-only) fast path for large-P model sweeps.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::engine::{prev_pow2, TAG_AR_FOLD, TAG_AR_ROUND, TAG_AR_UNFOLD};
use super::Phase;

/// One engine operation of a compiled plan. Mirrors the `RankCtx` calls an
/// algorithm makes, in program order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanOp {
    /// Non-blocking send (`RankCtx::isend`): `bytes` on the wire to `dst`.
    Send { dst: u32, tag: u32, bytes: u64 },
    /// Non-blocking receive post (`RankCtx::irecv`).
    Recv { src: u32, tag: u32 },
    /// Wait for every send/recv posted since the previous `Wait`
    /// (`RankCtx::waitall` over exactly that pending set).
    Wait,
    /// Modeled local copy charge (`RankCtx::copy`).
    Copy { bytes: u64 },
    /// Modeled local compute charge (`RankCtx::compute`).
    Compute { secs: f64 },
    /// Phase stopwatch restart (`RankCtx::phase_mark`).
    Mark,
    /// Attribute time since the last mark to `phase` and re-mark
    /// (`RankCtx::phase_lap`).
    Lap { phase: Phase },
}

/// One rank's compiled op sequence.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankPlan {
    pub ops: Vec<PlanOp>,
}

/// A compiled collective: per-rank op sequences plus the schedule stats
/// the run report carries (identical on every rank for the shipped
/// algorithms, so they are stored once).
#[derive(Clone, Debug, PartialEq)]
pub struct CommPlan {
    /// Total ranks the plan was compiled for.
    pub p: usize,
    /// Ranks per node the plan was compiled for.
    pub q: usize,
    /// Human-readable algorithm name (`AlgoKind::name`).
    pub algo: String,
    /// `ranks[r]` is rank `r`'s op sequence.
    pub ranks: Vec<RankPlan>,
    /// Peak temporary-buffer occupancy of the compiled schedule.
    pub t_peak: usize,
    /// Communication rounds of the compiled schedule.
    pub rounds: usize,
}

impl CommPlan {
    /// Total op count across all ranks (plan size telemetry).
    pub fn total_ops(&self) -> usize {
        self.ranks.iter().map(|r| r.ops.len()).sum()
    }

    /// Largest single-rank op list (plan size telemetry).
    pub fn peak_rank_ops(&self) -> usize {
        self.ranks.iter().map(|r| r.ops.len()).max().unwrap_or(0)
    }

    /// Peak per-rank plan memory in bytes — what `perf_engine` records
    /// as the per-row plan envelope.
    pub fn peak_rank_bytes(&self) -> usize {
        self.peak_rank_ops() * std::mem::size_of::<PlanOp>()
    }
}

/// Per-rank plan emitter. Compilers drive one builder per rank with the
/// same call sequence the algorithm would make against a `RankCtx`.
#[derive(Debug)]
pub struct PlanBuilder {
    me: usize,
    p: usize,
    ops: Vec<PlanOp>,
}

impl PlanBuilder {
    pub fn new(me: usize, p: usize) -> PlanBuilder {
        PlanBuilder {
            me,
            p,
            ops: Vec::new(),
        }
    }

    #[inline]
    pub fn send(&mut self, dst: usize, tag: u32, bytes: u64) {
        debug_assert!(dst < self.p);
        self.ops.push(PlanOp::Send {
            dst: dst as u32,
            tag,
            bytes,
        });
    }

    #[inline]
    pub fn recv(&mut self, src: usize, tag: u32) {
        debug_assert!(src < self.p);
        self.ops.push(PlanOp::Recv {
            src: src as u32,
            tag,
        });
    }

    #[inline]
    pub fn wait(&mut self) {
        self.ops.push(PlanOp::Wait);
    }

    #[inline]
    pub fn copy(&mut self, bytes: u64) {
        self.ops.push(PlanOp::Copy { bytes });
    }

    #[inline]
    pub fn compute(&mut self, secs: f64) {
        self.ops.push(PlanOp::Compute { secs });
    }

    #[inline]
    pub fn mark(&mut self) {
        self.ops.push(PlanOp::Mark);
    }

    #[inline]
    pub fn lap(&mut self, phase: Phase) {
        self.ops.push(PlanOp::Lap { phase });
    }

    /// `RankCtx::sendrecv`: send, then recv, then wait on both.
    pub fn sendrecv(&mut self, dst: usize, stag: u32, bytes: u64, src: usize, rtag: u32) {
        self.send(dst, stag, bytes);
        self.recv(src, rtag);
        self.wait();
    }

    /// Emit this rank's op sequence for one scalar allreduce (or barrier)
    /// — the same recursive-doubling schedule with pre/post folding that
    /// `RankCtx::allreduce` executes, 8 wire bytes per message. The
    /// reduced *value* never affects the schedule, so the op kind is
    /// irrelevant here; compilers that need the value (e.g. `tuna:auto`'s
    /// mean) compute it directly from the counts matrix.
    pub fn allreduce(&mut self) {
        let p = self.p;
        if p == 1 {
            return;
        }
        let p2 = prev_pow2(p);
        let extra = p - p2;
        let me = self.me;
        if me >= p2 {
            // Fold into the power-of-two core, then wait for the result.
            self.send(me - p2, TAG_AR_FOLD, 8);
            self.wait();
            self.recv(me - p2, TAG_AR_UNFOLD);
            self.wait();
            return;
        }
        if me < extra {
            self.recv(me + p2, TAG_AR_FOLD);
            self.wait();
        }
        for k in 0..p2.trailing_zeros() {
            let partner = me ^ (1usize << k);
            self.send(partner, TAG_AR_ROUND + k, 8);
            self.recv(partner, TAG_AR_ROUND + k);
            self.wait();
        }
        if me < extra {
            self.send(me + p2, TAG_AR_UNFOLD, 8);
            self.wait();
        }
    }

    pub fn finish(self) -> RankPlan {
        RankPlan { ops: self.ops }
    }
}

/// Keyed cache of compiled plans: `(algo spec, counts-matrix identity)`
/// → shared [`CommPlan`]. Attached to every [`Engine`](super::Engine), so
/// repeated collectives (FFT-style apps, bench iterations, selector
/// refinement) replay without re-compiling. Thread-safe: refinement
/// measures candidates concurrently on one shared engine.
///
/// Capacity is bounded at [`PlanCache::MAX_PLANS`] entries with FIFO
/// eviction: linear-family plans hold O(P²) ops, and sweeps that stream
/// through many one-shot workloads (per-iteration seeds) would otherwise
/// retain every plan they ever compiled.
#[derive(Debug, Default)]
pub struct PlanCache {
    inner: Mutex<CacheInner>,
}

#[derive(Debug, Default)]
struct CacheInner {
    map: HashMap<(String, u64), Arc<CommPlan>>,
    /// Insertion order, for FIFO eviction at capacity.
    order: VecDeque<(String, u64)>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// Retained-plan bound. Large enough for the repeat patterns that
    /// matter (one collective re-issued, a small radix sweep over one
    /// workload); small enough that even worst-case linear plans stay in
    /// the hundreds of MB.
    pub const MAX_PLANS: usize = 8;

    /// Look `key` up, compiling (outside the lock) and inserting on a
    /// miss. Concurrent misses on the same key may both compile; the
    /// first insert wins and the duplicate is dropped — plans are pure
    /// data, so this is only wasted work, never an inconsistency.
    pub fn get_or_try_insert<E>(
        &self,
        key: (String, u64),
        build: impl FnOnce() -> Result<CommPlan, E>,
    ) -> Result<Arc<CommPlan>, E> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(hit) = inner.map.get(&key).cloned() {
                inner.hits += 1;
                return Ok(hit);
            }
        }
        let plan = Arc::new(build()?);
        let mut inner = self.inner.lock().unwrap();
        inner.misses += 1;
        if let Some(existing) = inner.map.get(&key).cloned() {
            return Ok(existing);
        }
        if inner.map.len() >= Self::MAX_PLANS {
            if let Some(oldest) = inner.order.pop_front() {
                inner.map.remove(&oldest);
            }
        }
        inner.order.push_back(key.clone());
        inner.map.insert(key, plan.clone());
        Ok(plan)
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sendrecv_emits_canonical_triple() {
        let mut b = PlanBuilder::new(0, 4);
        b.sendrecv(1, 7, 100, 3, 7);
        let plan = b.finish();
        assert_eq!(
            plan.ops,
            vec![
                PlanOp::Send {
                    dst: 1,
                    tag: 7,
                    bytes: 100
                },
                PlanOp::Recv { src: 3, tag: 7 },
                PlanOp::Wait,
            ]
        );
    }

    #[test]
    fn allreduce_shapes_by_rank_role() {
        // P = 1: nothing.
        let mut b = PlanBuilder::new(0, 1);
        b.allreduce();
        assert!(b.finish().ops.is_empty());

        // P = 3 (p2 = 2, extra = 1): rank 2 folds into rank 0.
        let ops_of = |me: usize| {
            let mut b = PlanBuilder::new(me, 3);
            b.allreduce();
            b.finish().ops
        };
        let folder = ops_of(2);
        assert_eq!(
            folder[0],
            PlanOp::Send {
                dst: 0,
                tag: TAG_AR_FOLD,
                bytes: 8
            }
        );
        assert_eq!(folder.iter().filter(|o| matches!(o, PlanOp::Wait)).count(), 2);
        // Rank 0 absorbs the fold, runs 1 butterfly round, unfolds back.
        let core = ops_of(0);
        let sends = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Send { .. }))
            .count();
        assert_eq!(sends, 2); // round + unfold
        let recvs = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Recv { .. }))
            .count();
        assert_eq!(recvs, 2); // fold + round
        // Rank 1 runs only the butterfly round.
        let plain = ops_of(1);
        assert_eq!(plain.len(), 3); // send + recv + wait
    }

    #[test]
    fn cache_hits_share_one_plan() {
        let cache = PlanCache::default();
        let key = ("tuna:r=2".to_string(), 42u64);
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan {
                p: 2,
                q: 1,
                algo: "tuna(r=2)".into(),
                ranks: vec![RankPlan::default(), RankPlan::default()],
                t_peak: 0,
                rounds: 1,
            })
        };
        let a = cache.get_or_try_insert(key.clone(), build).unwrap();
        let b = cache.get_or_try_insert(key, build).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (1, 1));
        // A different key compiles fresh.
        let c = cache
            .get_or_try_insert(("tuna:r=2".to_string(), 43u64), build)
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats(), (1, 2));
    }

    #[test]
    fn cache_evicts_oldest_at_capacity() {
        let cache = PlanCache::default();
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan {
                p: 1,
                q: 1,
                algo: "x".into(),
                ranks: vec![RankPlan::default()],
                t_peak: 0,
                rounds: 0,
            })
        };
        for i in 0..PlanCache::MAX_PLANS as u64 + 3 {
            cache.get_or_try_insert(("a".to_string(), i), build).unwrap();
        }
        assert_eq!(cache.len(), PlanCache::MAX_PLANS);
        // The first keys were evicted FIFO; the newest are retained.
        let (hits_before, _) = cache.stats();
        cache.get_or_try_insert(("a".to_string(), 0), build).unwrap();
        let (hits_after_old, _) = cache.stats();
        assert_eq!(hits_after_old, hits_before, "evicted key must recompile");
        let newest = PlanCache::MAX_PLANS as u64 + 2;
        cache.get_or_try_insert(("a".to_string(), newest), build).unwrap();
        let (hits_after_new, _) = cache.stats();
        assert_eq!(hits_after_new, hits_before + 1, "retained key must hit");
    }

    #[test]
    fn total_ops_sums_ranks() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.copy(8);
        let mut b1 = PlanBuilder::new(1, 2);
        b1.sendrecv(0, 1, 8, 0, 1);
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        assert_eq!(plan.total_ops(), 4);
    }
}
