//! Compiled communication plans: the schedule of an all-to-all collective
//! as pure data, separated from its execution.
//!
//! A [`CommPlan`] holds, for every rank, the exact sequence of engine
//! operations ([`PlanOp`]) the algorithm would issue against a
//! [`RankCtx`](super::engine::RankCtx): sends/recvs as `(peer, tag,
//! bytes)`, wait points, modeled copy/compute charges, and phase
//! stopwatch marks. Each algorithm family compiles its plan from the
//! counts matrix alone (see `algos::compile_plan`), and the single
//! threaded replay executor ([`super::replay`]) then advances the
//! per-rank [`Clock`](super::clock::Clock)s through the plan without
//! spawning any rank threads — producing makespans, phase breakdowns and
//! counters **bit-identical** to the threaded engine's phantom mode
//! (`tests/replay_equivalence.rs`).
//!
//! # Plan-determinism contract
//!
//! A plan depends only on
//!
//! 1. the **counts matrix** (the P x P block-size matrix of the
//!    workload), and
//! 2. **resolved parameters**: P, Q, the algorithm spec, and — for
//!    `tuna:auto` — the radix resolved at compile time from the attached
//!    tuning table or the §V-A heuristic;
//!
//! and **never on payload bytes**. Compilation must not inspect, move or
//! fabricate payload data: every algorithm's control flow (round
//! schedules, moving-slot sets, metadata contents, batch boundaries) is a
//! function of block *sizes* only. This is what makes a plan reusable —
//! the same collective issued repeatedly (FFT transposes, selector
//! refinement sweeps) replays a cached plan without re-compilation, keyed
//! by `(algo spec, counts-matrix identity)` in a [`PlanCache`].
//!
//! The threaded engine remains the golden oracle: it is the only executor
//! that moves and validates real payload bytes. Replay is the phantom
//! (size-only) fast path for large-P model sweeps.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use super::engine::{prev_pow2, TAG_AR_FOLD, TAG_AR_ROUND, TAG_AR_UNFOLD};
use super::Phase;

/// One engine operation of a compiled plan. Mirrors the `RankCtx` calls an
/// algorithm makes, in program order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanOp {
    /// Non-blocking send (`RankCtx::isend`): `bytes` on the wire to `dst`.
    Send { dst: u32, tag: u32, bytes: u64 },
    /// Non-blocking receive post (`RankCtx::irecv`).
    Recv { src: u32, tag: u32 },
    /// Wait for every send/recv posted since the previous `Wait`
    /// (`RankCtx::waitall` over exactly that pending set).
    Wait,
    /// Modeled local copy charge (`RankCtx::copy`).
    Copy { bytes: u64 },
    /// Modeled local compute charge (`RankCtx::compute`).
    Compute { secs: f64 },
    /// Phase stopwatch restart (`RankCtx::phase_mark`).
    Mark,
    /// Attribute time since the last mark to `phase` and re-mark
    /// (`RankCtx::phase_lap`).
    Lap { phase: Phase },
}

/// One rank's compiled op sequence.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankPlan {
    pub ops: Vec<PlanOp>,
}

impl RankPlan {
    /// Split this rank's ops at the final `Wait`: `(prefix, suffix)`
    /// where the suffix starts with the last `Wait` (and carries any
    /// trailing ops, e.g. the closing `Lap`). The segmented overlap
    /// driver stitches chunk plans by deferring each chunk's suffix
    /// until after the next chunk's compute — the prefix posts the
    /// chunk's communication, the suffix is the completion point that
    /// user compute can hide. A plan with no `Wait` at all is all
    /// prefix (nothing in flight to hide).
    pub fn split_at_last_wait(&self) -> (&[PlanOp], &[PlanOp]) {
        match self.ops.iter().rposition(|op| matches!(op, PlanOp::Wait)) {
            Some(i) => self.ops.split_at(i),
            None => (&self.ops[..], &[]),
        }
    }
}

/// A compiled collective: per-rank op sequences plus the schedule stats
/// the run report carries (identical on every rank for the shipped
/// algorithms, so they are stored once).
#[derive(Clone, Debug, PartialEq)]
pub struct CommPlan {
    /// Total ranks the plan was compiled for.
    pub p: usize,
    /// Ranks per node the plan was compiled for.
    pub q: usize,
    /// Human-readable algorithm name (`AlgoKind::name`).
    pub algo: String,
    /// `ranks[r]` is rank `r`'s op sequence.
    pub ranks: Vec<RankPlan>,
    /// Peak temporary-buffer occupancy of the compiled schedule.
    pub t_peak: usize,
    /// Communication rounds of the compiled schedule.
    pub rounds: usize,
}

impl CommPlan {
    /// Total op count across all ranks (plan size telemetry).
    pub fn total_ops(&self) -> usize {
        self.ranks.iter().map(|r| r.ops.len()).sum()
    }

    /// Largest single-rank op list (plan size telemetry).
    pub fn peak_rank_ops(&self) -> usize {
        self.ranks.iter().map(|r| r.ops.len()).max().unwrap_or(0)
    }

    /// Peak per-rank plan memory in bytes — what `perf_engine` records
    /// as the per-row plan envelope.
    pub fn peak_rank_bytes(&self) -> usize {
        self.peak_rank_ops() * std::mem::size_of::<PlanOp>()
    }

    /// A copy of this plan with the listed ranks' op sequences replaced —
    /// the incremental-patch primitive: when a row diff shows only a few
    /// ranks' schedules changed, `algos::patch_plan` recompiles just those
    /// ranks and splices them in here instead of recompiling O(nnz).
    /// Schedule stats (`t_peak`, `rounds`) carry over; they are 0 for the
    /// linear families patching supports.
    pub fn with_rank_plans(&self, replacements: Vec<(usize, RankPlan)>) -> CommPlan {
        let mut ranks = self.ranks.clone();
        for (rank, rp) in replacements {
            ranks[rank] = rp;
        }
        CommPlan {
            p: self.p,
            q: self.q,
            algo: self.algo.clone(),
            ranks,
            t_peak: self.t_peak,
            rounds: self.rounds,
        }
    }
}

/// Per-rank plan emitter. Compilers drive one builder per rank with the
/// same call sequence the algorithm would make against a `RankCtx`.
#[derive(Debug)]
pub struct PlanBuilder {
    me: usize,
    p: usize,
    ops: Vec<PlanOp>,
}

impl PlanBuilder {
    pub fn new(me: usize, p: usize) -> PlanBuilder {
        PlanBuilder {
            me,
            p,
            ops: Vec::new(),
        }
    }

    #[inline]
    pub fn send(&mut self, dst: usize, tag: u32, bytes: u64) {
        debug_assert!(dst < self.p);
        self.ops.push(PlanOp::Send {
            dst: dst as u32,
            tag,
            bytes,
        });
    }

    #[inline]
    pub fn recv(&mut self, src: usize, tag: u32) {
        debug_assert!(src < self.p);
        self.ops.push(PlanOp::Recv {
            src: src as u32,
            tag,
        });
    }

    #[inline]
    pub fn wait(&mut self) {
        self.ops.push(PlanOp::Wait);
    }

    #[inline]
    pub fn copy(&mut self, bytes: u64) {
        self.ops.push(PlanOp::Copy { bytes });
    }

    #[inline]
    pub fn compute(&mut self, secs: f64) {
        self.ops.push(PlanOp::Compute { secs });
    }

    #[inline]
    pub fn mark(&mut self) {
        self.ops.push(PlanOp::Mark);
    }

    #[inline]
    pub fn lap(&mut self, phase: Phase) {
        self.ops.push(PlanOp::Lap { phase });
    }

    /// `RankCtx::sendrecv`: send, then recv, then wait on both.
    pub fn sendrecv(&mut self, dst: usize, stag: u32, bytes: u64, src: usize, rtag: u32) {
        self.send(dst, stag, bytes);
        self.recv(src, rtag);
        self.wait();
    }

    /// Emit this rank's op sequence for one scalar allreduce (or barrier)
    /// — the same recursive-doubling schedule with pre/post folding that
    /// `RankCtx::allreduce` executes, 8 wire bytes per message. The
    /// reduced *value* never affects the schedule, so the op kind is
    /// irrelevant here; compilers that need the value (e.g. `tuna:auto`'s
    /// mean) compute it directly from the counts matrix.
    pub fn allreduce(&mut self) {
        let p = self.p;
        if p == 1 {
            return;
        }
        let p2 = prev_pow2(p);
        let extra = p - p2;
        let me = self.me;
        if me >= p2 {
            // Fold into the power-of-two core, then wait for the result.
            self.send(me - p2, TAG_AR_FOLD, 8);
            self.wait();
            self.recv(me - p2, TAG_AR_UNFOLD);
            self.wait();
            return;
        }
        if me < extra {
            self.recv(me + p2, TAG_AR_FOLD);
            self.wait();
        }
        for k in 0..p2.trailing_zeros() {
            let partner = me ^ (1usize << k);
            self.send(partner, TAG_AR_ROUND + k, 8);
            self.recv(partner, TAG_AR_ROUND + k);
            self.wait();
        }
        if me < extra {
            self.send(me + p2, TAG_AR_UNFOLD, 8);
            self.wait();
        }
    }

    pub fn finish(self) -> RankPlan {
        RankPlan { ops: self.ops }
    }
}

/// Keyed cache of compiled plans: `(algo spec, counts-matrix identity)`
/// → shared [`CommPlan`]. Attached to every [`Engine`](super::Engine), so
/// repeated collectives (FFT-style apps, bench iterations, selector
/// refinement) replay without re-compiling. Thread-safe: refinement
/// measures candidates concurrently on one shared engine.
///
/// Capacity is bounded at [`PlanCache::MAX_PLANS`] entries with FIFO
/// eviction: linear-family plans hold O(P²) ops, and sweeps that stream
/// through many one-shot workloads (per-iteration seeds) would otherwise
/// retain every plan they ever compiled.
#[derive(Debug, Default)]
pub struct PlanCache {
    inner: Mutex<CacheInner>,
}

#[derive(Debug, Default)]
struct CacheInner {
    map: HashMap<(String, u64), Arc<CommPlan>>,
    /// Insertion order, for FIFO eviction at capacity.
    order: VecDeque<(String, u64)>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// Retained-plan bound. Large enough for the repeat patterns that
    /// matter (one collective re-issued, a small radix sweep over one
    /// workload); small enough that even worst-case linear plans stay in
    /// the hundreds of MB.
    pub const MAX_PLANS: usize = 8;

    /// Acquire the cache lock, recovering from poisoning. Cache
    /// operations never leave `CacheInner` torn mid-update (map and order
    /// are mutated only after all fallible work), so a panic on another
    /// thread holding the lock — e.g. a builder assertion during a
    /// concurrent refinement sweep — must not brick every subsequent run
    /// in-process: we take the inner value and continue, parking_lot
    /// style.
    fn lock(&self) -> MutexGuard<'_, CacheInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Look `key` up, compiling (outside the lock) and inserting on a
    /// miss. Concurrent misses on the same key may both compile; the
    /// first insert wins and the duplicate is dropped — plans are pure
    /// data, so this is only wasted work, never an inconsistency.
    ///
    /// `(p, q)` is the shape the caller is about to execute against. A
    /// key hit whose cached plan was compiled for a different shape is a
    /// hash collision (the 64-bit identity hash is not injective) — the
    /// stale entry is dropped and the plan recompiled, instead of handing
    /// a wrong-shape plan to the replay executor.
    pub fn get_or_try_insert<E>(
        &self,
        key: (String, u64),
        p: usize,
        q: usize,
        build: impl FnOnce() -> Result<CommPlan, E>,
    ) -> Result<Arc<CommPlan>, E> {
        {
            let mut inner = self.lock();
            match inner.map.get(&key).cloned() {
                Some(hit) if hit.p == p && hit.q == q => {
                    inner.hits += 1;
                    return Ok(hit);
                }
                Some(_) => {
                    // Collision: same (spec, hash), different shape.
                    inner.map.remove(&key);
                    inner.order.retain(|k| k != &key);
                }
                None => {}
            }
        }
        let plan = Arc::new(build()?);
        let mut inner = self.lock();
        inner.misses += 1;
        match inner.map.get(&key).cloned() {
            Some(existing) if existing.p == p && existing.q == q => return Ok(existing),
            Some(_) => {
                inner.map.remove(&key);
                inner.order.retain(|k| k != &key);
            }
            None => {}
        }
        Self::insert_locked(&mut inner, key, plan.clone());
        Ok(plan)
    }

    /// Insert (or replace) `plan` under `key` without touching the
    /// hit/miss counters — the path patched plans take, so bench rows
    /// still read `(hits, misses)` as (replays, compiles).
    pub fn insert(&self, key: (String, u64), plan: Arc<CommPlan>) {
        let mut inner = self.lock();
        if inner.map.contains_key(&key) {
            inner.map.insert(key, plan);
            return;
        }
        Self::insert_locked(&mut inner, key, plan);
    }

    /// FIFO-evict at capacity, then insert a key not currently present.
    fn insert_locked(inner: &mut CacheInner, key: (String, u64), plan: Arc<CommPlan>) {
        if inner.map.len() >= Self::MAX_PLANS {
            if let Some(oldest) = inner.order.pop_front() {
                inner.map.remove(&oldest);
            }
        }
        inner.order.push_back(key.clone());
        inner.map.insert(key, plan);
    }

    /// Cached plan count.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.lock();
        (inner.hits, inner.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sendrecv_emits_canonical_triple() {
        let mut b = PlanBuilder::new(0, 4);
        b.sendrecv(1, 7, 100, 3, 7);
        let plan = b.finish();
        assert_eq!(
            plan.ops,
            vec![
                PlanOp::Send {
                    dst: 1,
                    tag: 7,
                    bytes: 100
                },
                PlanOp::Recv { src: 3, tag: 7 },
                PlanOp::Wait,
            ]
        );
    }

    #[test]
    fn allreduce_shapes_by_rank_role() {
        // P = 1: nothing.
        let mut b = PlanBuilder::new(0, 1);
        b.allreduce();
        assert!(b.finish().ops.is_empty());

        // P = 3 (p2 = 2, extra = 1): rank 2 folds into rank 0.
        let ops_of = |me: usize| {
            let mut b = PlanBuilder::new(me, 3);
            b.allreduce();
            b.finish().ops
        };
        let folder = ops_of(2);
        assert_eq!(
            folder[0],
            PlanOp::Send {
                dst: 0,
                tag: TAG_AR_FOLD,
                bytes: 8
            }
        );
        assert_eq!(folder.iter().filter(|o| matches!(o, PlanOp::Wait)).count(), 2);
        // Rank 0 absorbs the fold, runs 1 butterfly round, unfolds back.
        let core = ops_of(0);
        let sends = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Send { .. }))
            .count();
        assert_eq!(sends, 2); // round + unfold
        let recvs = core
            .iter()
            .filter(|o| matches!(o, PlanOp::Recv { .. }))
            .count();
        assert_eq!(recvs, 2); // fold + round
        // Rank 1 runs only the butterfly round.
        let plain = ops_of(1);
        assert_eq!(plain.len(), 3); // send + recv + wait
    }

    #[test]
    fn cache_hits_share_one_plan() {
        let cache = PlanCache::default();
        let key = ("tuna:r=2".to_string(), 42u64);
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan {
                p: 2,
                q: 1,
                algo: "tuna(r=2)".into(),
                ranks: vec![RankPlan::default(), RankPlan::default()],
                t_peak: 0,
                rounds: 1,
            })
        };
        let a = cache.get_or_try_insert(key.clone(), 2, 1, build).unwrap();
        let b = cache.get_or_try_insert(key, 2, 1, build).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (1, 1));
        // A different key compiles fresh.
        let c = cache
            .get_or_try_insert(("tuna:r=2".to_string(), 43u64), 2, 1, build)
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats(), (1, 2));
    }

    #[test]
    fn cache_evicts_oldest_at_capacity() {
        let cache = PlanCache::default();
        let build = || -> Result<CommPlan, ()> {
            Ok(CommPlan {
                p: 1,
                q: 1,
                algo: "x".into(),
                ranks: vec![RankPlan::default()],
                t_peak: 0,
                rounds: 0,
            })
        };
        for i in 0..PlanCache::MAX_PLANS as u64 + 3 {
            cache
                .get_or_try_insert(("a".to_string(), i), 1, 1, build)
                .unwrap();
        }
        assert_eq!(cache.len(), PlanCache::MAX_PLANS);
        // The first keys were evicted FIFO; the newest are retained.
        let (hits_before, _) = cache.stats();
        cache
            .get_or_try_insert(("a".to_string(), 0), 1, 1, build)
            .unwrap();
        let (hits_after_old, _) = cache.stats();
        assert_eq!(hits_after_old, hits_before, "evicted key must recompile");
        let newest = PlanCache::MAX_PLANS as u64 + 2;
        cache
            .get_or_try_insert(("a".to_string(), newest), 1, 1, build)
            .unwrap();
        let (hits_after_new, _) = cache.stats();
        assert_eq!(hits_after_new, hits_before + 1, "retained key must hit");
    }

    fn plan_of_shape(p: usize, q: usize) -> CommPlan {
        CommPlan {
            p,
            q,
            algo: "x".into(),
            ranks: vec![RankPlan::default(); p],
            t_peak: 0,
            rounds: 0,
        }
    }

    #[test]
    fn key_collision_with_different_shape_recompiles() {
        // Two workloads whose (spec, identity_hash) keys collide but that
        // were compiled for different (p, q) must never share a plan.
        let cache = PlanCache::default();
        let key = ("so".to_string(), 7u64);
        let small = cache
            .get_or_try_insert(key.clone(), 2, 1, || Ok::<_, ()>(plan_of_shape(2, 1)))
            .unwrap();
        // Same key, different shape: the stale entry is dropped and the
        // correct-shape plan compiled and returned.
        let big = cache
            .get_or_try_insert(key.clone(), 4, 2, || Ok::<_, ()>(plan_of_shape(4, 2)))
            .unwrap();
        assert!(!Arc::ptr_eq(&small, &big));
        assert_eq!((big.p, big.q), (4, 2));
        assert_eq!(cache.stats(), (0, 2), "a collision is a miss, not a hit");
        // The replacement is now the cached entry for the key.
        let again = cache
            .get_or_try_insert(key, 4, 2, || Ok::<_, ()>(plan_of_shape(4, 2)))
            .unwrap();
        assert!(Arc::ptr_eq(&big, &again));
    }

    #[test]
    fn poisoned_lock_recovers_instead_of_bricking_the_cache() {
        let cache = PlanCache::default();
        cache
            .get_or_try_insert(("k".to_string(), 1), 1, 1, || {
                Ok::<_, ()>(plan_of_shape(1, 1))
            })
            .unwrap();
        // Poison the mutex: panic on another thread while holding it.
        let result = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = cache.inner.lock().unwrap();
                    panic!("boom while holding the cache lock");
                })
                .join()
        });
        assert!(result.is_err(), "the poisoning thread must have panicked");
        // Every cache entry point still works — the poisoned state is
        // taken over, parking_lot style, not propagated as a panic.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 1));
        let hit = cache
            .get_or_try_insert(("k".to_string(), 1), 1, 1, || {
                Ok::<_, ()>(plan_of_shape(1, 1))
            })
            .unwrap();
        assert_eq!((hit.p, hit.q), (1, 1));
        assert_eq!(cache.stats(), (1, 1));
        cache.insert(("k".to_string(), 2), Arc::new(plan_of_shape(1, 1)));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn insert_replaces_in_place_without_counter_bumps() {
        let cache = PlanCache::default();
        let key = ("p".to_string(), 9u64);
        let first = Arc::new(plan_of_shape(2, 1));
        cache.insert(key.clone(), first.clone());
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 0));
        let second = Arc::new(plan_of_shape(2, 1));
        cache.insert(key.clone(), second.clone());
        assert_eq!(cache.len(), 1, "replace in place, no duplicate order entry");
        let got = cache
            .get_or_try_insert(key, 2, 1, || Ok::<_, ()>(plan_of_shape(2, 1)))
            .unwrap();
        assert!(Arc::ptr_eq(&got, &second));
    }

    #[test]
    fn with_rank_plans_splices_only_the_named_ranks() {
        let base = {
            let mut b0 = PlanBuilder::new(0, 3);
            b0.copy(8);
            let mut b1 = PlanBuilder::new(1, 3);
            b1.copy(16);
            let mut b2 = PlanBuilder::new(2, 3);
            b2.copy(24);
            CommPlan {
                p: 3,
                q: 1,
                algo: "x".into(),
                ranks: vec![b0.finish(), b1.finish(), b2.finish()],
                t_peak: 5,
                rounds: 7,
            }
        };
        let mut nb = PlanBuilder::new(1, 3);
        nb.copy(999);
        let patched = base.with_rank_plans(vec![(1, nb.finish())]);
        assert_eq!(patched.ranks[0], base.ranks[0]);
        assert_eq!(patched.ranks[2], base.ranks[2]);
        assert_eq!(patched.ranks[1].ops, vec![PlanOp::Copy { bytes: 999 }]);
        assert_eq!((patched.t_peak, patched.rounds), (5, 7));
        assert_eq!(patched.algo, base.algo);
    }

    #[test]
    fn split_at_last_wait_keeps_trailing_ops_with_the_suffix() {
        let mut b = PlanBuilder::new(0, 4);
        b.mark();
        b.send(1, 0, 64);
        b.recv(2, 0);
        b.wait();
        b.send(3, 1, 32);
        b.recv(3, 1);
        b.wait();
        b.lap(Phase::Data);
        let rp = b.finish();
        let (prefix, suffix) = rp.split_at_last_wait();
        assert_eq!(prefix.len(), 6, "prefix ends just before the last Wait");
        assert_eq!(suffix[0], PlanOp::Wait);
        assert_eq!(suffix.len(), 2, "trailing Lap rides with the suffix");
        // Reassembly is the original sequence.
        let mut joined = prefix.to_vec();
        joined.extend_from_slice(suffix);
        assert_eq!(joined, rp.ops);
        // No Wait at all: everything is prefix.
        let mut c = PlanBuilder::new(0, 2);
        c.copy(8);
        let rp = c.finish();
        let (pre, suf) = rp.split_at_last_wait();
        assert_eq!(pre.len(), 1);
        assert!(suf.is_empty());
    }

    #[test]
    fn total_ops_sums_ranks() {
        let mut b0 = PlanBuilder::new(0, 2);
        b0.copy(8);
        let mut b1 = PlanBuilder::new(1, 2);
        b1.sendrecv(0, 1, 8, 0, 1);
        let plan = CommPlan {
            p: 2,
            q: 1,
            algo: "x".into(),
            ranks: vec![b0.finish(), b1.finish()],
            t_peak: 0,
            rounds: 0,
        };
        assert_eq!(plan.total_ops(), 4);
    }
}
