//! Persistent collective handles (`MPI_Alltoallv_init`-style).
//!
//! A [`PersistentColl`] freezes one collective at construction and
//! replays it cheaply on every [`PersistentColl::start`] call — the
//! amortization pattern the locality-aware MPI literature is explicit
//! about: expensive schedules only pay off in a persistent version.
//!
//! # The freeze contract
//!
//! **Frozen at [`PersistentColl::init`], shared by every `start`:**
//!
//! * the counts matrix identity (`BlockSizes::identity_hash`) and its
//!   `(P, Q)` shape against the engine topology;
//! * the algorithm, parameters fully resolved (`tuna:auto` resolves its
//!   radix once, at compile time, via the engine's tuning table);
//! * the execution mode (`ExecMode::Auto` resolves against the payload
//!   flag here, once) and the payload mode (real / phantom);
//! * replay mode: the compiled [`CommPlan`] and the worker-shard count;
//! * threaded mode: the `senders()` transpose / expectation counts, the
//!   receive fingerprints, and the payload arena (pattern ropes written
//!   once; each call clones zero-copy views);
//! * the load-balanced drain order of `hier` local `balanced` — the
//!   schedule whose O(P·r) enumeration is only worth paying per handle,
//!   and which is therefore *only* constructible through this type
//!   ([`AlgoKind::persistent_only`]).
//!
//! **Allowed to vary per call:** nothing that the schedule can observe.
//! In MPI terms the user may refill the send buffers between starts; our
//! payloads are deterministic patterns, so consecutive `start` calls are
//! bit-identical replays of the same virtual-time run — asserted against
//! the equivalent one-shot execution in `tests/persistent.rs`.
//!
//! **Misuse:** calling [`PersistentColl::start`] with a workload whose
//! identity no longer matches the frozen counts (the classic stale
//! pattern: the app regenerated its distribution and kept the old
//! handle) is a typed [`TunaError`], never a panic or a silent wrong
//! answer.

use std::sync::Arc;

use crate::algos::{
    plan_for, replay_plan_report, run_alltoallv_prepared, AlgoKind, ExecMode, PayloadArena,
    PreparedParts, RunReport,
};
use crate::comm::{CommPlan, Engine};
use crate::error::{Result, TunaError};
use crate::workload::BlockSizes;

/// A collective frozen at init and restartable at plan-replay (or
/// prebuilt-arena) cost. Borrows the engine: handles are as long-lived
/// as the engine that compiled them, and several handles (one per
/// tenant, say) may share one engine and its plan cache.
pub struct PersistentColl<'e> {
    engine: &'e Engine,
    kind: AlgoKind,
    /// The frozen workload (cheap to hold: generator descriptor or
    /// shared CSR storage).
    sizes: BlockSizes,
    identity: u64,
    real_payloads: bool,
    mode: ExecMode,
    /// Replay mode: the compiled plan, fetched through the engine cache
    /// once at init.
    plan: Option<Arc<CommPlan>>,
    /// Replay mode: frozen worker-shard assignment.
    shards: usize,
    /// Threaded mode: expectation counts + fingerprints, built once.
    parts: Option<PreparedParts>,
    /// Threaded mode: prebuilt pattern rows / entry lists.
    arena: Option<Arc<PayloadArena>>,
}

impl<'e> PersistentColl<'e> {
    /// Freeze `kind` over `sizes` on `engine`. All setup happens here:
    /// plan compilation and shard sizing (replay), or transpose,
    /// fingerprints and payload arena (threaded). `mode` resolves
    /// `Auto` against `real_payloads` exactly like the one-shot path.
    pub fn init(
        engine: &'e Engine,
        kind: AlgoKind,
        sizes: &BlockSizes,
        real_payloads: bool,
        mode: ExecMode,
    ) -> Result<PersistentColl<'e>> {
        let p = engine.topo.p();
        if sizes.p() != p {
            return Err(TunaError::config(format!(
                "persistent init: workload is for P={} but engine has P={p}",
                sizes.p()
            )));
        }
        kind.check(p, engine.topo.q())?;

        let mode = mode.resolve(real_payloads);
        let mut handle = PersistentColl {
            engine,
            kind,
            sizes: sizes.clone(),
            identity: sizes.identity_hash(),
            real_payloads,
            mode,
            plan: None,
            shards: 1,
            parts: None,
            arena: None,
        };
        match mode {
            ExecMode::Replay => {
                if real_payloads {
                    return Err(TunaError::config(
                        "persistent init: mode=replay is phantom-only (real payloads \
                         need the threaded oracle); use real=false or mode=threaded",
                    ));
                }
                handle.plan = Some(plan_for(engine, &kind, sizes)?);
                handle.shards = engine
                    .replay_shards
                    .unwrap_or_else(|| crate::comm::replay::auto_shards(p));
            }
            _ => {
                handle.parts = Some(PreparedParts::build(engine, sizes)?);
                handle.arena = Some(Arc::new(PayloadArena::build(sizes, real_payloads)));
            }
        }
        Ok(handle)
    }

    /// Start one collective call. `sizes` is the caller's current
    /// workload and must still match the frozen counts — the handle
    /// checks content identity (not object identity) and returns a
    /// typed error on any drift, so a stale handle can never replay a
    /// schedule against counts it was not compiled for.
    pub fn start(&self, sizes: &BlockSizes) -> Result<RunReport> {
        if sizes.p() != self.sizes.p() || sizes.identity_hash() != self.identity {
            return Err(TunaError::config(format!(
                "persistent start: workload changed shape since init (frozen {} \
                 P={}, got P={}) — counts are frozen at init; re-init the handle \
                 for the new workload",
                self.kind.name(),
                self.sizes.p(),
                sizes.p(),
            )));
        }
        self.start_frozen()
    }

    /// Start one collective call against the frozen workload without a
    /// caller-side counts check (the handle owns the workload, so there
    /// is nothing to drift). This is the hot path the serving engine
    /// drives.
    pub fn start_frozen(&self) -> Result<RunReport> {
        match self.mode {
            ExecMode::Replay => {
                let plan = self.plan.as_ref().expect("replay handle holds a plan");
                replay_plan_report(self.engine, &self.kind, plan, self.shards)
            }
            _ => run_alltoallv_prepared(
                self.engine,
                &self.kind,
                &self.sizes,
                self.real_payloads,
                self.parts.as_ref().expect("threaded handle holds parts"),
                self.arena.as_ref(),
            ),
        }
    }

    /// The frozen algorithm.
    pub fn kind(&self) -> &AlgoKind {
        &self.kind
    }

    /// The resolved execution mode (never `Auto`).
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Replay handles: the frozen compiled plan.
    pub fn plan(&self) -> Option<&Arc<CommPlan>> {
        self.plan.as_ref()
    }

    /// Replay handles: the frozen worker-shard count (0 threads spawned
    /// on the threaded path, where this is 1 and unused).
    pub fn shards(&self) -> usize {
        self.shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::model::MachineProfile;
    use crate::workload::Dist;

    #[test]
    fn init_freezes_and_start_replays() {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(12, 4));
        let sizes = BlockSizes::generate(12, Dist::Uniform { max: 128 }, 5);
        let kind = AlgoKind::Tuna { radix: 2 };
        let h = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Replay).unwrap();
        assert_eq!(h.mode(), ExecMode::Replay);
        assert!(h.plan().is_some());
        let a = h.start(&sizes).unwrap();
        let b = h.start_frozen().unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        // One compile at init; every start hits the frozen Arc without
        // touching the cache again.
        assert_eq!(e.plan_cache.stats(), (0, 1));
    }

    #[test]
    fn stale_counts_is_a_typed_error() {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(8, 2));
        let sizes = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 1);
        let kind = AlgoKind::SpreadOut;
        let h = PersistentColl::init(&e, kind, &sizes, false, ExecMode::Auto).unwrap();
        let drifted = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 2);
        let err = h.start(&drifted).unwrap_err();
        assert!(matches!(err, TunaError::Config(_)), "{err}");
        assert!(err.to_string().contains("frozen at init"), "{err}");
        // The handle itself still works.
        assert!(h.start(&sizes).unwrap().validated);
    }

    #[test]
    fn replay_handles_reject_real_payloads() {
        let e = Engine::new(MachineProfile::test_flat(), Topology::new(8, 2));
        let sizes = BlockSizes::generate(8, Dist::Uniform { max: 64 }, 1);
        let err = PersistentColl::init(&e, AlgoKind::SpreadOut, &sizes, true, ExecMode::Replay)
            .unwrap_err()
            .to_string();
        assert!(err.contains("phantom-only"), "{err}");
        // Auto resolves real payloads to the threaded oracle.
        let h = PersistentColl::init(&e, AlgoKind::SpreadOut, &sizes, true, ExecMode::Auto)
            .unwrap();
        assert_eq!(h.mode(), ExecMode::Threaded);
        assert!(h.start(&sizes).unwrap().validated);
    }
}
