//! The `Counts` abstraction: the P x P block-size ("counts") matrix
//! behind every layer of the crate, in three interchangeable
//! representations sharing one **CountsView** API (see [`super`] for the
//! contract):
//!
//! * **generator-backed lazy rows** ([`Counts::generate`]) — row `src` is
//!   regenerated on demand from `(seed, src)` with an independent PRNG
//!   stream, so no O(P²) memory is ever held and any rank (or the
//!   validator) can reproduce any other rank's row;
//! * **dense rows** ([`Counts::from_dense`]) — explicit `Vec<Vec<u64>>`
//!   for tests and externally supplied workloads;
//! * **CSR-style sparse rows** ([`Counts::from_sparse_rows`]) — only the
//!   structural nonzeros of each row are stored, sorted by destination.
//!
//! # Structural sparsity
//!
//! A matrix entry is **structural** when the pair `(src, dst)` exchanges
//! a block at all. Dense representations (generator-backed dense
//! distributions included) treat *every* destination as structural — a
//! sampled size of 0 still sends a zero-byte block, exactly as before
//! this abstraction existed, so all dense schedules, golden snapshots
//! and replay bit-identity are unchanged. Sparse representations
//! (`Dist::Sparse` generators and CSR rows) treat *absent* entries as
//! "no block": algorithms skip them entirely — no phantom sends, no
//! empty rope segments — and plan op-counts scale with the number of
//! nonzeros instead of P². Sparse structural entries always carry a
//! positive size ([`Counts::from_sparse_rows`] drops explicit zeros), so
//! "structural" and "nonzero" coincide for sparse rows.

use std::sync::{Arc, OnceLock};

use super::distributions::Dist;
use crate::util::prng::Pcg64;

/// Handle on a counts matrix: cheap to clone and share (all backing
/// storage is `Arc`-shared; the lazily built transpose is shared too).
#[derive(Clone, Debug)]
pub struct Counts {
    p: usize,
    repr: Repr,
    /// Sorted structural sender lists per destination, built on first
    /// use (sparse representations only — a dense transpose would be the
    /// O(P²) matrix this type exists to avoid).
    transpose: Arc<OnceLock<Arc<Vec<Vec<u32>>>>>,
}

#[derive(Clone, Debug)]
enum Repr {
    /// Rows regenerated on demand from `(seed, src)`.
    Gen { dist: Dist, seed: u64 },
    /// Materialized dense rows.
    Dense(Arc<Vec<Vec<u64>>>),
    /// CSR-style sparse rows.
    Csr(Arc<CsrCounts>),
    /// Segment `idx` of `k` of a base workload (see [`segment_counts`]):
    /// every base entry of B bytes contributes its `[B*idx/k,
    /// B*(idx+1)/k)` byte range, computed on demand — no O(P²) storage
    /// per segment.
    Seg { base: Arc<Counts>, k: u32, idx: u32 },
}

/// The byte share segment `idx` of `k` takes from a block of `bytes`:
/// the half-open range `[bytes*idx/k, bytes*(idx+1)/k)`. Floor
/// arithmetic makes the shares partition the block exactly —
/// `sum over idx == bytes` — and blocks smaller than `k` simply leave
/// some segments empty (a zero-byte send for dense workloads, no entry
/// at all for sparse ones).
#[inline]
fn segment_share(bytes: u64, k: u32, idx: u32) -> u64 {
    bytes * (idx as u64 + 1) / k as u64 - bytes * idx as u64 / k as u64
}

/// Split a counts matrix into `k` per-destination byte-range segments:
/// segment `idx` of the result carries bytes `[B*idx/k, B*(idx+1)/k)`
/// of every block of B bytes, so the segments sum back to the original
/// matrix entry-for-entry. Each segment is a full-fledged lazy
/// [`Counts`] (any algorithm can compile a plan over it). `k = 1`
/// returns a clone of the input. Structural sparsity is preserved:
/// sparse entries whose share rounds to zero are absent from that
/// segment, dense zero shares remain zero-byte structural sends.
pub fn segment_counts(counts: &Counts, k: usize) -> Vec<Counts> {
    assert!(k >= 1, "segment_counts needs k >= 1");
    if k == 1 {
        return vec![counts.clone()];
    }
    let base = Arc::new(counts.clone());
    (0..k as u32)
        .map(|idx| Counts {
            p: counts.p,
            repr: Repr::Seg { base: Arc::clone(&base), k: k as u32, idx },
            transpose: Arc::new(OnceLock::new()),
        })
        .collect()
}

/// Compressed sparse rows: `entries[indptr[r]..indptr[r+1]]` are row
/// `r`'s structural `(dst, size)` pairs, sorted by `dst`, sizes > 0.
#[derive(Debug)]
struct CsrCounts {
    indptr: Vec<usize>,
    entries: Vec<(u32, u64)>,
}

/// One rank's send row in whichever representation the workload uses —
/// the per-row half of the CountsView API.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CountsRow {
    /// Every destination structural (index = destination).
    Dense(Vec<u64>),
    /// Only the stored `(dst, size)` pairs are structural (sorted by
    /// `dst`, sizes > 0); `p` is the row length.
    Sparse { p: usize, entries: Vec<(u32, u64)> },
}

impl CountsRow {
    /// Row length (the communicator size P).
    pub fn p(&self) -> usize {
        match self {
            CountsRow::Dense(v) => v.len(),
            CountsRow::Sparse { p, .. } => *p,
        }
    }

    /// Number of structural entries: P for dense rows, the stored
    /// nonzero count for sparse rows.
    pub fn nnz(&self) -> usize {
        match self {
            CountsRow::Dense(v) => v.len(),
            CountsRow::Sparse { entries, .. } => entries.len(),
        }
    }

    /// Block size for `dst`: the stored value, or 0 when `(src, dst)` is
    /// structurally absent (sparse rows only — dense rows store every
    /// destination).
    pub fn get(&self, dst: usize) -> u64 {
        match self {
            CountsRow::Dense(v) => v[dst],
            CountsRow::Sparse { entries, .. } => entries
                .binary_search_by_key(&(dst as u32), |&(d, _)| d)
                .map(|i| entries[i].1)
                .unwrap_or(0),
        }
    }

    /// Is `dst` a structural destination of this row?
    pub fn contains(&self, dst: usize) -> bool {
        match self {
            CountsRow::Dense(v) => dst < v.len(),
            CountsRow::Sparse { entries, .. } => entries
                .binary_search_by_key(&(dst as u32), |&(d, _)| d)
                .is_ok(),
        }
    }

    /// Row total in bytes.
    pub fn total(&self) -> u64 {
        match self {
            CountsRow::Dense(v) => v.iter().sum(),
            CountsRow::Sparse { entries, .. } => entries.iter().map(|&(_, s)| s).sum(),
        }
    }

    /// Largest block in the row.
    pub fn max_size(&self) -> u64 {
        match self {
            CountsRow::Dense(v) => v.iter().copied().max().unwrap_or(0),
            CountsRow::Sparse { entries, .. } => {
                entries.iter().map(|&(_, s)| s).max().unwrap_or(0)
            }
        }
    }

    /// Iterate the row's structural `(dst, size)` entries in ascending
    /// destination order. Dense rows yield every destination (including
    /// zero sizes); sparse rows yield only their stored nonzeros.
    pub fn entries(&self) -> CountsRowIter<'_> {
        match self {
            CountsRow::Dense(v) => CountsRowIter::Dense(v.iter().enumerate()),
            CountsRow::Sparse { entries, .. } => CountsRowIter::Sparse(entries.iter()),
        }
    }

    /// Materialize the row densely (index = destination), consuming the
    /// view — dense rows hand over their buffer without copying. The
    /// bridge for dense-only consumers; sparse callers should prefer
    /// [`CountsRow::entries`].
    pub fn into_dense(self) -> Vec<u64> {
        match self {
            CountsRow::Dense(v) => v,
            CountsRow::Sparse { p, entries } => {
                let mut out = vec![0u64; p];
                for (d, s) in entries {
                    out[d as usize] = s;
                }
                out
            }
        }
    }
}

/// Iterator over a row's structural `(dst, size)` entries.
pub enum CountsRowIter<'a> {
    Dense(std::iter::Enumerate<std::slice::Iter<'a, u64>>),
    Sparse(std::slice::Iter<'a, (u32, u64)>),
}

impl Iterator for CountsRowIter<'_> {
    type Item = (usize, u64);

    fn next(&mut self) -> Option<(usize, u64)> {
        match self {
            CountsRowIter::Dense(it) => it.next().map(|(d, &s)| (d, s)),
            CountsRowIter::Sparse(it) => it.next().map(|&(d, s)| (d as usize, s)),
        }
    }
}

impl Counts {
    /// Generator-backed workload: rows are regenerated on demand from
    /// `(seed, src)`. Dense distributions produce dense rows exactly as
    /// they always have; [`Dist::Sparse`] produces structural-sparse
    /// rows (see the module header).
    pub fn generate(p: usize, dist: Dist, seed: u64) -> Counts {
        assert!(p >= 1);
        Counts {
            p,
            repr: Repr::Gen { dist, seed },
            transpose: Arc::new(OnceLock::new()),
        }
    }

    /// Materialized dense rows: every destination structural, zero sizes
    /// included (a zero-size block is still exchanged).
    pub fn from_dense(rows: Vec<Vec<u64>>) -> Counts {
        let p = rows.len();
        assert!(p >= 1, "counts matrix needs at least one row");
        for (src, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), p, "row {src} has {} entries, want {p}", row.len());
        }
        Counts {
            p,
            repr: Repr::Dense(Arc::new(rows)),
            transpose: Arc::new(OnceLock::new()),
        }
    }

    /// CSR-style sparse rows from per-row `(dst, size)` lists. Entries
    /// are sorted by destination, explicit zero sizes are dropped
    /// (structurally absent = no block at all), and duplicate
    /// destinations are rejected.
    pub fn from_sparse_rows(p: usize, rows: Vec<Vec<(usize, u64)>>) -> Counts {
        assert!(p >= 1);
        assert_eq!(rows.len(), p, "need one entry list per source rank");
        let mut indptr = Vec::with_capacity(p + 1);
        let mut entries: Vec<(u32, u64)> = Vec::new();
        indptr.push(0);
        for (src, row) in rows.into_iter().enumerate() {
            let mut cleaned: Vec<(u32, u64)> = row
                .into_iter()
                .filter(|&(_, s)| s > 0)
                .map(|(d, s)| {
                    assert!(d < p, "row {src}: destination {d} out of range (P={p})");
                    (d as u32, s)
                })
                .collect();
            cleaned.sort_unstable_by_key(|&(d, _)| d);
            for w in cleaned.windows(2) {
                assert!(w[0].0 != w[1].0, "row {src}: duplicate destination {}", w[0].0);
            }
            entries.extend(cleaned);
            indptr.push(entries.len());
        }
        Counts {
            p,
            repr: Repr::Csr(Arc::new(CsrCounts { indptr, entries })),
            transpose: Arc::new(OnceLock::new()),
        }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// The generating distribution, for generator-backed workloads.
    pub fn dist(&self) -> Option<&Dist> {
        match &self.repr {
            Repr::Gen { dist, .. } => Some(dist),
            _ => None,
        }
    }

    /// The generator seed, for generator-backed workloads.
    pub fn seed(&self) -> Option<u64> {
        match &self.repr {
            Repr::Gen { seed, .. } => Some(*seed),
            _ => None,
        }
    }

    /// Does this workload use structural sparsity (absent entries send
    /// nothing at all)? Decides which dispatch/compile path every
    /// algorithm takes.
    pub fn is_sparse(&self) -> bool {
        match &self.repr {
            Repr::Gen { dist, .. } => dist.sparse_nnz().is_some(),
            Repr::Dense(_) => false,
            Repr::Csr(_) => true,
            Repr::Seg { base, .. } => base.is_sparse(),
        }
    }

    /// Row `src` in its native representation — the CountsView row.
    pub fn row_view(&self, src: usize) -> CountsRow {
        assert!(src < self.p);
        match &self.repr {
            Repr::Gen { dist, seed } => match dist.sparse_nnz() {
                None => {
                    let mut rng = Pcg64::new(*seed, src as u64);
                    CountsRow::Dense(
                        (0..self.p)
                            .map(|dst| dist.sample(&mut rng, src, dst, self.p))
                            .collect(),
                    )
                }
                Some(nnz) => CountsRow::Sparse {
                    p: self.p,
                    entries: gen_sparse_row(self.p, src, *seed, nnz, dist.sparse_max()),
                },
            },
            Repr::Dense(rows) => CountsRow::Dense(rows[src].clone()),
            Repr::Csr(csr) => CountsRow::Sparse {
                p: self.p,
                entries: csr.entries[csr.indptr[src]..csr.indptr[src + 1]].to_vec(),
            },
            Repr::Seg { base, k, idx } => match base.row_view(src) {
                CountsRow::Dense(v) => CountsRow::Dense(
                    v.into_iter().map(|b| segment_share(b, *k, *idx)).collect(),
                ),
                CountsRow::Sparse { p, entries } => CountsRow::Sparse {
                    p,
                    entries: entries
                        .into_iter()
                        .filter_map(|(d, b)| {
                            let share = segment_share(b, *k, *idx);
                            (share > 0).then_some((d, share))
                        })
                        .collect(),
                },
            },
        }
    }

    /// Sizes of the blocks rank `src` sends to every destination, as a
    /// dense vector (structurally absent entries read as 0) — one
    /// materialization, no intermediate copy. Sparse-aware consumers
    /// should use [`Counts::row_view`] instead.
    pub fn row(&self, src: usize) -> Vec<u64> {
        self.row_view(src).into_dense()
    }

    /// One matrix entry — the CountsView `block(r, d)` accessor
    /// (regenerates the row for generator-backed workloads; use
    /// [`Counts::row_view`] in loops).
    pub fn block(&self, src: usize, dst: usize) -> u64 {
        assert!(dst < self.p);
        self.row_view(src).get(dst)
    }

    /// Alias of [`Counts::block`], kept for existing call sites.
    pub fn size(&self, src: usize, dst: usize) -> u64 {
        self.block(src, dst)
    }

    /// Structural entry count of row `src` (P for dense rows, answered
    /// without sampling them).
    pub fn nnz_row(&self, src: usize) -> usize {
        assert!(src < self.p);
        match &self.repr {
            Repr::Gen { dist, .. } => match dist.sparse_nnz() {
                None => self.p,
                Some(_) => self.row_view(src).nnz(),
            },
            Repr::Dense(_) => self.p,
            Repr::Csr(csr) => csr.indptr[src + 1] - csr.indptr[src],
            Repr::Seg { base, .. } => {
                if base.is_sparse() {
                    // Zero shares are dropped, so the segment's row can
                    // be strictly smaller than the base row's.
                    self.row_view(src).nnz()
                } else {
                    self.p
                }
            }
        }
    }

    /// Total structural entries across the matrix (P² for dense).
    pub fn total_nnz(&self) -> u64 {
        (0..self.p).map(|s| self.nnz_row(s) as u64).sum()
    }

    /// Maximum block size across the whole matrix (the paper's `M`).
    pub fn max_block(&self) -> u64 {
        (0..self.p).map(|s| self.row_view(s).max_size()).max().unwrap_or(0)
    }

    /// Total bytes moved by one all-to-allv.
    pub fn total_bytes(&self) -> u64 {
        (0..self.p).map(|s| self.row_view(s).total()).sum()
    }

    /// Mean block size over all P² pairs (absent entries count as 0, so
    /// dense and sparse workloads are comparable volume-wise). Exact up
    /// to P = 256; beyond that a deterministic 256-row sample is used —
    /// the full matrix would cost O(P²) generator calls per estimate
    /// (1.9 s at P = 16,384), and a 256-row sample of P entries each is
    /// already a ±0.1%-accurate mean for every distribution we ship.
    pub fn mean_size(&self) -> f64 {
        let (total, pairs, _) = self.sampled_sums();
        total as f64 / pairs as f64
    }

    /// Mean size of the *structural* entries alone (equals
    /// [`Counts::mean_size`] for dense workloads). Sampled like
    /// `mean_size`.
    pub fn mean_structural(&self) -> f64 {
        let (total, _, nnz) = self.sampled_sums();
        if nnz == 0 {
            0.0
        } else {
            total as f64 / nnz as f64
        }
    }

    /// Mean structural entries per row (P for dense workloads). Sampled
    /// like `mean_size`.
    pub fn mean_nnz_row(&self) -> f64 {
        let sample_rows = self.p.min(256);
        let stride = (self.p / sample_rows).max(1);
        let mut nnz = 0u64;
        let mut rows = 0u64;
        let mut src = 0usize;
        while src < self.p && rows < sample_rows as u64 {
            nnz += self.nnz_row(src) as u64;
            rows += 1;
            src += stride;
        }
        nnz as f64 / rows as f64
    }

    /// `(mean_size, mean_structural, mean_nnz_row)` from **one** sampled
    /// pass — what [`crate::model::analytic::WorkloadShape`] consumes
    /// instead of three independent row-generating passes.
    pub fn shape_stats(&self) -> (f64, f64, f64) {
        let (total, pairs, nnz) = self.sampled_sums();
        let mean = total as f64 / pairs as f64;
        let mean_nz = if nnz == 0 { 0.0 } else { total as f64 / nnz as f64 };
        let rows = (pairs / self.p as u64).max(1);
        (mean, mean_nz, nnz as f64 / rows as f64)
    }

    /// `(total bytes, pair count, structural count)` over the sample rows.
    fn sampled_sums(&self) -> (u64, u64, u64) {
        let sample_rows = self.p.min(256);
        let stride = (self.p / sample_rows).max(1);
        let mut total = 0u64;
        let mut pairs = 0u64;
        let mut nnz = 0u64;
        let mut src = 0usize;
        while src < self.p && pairs < (sample_rows * self.p) as u64 {
            let row = self.row_view(src);
            total += row.total();
            pairs += self.p as u64;
            nnz += row.nnz() as u64;
            src += stride;
        }
        (total, pairs, nnz)
    }

    /// Per-destination validation fingerprints, computed in O(nnz) time
    /// and O(P) memory: `fp[dst]` folds `(src, size)` over the
    /// *structural* senders of `dst` (every source for dense workloads).
    /// A rank that received its full, correctly-sized block set can
    /// reproduce its fingerprint without the matrix.
    pub fn recv_fingerprints(&self) -> Vec<u64> {
        let mut fp = vec![0u64; self.p];
        for src in 0..self.p {
            for (dst, sz) in self.row_view(src).entries() {
                fp[dst] = fp[dst].wrapping_add(super::fingerprint_one(src, sz));
            }
        }
        fp
    }

    /// Sorted structural sender lists per destination — the transpose of
    /// the structural pattern, built once (O(total nnz) time and memory)
    /// and shared across clones. Receivers use it to know whom to post
    /// receives for. Sparse workloads only: the dense transpose is
    /// "everyone", and materializing it would be the O(P²) structure
    /// this type exists to avoid.
    pub fn senders(&self) -> Arc<Vec<Vec<u32>>> {
        assert!(
            self.is_sparse(),
            "senders(): dense workloads receive from every rank"
        );
        self.transpose
            .get_or_init(|| {
                let mut lists: Vec<Vec<u32>> = vec![Vec::new(); self.p];
                for src in 0..self.p {
                    for (dst, _) in self.row_view(src).entries() {
                        lists[dst].push(src as u32);
                    }
                }
                // Ascending src per destination by construction.
                Arc::new(lists)
            })
            .clone()
    }

    /// Source ranks whose send rows differ from `base`'s, in ascending
    /// order — the input to incremental plan patching
    /// (`algos::patch_plan`): when only a few rows of an iterating
    /// workload change, only those ranks' op sequences need recompiling.
    ///
    /// Returns `None` when the diff is unusable for patching: the shapes
    /// or structural-sparsity classes differ (a dense row and a sparse
    /// row schedule different ops even with equal nonzeros), or more
    /// than `limit` rows changed (at which point a full recompile is
    /// cheaper than diffing). Equal generator descriptors short-circuit
    /// to `Some(vec![])` in O(1) — rows are a pure function of
    /// `(p, dist, seed)`.
    pub fn row_diff(&self, base: &Counts, limit: usize) -> Option<Vec<usize>> {
        if self.p != base.p || self.is_sparse() != base.is_sparse() {
            return None;
        }
        if let (Repr::Gen { dist: da, seed: sa }, Repr::Gen { dist: db, seed: sb }) =
            (&self.repr, &base.repr)
        {
            if da == db && sa == sb {
                return Some(Vec::new());
            }
        }
        let mut changed = Vec::new();
        for src in 0..self.p {
            if self.row_view(src) != base.row_view(src) {
                changed.push(src);
                if changed.len() > limit {
                    return None;
                }
            }
        }
        Some(changed)
    }

    /// A new sparse workload equal to this one except that row `src` is
    /// replaced by `entries` (same cleaning rules as
    /// [`Counts::from_sparse_rows`]: sorted, zero sizes dropped,
    /// duplicates rejected). Materializes generator-backed rows into CSR
    /// — the iterating-workload path that feeds [`Counts::row_diff`].
    pub fn replace_sparse_row(&self, src: usize, entries: Vec<(usize, u64)>) -> Counts {
        assert!(
            self.is_sparse(),
            "replace_sparse_row needs a structurally sparse workload"
        );
        assert!(src < self.p);
        let mut rows: Vec<Vec<(usize, u64)>> = (0..self.p)
            .map(|r| self.row_view(r).entries().collect())
            .collect();
        rows[src] = entries;
        Counts::from_sparse_rows(self.p, rows)
    }

    /// A new dense workload equal to this one except that row `src` is
    /// replaced by `row` (which must have length P).
    pub fn replace_dense_row(&self, src: usize, row: Vec<u64>) -> Counts {
        assert!(
            !self.is_sparse(),
            "replace_dense_row needs a dense workload"
        );
        assert!(src < self.p);
        assert_eq!(row.len(), self.p, "replacement row must have length P");
        let mut rows: Vec<Vec<u64>> = (0..self.p).map(|r| self.row(r)).collect();
        rows[src] = row;
        Counts::from_dense(rows)
    }

    /// Content identity for plan caching, hashed *incrementally through
    /// the row views* — no dense materialization for sparse or CSR
    /// workloads. Generator-backed workloads hash their `(p, dist,
    /// seed)` descriptor (rows are a pure function of it, so equal
    /// descriptors guarantee equal matrices in O(1)); materialized
    /// representations hash their structural entries row by row. The
    /// representation class is part of the identity: a dense row with an
    /// explicit zero schedules a zero-byte send, which an absent sparse
    /// entry does not.
    pub fn identity_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |h: &mut u64, v: u64| {
            *h ^= v;
            *h = h.wrapping_mul(0x100_0000_01b3);
        };
        mix(&mut h, self.p as u64);
        match &self.repr {
            Repr::Gen { dist, seed } => {
                mix(&mut h, 1);
                mix(&mut h, *seed);
                for byte in format!("{dist:?}").bytes() {
                    mix(&mut h, byte as u64);
                }
            }
            Repr::Dense(rows) => {
                mix(&mut h, 2);
                for row in rows.iter() {
                    mix(&mut h, row.len() as u64);
                    for &v in row {
                        mix(&mut h, v);
                    }
                }
            }
            Repr::Csr(csr) => {
                mix(&mut h, 3);
                for src in 0..self.p {
                    let span = &csr.entries[csr.indptr[src]..csr.indptr[src + 1]];
                    mix(&mut h, span.len() as u64);
                    for &(d, s) in span {
                        mix(&mut h, d as u64);
                        mix(&mut h, s);
                    }
                }
            }
            Repr::Seg { base, k, idx } => {
                mix(&mut h, 4);
                mix(&mut h, *k as u64);
                mix(&mut h, *idx as u64);
                mix(&mut h, base.identity_hash());
            }
        }
        h
    }
}

/// Deterministic structural-sparse row: exactly `min(nnz, p)` distinct
/// destinations drawn with Floyd's sampling from `(seed, src)`, sorted,
/// then one uniform size in `[8, max]` (multiple of 8) per destination in
/// sorted order — so the row is a pure function of `(p, src, seed, nnz,
/// max)` and any rank can reproduce any other rank's row.
fn gen_sparse_row(p: usize, src: usize, seed: u64, nnz: usize, max: u64) -> Vec<(u32, u64)> {
    let mut rng = Pcg64::new(seed, src as u64);
    let k = nnz.min(p);
    if k == 0 {
        return Vec::new();
    }
    let mut chosen: std::collections::HashSet<u32> = std::collections::HashSet::with_capacity(k);
    for j in (p - k)..p {
        let t = rng.next_below(j as u64 + 1) as u32;
        if !chosen.insert(t) {
            chosen.insert(j as u32);
        }
    }
    let mut dsts: Vec<u32> = chosen.into_iter().collect();
    dsts.sort_unstable();
    let units = (max / 8).max(1);
    dsts.into_iter()
        .map(|d| (d, 8 * rng.range_inclusive(1, units)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_rows_deterministic_exact_nnz_sorted_unique() {
        let w = Counts::generate(64, Dist::Sparse { nnz: 7, max: 1024 }, 9);
        assert!(w.is_sparse());
        for src in 0..64 {
            let a = w.row_view(src);
            let b = w.row_view(src);
            assert_eq!(a, b, "row {src} must be deterministic");
            assert_eq!(a.nnz(), 7, "row {src}");
            let ents: Vec<(usize, u64)> = a.entries().collect();
            for w2 in ents.windows(2) {
                assert!(w2[0].0 < w2[1].0, "row {src} not sorted/unique: {ents:?}");
            }
            for &(d, s) in &ents {
                assert!(d < 64);
                assert!(s >= 8 && s <= 1024 && s % 8 == 0, "row {src}: size {s}");
            }
        }
        // Different seeds give different patterns.
        let other = Counts::generate(64, Dist::Sparse { nnz: 7, max: 1024 }, 10);
        assert_ne!(w.row_view(0), other.row_view(0));
    }

    #[test]
    fn sparse_nnz_clamps_to_p_and_zero_is_empty() {
        let full = Counts::generate(8, Dist::Sparse { nnz: 100, max: 64 }, 1);
        for src in 0..8 {
            assert_eq!(full.nnz_row(src), 8);
        }
        let empty = Counts::generate(8, Dist::Sparse { nnz: 0, max: 64 }, 1);
        assert_eq!(empty.total_nnz(), 0);
        assert_eq!(empty.total_bytes(), 0);
        assert!(empty.recv_fingerprints().iter().all(|&f| f == 0));
    }

    #[test]
    fn row_dense_view_and_get_agree() {
        let w = Counts::generate(32, Dist::Sparse { nnz: 5, max: 256 }, 3);
        for src in 0..32 {
            let dense = w.row(src);
            let view = w.row_view(src);
            assert_eq!(dense.len(), 32);
            for dst in 0..32 {
                assert_eq!(dense[dst], view.get(dst), "({src},{dst})");
                assert_eq!(dense[dst], w.block(src, dst));
            }
            assert_eq!(dense.iter().sum::<u64>(), view.total());
            assert_eq!(dense.iter().copied().max().unwrap(), view.max_size());
        }
    }

    #[test]
    fn from_sparse_rows_drops_zeros_and_sorts() {
        let w = Counts::from_sparse_rows(
            4,
            vec![
                vec![(3, 16), (1, 8), (2, 0)], // zero dropped, sorted
                vec![],                        // empty send row
                vec![(0, 24)],
                vec![(3, 8)], // self entry allowed
            ],
        );
        assert!(w.is_sparse());
        assert_eq!(w.nnz_row(0), 2);
        assert_eq!(w.nnz_row(1), 0);
        assert_eq!(w.block(0, 2), 0, "explicit zero must be structurally absent");
        assert!(!w.row_view(0).contains(2));
        assert!(w.row_view(0).contains(1));
        assert_eq!(
            w.row_view(0).entries().collect::<Vec<_>>(),
            vec![(1, 8), (3, 16)]
        );
        assert_eq!(w.total_bytes(), 16 + 8 + 24 + 8);
        assert_eq!(w.total_nnz(), 4);
    }

    #[test]
    #[should_panic(expected = "duplicate destination")]
    fn from_sparse_rows_rejects_duplicates() {
        Counts::from_sparse_rows(2, vec![vec![(1, 8), (1, 16)], vec![]]);
    }

    #[test]
    fn transpose_matches_brute_force() {
        let w = Counts::generate(48, Dist::Sparse { nnz: 6, max: 128 }, 17);
        let senders = w.senders();
        for dst in 0..48 {
            let brute: Vec<u32> = (0..48)
                .filter(|&src| w.row_view(src).contains(dst))
                .map(|s| s as u32)
                .collect();
            assert_eq!(senders[dst], brute, "dst {dst}");
        }
        // Shared across clones: same Arc.
        let clone = w.clone();
        assert!(Arc::ptr_eq(&senders, &clone.senders()));
    }

    #[test]
    fn dense_from_rows_counts_every_destination_as_structural() {
        let w = Counts::from_dense(vec![vec![0, 8], vec![16, 0]]);
        assert!(!w.is_sparse());
        assert_eq!(w.nnz_row(0), 2, "dense zero entries stay structural");
        assert_eq!(w.total_nnz(), 4);
        assert_eq!(w.total_bytes(), 24);
        assert_eq!(w.block(0, 0), 0);
    }

    #[test]
    fn identity_hash_is_content_identity() {
        // Same generator descriptor, separately constructed: same hash.
        let a = Counts::generate(16, Dist::Sparse { nnz: 4, max: 64 }, 5);
        let b = Counts::generate(16, Dist::Sparse { nnz: 4, max: 64 }, 5);
        assert_eq!(a.identity_hash(), b.identity_hash());
        // Different seed: different hash.
        let c = Counts::generate(16, Dist::Sparse { nnz: 4, max: 64 }, 6);
        assert_ne!(a.identity_hash(), c.identity_hash());
        // Equal CSR contents, separately built: same hash.
        let r1 = Counts::from_sparse_rows(3, vec![vec![(1, 8)], vec![], vec![(0, 16)]]);
        let r2 = Counts::from_sparse_rows(3, vec![vec![(1, 8), (2, 0)], vec![], vec![(0, 16)]]);
        assert_eq!(r1.identity_hash(), r2.identity_hash());
        // A dense matrix with the same nonzeros is a *different* structure
        // (its zero entries still schedule sends) and must not collide.
        let dense = Counts::from_dense(vec![vec![0, 8, 0], vec![0, 0, 0], vec![16, 0, 0]]);
        assert_ne!(dense.identity_hash(), r1.identity_hash());
    }

    #[test]
    fn sparse_fingerprints_cover_only_structural_senders() {
        let w = Counts::from_sparse_rows(3, vec![vec![(2, 8)], vec![(2, 24)], vec![]]);
        let fp = w.recv_fingerprints();
        assert_eq!(fp[0], 0);
        assert_eq!(fp[1], 0);
        let expect = super::super::fingerprint_one(0, 8)
            .wrapping_add(super::super::fingerprint_one(1, 24));
        assert_eq!(fp[2], expect);
    }

    #[test]
    fn row_diff_reports_changed_rows_and_bails_over_limit() {
        // Identical generator descriptors: O(1) empty diff.
        let a = Counts::generate(32, Dist::Sparse { nnz: 4, max: 64 }, 5);
        let b = Counts::generate(32, Dist::Sparse { nnz: 4, max: 64 }, 5);
        assert_eq!(a.row_diff(&b, 8), Some(vec![]));
        // One replaced row: exactly that row reported.
        let patched = a.replace_sparse_row(7, vec![(0, 8), (31, 16)]);
        assert_eq!(patched.row_diff(&a, 8), Some(vec![7]));
        assert_eq!(a.row_diff(&patched, 8), Some(vec![7]), "diff is symmetric");
        // Over the limit: unusable.
        let other_seed = Counts::generate(32, Dist::Sparse { nnz: 4, max: 64 }, 6);
        assert_eq!(other_seed.row_diff(&a, 2), None);
        // Shape or sparsity-class mismatch: unusable.
        let smaller = Counts::generate(16, Dist::Sparse { nnz: 4, max: 64 }, 5);
        assert_eq!(smaller.row_diff(&a, 8), None);
        let dense = Counts::generate(32, Dist::Uniform { max: 64 }, 5);
        assert_eq!(dense.row_diff(&a, 8), None);
        // Dense diffs work the same way.
        let d = Counts::from_dense(vec![vec![1, 2], vec![3, 4]]);
        let d2 = d.replace_dense_row(1, vec![9, 9]);
        assert_eq!(d2.row_diff(&d, 8), Some(vec![1]));
        assert_eq!(d.row_diff(&d, 8), Some(vec![]));
    }

    #[test]
    fn replace_rows_keep_other_rows_and_clean_entries() {
        let w = Counts::from_sparse_rows(3, vec![vec![(1, 8)], vec![(2, 16)], vec![]]);
        let r = w.replace_sparse_row(1, vec![(0, 24), (2, 0)]);
        assert_eq!(r.row_view(0), w.row_view(0));
        assert_eq!(r.row_view(2), w.row_view(2));
        assert_eq!(
            r.row_view(1).entries().collect::<Vec<_>>(),
            vec![(0, 24)],
            "explicit zero dropped"
        );
        // The replacement is a distinct workload with its own identity.
        assert_ne!(r.identity_hash(), w.identity_hash());

        let d = Counts::from_dense(vec![vec![0, 8], vec![16, 0]]);
        let d2 = d.replace_dense_row(0, vec![4, 4]);
        assert_eq!(d2.row(0), vec![4, 4]);
        assert_eq!(d2.row(1), d.row(1));
    }

    #[test]
    fn segment_counts_partitions_every_entry_exactly() {
        // Dense: shares sum back to the base entry-for-entry, zero
        // shares stay structural (zero-byte sends).
        let dense = Counts::generate(24, Dist::Uniform { max: 300 }, 11);
        for k in [1usize, 2, 3, 5, 8] {
            let segs = segment_counts(&dense, k);
            assert_eq!(segs.len(), k);
            for src in 0..24 {
                let base_row = dense.row(src);
                let mut sum = vec![0u64; 24];
                for seg in &segs {
                    assert!(!seg.is_sparse());
                    assert_eq!(seg.nnz_row(src), 24, "dense segments stay dense");
                    for (d, s) in seg.row_view(src).entries() {
                        sum[d] += s;
                    }
                }
                assert_eq!(sum, base_row, "k={k} src={src}");
            }
        }
        // k = 1 is the base workload itself (same identity).
        let one = segment_counts(&dense, 1);
        assert_eq!(one[0].identity_hash(), dense.identity_hash());

        // Sparse: zero shares are structurally absent, nonzero shares
        // keep the structural == nonzero invariant, totals partition.
        let sparse = Counts::generate(32, Dist::Sparse { nnz: 5, max: 64 }, 7);
        let k = 4;
        let segs = segment_counts(&sparse, k);
        let mut total = 0u64;
        for seg in &segs {
            assert!(seg.is_sparse());
            for src in 0..32 {
                for (_, s) in seg.row_view(src).entries() {
                    assert!(s > 0, "sparse segment carries a zero entry");
                }
                assert_eq!(seg.nnz_row(src), seg.row_view(src).nnz());
            }
            total += seg.total_bytes();
        }
        assert_eq!(total, sparse.total_bytes());

        // Blocks smaller than k leave later segments empty: an 8-byte
        // block split 16 ways puts one byte in the first 8 segments.
        let tiny = Counts::from_dense(vec![vec![8, 0], vec![0, 8]]);
        let segs = segment_counts(&tiny, 16);
        let nonempty = segs.iter().filter(|s| s.total_bytes() > 0).count();
        assert_eq!(nonempty, 8);
        assert_eq!(segs.iter().map(|s| s.total_bytes()).sum::<u64>(), 16);

        // Segments are distinct cache identities.
        let a = segment_counts(&dense, 3);
        assert_ne!(a[0].identity_hash(), a[1].identity_hash());
        assert_ne!(a[0].identity_hash(), dense.identity_hash());
    }

    #[test]
    fn mean_helpers_distinguish_structural_density() {
        let w = Counts::generate(64, Dist::Sparse { nnz: 8, max: 800 }, 2);
        let mean = w.mean_size();
        let nz = w.mean_structural();
        let nnz = w.mean_nnz_row();
        assert!((nnz - 8.0).abs() < 1e-9, "nnz_row {nnz}");
        // Per-pair mean is the structural mean diluted by sparsity.
        assert!((mean - nz * 8.0 / 64.0).abs() < 1e-6 * nz.max(1.0));
        assert!(nz >= 8.0 && nz <= 800.0);
        // Dense workloads: structural mean == pair mean, nnz_row == P.
        let d = Counts::generate(16, Dist::Uniform { max: 256 }, 3);
        assert!((d.mean_structural() - d.mean_size()).abs() < 1e-12);
        assert!((d.mean_nnz_row() - 16.0).abs() < 1e-12);
    }
}
