//! Synthetic directed graphs for the transitive-closure application
//! (§VI-B). The paper uses a 1,014,951-edge SuiteSparse graph; offline we
//! generate scale-free digraphs with the same qualitative properties
//! (power-law out-degree, one giant component, long path chains).

use crate::util::prng::Pcg64;

/// An edge list over vertices `0..n`.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    pub edges: Vec<(u32, u32)>,
}

impl Graph {
    /// Preferential-attachment style digraph: `n` vertices, ~`m_per_v`
    /// out-edges per vertex with power-law target popularity, plus a
    /// backbone path so the transitive closure has depth.
    pub fn scale_free(n: usize, m_per_v: usize, seed: u64) -> Graph {
        assert!(n >= 2);
        let mut rng = Pcg64::new(seed, 0xface);
        let mut edges = Vec::with_capacity(n * m_per_v + n);
        // Backbone: a path 0 -> 1 -> ... so closure depth ~ n.
        for v in 0..n - 1 {
            edges.push((v as u32, v as u32 + 1));
        }
        // Power-law extra edges: target ~ n * u^3 biases toward low ids
        // (the "celebrities"), source uniform.
        for _ in 0..n * m_per_v {
            let src = rng.next_below(n as u64) as u32;
            let u = rng.next_f64();
            let dst = ((n as f64) * u * u * u) as u32;
            let dst = dst.min(n as u32 - 1);
            if src != dst {
                edges.push((src, dst));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        Graph { n, edges }
    }

    /// A simple chain (for exact-answer tests: TC of a chain of n vertices
    /// has n*(n-1)/2 pairs).
    pub fn chain(n: usize) -> Graph {
        Graph {
            n,
            edges: (0..n as u32 - 1).map(|v| (v, v + 1)).collect(),
        }
    }

    /// A binary tree rooted at 0 (TC size computable in closed form).
    pub fn binary_tree(depth: u32) -> Graph {
        let n = (1usize << (depth + 1)) - 1;
        let mut edges = Vec::new();
        for v in 0..n {
            for c in [2 * v + 1, 2 * v + 2] {
                if c < n {
                    edges.push((v as u32, c as u32));
                }
            }
        }
        Graph { n, edges }
    }

    pub fn out_degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.n];
        for &(s, _) in &self.edges {
            d[s as usize] += 1;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_free_shape() {
        let g = Graph::scale_free(500, 4, 11);
        assert!(g.edges.len() >= 500 - 1);
        let degs = g.out_degrees();
        let max_deg = *degs.iter().max().unwrap();
        let mean_deg = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        assert!(
            max_deg as f64 > 2.0 * mean_deg,
            "degree distribution should be skewed (max {max_deg}, mean {mean_deg})"
        );
        // Deterministic.
        let g2 = Graph::scale_free(500, 4, 11);
        assert_eq!(g.edges, g2.edges);
    }

    #[test]
    fn chain_and_tree() {
        let c = Graph::chain(5);
        assert_eq!(c.edges.len(), 4);
        let t = Graph::binary_tree(3);
        assert_eq!(t.n, 15);
        assert_eq!(t.edges.len(), 14);
    }

    #[test]
    fn no_self_loops_or_dups() {
        let g = Graph::scale_free(200, 3, 5);
        let mut seen = std::collections::HashSet::new();
        for &(s, d) in &g.edges {
            assert_ne!(s, d);
            assert!(seen.insert((s, d)));
        }
    }
}
