//! Block-size distributions from the paper's evaluation.
//!
//! * `Uniform` — §V-A: sizes uniformly sampled in [0, S] as FP64 vectors
//!   (multiples of 8 bytes), average S/2.
//! * `Normal` — §VI-C Fig. 16(a): Gaussian (paper: mean 1000, stddev 240),
//!   clamped to [0, max].
//! * `PowerLaw` — §VI-C Fig. 16(b): heavy skew, "rarity of large-sized
//!   data blocks and sparsity" — most blocks tiny, few large. The paper's
//!   generator (exponent 0.95) is not specified precisely; we use the
//!   inverse-transform `size = max * u^skew` which reproduces the plotted
//!   histogram shape (documented substitution, DESIGN.md §2).
//! * `Const` — uniform all-to-all (for the Bruck lineage tests).
//! * `FftN1` / `FftN2` — §VI-A FFT decompositions (see [`super::fft`]).
//! * `Sparse` — structurally sparse traffic (relational algebra / graph
//!   workloads): exactly `nnz` destinations per row, the rest absent —
//!   not zero-*sized*, absent: no block is exchanged at all. Rows are
//!   generated whole by [`super::Counts::row_view`] (Floyd sampling of
//!   destinations plus uniform sizes in `[8, max]`), never through
//!   per-entry [`Dist::sample`].

use crate::util::prng::Pcg64;

/// A block-size distribution. `sample` must be deterministic in
/// `(rng-state, src, dst, p)` — rows are regenerated on demand.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dist {
    /// Uniform in [0, max], rounded down to a multiple of 8 (FP64 vectors).
    Uniform { max: u64 },
    /// Gaussian clamped to [0, max].
    Normal { mean: f64, stddev: f64, max: u64 },
    /// `max * u^skew` — heavy-tailed toward small blocks for skew > 1.
    PowerLaw { max: u64, skew: f64 },
    /// Every block the same size (uniform all-to-all).
    Const { size: u64 },
    /// FFT worker distribution 𝒩₁ (§VI-A).
    FftN1,
    /// FFT near-uniform distribution 𝒩₂ (§VI-A).
    FftN2,
    /// Structurally sparse rows: exactly `nnz` destinations per row
    /// (clamped to P), sizes uniform in `[8, max]`; absent pairs send
    /// nothing at all. Spec `sparse:nnz=K[,max=S]`.
    Sparse { nnz: usize, max: u64 },
}

impl Dist {
    /// Paper defaults for the normal distribution (Fig. 16a).
    pub fn normal_default() -> Dist {
        Dist::Normal {
            mean: 1000.0,
            stddev: 240.0,
            max: 1024,
        }
    }

    /// Paper defaults for the power-law distribution (Fig. 16b).
    pub fn powerlaw_default() -> Dist {
        Dist::PowerLaw { max: 1024, skew: 4.0 }
    }

    pub fn sample(&self, rng: &mut Pcg64, src: usize, dst: usize, p: usize) -> u64 {
        match *self {
            Dist::Uniform { max } => {
                let units = max / 8;
                8 * rng.range_inclusive(0, units)
            }
            Dist::Normal { mean, stddev, max } => {
                let v = mean + stddev * rng.next_gaussian();
                (v.max(0.0) as u64).min(max)
            }
            Dist::PowerLaw { max, skew } => {
                let u = rng.next_f64();
                (max as f64 * u.powf(skew)) as u64
            }
            Dist::Const { size } => {
                // Burn one sample to keep streams aligned across dists.
                let _ = rng.next_u64();
                size
            }
            Dist::FftN1 => super::fft::n1_size(src, dst, p, rng),
            Dist::FftN2 => super::fft::n2_size(src, dst, p, rng),
            Dist::Sparse { .. } => unreachable!(
                "sparse rows are generated whole by Counts::row_view, \
                 never through per-entry sampling"
            ),
        }
    }

    /// Target structural entries per row for sparse distributions;
    /// `None` for the dense families. This is what routes a workload
    /// down the structural-sparse dispatch/compile paths.
    pub fn sparse_nnz(&self) -> Option<usize> {
        match *self {
            Dist::Sparse { nnz, .. } => Some(nnz),
            _ => None,
        }
    }

    /// Upper size bound of the sparse generator (8 when unset/smaller).
    pub fn sparse_max(&self) -> u64 {
        match *self {
            Dist::Sparse { max, .. } => max.max(8),
            _ => 0,
        }
    }

    /// A heavy-tailed companion with the same upper bound: what the
    /// selector's refinement stage runs to stress shortlisted candidates
    /// under skew (Fig. 16(b)-style workloads). Already-skewed
    /// distributions are their own companion.
    pub fn skewed_companion(&self) -> Dist {
        match *self {
            Dist::PowerLaw { .. } => *self,
            Dist::Uniform { max } | Dist::Normal { max, .. } => Dist::PowerLaw {
                max: max.max(8),
                skew: 4.0,
            },
            Dist::Const { size } => Dist::PowerLaw {
                max: size.max(8),
                skew: 4.0,
            },
            // The FFT distributions are structural; stress them with the
            // paper's default power law.
            Dist::FftN1 | Dist::FftN2 => Dist::powerlaw_default(),
            // Structural sparsity already is the extreme-skew regime (the
            // paper's graph/relational workloads); it is its own
            // companion.
            Dist::Sparse { .. } => *self,
        }
    }

    /// Short name for tables and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            Dist::Uniform { .. } => "uniform",
            Dist::Normal { .. } => "normal",
            Dist::PowerLaw { .. } => "powerlaw",
            Dist::Const { .. } => "const",
            Dist::FftN1 => "fft-n1",
            Dist::FftN2 => "fft-n2",
            Dist::Sparse { .. } => "sparse",
        }
    }

    /// Parse `"uniform:1024"`, `"normal"`, `"powerlaw"`, `"const:64"`,
    /// `"fft-n1"`, `"fft-n2"`, `"sparse:nnz=16"`,
    /// `"sparse:nnz=16,max=2048"`.
    pub fn parse(s: &str) -> Option<Dist> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "uniform" => Some(Dist::Uniform {
                max: arg?.parse().ok()?,
            }),
            "normal" => Some(Dist::normal_default()),
            "powerlaw" => Some(Dist::powerlaw_default()),
            "const" => Some(Dist::Const {
                size: arg?.parse().ok()?,
            }),
            "fft-n1" => Some(Dist::FftN1),
            "fft-n2" => Some(Dist::FftN2),
            "sparse" => {
                let mut nnz: Option<usize> = None;
                let mut max: u64 = 1024;
                for kv in arg?.split(',') {
                    let (k, v) = kv.split_once('=')?;
                    match k {
                        "nnz" => nnz = Some(v.parse().ok()?),
                        "max" => max = v.parse().ok()?,
                        _ => return None,
                    }
                }
                Some(Dist::Sparse {
                    nnz: nnz?,
                    max: max.max(8),
                })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_many(d: Dist, n: usize) -> Vec<u64> {
        let mut rng = Pcg64::new(1, 1);
        (0..n).map(|i| d.sample(&mut rng, 0, i % 16, 16)).collect()
    }

    #[test]
    fn uniform_bounds_and_alignment() {
        let xs = sample_many(Dist::Uniform { max: 1024 }, 5000);
        assert!(xs.iter().all(|&x| x <= 1024 && x % 8 == 0));
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        assert!((mean - 512.0).abs() < 30.0, "mean {mean} should be ~S/2");
        assert!(xs.iter().any(|&x| x == 0) || xs.iter().any(|&x| x < 64));
    }

    #[test]
    fn normal_clamped() {
        let xs = sample_many(Dist::normal_default(), 5000);
        assert!(xs.iter().all(|&x| x <= 1024));
        let mean = xs.iter().sum::<u64>() as f64 / xs.len() as f64;
        // Mean 1000 clamped at 1024 pulls the observed mean below 1000.
        assert!(mean > 850.0 && mean < 1010.0, "mean {mean}");
    }

    #[test]
    fn powerlaw_skews_small() {
        let xs = sample_many(Dist::powerlaw_default(), 5000);
        assert!(xs.iter().all(|&x| x <= 1024));
        let small = xs.iter().filter(|&&x| x < 128).count();
        let large = xs.iter().filter(|&&x| x > 512).count();
        assert!(
            small > 3 * large,
            "power law should skew small: {small} small vs {large} large"
        );
        assert!(large > 0, "large blocks must still occur");
    }

    #[test]
    fn const_is_const() {
        let xs = sample_many(Dist::Const { size: 96 }, 100);
        assert!(xs.iter().all(|&x| x == 96));
    }

    #[test]
    fn skewed_companion_is_heavy_tailed_and_bounded() {
        for d in [
            Dist::Uniform { max: 2048 },
            Dist::normal_default(),
            Dist::Const { size: 512 },
            Dist::FftN1,
            Dist::FftN2,
            Dist::powerlaw_default(),
        ] {
            match d.skewed_companion() {
                Dist::PowerLaw { max, skew } => {
                    assert!(max >= 8, "{d:?}");
                    assert!(skew > 1.0, "{d:?}: skew must favor small blocks");
                }
                other => panic!("{d:?}: companion {other:?} is not a power law"),
            }
        }
        // Idempotent on already-skewed workloads.
        let p = Dist::PowerLaw { max: 99, skew: 2.5 };
        assert_eq!(p.skewed_companion(), p);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Dist::parse("uniform:2048"), Some(Dist::Uniform { max: 2048 }));
        assert_eq!(Dist::parse("normal"), Some(Dist::normal_default()));
        assert_eq!(Dist::parse("powerlaw"), Some(Dist::powerlaw_default()));
        assert_eq!(Dist::parse("const:8"), Some(Dist::Const { size: 8 }));
        assert_eq!(Dist::parse("fft-n1"), Some(Dist::FftN1));
        assert_eq!(Dist::parse("bogus"), None);
        assert_eq!(Dist::parse("uniform"), None);
    }

    #[test]
    fn parse_sparse_family() {
        assert_eq!(
            Dist::parse("sparse:nnz=16"),
            Some(Dist::Sparse { nnz: 16, max: 1024 })
        );
        assert_eq!(
            Dist::parse("sparse:nnz=4,max=2048"),
            Some(Dist::Sparse { nnz: 4, max: 2048 })
        );
        // Sub-8 bounds clamp so structural entries keep a positive size.
        assert_eq!(
            Dist::parse("sparse:nnz=4,max=1"),
            Some(Dist::Sparse { nnz: 4, max: 8 })
        );
        assert_eq!(Dist::parse("sparse"), None);
        assert_eq!(Dist::parse("sparse:max=64"), None);
        assert_eq!(Dist::parse("sparse:nnz=x"), None);
        assert_eq!(Dist::parse("sparse:nnz=4,zig=1"), None);
        // Sparse-family helpers.
        let d = Dist::Sparse { nnz: 7, max: 512 };
        assert_eq!(d.sparse_nnz(), Some(7));
        assert_eq!(d.sparse_max(), 512);
        assert_eq!(d.name(), "sparse");
        assert_eq!(d.skewed_companion(), d);
        assert_eq!(Dist::Uniform { max: 64 }.sparse_nnz(), None);
    }
}
