//! FFT transpose workloads (§VI-A).
//!
//! FFTW-style slab decomposition produces a non-uniform all-to-all when
//! the problem size 𝒩 is not a multiple of P². The paper constructs two
//! instances:
//!
//! * 𝒩₁ = ⌈0.78125·P⌉ · ⌈0.625·P⌉ · 8 — only ranks below ⌈0.625·P⌉
//!   ("workers") hold data; each worker fills its first ⌈0.78125·P⌉
//!   blocks with 8 FP64 values (64 B) and sends nothing elsewhere.
//! * 𝒩₂ = ((P−1)·32 + 8) · P — near-uniform: every rank sends 64 FP64
//!   values (512 B) per block, except the last rank which sends 16 FP64
//!   (128 B) per block.

use crate::util::prng::Pcg64;

/// Number of worker ranks for 𝒩₁.
pub fn n1_workers(p: usize) -> usize {
    ((0.625 * p as f64).ceil() as usize).min(p)
}

/// Number of filled destination blocks per worker for 𝒩₁.
pub fn n1_filled_blocks(p: usize) -> usize {
    ((0.78125 * p as f64).ceil() as usize).min(p)
}

/// Block size for the 𝒩₁ decomposition.
pub fn n1_size(src: usize, dst: usize, p: usize, rng: &mut Pcg64) -> u64 {
    let _ = rng.next_u64(); // keep streams aligned across distributions
    if src < n1_workers(p) && dst < n1_filled_blocks(p) {
        8 * 8 // 8 FP64 values
    } else {
        0
    }
}

/// Block size for the 𝒩₂ decomposition.
pub fn n2_size(src: usize, _dst: usize, p: usize, rng: &mut Pcg64) -> u64 {
    let _ = rng.next_u64();
    if src + 1 == p {
        16 * 8 // 16 FP64 values
    } else {
        64 * 8 // 64 FP64 values
    }
}

/// Total problem size (complex FP64 pairs count as 2 values) implied by
/// the 𝒩₁ workload — used to cross-check against the paper's formula.
pub fn n1_total_bytes(p: usize) -> u64 {
    (n1_workers(p) as u64) * (n1_filled_blocks(p) as u64) * 64
}

pub fn n2_total_bytes(p: usize) -> u64 {
    ((p as u64 - 1) * 512 + 128) * p as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{BlockSizes, Dist};

    #[test]
    fn n1_structure() {
        let p = 16;
        let w = BlockSizes::generate(p, Dist::FftN1, 0);
        let workers = n1_workers(p);
        let filled = n1_filled_blocks(p);
        assert_eq!(workers, 10);
        assert_eq!(filled, 13);
        for src in 0..p {
            let row = w.row(src);
            for dst in 0..p {
                let expect = if src < workers && dst < filled { 64 } else { 0 };
                assert_eq!(row[dst], expect, "src={src} dst={dst}");
            }
        }
        assert_eq!(w.total_bytes(), n1_total_bytes(p));
    }

    #[test]
    fn n2_structure() {
        let p = 8;
        let w = BlockSizes::generate(p, Dist::FftN2, 0);
        for src in 0..p {
            let expect = if src == p - 1 { 128 } else { 512 };
            assert!(w.row(src).iter().all(|&s| s == expect));
        }
        assert_eq!(w.total_bytes(), n2_total_bytes(p));
    }

    #[test]
    fn n1_is_genuinely_nonuniform() {
        let p = 32;
        let w = BlockSizes::generate(p, Dist::FftN1, 0);
        let sums: Vec<u64> = (0..p).map(|s| w.row(s).iter().sum()).collect();
        assert!(sums.iter().any(|&s| s == 0), "some ranks send nothing");
        assert!(sums.iter().any(|&s| s > 0), "workers send data");
    }
}
