//! Non-uniform all-to-all workload generation.
//!
//! A workload is the P x P matrix of block sizes `size(src, dst)`. The
//! matrix is never materialized: row `src` is regenerated on demand from
//! `(seed, src)` with an independent PRNG stream, so a 16,384-rank
//! simulation needs no O(P^2) memory and any rank (or the validator) can
//! reproduce any other rank's row.

pub mod distributions;
pub mod fft;
pub mod graph;

pub use distributions::Dist;

use crate::util::prng::Pcg64;

/// Handle on a generated workload: cheap to clone and share.
#[derive(Clone, Debug)]
pub struct BlockSizes {
    p: usize,
    dist: Dist,
    seed: u64,
}

impl BlockSizes {
    pub fn generate(p: usize, dist: Dist, seed: u64) -> BlockSizes {
        assert!(p >= 1);
        BlockSizes { p, dist, seed }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    pub fn dist(&self) -> &Dist {
        &self.dist
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Sizes of the blocks rank `src` sends to every destination.
    pub fn row(&self, src: usize) -> Vec<u64> {
        assert!(src < self.p);
        let mut rng = Pcg64::new(self.seed, src as u64);
        (0..self.p)
            .map(|dst| self.dist.sample(&mut rng, src, dst, self.p))
            .collect()
    }

    /// One matrix entry (regenerates the row prefix; use `row` in loops).
    pub fn size(&self, src: usize, dst: usize) -> u64 {
        self.row(src)[dst]
    }

    /// Maximum block size across the whole matrix (the paper's `M`).
    pub fn max_block(&self) -> u64 {
        (0..self.p).map(|s| self.row(s).iter().copied().max().unwrap_or(0)).max().unwrap_or(0)
    }

    /// Total bytes moved by one all-to-allv.
    pub fn total_bytes(&self) -> u64 {
        (0..self.p).map(|s| self.row(s).iter().sum::<u64>()).sum()
    }

    /// Mean block size (for the analytic model). Exact up to P = 256;
    /// beyond that a deterministic 256-row sample is used — the full
    /// matrix would cost O(P²) generator calls per estimate (1.9 s at
    /// P = 16,384), and a 256-row sample of P entries each is already a
    /// ±0.1%-accurate mean for every distribution we ship.
    pub fn mean_size(&self) -> f64 {
        let sample_rows = self.p.min(256);
        let stride = (self.p / sample_rows).max(1);
        let mut total = 0u64;
        let mut count = 0u64;
        let mut src = 0usize;
        while src < self.p && count < (sample_rows * self.p) as u64 {
            let row = self.row(src);
            total += row.iter().sum::<u64>();
            count += row.len() as u64;
            src += stride;
        }
        total as f64 / count as f64
    }

    /// Per-destination validation fingerprints, computed in O(P^2) time but
    /// O(P) memory: `fp[dst]` folds `(src, size(src, dst))` over all
    /// sources. A rank that received a full, correctly-sized block set can
    /// reproduce its fingerprint without the matrix.
    pub fn recv_fingerprints(&self) -> Vec<u64> {
        let mut fp = vec![0u64; self.p];
        for src in 0..self.p {
            let row = self.row(src);
            for (dst, &sz) in row.iter().enumerate() {
                fp[dst] = fp[dst].wrapping_add(fingerprint_one(src, sz));
            }
        }
        fp
    }
}

/// Commutative per-block fingerprint so receive order does not matter.
#[inline]
pub fn fingerprint_one(src: usize, size: u64) -> u64 {
    let mut h = (src as u64 + 1)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(size.wrapping_mul(0xc4ce_b9fe_1a85_ec53));
    h ^= h >> 31;
    h.wrapping_mul(0xff51_afd7_ed55_8ccd) | 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_deterministic() {
        let w = BlockSizes::generate(16, Dist::Uniform { max: 1024 }, 7);
        assert_eq!(w.row(3), w.row(3));
        let w2 = BlockSizes::generate(16, Dist::Uniform { max: 1024 }, 7);
        assert_eq!(w.row(5), w2.row(5));
    }

    #[test]
    fn different_seeds_differ() {
        let a = BlockSizes::generate(32, Dist::Uniform { max: 4096 }, 1);
        let b = BlockSizes::generate(32, Dist::Uniform { max: 4096 }, 2);
        assert_ne!(a.row(0), b.row(0));
    }

    #[test]
    fn size_matches_row() {
        let w = BlockSizes::generate(8, Dist::Uniform { max: 512 }, 3);
        for s in 0..8 {
            let row = w.row(s);
            for d in 0..8 {
                assert_eq!(w.size(s, d), row[d]);
            }
        }
    }

    #[test]
    fn max_and_total_consistent() {
        let w = BlockSizes::generate(10, Dist::Uniform { max: 100 }, 9);
        let mut total = 0u64;
        let mut max = 0u64;
        for s in 0..10 {
            for v in w.row(s) {
                total += v;
                max = max.max(v);
            }
        }
        assert_eq!(w.total_bytes(), total);
        assert_eq!(w.max_block(), max);
        assert!((w.mean_size() - total as f64 / 100.0).abs() < 1e-9);
    }

    #[test]
    fn fingerprints_detect_size_change() {
        let w = BlockSizes::generate(6, Dist::Uniform { max: 64 }, 4);
        let fp = w.recv_fingerprints();
        // Rebuild dst 2's fingerprint by hand.
        let mut h = 0u64;
        for src in 0..6 {
            h = h.wrapping_add(fingerprint_one(src, w.size(src, 2)));
        }
        assert_eq!(h, fp[2]);
        // A wrong size breaks it.
        let mut bad = 0u64;
        for src in 0..6 {
            let sz = if src == 3 { w.size(src, 2) + 1 } else { w.size(src, 2) };
            bad = bad.wrapping_add(fingerprint_one(src, sz));
        }
        assert_ne!(bad, fp[2]);
    }
}
