//! Non-uniform all-to-all workload generation: the **counts matrix**
//! (`size(src, dst)` for every rank pair) behind every layer of the
//! crate, in three representations sharing one API.
//!
//! # The CountsView contract
//!
//! [`Counts`] (aliased as [`BlockSizes`] for historical call sites) is
//! the load-bearing type of the whole crate: the threaded engine builds
//! payloads from it, every plan compiler derives its schedule from it,
//! the analytic model summarizes it, and the plan cache keys on its
//! identity. All three representations — generator-backed lazy rows,
//! dense rows, and CSR-style sparse rows — answer the same queries:
//!
//! * `row_view(r)` — row `r` as a [`CountsRow`] in its native
//!   representation; `row(r)` is the dense materialization of the same
//!   row for legacy/diagnostic consumers.
//! * `block(r, d)` / `nnz_row(r)` — one entry; the row's structural
//!   entry count.
//! * Row/total reductions — `total_bytes`, `max_block`, `total_nnz`,
//!   `mean_size`, `mean_structural`, `mean_nnz_row`,
//!   `recv_fingerprints`.
//! * `senders()` — the structural transpose (sparse only): sorted sender
//!   lists per destination, O(total nnz), built once and shared.
//! * `identity_hash()` — content identity for the plan cache, hashed
//!   incrementally through the row views (never via a dense
//!   materialization).
//!
//! **Structural semantics.** Dense representations treat every
//! destination as structural — a sampled size of 0 still exchanges a
//! zero-byte block, so all pre-sparsity schedules, golden snapshots and
//! replay bit-identity are unchanged. Sparse representations
//! ([`Dist::Sparse`] generators, CSR rows) treat absent entries as "no
//! block at all": algorithms send nothing for them, and compiled plans
//! scale with the nonzero count instead of P².
//!
//! # Memory envelope per execution mode
//!
//! * **Threaded** (`mode=threaded`, real or phantom): one OS thread per
//!   rank; each rank materializes only its own row (O(P) dense, O(nnz)
//!   sparse). Bounded by the thread budget (`limit-linear` /
//!   `limit-log`), not by counts memory.
//! * **Replay** (`mode=replay`, phantom): plan compilation is
//!   **streaming** — per-rank op lists are built from row views without
//!   ever materializing the P×P matrix. Dense log-family plans hold
//!   O(P·K) working state and O(P·K) ops (K = rounds); dense linear
//!   plans hold O(P²) ops (hence their tighter `limit-replay` cap);
//!   sparse plans hold O(nnz) ops plus O(P·K) accumulators, which is
//!   what lets exact replay reach P ≥ 32k on sparse workloads
//!   (`limit-replay-sparse`). The one exception: a `bruck` *global*
//!   level compiles from node-level bucket sums, O(P·N) transient.
//! * **Analytic** (beyond the exact budgets): O(1) — closed-form
//!   estimates from the workload's sampled shape summary.
//!
//! Rows are never stored globally for generator-backed workloads: row
//! `src` is regenerated on demand from `(seed, src)` with an independent
//! PRNG stream, so a 32k-rank simulation needs no O(P²) memory and any
//! rank (or the validator) can reproduce any other rank's row.

pub mod counts;
pub mod distributions;
pub mod fft;
pub mod graph;

pub use counts::{segment_counts, Counts, CountsRow, CountsRowIter};
pub use distributions::Dist;

/// Historical name of [`Counts`]: the workload handle every call site
/// passes around. Cheap to clone and share.
pub type BlockSizes = Counts;

/// Commutative per-block fingerprint so receive order does not matter.
#[inline]
pub fn fingerprint_one(src: usize, size: u64) -> u64 {
    let mut h = (src as u64 + 1)
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(size.wrapping_mul(0xc4ce_b9fe_1a85_ec53));
    h ^= h >> 31;
    h.wrapping_mul(0xff51_afd7_ed55_8ccd) | 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_deterministic() {
        let w = BlockSizes::generate(16, Dist::Uniform { max: 1024 }, 7);
        assert_eq!(w.row(3), w.row(3));
        let w2 = BlockSizes::generate(16, Dist::Uniform { max: 1024 }, 7);
        assert_eq!(w.row(5), w2.row(5));
    }

    #[test]
    fn different_seeds_differ() {
        let a = BlockSizes::generate(32, Dist::Uniform { max: 4096 }, 1);
        let b = BlockSizes::generate(32, Dist::Uniform { max: 4096 }, 2);
        assert_ne!(a.row(0), b.row(0));
    }

    #[test]
    fn size_matches_row() {
        let w = BlockSizes::generate(8, Dist::Uniform { max: 512 }, 3);
        for s in 0..8 {
            let row = w.row(s);
            for d in 0..8 {
                assert_eq!(w.size(s, d), row[d]);
            }
        }
    }

    #[test]
    fn max_and_total_consistent() {
        let w = BlockSizes::generate(10, Dist::Uniform { max: 100 }, 9);
        let mut total = 0u64;
        let mut max = 0u64;
        for s in 0..10 {
            for v in w.row(s) {
                total += v;
                max = max.max(v);
            }
        }
        assert_eq!(w.total_bytes(), total);
        assert_eq!(w.max_block(), max);
        assert!((w.mean_size() - total as f64 / 100.0).abs() < 1e-9);
    }

    #[test]
    fn fingerprints_detect_size_change() {
        let w = BlockSizes::generate(6, Dist::Uniform { max: 64 }, 4);
        let fp = w.recv_fingerprints();
        // Rebuild dst 2's fingerprint by hand.
        let mut h = 0u64;
        for src in 0..6 {
            h = h.wrapping_add(fingerprint_one(src, w.size(src, 2)));
        }
        assert_eq!(h, fp[2]);
        // A wrong size breaks it.
        let mut bad = 0u64;
        for src in 0..6 {
            let sz = if src == 3 { w.size(src, 2) + 1 } else { w.size(src, 2) };
            bad = bad.wrapping_add(fingerprint_one(src, sz));
        }
        assert_ne!(bad, fp[2]);
    }
}
