//! Wallclock metrics for the host-side harness (distinct from the
//! *virtual* time the engine simulates): used by the perf benches and the
//! end-to-end application drivers.

use std::time::Instant;

/// A simple named stopwatch accumulator.
#[derive(Debug, Default)]
pub struct WallMetrics {
    entries: Vec<(String, f64)>,
}

impl WallMetrics {
    pub fn new() -> WallMetrics {
        WallMetrics::default()
    }

    /// Time a closure and record it under `name` (accumulating across
    /// calls with the same name).
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|e| e.0 == name)
            .map(|e| e.1)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|e| e.1).sum()
    }

    pub fn entries(&self) -> &[(String, f64)] {
        &self.entries
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, secs) in &self.entries {
            out.push_str(&format!(
                "  {name:<24} {}\n",
                crate::util::stats::fmt_time(*secs)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_name() {
        let mut m = WallMetrics::new();
        m.add("comm", 1.0);
        m.add("comm", 0.5);
        m.add("compute", 2.0);
        assert_eq!(m.get("comm"), 1.5);
        assert_eq!(m.get("compute"), 2.0);
        assert_eq!(m.get("missing"), 0.0);
        assert_eq!(m.total(), 3.5);
    }

    #[test]
    fn time_records_elapsed() {
        let mut m = WallMetrics::new();
        let v = m.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(m.get("work") >= 0.004);
    }

    #[test]
    fn render_contains_names() {
        let mut m = WallMetrics::new();
        m.add("alpha", 0.001);
        assert!(m.render().contains("alpha"));
    }
}
