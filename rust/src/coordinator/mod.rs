//! Experiment coordination: configuration, fidelity selection (exact
//! engine vs analytic replay), repetition, and measurement aggregation.
//!
//! The paper reports medians and deviations over >= 20 iterations; we do
//! the same, varying the workload seed per iteration. Fidelity is chosen
//! per point: the threaded engine (exact, real message matching) up to a
//! configurable rank budget, the single-rank analytic replay beyond it —
//! each table/CSV row records which one produced it.

pub mod config;
pub mod metrics;

pub use config::{RunConfig, SelectConfig};

use crate::algos::{run_alltoallv, AlgoKind};
use crate::comm::{Engine, PhaseBreakdown, Topology};
use crate::model::analytic::Estimator;
use crate::util::stats::Summary;
use crate::workload::BlockSizes;

/// How a measurement was produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    /// Threaded engine, every rank simulated with real message matching.
    Engine,
    /// Single-rank analytic replay (for paper-scale P).
    Analytic,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::Engine => "engine",
            Fidelity::Analytic => "model",
        }
    }
}

/// An aggregated measurement of one (algorithm, workload, machine) point.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub algo: AlgoKind,
    pub summary: Summary,
    pub phases: PhaseBreakdown,
    pub fidelity: Fidelity,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        self.summary.median
    }
}

/// Decide fidelity for an algorithm at scale `p`: linear algorithms post
/// O(P²) messages so their engine budget is tighter than the logarithmic
/// family's.
pub fn choose_fidelity(kind: &AlgoKind, p: usize, cfg: &RunConfig) -> Fidelity {
    let limit = match kind {
        AlgoKind::SpreadOut
        | AlgoKind::OmpiLinear
        | AlgoKind::Pairwise
        | AlgoKind::Scattered { .. }
        | AlgoKind::Vendor => cfg.engine_limit_linear,
        _ => cfg.engine_limit_log,
    };
    if p <= limit {
        Fidelity::Engine
    } else {
        Fidelity::Analytic
    }
}

/// Measure one algorithm under a config: `iters` runs with per-iteration
/// seeds on the engine, or one analytic replay (deterministic) beyond the
/// engine budget.
pub fn measure(cfg: &RunConfig, kind: &AlgoKind) -> crate::Result<Measurement> {
    kind.check(cfg.p, cfg.q)?;
    let topo = Topology::new(cfg.p, cfg.q);
    match choose_fidelity(kind, cfg.p, cfg) {
        Fidelity::Engine => {
            let engine = Engine::new(cfg.profile.clone(), topo).with_tuning(cfg.tuning.clone());
            let mut times = Vec::with_capacity(cfg.iters);
            let mut phases = PhaseBreakdown::default();
            for it in 0..cfg.iters.max(1) {
                let sizes = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed.wrapping_add(it as u64));
                let rep = run_alltoallv(&engine, kind, &sizes, cfg.real_payloads)?;
                times.push(rep.makespan);
                phases.max_with(&rep.phases);
            }
            Ok(Measurement {
                algo: *kind,
                summary: Summary::of(&times),
                phases,
                fidelity: Fidelity::Engine,
            })
        }
        Fidelity::Analytic => {
            let sizes = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed);
            let mean = sizes.mean_size();
            let est = Estimator::new(&cfg.profile, topo).estimate(kind, mean);
            Ok(Measurement {
                algo: *kind,
                summary: Summary::of(&[est.makespan]),
                phases: est.phases,
                fidelity: Fidelity::Analytic,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dist;

    fn cfg(p: usize, q: usize) -> RunConfig {
        RunConfig {
            p,
            q,
            dist: Dist::Uniform { max: 256 },
            iters: 3,
            ..RunConfig::default()
        }
    }

    #[test]
    fn engine_fidelity_below_limit() {
        let c = cfg(16, 4);
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Engine);
        assert_eq!(m.summary.n, 3);
        assert!(m.median() > 0.0);
        assert!(m.phases.total() > 0.0);
    }

    #[test]
    fn analytic_fidelity_above_limit() {
        let mut c = cfg(16, 4);
        c.engine_limit_log = 8;
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Analytic);
    }

    #[test]
    fn linear_gets_tighter_budget() {
        let c = RunConfig {
            engine_limit_linear: 64,
            engine_limit_log: 1024,
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 128, &c),
            Fidelity::Analytic
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 2 }, 128, &c),
            Fidelity::Engine
        );
    }

    #[test]
    fn measure_rejects_invalid_params() {
        let c = cfg(16, 4);
        assert!(measure(&c, &AlgoKind::Tuna { radix: 99 }).is_err());
    }

    #[test]
    fn iterations_produce_spread() {
        let c = cfg(16, 4);
        let m = measure(&c, &AlgoKind::Tuna { radix: 2 }).unwrap();
        // Different seeds -> different workloads -> nonzero spread.
        assert!(m.summary.max >= m.summary.min);
        assert!(m.summary.stddev >= 0.0);
    }
}
