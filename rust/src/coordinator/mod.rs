//! Experiment coordination: configuration, fidelity selection (exact
//! engine vs analytic model), repetition, and measurement aggregation.
//!
//! The paper reports medians and deviations over >= 20 iterations; we do
//! the same, varying the workload seed per iteration. Fidelity is chosen
//! per point: exact simulation up to a configurable rank budget — the
//! threaded engine for real payloads, the bit-identical plan/replay
//! executor for phantom ones (see [`ExecMode`]) — and the closed-form
//! analytic model beyond it. Each table/CSV row records which one
//! produced it.

pub mod config;
pub mod metrics;
pub mod serve;

pub use config::{RunConfig, SelectConfig};
pub use serve::{ServeConfig, ServeReport, TenantSpec, TenantStat};

use crate::algos::{
    run_alltoallv, run_alltoallv_replay, run_alltoallv_segmented, run_alltoallv_segmented_replay,
    AlgoKind, ExecMode, SegmentCompute,
};
use crate::comm::{Counters, Engine, PersistentColl, PhaseBreakdown, Topology};
use crate::model::analytic::Estimator;
use crate::util::stats::Summary;
use crate::workload::BlockSizes;

/// Linear algorithms post O(P²) messages, so their compiled plans hold
/// O(P²) ops — replaying them beyond this rank count costs more plan
/// memory than the point is worth; the analytic model takes over.
pub const REPLAY_LIMIT_LINEAR: usize = 1024;

/// Per-row structural-nonzero bound under which the sparse replay budget
/// (`limit-replay-sparse`) applies — the "nnz ≤ 64 per row" envelope the
/// large-P acceptance points run at. Denser "sparse" workloads take the
/// dense budgets instead (their plans approach the dense op counts).
pub const SPARSE_REPLAY_NNZ_ROW: usize = 64;

/// How a measurement was produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fidelity {
    /// Threaded engine, every rank simulated with real message matching.
    Engine,
    /// Plan/replay executor: exact (bit-identical to the threaded
    /// engine) but single-threaded and phantom-only.
    Replay,
    /// Closed-form analytic model (for beyond-budget P).
    Analytic,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Fidelity::Engine => "engine",
            Fidelity::Replay => "replay",
            Fidelity::Analytic => "model",
        }
    }
}

/// An aggregated measurement of one (algorithm, workload, machine) point.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub algo: AlgoKind,
    pub summary: Summary,
    pub phases: PhaseBreakdown,
    pub fidelity: Fidelity,
    /// Aggregate counters of the last exact iteration (virtual time is
    /// seed-deterministic, so any iteration is representative of its
    /// seed). `None` on the analytic path — the model has no clocks to
    /// measure `exposed_comm`/`hidden_comm` with.
    pub counters: Option<Counters>,
    /// Plan-IR statistics of the seed workload's compiled (unsegmented)
    /// plan: total ops, distinct interned programs, arena bytes and the
    /// legacy byte count. Filled only when [`RunConfig::plan_stats`] is
    /// set on a replay-fidelity point — threaded and analytic runs never
    /// compile a plan to report on.
    pub plan_stats: Option<crate::comm::PlanStats>,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        self.summary.median
    }
}

/// Decide fidelity for an algorithm at scale `p`: linear algorithms post
/// O(P²) messages so their budget is tighter than the logarithmic
/// family's, and the plan/replay executor (no rank threads) affords a
/// much larger exact budget than thread-per-rank execution.
///
/// The replay budget is **sparsity-aware**: structurally sparse
/// workloads compile plans whose op count scales with the nonzeros, not
/// P² (linear families included), so they use the far larger
/// `limit-replay-sparse` budget — exact replay at P ≥ 32k — while dense
/// workloads keep the dense caps (streaming compilation holds O(P·K)
/// working memory, but dense linear plans still hold O(P²) ops).
pub fn choose_fidelity(kind: &AlgoKind, p: usize, cfg: &RunConfig) -> Fidelity {
    let linear = matches!(
        kind,
        AlgoKind::SpreadOut
            | AlgoKind::OmpiLinear
            | AlgoKind::Pairwise
            | AlgoKind::Scattered { .. }
            | AlgoKind::Vendor
    );
    let threaded_limit = if linear {
        cfg.engine_limit_linear
    } else {
        cfg.engine_limit_log
    };
    if cfg.mode.resolve(cfg.real_payloads) == ExecMode::Replay {
        // Sparse plans hold O(total nnz) ops, so the sparse budget is a
        // *volume* budget, not just a rank count: it applies only while
        // the expected nonzeros stay inside the documented envelope
        // (nnz_row <= SPARSE_REPLAY_NNZ_ROW, the acceptance bound). A
        // sparse dist dense enough to escape it (nnz ~ P would rebuild
        // the O(P²) plans the dense caps exist to prevent) falls through
        // to the dense rules below.
        let sparse_within_budget = cfg.dist.sparse_nnz().is_some_and(|nnz| {
            p <= cfg.engine_limit_replay_sparse && nnz <= SPARSE_REPLAY_NNZ_ROW
        });
        let replay_limit = if sparse_within_budget {
            cfg.engine_limit_replay_sparse
        } else if linear {
            cfg.engine_limit_replay.min(REPLAY_LIMIT_LINEAR)
        } else {
            cfg.engine_limit_replay
        };
        if p <= replay_limit {
            return Fidelity::Replay;
        }
        // Beyond the replay budget (O(P²)-op plans for dense linear
        // families), fall through: the threaded oracle still applies its
        // own budget, so replay never shrinks exact coverage — it only
        // extends it.
    }
    if p <= threaded_limit {
        Fidelity::Engine
    } else {
        Fidelity::Analytic
    }
}

/// Measure one algorithm under a config: `iters` runs with per-iteration
/// seeds at exact fidelity (threaded engine or bit-identical plan
/// replay, per [`choose_fidelity`]), or one analytic estimate
/// (deterministic) beyond the exact budget.
pub fn measure(cfg: &RunConfig, kind: &AlgoKind) -> crate::Result<Measurement> {
    kind.check(cfg.p, cfg.q)?;
    // Guard programmatically built configs too (parse_args validates the
    // same contradiction): replay never materializes payload bytes, so
    // combining it with real payloads must fail, not silently downgrade.
    if cfg.mode == ExecMode::Replay && cfg.real_payloads {
        return Err(crate::TunaError::config(
            "mode=replay is phantom-only (real payloads need the threaded oracle); \
             set real=false or mode=threaded",
        ));
    }
    // Segmented knobs get the same programmatic guards parse_args
    // applies — a hand-built config must not reach the driver with a
    // contradiction the CLI would have rejected.
    if cfg.segments == 0 {
        return Err(crate::TunaError::config(
            "segments must be >= 1 (segments=1 is the unsegmented run)",
        ));
    }
    if cfg.overlap && cfg.segments < 2 {
        return Err(crate::TunaError::config(
            "overlap=true requires segments >= 2 (nothing to pipeline with one segment)",
        ));
    }
    if cfg.segments > 1 && cfg.real_payloads {
        return Err(crate::TunaError::config(
            "segments are phantom-only (plans model byte ranges, never payload bytes); \
             set real=false",
        ));
    }
    if cfg.segments > 1 && cfg.persistent {
        return Err(crate::TunaError::config(
            "persistent=true does not compose with segments yet: a handle freezes one \
             plan, the segmented driver stitches per call",
        ));
    }
    // Guard programmatically built configs (parse_args runs the same
    // checks): reject poisoned machine parameters and out-of-range fault
    // targets before any clock consumes them.
    cfg.profile.validate()?;
    cfg.faults.check(cfg.p, cfg.q)?;
    let topo = Topology::try_new(cfg.p, cfg.q)?;
    match choose_fidelity(kind, cfg.p, cfg) {
        fidelity @ (Fidelity::Engine | Fidelity::Replay) => {
            let engine = Engine::new(cfg.profile.clone(), topo)
                .with_tuning(cfg.tuning.clone())
                .with_replay_shards(cfg.replay_shards)
                .with_compile_threads(cfg.compile_threads)
                .with_faults(&cfg.faults);
            let mut times = Vec::with_capacity(cfg.iters);
            let mut phases = PhaseBreakdown::default();
            let mut counters = None;
            if cfg.persistent {
                // Persistent path: freeze the workload at `seed` and hoist
                // every one-shot artifact (plan compile, payload arena,
                // transpose, fingerprints) out of the iteration loop —
                // init once, start per iter. The only path that admits
                // persistent-only kinds (hier local `balanced`).
                let sizes = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed);
                let mode = match fidelity {
                    Fidelity::Replay => ExecMode::Replay,
                    _ => ExecMode::Threaded,
                };
                let handle =
                    PersistentColl::init(&engine, *kind, &sizes, cfg.real_payloads, mode)?;
                for _ in 0..cfg.iters.max(1) {
                    let rep = handle.start_frozen()?;
                    times.push(rep.makespan);
                    phases.max_with(&rep.phases);
                    counters = Some(rep.counters);
                }
            } else {
                // The CLI's constant `compute=` cost; `segments=1` takes
                // the ordinary unsegmented entry points below.
                let seg_compute = if cfg.compute > 0.0 {
                    SegmentCompute::Uniform(cfg.compute)
                } else {
                    SegmentCompute::None
                };
                for it in 0..cfg.iters.max(1) {
                    let sizes =
                        BlockSizes::generate(cfg.p, cfg.dist, cfg.seed.wrapping_add(it as u64));
                    let rep = match (cfg.segments > 1, fidelity == Fidelity::Replay) {
                        (true, true) => run_alltoallv_segmented_replay(
                            &engine,
                            kind,
                            &sizes,
                            cfg.segments,
                            cfg.overlap,
                            &seg_compute,
                        )?,
                        (true, false) => run_alltoallv_segmented(
                            &engine,
                            kind,
                            &sizes,
                            cfg.segments,
                            cfg.overlap,
                            &seg_compute,
                        )?,
                        (false, true) => run_alltoallv_replay(&engine, kind, &sizes)?,
                        (false, false) => run_alltoallv(&engine, kind, &sizes, cfg.real_payloads)?,
                    };
                    times.push(rep.makespan);
                    phases.max_with(&rep.phases);
                    counters = Some(rep.counters);
                }
            }
            // Diagnostic plan-IR stats, on request: recompile the seed
            // workload's unsegmented plan once (replay fidelity only —
            // the threaded oracle never compiles one). Persistent-only
            // kinds (hier local `balanced`) have no one-shot compile
            // path, so a failed compile simply reports no stats.
            let plan_stats = if cfg.plan_stats && fidelity == Fidelity::Replay {
                let sizes = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed);
                crate::algos::compile_plan(&engine, kind, &sizes).ok().map(|pl| pl.stats())
            } else {
                None
            };
            Ok(Measurement {
                algo: *kind,
                summary: Summary::of(&times),
                phases,
                fidelity,
                counters,
                plan_stats,
            })
        }
        Fidelity::Analytic => {
            let sizes = BlockSizes::generate(cfg.p, cfg.dist, cfg.seed);
            let shape = crate::model::analytic::WorkloadShape::of(&sizes);
            let faults = if cfg.faults.is_empty() {
                None
            } else {
                Some(crate::comm::FaultModel::compile(&cfg.faults, cfg.q))
            };
            let estimator = Estimator::new(&cfg.profile, topo);
            let est = if cfg.segments > 1 {
                estimator.estimate_segmented_faulted(
                    kind,
                    &shape,
                    cfg.segments,
                    cfg.overlap,
                    cfg.compute,
                    faults.as_ref(),
                )
            } else {
                estimator.estimate_shape_faulted(kind, &shape, faults.as_ref())
            };
            Ok(Measurement {
                algo: *kind,
                summary: Summary::of(&[est.makespan]),
                phases: est.phases,
                fidelity: Fidelity::Analytic,
                counters: None,
                plan_stats: None,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Dist;

    fn cfg(p: usize, q: usize) -> RunConfig {
        RunConfig {
            p,
            q,
            dist: Dist::Uniform { max: 256 },
            iters: 3,
            ..RunConfig::default()
        }
    }

    #[test]
    fn replay_fidelity_for_phantom_auto_below_limit() {
        // Auto mode + phantom workload: exact fidelity via plan replay.
        let c = cfg(16, 4);
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Replay);
        assert_eq!(m.summary.n, 3);
        assert!(m.median() > 0.0);
        assert!(m.phases.total() > 0.0);
    }

    #[test]
    fn engine_fidelity_for_real_payloads_or_threaded_mode() {
        let mut c = cfg(16, 4);
        c.real_payloads = true;
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Engine);
        let mut c = cfg(16, 4);
        c.mode = ExecMode::Threaded;
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Engine);
    }

    #[test]
    fn threaded_and_replay_measurements_are_bit_identical() {
        let threaded = RunConfig {
            mode: ExecMode::Threaded,
            ..cfg(24, 4)
        };
        let replay = RunConfig {
            mode: ExecMode::Replay,
            ..cfg(24, 4)
        };
        for kind in [
            AlgoKind::Tuna { radix: 3 },
            AlgoKind::SpreadOut,
            AlgoKind::hier_staggered(2, 3),
        ] {
            let a = measure(&threaded, &kind).unwrap();
            let b = measure(&replay, &kind).unwrap();
            assert_eq!(a.summary.median.to_bits(), b.summary.median.to_bits());
            assert_eq!(a.summary.min.to_bits(), b.summary.min.to_bits());
            assert_eq!(a.summary.max.to_bits(), b.summary.max.to_bits());
            assert_eq!(a.phases, b.phases, "{}", kind.name());
        }
    }

    #[test]
    fn faulted_measurements_stay_bit_identical_across_executors() {
        use crate::comm::FaultSpec;
        let spec = FaultSpec::parse(
            "straggler:rank=2,slow=3/link:node=0-2,bw=0.5,lat=2/jitter:sigma=0.15,seed=11",
        )
        .unwrap();
        let threaded = RunConfig {
            mode: ExecMode::Threaded,
            faults: spec.clone(),
            ..cfg(24, 4)
        };
        let replay = RunConfig {
            mode: ExecMode::Replay,
            faults: spec,
            ..cfg(24, 4)
        };
        for kind in [AlgoKind::Tuna { radix: 3 }, AlgoKind::SpreadOut] {
            let a = measure(&threaded, &kind).unwrap();
            let b = measure(&replay, &kind).unwrap();
            assert_eq!(a.summary.median.to_bits(), b.summary.median.to_bits(), "{}", kind.name());
            assert_eq!(a.summary.max.to_bits(), b.summary.max.to_bits());
            // And the faults actually bite: the healthy run differs.
            let healthy = measure(&RunConfig { mode: ExecMode::Threaded, ..cfg(24, 4) }, &kind)
                .unwrap();
            assert_ne!(a.summary.median.to_bits(), healthy.summary.median.to_bits());
        }
    }

    #[test]
    fn measure_rejects_out_of_range_fault_targets_and_bad_profiles() {
        use crate::comm::FaultSpec;
        let c = RunConfig {
            faults: FaultSpec::parse("straggler:rank=99,slow=2").unwrap(),
            ..cfg(16, 4)
        };
        let err = measure(&c, &AlgoKind::SpreadOut).unwrap_err().to_string();
        assert!(err.contains("rank"), "{err}");
        let mut c = cfg(16, 4);
        c.profile.alpha_g = f64::NAN;
        let err = measure(&c, &AlgoKind::SpreadOut).unwrap_err().to_string();
        assert!(err.contains("alpha_g"), "{err}");
    }

    #[test]
    fn analytic_estimate_degrades_under_faults() {
        use crate::comm::FaultSpec;
        let mut c = cfg(16, 4);
        c.engine_limit_log = 8;
        c.engine_limit_replay = 8;
        let healthy = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(healthy.fidelity, Fidelity::Analytic);
        c.faults = FaultSpec::parse("straggler:rank=0,slow=4").unwrap();
        let faulted = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(faulted.fidelity, Fidelity::Analytic);
        assert!(faulted.median() > healthy.median(), "{} vs {}", faulted.median(), healthy.median());
    }

    #[test]
    fn persistent_measure_freezes_workload_and_admits_balanced() {
        use crate::algos::{GlobalAlgo, LocalAlgo};
        let base = cfg(16, 4);
        // Frozen workload: every start is the same run, and it matches a
        // one-shot measurement of the seed workload bit for bit.
        let one = measure(&RunConfig { iters: 1, ..base.clone() }, &AlgoKind::Tuna { radix: 4 })
            .unwrap();
        let per = measure(
            &RunConfig { persistent: true, ..base.clone() },
            &AlgoKind::Tuna { radix: 4 },
        )
        .unwrap();
        assert_eq!(per.summary.n, 3);
        assert_eq!(per.summary.min.to_bits(), per.summary.max.to_bits());
        assert_eq!(per.median().to_bits(), one.median().to_bits());
        // The balanced local schedule is only measurable persistently.
        let kind = AlgoKind::Hier { local: LocalAlgo::Balanced, global: GlobalAlgo::Linear };
        let err = measure(&base, &kind).unwrap_err().to_string();
        assert!(err.contains("persistent-only"), "{err}");
        let m = measure(&RunConfig { persistent: true, ..base }, &kind).unwrap();
        assert!(m.median() > 0.0);
        assert_eq!(m.fidelity, Fidelity::Replay);
    }

    #[test]
    fn analytic_fidelity_above_limit() {
        let mut c = cfg(16, 4);
        c.engine_limit_log = 8;
        c.engine_limit_replay = 8;
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Analytic);
    }

    #[test]
    fn linear_gets_tighter_budget() {
        // Threaded mode: the classic engine budgets.
        let c = RunConfig {
            engine_limit_linear: 64,
            engine_limit_log: 1024,
            mode: ExecMode::Threaded,
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 128, &c),
            Fidelity::Analytic
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 2 }, 128, &c),
            Fidelity::Engine
        );
    }

    #[test]
    fn replay_budget_extends_exact_fidelity() {
        // Phantom + auto: log-family points replay far past the thread
        // budget; linear families are capped at REPLAY_LIMIT_LINEAR.
        let c = RunConfig::default(); // limits 512 / 2048 / 8192 / 65536, auto
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 2 }, 8192, &c),
            Fidelity::Replay
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 2 }, 16384, &c),
            Fidelity::Analytic
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, REPLAY_LIMIT_LINEAR, &c),
            Fidelity::Replay
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, REPLAY_LIMIT_LINEAR + 1, &c),
            Fidelity::Analytic
        );
        // An explicitly tightened replay budget hands points back to the
        // threaded oracle (its own budget permitting), never the other
        // way around: replay extends exact coverage, it cannot shrink it.
        let tight = RunConfig {
            engine_limit_replay: 8,
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 2 }, 16, &tight),
            Fidelity::Engine
        );
        // Linear plans hold O(P²) ops: a huge threaded budget must not
        // smuggle a beyond-cap P into the plan compiler.
        let wide_linear = RunConfig {
            engine_limit_linear: 8192,
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 8192, &wide_linear),
            Fidelity::Engine
        );
    }

    #[test]
    fn sparse_workloads_use_the_sparse_replay_budget() {
        // Sparse plans scale with nnz, so the far larger sparse budget
        // applies — to every family, linear ones included.
        let c = RunConfig {
            dist: Dist::Sparse { nnz: 16, max: 1024 },
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 4 }, 32768, &c),
            Fidelity::Replay
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 32768, &c),
            Fidelity::Replay
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 4 }, 65536, &c),
            Fidelity::Replay,
            "sharded replay raised the default sparse budget to 65536"
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 4 }, 131072, &c),
            Fidelity::Analytic
        );
        // Dense workloads keep the dense caps.
        let d = RunConfig::default();
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 32768, &d),
            Fidelity::Analytic
        );
        // A "sparse" dist dense enough to escape the nnz envelope must
        // not smuggle O(P²)-scale plans past the dense caps: it falls
        // back to the dense rules (linear cap / dense log cap).
        let dense_sparse = RunConfig {
            dist: Dist::Sparse { nnz: 32768, max: 1024 },
            ..RunConfig::default()
        };
        assert_eq!(
            choose_fidelity(&AlgoKind::SpreadOut, 32768, &dense_sparse),
            Fidelity::Analytic
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 4 }, 32768, &dense_sparse),
            Fidelity::Analytic
        );
        assert_eq!(
            choose_fidelity(&AlgoKind::Tuna { radix: 4 }, 8192, &dense_sparse),
            Fidelity::Replay,
            "inside the dense log budget the fallback still replays"
        );
        assert_eq!(SPARSE_REPLAY_NNZ_ROW, 64);
    }

    #[test]
    fn measure_rejects_replay_with_real_payloads() {
        let c = RunConfig {
            mode: ExecMode::Replay,
            real_payloads: true,
            ..cfg(16, 4)
        };
        let err = measure(&c, &AlgoKind::Tuna { radix: 2 }).unwrap_err().to_string();
        assert!(err.contains("phantom-only"), "{err}");
    }

    #[test]
    fn measure_rejects_invalid_params() {
        let c = cfg(16, 4);
        assert!(measure(&c, &AlgoKind::Tuna { radix: 99 }).is_err());
    }

    #[test]
    fn measure_surfaces_bad_topology_as_config_error() {
        // q ∤ p and q = 0 must come back as typed config errors from the
        // shared Topology::try_new check — never a rank-thread panic.
        for (p, q) in [(10usize, 4usize), (8, 0)] {
            let c = RunConfig { p, q, ..RunConfig::default() };
            let err = measure(&c, &AlgoKind::SpreadOut).unwrap_err().to_string();
            assert!(err.contains("configuration"), "P={p} Q={q}: {err}");
        }
    }

    #[test]
    fn segmented_measure_is_bit_identical_across_executors() {
        for overlap in [false, true] {
            let seg = |mode| RunConfig {
                mode,
                segments: 4,
                overlap,
                compute: 2e-5,
                ..cfg(24, 4)
            };
            let a = measure(&seg(ExecMode::Threaded), &AlgoKind::Tuna { radix: 3 }).unwrap();
            let b = measure(&seg(ExecMode::Replay), &AlgoKind::Tuna { radix: 3 }).unwrap();
            assert_eq!(a.fidelity, Fidelity::Engine);
            assert_eq!(b.fidelity, Fidelity::Replay);
            assert_eq!(a.summary.median.to_bits(), b.summary.median.to_bits(), "overlap={overlap}");
            assert_eq!(a.summary.min.to_bits(), b.summary.min.to_bits());
            assert_eq!(a.summary.max.to_bits(), b.summary.max.to_bits());
            assert_eq!(a.phases, b.phases);
        }
    }

    #[test]
    fn measure_rejects_segment_contradictions() {
        let err = |c: &RunConfig| measure(c, &AlgoKind::Tuna { radix: 2 }).unwrap_err().to_string();
        let e = err(&RunConfig { segments: 0, ..cfg(16, 4) });
        assert!(e.contains("segments must be >= 1"), "{e}");
        let e = err(&RunConfig { overlap: true, ..cfg(16, 4) });
        assert!(e.contains("requires segments >= 2"), "{e}");
        let e = err(&RunConfig { segments: 4, real_payloads: true, ..cfg(16, 4) });
        assert!(e.contains("phantom-only"), "{e}");
        let e = err(&RunConfig { segments: 4, persistent: true, ..cfg(16, 4) });
        assert!(e.contains("persistent"), "{e}");
    }

    #[test]
    fn plan_stats_surface_on_replay_points_only() {
        let c = RunConfig { plan_stats: true, ..cfg(16, 4) };
        let m = measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap();
        assert_eq!(m.fidelity, Fidelity::Replay);
        let st = m.plan_stats.expect("replay point with plan-stats=true");
        assert!(st.total_ops > 0);
        assert!(st.distinct_programs >= 1);
        assert!(st.plan_bytes > 0 && st.legacy_bytes > 0);
        // Threaded runs never compile a plan to report on, and the knob
        // off means no extra compile at all.
        let c = RunConfig { plan_stats: true, mode: ExecMode::Threaded, ..cfg(16, 4) };
        assert!(measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap().plan_stats.is_none());
        let c = cfg(16, 4);
        assert!(measure(&c, &AlgoKind::Tuna { radix: 4 }).unwrap().plan_stats.is_none());
    }

    #[test]
    fn explicit_compile_threads_measure_bit_identically() {
        // Purely a wallclock knob: every worker count replays to the
        // same virtual clocks.
        let base = measure(&cfg(24, 4), &AlgoKind::Tuna { radix: 3 }).unwrap();
        for threads in [1usize, 2, 8] {
            let c = RunConfig { compile_threads: Some(threads), ..cfg(24, 4) };
            let m = measure(&c, &AlgoKind::Tuna { radix: 3 }).unwrap();
            assert_eq!(m.summary.median.to_bits(), base.summary.median.to_bits(), "t={threads}");
            assert_eq!(m.phases, base.phases);
        }
    }

    #[test]
    fn iterations_produce_spread() {
        let c = cfg(16, 4);
        let m = measure(&c, &AlgoKind::Tuna { radix: 2 }).unwrap();
        // Different seeds -> different workloads -> nonzero spread.
        assert!(m.summary.max >= m.summary.min);
        assert!(m.summary.stddev >= 0.0);
    }
}
