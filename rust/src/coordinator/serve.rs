//! Multi-tenant serving of persistent collectives (`tuna serve`).
//!
//! N tenants each freeze one collective in a [`PersistentColl`] handle
//! — heterogeneous (P, Q, distribution, algorithm) mixes are the point —
//! and issue calls with Poisson arrivals into one shared serving engine.
//! Per-call demand (the collective's virtual-time makespan) is measured
//! **once per tenant** through the handle; the serving simulation then
//! models cross-tenant contention with deterministic processor sharing:
//! all admitted calls share the engine's capacity equally, so a call's
//! service rate is 1/n while n calls are in flight.
//!
//! The `pace` knob is burst pacing / admission control: at most `pace`
//! calls are admitted concurrently (0 = unlimited), the rest wait in a
//! FIFO queue. Latency is completion minus arrival — queue wait included
//! — reported per tenant as nearest-rank p50/p95/p99.
//!
//! Everything is deterministic: arrivals come from per-tenant PCG
//! streams, the event loop breaks ties by (time, sequence), and demands
//! come from the bit-identical simulator — two runs of the same config
//! produce byte-identical reports.

use std::collections::VecDeque;

use crate::algos::{AlgoKind, ExecMode};
use crate::comm::{Engine, PersistentColl, Topology};
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::util::prng::Pcg64;
use crate::workload::{BlockSizes, Dist};

/// One tenant: a frozen collective plus its traffic intensity.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub p: usize,
    pub q: usize,
    pub dist: Dist,
    pub algo: AlgoKind,
    /// Mean arrival rate, calls per simulated second.
    pub rate: f64,
    /// Workload seed (frozen into the tenant's handle).
    pub seed: u64,
}

/// Configuration of one serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub tenants: Vec<TenantSpec>,
    pub profile: MachineProfile,
    /// Arrival horizon, simulated seconds (arrivals stop here; in-flight
    /// calls drain to completion).
    pub seconds: f64,
    /// Max concurrently admitted calls (0 = unlimited).
    pub pace: usize,
    /// Seed for the arrival processes.
    pub seed: u64,
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(TunaError::config("serve: need at least one tenant"));
        }
        if !(self.seconds > 0.0) {
            return Err(TunaError::config("serve: seconds must be > 0"));
        }
        for t in &self.tenants {
            if !(t.rate > 0.0) {
                return Err(TunaError::config(format!(
                    "serve: tenant `{}` rate must be > 0",
                    t.name
                )));
            }
        }
        Ok(())
    }
}

/// Per-tenant serving statistics.
#[derive(Clone, Debug)]
pub struct TenantStat {
    pub name: String,
    pub algo: String,
    pub p: usize,
    pub q: usize,
    pub dist: String,
    pub rate: f64,
    /// Per-call demand through the persistent handle, seconds.
    pub demand: f64,
    pub calls: usize,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

/// Result of one serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub tenants: Vec<TenantStat>,
    pub pace: usize,
    pub seconds: f64,
    pub total_calls: usize,
    /// Time the last call completed (>= `seconds` under load).
    pub drain: f64,
    /// Offered load: Σ rate·demand — > 1 means arrivals outpace the
    /// engine and queues grow until the horizon.
    pub offered_load: f64,
}

/// One call arrival.
#[derive(Clone, Copy, Debug)]
pub struct Call {
    pub tenant: usize,
    pub arrival: f64,
}

/// Measure each tenant's per-call demand: build the tenant's engine,
/// freeze its collective in a [`PersistentColl`], and start it once.
/// Phantom payloads, so `Auto` resolves to the bit-identical replay
/// executor; persistent-only kinds (hier local `balanced`) are admitted
/// because the handle is the authorization. Split from [`simulate`] so
/// pace/load sweeps re-simulate without re-measuring.
pub fn measure_tenants(cfg: &ServeConfig) -> Result<Vec<f64>> {
    cfg.validate()?;
    let mut demands = Vec::with_capacity(cfg.tenants.len());
    for t in &cfg.tenants {
        let topo = Topology::try_new(t.p, t.q)?;
        let engine = Engine::new(cfg.profile.clone(), topo);
        let sizes = BlockSizes::generate(t.p, t.dist, t.seed);
        let handle = PersistentColl::init(&engine, t.algo, &sizes, false, ExecMode::Auto)?;
        demands.push(handle.start_frozen()?.makespan);
    }
    Ok(demands)
}

/// Poisson arrivals for every tenant over `[0, cfg.seconds)`, merged and
/// sorted by (time, generation order). Each tenant draws from its own
/// PCG stream, so adding a tenant never perturbs the others' arrivals.
pub fn poisson_calls(cfg: &ServeConfig) -> Vec<Call> {
    let mut calls: Vec<(f64, usize, Call)> = Vec::new();
    let mut seq = 0usize;
    for (i, t) in cfg.tenants.iter().enumerate() {
        let mut rng = Pcg64::new(cfg.seed, 0x5E12_5E12u64 ^ (i as u64));
        let mut at = 0.0f64;
        loop {
            let u = rng.next_f64();
            at += -(1.0 - u).ln() / t.rate;
            if at >= cfg.seconds {
                break;
            }
            calls.push((at, seq, Call { tenant: i, arrival: at }));
            seq += 1;
        }
    }
    calls.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    calls.into_iter().map(|(_, _, c)| c).collect()
}

/// Deterministic processor-sharing event loop: admitted calls split the
/// engine's capacity equally; beyond `pace` concurrent calls (0 =
/// unlimited) arrivals queue FIFO. Returns per-tenant latency lists (in
/// completion order) and the drain time. Completions tie-break before
/// arrivals, and simultaneous completions resolve in admission order —
/// the loop is a pure function of its inputs.
pub fn simulate_calls(
    n_tenants: usize,
    calls: &[Call],
    demands: &[f64],
    pace: usize,
) -> (Vec<Vec<f64>>, f64) {
    let cap = if pace == 0 { usize::MAX } else { pace };
    // Progress is tracked in cumulative per-call service `v` (the classic
    // PS virtual time): while n calls are admitted, v advances at 1/n per
    // wall second, and a call admitted at v0 with demand d completes when
    // v reaches v0 + d. Completion times are then exact comparisons on
    // targets — no per-call decrement drift.
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_tenants];
    let mut active: Vec<(usize, f64)> = Vec::new(); // (call idx, target v)
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut t = 0.0f64;
    let mut v = 0.0f64;
    let mut next = 0usize;
    let mut drain = 0.0f64;
    loop {
        let min_target = active
            .iter()
            .map(|&(_, tv)| tv)
            .fold(f64::INFINITY, f64::min);
        let t_comp = if active.is_empty() {
            f64::INFINITY
        } else {
            t + (min_target - v) * active.len() as f64
        };
        let t_arr = if next < calls.len() { calls[next].arrival } else { f64::INFINITY };
        if t_comp == f64::INFINITY && t_arr == f64::INFINITY {
            break;
        }
        if t_comp <= t_arr {
            t = t_comp;
            v = min_target;
            // Complete every call whose target is reached (ties complete
            // together, in admission order — `retain` preserves it).
            active.retain(|&(idx, tv)| {
                if tv <= v {
                    let c = calls[idx];
                    latencies[c.tenant].push(t - c.arrival);
                    drain = t;
                    false
                } else {
                    true
                }
            });
            while active.len() < cap {
                match queue.pop_front() {
                    Some(idx) => active.push((idx, v + demands[calls[idx].tenant])),
                    None => break,
                }
            }
        } else {
            if !active.is_empty() {
                v += (t_arr - t) / active.len() as f64;
            }
            t = t_arr;
            let idx = next;
            next += 1;
            if active.len() < cap {
                active.push((idx, v + demands[calls[idx].tenant]));
            } else {
                queue.push_back(idx);
            }
        }
    }
    (latencies, drain)
}

/// Nearest-rank percentile of an unsorted sample (0.0 on empty input).
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((pct / 100.0) * s.len() as f64).ceil() as usize;
    s[rank.clamp(1, s.len()) - 1]
}

/// Simulate serving with pre-measured `demands` (from
/// [`measure_tenants`]) and assemble the per-tenant report.
pub fn simulate(cfg: &ServeConfig, demands: &[f64]) -> ServeReport {
    let calls = poisson_calls(cfg);
    let (latencies, drain) = simulate_calls(cfg.tenants.len(), &calls, demands, cfg.pace);
    let tenants: Vec<TenantStat> = cfg
        .tenants
        .iter()
        .zip(demands)
        .zip(&latencies)
        .map(|((t, &demand), lat)| TenantStat {
            name: t.name.clone(),
            algo: t.algo.name(),
            p: t.p,
            q: t.q,
            dist: t.dist.name().to_string(),
            rate: t.rate,
            demand,
            calls: lat.len(),
            p50: percentile(lat, 50.0),
            p95: percentile(lat, 95.0),
            p99: percentile(lat, 99.0),
            mean: if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 },
            max: lat.iter().copied().fold(0.0, f64::max),
        })
        .collect();
    let total_calls = tenants.iter().map(|t| t.calls).sum();
    let offered_load = cfg
        .tenants
        .iter()
        .zip(demands)
        .map(|(t, &d)| t.rate * d)
        .sum();
    ServeReport {
        tenants,
        pace: cfg.pace,
        seconds: cfg.seconds,
        total_calls,
        drain,
        offered_load,
    }
}

/// Full serving run: measure every tenant's demand through its
/// persistent handle, then simulate the shared engine.
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    let demands = measure_tenants(cfg)?;
    Ok(simulate(cfg, &demands))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{GlobalAlgo, LocalAlgo};

    fn tenant(name: &str, rate: f64, algo: AlgoKind) -> TenantSpec {
        TenantSpec {
            name: name.into(),
            p: 16,
            q: 4,
            dist: Dist::Uniform { max: 128 },
            algo,
            rate,
            seed: 7,
        }
    }

    fn cfg2() -> ServeConfig {
        ServeConfig {
            tenants: vec![
                tenant("a", 40.0, AlgoKind::Tuna { radix: 4 }),
                tenant("b", 25.0, AlgoKind::SpreadOut),
            ],
            profile: MachineProfile::test_flat(),
            seconds: 0.5,
            pace: 0,
            seed: 11,
        }
    }

    #[test]
    fn two_simultaneous_calls_share_capacity() {
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let (lat, drain) = simulate_calls(2, &calls, &[1.0, 1.0], 0);
        // Processor sharing: both run at rate 1/2, both finish at t = 2.
        assert_eq!(lat[0], vec![2.0]);
        assert_eq!(lat[1], vec![2.0]);
        assert_eq!(drain, 2.0);
    }

    #[test]
    fn pace_one_serializes_with_fifo_queueing() {
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let (lat, drain) = simulate_calls(2, &calls, &[1.0, 1.0], 1);
        // Admission control: the first call runs alone (finishes at 1),
        // the second waits in queue and finishes at 2.
        assert_eq!(lat[0], vec![1.0]);
        assert_eq!(lat[1], vec![2.0]);
        assert_eq!(drain, 2.0);
    }

    #[test]
    fn staggered_arrivals_interleave_correctly() {
        // Call A (demand 2) arrives at 0; call B (demand 1) at 1. From
        // t=1 they share: A has 1 unit left, B has 1; both finish at 3.
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 1.0 },
        ];
        let (lat, _) = simulate_calls(2, &calls, &[2.0, 1.0], 0);
        assert_eq!(lat[0], vec![3.0]);
        assert_eq!(lat[1], vec![2.0]);
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let s = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 95.0), 4.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn poisson_streams_are_per_tenant_and_deterministic() {
        let cfg = cfg2();
        let a = poisson_calls(&cfg);
        let b = poisson_calls(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.arrival.to_bits() == y.arrival.to_bits() && x.tenant == y.tenant));
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(a.iter().all(|c| c.arrival < cfg.seconds));
        // Dropping a tenant leaves the survivor's stream untouched.
        let solo = ServeConfig { tenants: vec![cfg.tenants[0].clone()], ..cfg.clone() };
        let sa = poisson_calls(&solo);
        let first: Vec<u64> = a
            .iter()
            .filter(|c| c.tenant == 0)
            .map(|c| c.arrival.to_bits())
            .collect();
        let solo_bits: Vec<u64> = sa.iter().map(|c| c.arrival.to_bits()).collect();
        assert_eq!(first, solo_bits);
    }

    #[test]
    fn serve_end_to_end_is_deterministic_and_reports_percentiles() {
        let cfg = cfg2();
        let r1 = serve(&cfg).unwrap();
        let r2 = serve(&cfg).unwrap();
        assert_eq!(r1.total_calls, r2.total_calls);
        assert!(r1.total_calls > 0);
        assert!(r1.offered_load > 0.0);
        for (a, b) in r1.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.p50.to_bits(), b.p50.to_bits());
            assert_eq!(a.p95.to_bits(), b.p95.to_bits());
            assert_eq!(a.p99.to_bits(), b.p99.to_bits());
            assert!(a.p50 <= a.p95 && a.p95 <= a.p99, "{}: percentile order", a.name);
            // Latency can never beat the bare demand (tolerance: the
            // completion-minus-arrival subtraction rounds at ~1 ulp of
            // the arrival clock).
            assert!(a.p50 >= a.demand * (1.0 - 1e-9), "{} p50 < demand", a.name);
        }
        assert!(r1.drain > 0.0);
    }

    #[test]
    fn balanced_tenants_serve_through_their_handles() {
        // The persistent-only composition is a legal tenant algo: the
        // serving engine runs everything through PersistentColl.
        let cfg = ServeConfig {
            tenants: vec![tenant(
                "bal",
                30.0,
                AlgoKind::Hier { local: LocalAlgo::Balanced, global: GlobalAlgo::Linear },
            )],
            ..cfg2()
        };
        let r = serve(&cfg).unwrap();
        assert!(r.tenants[0].calls > 0);
        assert!(r.tenants[0].demand > 0.0);
    }

    #[test]
    fn tighter_pace_never_reduces_queueing_below_zero_and_validates() {
        let cfg = cfg2();
        let demands = measure_tenants(&cfg).unwrap();
        let free = simulate(&cfg, &demands);
        let paced = simulate(&ServeConfig { pace: 1, ..cfg.clone() }, &demands);
        // Same arrivals either way; the knob only changes scheduling.
        assert_eq!(free.total_calls, paced.total_calls);
        // Bad configs are typed errors.
        assert!(ServeConfig { tenants: vec![], ..cfg.clone() }.validate().is_err());
        assert!(ServeConfig { seconds: 0.0, ..cfg.clone() }.validate().is_err());
        let mut bad = cfg;
        bad.tenants[0].rate = 0.0;
        assert!(bad.validate().is_err());
    }
}
