//! Multi-tenant serving of persistent collectives (`tuna serve`).
//!
//! N tenants each freeze one collective in a [`PersistentColl`] handle
//! — heterogeneous (P, Q, distribution, algorithm) mixes are the point —
//! and issue calls with Poisson arrivals into one shared serving engine.
//! Per-call demand (the collective's virtual-time makespan) is measured
//! **once per tenant** through the handle; the serving simulation then
//! models cross-tenant contention with deterministic processor sharing:
//! all admitted calls share the engine's capacity equally, so a call's
//! service rate is 1/n while n calls are in flight.
//!
//! The `pace` knob is burst pacing / admission control: at most `pace`
//! calls are admitted concurrently (0 = unlimited), the rest wait in a
//! FIFO queue. Latency is completion minus arrival — queue wait included
//! — reported per tenant as nearest-rank p50/p95/p99.
//!
//! Everything is deterministic: arrivals come from per-tenant PCG
//! streams, the event loop breaks ties by (time, sequence), and demands
//! come from the bit-identical simulator — two runs of the same config
//! produce byte-identical reports.

use std::collections::VecDeque;

use crate::algos::{AlgoKind, ExecMode};
use crate::comm::{Engine, PersistentColl, Topology};
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::util::prng::Pcg64;
use crate::workload::{BlockSizes, Dist};

/// One tenant: a frozen collective plus its traffic intensity.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    pub p: usize,
    pub q: usize,
    pub dist: Dist,
    pub algo: AlgoKind,
    /// Mean arrival rate, calls per simulated second.
    pub rate: f64,
    /// Workload seed (frozen into the tenant's handle).
    pub seed: u64,
    /// Per-attempt deadline, simulated seconds (0 = none). An attempt —
    /// original call or retry — that has not completed `deadline`
    /// seconds after *its own* issue time is cancelled (its engine
    /// share is freed immediately) and either retried or shed.
    pub deadline: f64,
    /// Retry budget after a timeout. Retry k is re-issued
    /// `deadline * 2^(k-1)` after its timeout fires — deterministic
    /// exponential backoff, no RNG, so the event loop stays a pure
    /// function of its inputs. A call that exhausts the budget is shed.
    pub retries: u32,
}

/// Configuration of one serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub tenants: Vec<TenantSpec>,
    pub profile: MachineProfile,
    /// Arrival horizon, simulated seconds (arrivals stop here; in-flight
    /// calls drain to completion).
    pub seconds: f64,
    /// Max concurrently admitted calls (0 = unlimited).
    pub pace: usize,
    /// Seed for the arrival processes.
    pub seed: u64,
    /// Retained-plan bound per tenant engine (`plan-cache-cap=N`, LRU):
    /// long-lived serving engines keep at most this many compiled plans
    /// alive; evictions are counted next to hits/misses. Values are
    /// clamped to >= 1 by [`crate::comm::PlanCache::with_capacity`].
    pub plan_cache_cap: usize,
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(TunaError::config("serve: need at least one tenant"));
        }
        if !(self.seconds > 0.0) {
            return Err(TunaError::config("serve: seconds must be > 0"));
        }
        // Reject poisoned machine parameters before measuring demands.
        self.profile.validate()?;
        for t in &self.tenants {
            if !(t.rate > 0.0) {
                return Err(TunaError::config(format!(
                    "serve: tenant `{}` rate must be > 0",
                    t.name
                )));
            }
            if !t.deadline.is_finite() || t.deadline < 0.0 {
                return Err(TunaError::config(format!(
                    "serve: tenant `{}` deadline must be finite and >= 0 (0 = none)",
                    t.name
                )));
            }
            if t.retries > 0 && t.deadline == 0.0 {
                return Err(TunaError::config(format!(
                    "serve: tenant `{}` retries require a deadline (retries re-issue \
                     timed-out calls; without a deadline nothing ever times out)",
                    t.name
                )));
            }
        }
        Ok(())
    }
}

/// Per-tenant serving statistics.
#[derive(Clone, Debug)]
pub struct TenantStat {
    pub name: String,
    pub algo: String,
    pub p: usize,
    pub q: usize,
    pub dist: String,
    pub rate: f64,
    /// Per-call demand through the persistent handle, seconds.
    pub demand: f64,
    /// Successfully completed calls (== all arrivals when the tenant has
    /// no deadline; percentiles and mean/max are over these).
    pub calls: usize,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
    /// Attempts (originals and retries) cancelled at their deadline.
    pub timeouts: u64,
    /// Re-issued attempts after a timeout.
    pub retries: u64,
    /// Calls dropped after exhausting the retry budget.
    pub shed: u64,
    /// Fraction of the tenant's original calls that eventually
    /// completed (1.0 when nothing is shed).
    pub goodput: f64,
}

/// Result of one serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub tenants: Vec<TenantStat>,
    pub pace: usize,
    pub seconds: f64,
    pub total_calls: usize,
    /// Time the last call completed (>= `seconds` under load).
    pub drain: f64,
    /// Offered load: Σ rate·demand — > 1 means arrivals outpace the
    /// engine and queues grow until the horizon.
    pub offered_load: f64,
}

/// One call arrival.
#[derive(Clone, Copy, Debug)]
pub struct Call {
    pub tenant: usize,
    pub arrival: f64,
}

/// Measure each tenant's per-call demand: build the tenant's engine,
/// freeze its collective in a [`PersistentColl`], and start it once.
/// Phantom payloads, so `Auto` resolves to the bit-identical replay
/// executor; persistent-only kinds (hier local `balanced`) are admitted
/// because the handle is the authorization. Split from [`simulate`] so
/// pace/load sweeps re-simulate without re-measuring.
pub fn measure_tenants(cfg: &ServeConfig) -> Result<Vec<f64>> {
    Ok(measure_tenants_counters(cfg)?.0)
}

/// Aggregate plan-cache accounting across the tenant engines of one
/// [`measure_tenants_counters`] pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// The configured per-engine bound (`plan-cache-cap`).
    pub capacity: usize,
}

/// [`measure_tenants`] plus the aggregated plan-cache counters of the
/// tenant engines (hits / misses / evictions under the configured LRU
/// bound), for the serving report.
pub fn measure_tenants_counters(cfg: &ServeConfig) -> Result<(Vec<f64>, PlanCacheCounters)> {
    cfg.validate()?;
    let mut demands = Vec::with_capacity(cfg.tenants.len());
    let mut counters = PlanCacheCounters {
        capacity: cfg.plan_cache_cap.max(1),
        ..PlanCacheCounters::default()
    };
    for t in &cfg.tenants {
        let topo = Topology::try_new(t.p, t.q)?;
        let engine = Engine::new(cfg.profile.clone(), topo)
            .with_plan_cache_capacity(cfg.plan_cache_cap);
        let sizes = BlockSizes::generate(t.p, t.dist, t.seed);
        let handle = PersistentColl::init(&engine, t.algo, &sizes, false, ExecMode::Auto)?;
        demands.push(handle.start_frozen()?.makespan);
        let (hits, misses) = engine.plan_cache.stats();
        counters.hits += hits;
        counters.misses += misses;
        counters.evictions += engine.plan_cache.evictions();
    }
    Ok((demands, counters))
}

/// Poisson arrivals for every tenant over `[0, cfg.seconds)`, merged and
/// sorted by (time, generation order). Each tenant draws from its own
/// PCG stream, so adding a tenant never perturbs the others' arrivals.
pub fn poisson_calls(cfg: &ServeConfig) -> Vec<Call> {
    let mut calls: Vec<(f64, usize, Call)> = Vec::new();
    let mut seq = 0usize;
    for (i, t) in cfg.tenants.iter().enumerate() {
        let mut rng = Pcg64::new(cfg.seed, 0x5E12_5E12u64 ^ (i as u64));
        let mut at = 0.0f64;
        loop {
            let u = rng.next_f64();
            at += -(1.0 - u).ln() / t.rate;
            if at >= cfg.seconds {
                break;
            }
            calls.push((at, seq, Call { tenant: i, arrival: at }));
            seq += 1;
        }
    }
    calls.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    calls.into_iter().map(|(_, _, c)| c).collect()
}

/// Deterministic processor-sharing event loop: admitted calls split the
/// engine's capacity equally; beyond `pace` concurrent calls (0 =
/// unlimited) arrivals queue FIFO. Returns per-tenant latency lists (in
/// completion order) and the drain time. Completions tie-break before
/// arrivals, and simultaneous completions resolve in admission order —
/// the loop is a pure function of its inputs.
pub fn simulate_calls(
    n_tenants: usize,
    calls: &[Call],
    demands: &[f64],
    pace: usize,
) -> (Vec<Vec<f64>>, f64) {
    let cap = if pace == 0 { usize::MAX } else { pace };
    // Progress is tracked in cumulative per-call service `v` (the classic
    // PS virtual time): while n calls are admitted, v advances at 1/n per
    // wall second, and a call admitted at v0 with demand d completes when
    // v reaches v0 + d. Completion times are then exact comparisons on
    // targets — no per-call decrement drift.
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_tenants];
    let mut active: Vec<(usize, f64)> = Vec::new(); // (call idx, target v)
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut t = 0.0f64;
    let mut v = 0.0f64;
    let mut next = 0usize;
    let mut drain = 0.0f64;
    loop {
        let min_target = active
            .iter()
            .map(|&(_, tv)| tv)
            .fold(f64::INFINITY, f64::min);
        let t_comp = if active.is_empty() {
            f64::INFINITY
        } else {
            t + (min_target - v) * active.len() as f64
        };
        let t_arr = if next < calls.len() { calls[next].arrival } else { f64::INFINITY };
        if t_comp == f64::INFINITY && t_arr == f64::INFINITY {
            break;
        }
        if t_comp <= t_arr {
            t = t_comp;
            v = min_target;
            // Complete every call whose target is reached (ties complete
            // together, in admission order — `retain` preserves it).
            active.retain(|&(idx, tv)| {
                if tv <= v {
                    let c = calls[idx];
                    latencies[c.tenant].push(t - c.arrival);
                    drain = t;
                    false
                } else {
                    true
                }
            });
            while active.len() < cap {
                match queue.pop_front() {
                    Some(idx) => active.push((idx, v + demands[calls[idx].tenant])),
                    None => break,
                }
            }
        } else {
            if !active.is_empty() {
                v += (t_arr - t) / active.len() as f64;
            }
            t = t_arr;
            let idx = next;
            next += 1;
            if active.len() < cap {
                active.push((idx, v + demands[calls[idx].tenant]));
            } else {
                queue.push_back(idx);
            }
        }
    }
    (latencies, drain)
}

/// Outcome of one [`simulate_calls_resilient`] run: per-tenant latency
/// samples (successful calls only, completion minus *original* arrival)
/// plus the degradation counters.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub latencies: Vec<Vec<f64>>,
    pub drain: f64,
    pub timeouts: Vec<u64>,
    pub retries: Vec<u64>,
    pub shed: Vec<u64>,
    /// Attempts issued per tenant (originals + retries).
    pub issued: Vec<u64>,
}

/// [`simulate_calls`] with graceful degradation: per-tenant deadlines
/// cancel attempts (active *or* queued) that outlive them, freeing the
/// engine share for the survivors; cancelled attempts are re-issued
/// with deterministic exponential backoff (`deadline * 2^(k-1)` after
/// the k-th timeout) until the tenant's retry budget runs out, then
/// shed. Tenants with `deadline = 0` never time out. Completions
/// tie-break before timeouts, timeouts before arrivals, so the loop
/// remains a pure function of its inputs — a call completing exactly at
/// its deadline succeeds.
pub fn simulate_calls_resilient(
    n_tenants: usize,
    calls: &[Call],
    demands: &[f64],
    pace: usize,
    deadlines: &[f64],
    retry_budget: &[u32],
) -> SimOutcome {
    // One outstanding attempt per call at any time; each attempt carries
    // its own issue time (deadlines are per attempt, queue wait counts).
    struct Attempt {
        tenant: usize,
        orig_arrival: f64,
        issued_at: f64,
        try_no: u32,
    }
    let cap = if pace == 0 { usize::MAX } else { pace };
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_tenants];
    let mut timeouts = vec![0u64; n_tenants];
    let mut retries = vec![0u64; n_tenants];
    let mut shed = vec![0u64; n_tenants];
    let mut issued = vec![0u64; n_tenants];
    let mut attempts: Vec<Attempt> = Vec::new();
    let mut active: Vec<(usize, f64)> = Vec::new(); // (attempt id, target v)
    let mut queue: VecDeque<usize> = VecDeque::new();
    // Scheduled retry arrivals, kept sorted by (time, schedule order).
    let mut pending: VecDeque<(f64, usize)> = VecDeque::new();
    let mut t = 0.0f64;
    let mut v = 0.0f64;
    let mut next = 0usize;
    let mut drain = 0.0f64;
    let expiry = |a: &Attempt| {
        let d = deadlines[a.tenant];
        if d > 0.0 {
            a.issued_at + d
        } else {
            f64::INFINITY
        }
    };
    loop {
        let min_target = active
            .iter()
            .map(|&(_, tv)| tv)
            .fold(f64::INFINITY, f64::min);
        let t_comp = if active.is_empty() {
            f64::INFINITY
        } else {
            t + (min_target - v) * active.len() as f64
        };
        let t_orig = if next < calls.len() { calls[next].arrival } else { f64::INFINITY };
        let t_retry = pending.front().map_or(f64::INFINITY, |&(at, _)| at);
        let t_arr = t_orig.min(t_retry);
        let t_out = active
            .iter()
            .map(|&(id, _)| expiry(&attempts[id]))
            .chain(queue.iter().map(|&id| expiry(&attempts[id])))
            .fold(f64::INFINITY, f64::min);
        if t_comp == f64::INFINITY && t_arr == f64::INFINITY && t_out == f64::INFINITY {
            break;
        }
        if t_comp <= t_arr && t_comp <= t_out {
            // Completion(s): identical to the legacy loop.
            t = t_comp;
            v = min_target;
            active.retain(|&(id, tv)| {
                if tv <= v {
                    let a = &attempts[id];
                    latencies[a.tenant].push(t - a.orig_arrival);
                    drain = t;
                    false
                } else {
                    true
                }
            });
        } else if t_out <= t_arr {
            // Timeout(s): cancel every expired attempt, active or
            // queued. An active attempt's engine share is freed on the
            // spot (its partial progress is wasted work); survivors
            // speed up from here because t_comp re-derives from the
            // shrunken active set.
            if !active.is_empty() {
                v += (t_out - t) / active.len() as f64;
            }
            t = t_out;
            let mut expire = |a_id: usize,
                              attempts: &mut Vec<Attempt>,
                              pending: &mut VecDeque<(f64, usize)>| {
                let (tenant, orig_arrival, try_no) = {
                    let a = &attempts[a_id];
                    (a.tenant, a.orig_arrival, a.try_no)
                };
                timeouts[tenant] += 1;
                if try_no < retry_budget[tenant] {
                    // Exponential backoff: retry k (1-based) waits
                    // deadline * 2^(k-1) after its timeout.
                    let backoff = deadlines[tenant] * (1u64 << try_no.min(62)) as f64;
                    let at = t + backoff;
                    retries[tenant] += 1;
                    issued[tenant] += 1;
                    let id = attempts.len();
                    attempts.push(Attempt {
                        tenant,
                        orig_arrival,
                        issued_at: at,
                        try_no: try_no + 1,
                    });
                    // Backoffs are per-tenant multiples of now, so later
                    // schedulings can land earlier: insert in (time,
                    // order) position to keep the queue sorted.
                    let pos = pending
                        .iter()
                        .position(|&(pt, _)| pt > at)
                        .unwrap_or(pending.len());
                    pending.insert(pos, (at, id));
                } else {
                    shed[tenant] += 1;
                }
            };
            let mut survivors: Vec<(usize, f64)> = Vec::with_capacity(active.len());
            for (id, tv) in active.drain(..) {
                if expiry(&attempts[id]) <= t {
                    expire(id, &mut attempts, &mut pending);
                } else {
                    survivors.push((id, tv));
                }
            }
            active = survivors;
            let mut waiting: VecDeque<usize> = VecDeque::with_capacity(queue.len());
            for id in queue.drain(..) {
                if expiry(&attempts[id]) <= t {
                    expire(id, &mut attempts, &mut pending);
                } else {
                    waiting.push_back(id);
                }
            }
            queue = waiting;
        } else {
            // Arrival (retry arrivals win time ties over originals —
            // they were scheduled strictly earlier).
            if !active.is_empty() {
                v += (t_arr - t) / active.len() as f64;
            }
            t = t_arr;
            let id = if t_retry <= t_orig {
                pending.pop_front().expect("retry arrival implies a pending entry").1
            } else {
                let c = calls[next];
                next += 1;
                issued[c.tenant] += 1;
                let id = attempts.len();
                attempts.push(Attempt {
                    tenant: c.tenant,
                    orig_arrival: c.arrival,
                    issued_at: c.arrival,
                    try_no: 0,
                });
                id
            };
            if active.len() < cap {
                active.push((id, v + demands[attempts[id].tenant]));
                continue;
            }
            queue.push_back(id);
            continue;
        }
        // Completion or timeout freed slots: admit FIFO from the queue.
        while active.len() < cap {
            match queue.pop_front() {
                Some(id) => active.push((id, v + demands[attempts[id].tenant])),
                None => break,
            }
        }
    }
    SimOutcome {
        latencies,
        drain,
        timeouts,
        retries,
        shed,
        issued,
    }
}

/// Nearest-rank percentile — re-exported from the one shared NaN-safe
/// implementation in [`crate::util::stats`] so serving and chaos
/// reporting can never drift apart on tie/NaN semantics.
pub use crate::util::stats::percentile;

/// Simulate serving with pre-measured `demands` (from
/// [`measure_tenants`]) and assemble the per-tenant report. When no
/// tenant sets a deadline the legacy processor-sharing loop runs
/// verbatim (bit-identical to every pre-degradation report); otherwise
/// the resilient loop handles timeouts, retries and shedding.
pub fn simulate(cfg: &ServeConfig, demands: &[f64]) -> ServeReport {
    let calls = poisson_calls(cfg);
    let n = cfg.tenants.len();
    let resilient = cfg.tenants.iter().any(|t| t.deadline > 0.0);
    let outcome = if resilient {
        let deadlines: Vec<f64> = cfg.tenants.iter().map(|t| t.deadline).collect();
        let budgets: Vec<u32> = cfg.tenants.iter().map(|t| t.retries).collect();
        simulate_calls_resilient(n, &calls, demands, cfg.pace, &deadlines, &budgets)
    } else {
        let (latencies, drain) = simulate_calls(n, &calls, demands, cfg.pace);
        let issued: Vec<u64> = {
            let mut per = vec![0u64; n];
            for c in &calls {
                per[c.tenant] += 1;
            }
            per
        };
        SimOutcome {
            latencies,
            drain,
            timeouts: vec![0; n],
            retries: vec![0; n],
            shed: vec![0; n],
            issued,
        }
    };
    let mut originals = vec![0u64; n];
    for c in &calls {
        originals[c.tenant] += 1;
    }
    let tenants: Vec<TenantStat> = cfg
        .tenants
        .iter()
        .enumerate()
        .zip(demands)
        .map(|((i, t), &demand)| {
            let lat = &outcome.latencies[i];
            TenantStat {
                name: t.name.clone(),
                algo: t.algo.name(),
                p: t.p,
                q: t.q,
                dist: t.dist.name().to_string(),
                rate: t.rate,
                demand,
                calls: lat.len(),
                p50: percentile(lat, 50.0),
                p95: percentile(lat, 95.0),
                p99: percentile(lat, 99.0),
                mean: if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 },
                // total_cmp keeps a stray NaN from silently vanishing
                // into the fold (f64::max would drop it).
                max: lat
                    .iter()
                    .copied()
                    .fold(0.0, |a, b| if b.total_cmp(&a).is_gt() { b } else { a }),
                timeouts: outcome.timeouts[i],
                retries: outcome.retries[i],
                shed: outcome.shed[i],
                goodput: if originals[i] == 0 {
                    1.0
                } else {
                    lat.len() as f64 / originals[i] as f64
                },
            }
        })
        .collect();
    let drain = outcome.drain;
    let total_calls = tenants.iter().map(|t| t.calls).sum();
    let offered_load = cfg
        .tenants
        .iter()
        .zip(demands)
        .map(|(t, &d)| t.rate * d)
        .sum();
    ServeReport {
        tenants,
        pace: cfg.pace,
        seconds: cfg.seconds,
        total_calls,
        drain,
        offered_load,
    }
}

/// Full serving run: measure every tenant's demand through its
/// persistent handle, then simulate the shared engine.
pub fn serve(cfg: &ServeConfig) -> Result<ServeReport> {
    let demands = measure_tenants(cfg)?;
    Ok(simulate(cfg, &demands))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{GlobalAlgo, LocalAlgo};

    fn tenant(name: &str, rate: f64, algo: AlgoKind) -> TenantSpec {
        TenantSpec {
            name: name.into(),
            p: 16,
            q: 4,
            dist: Dist::Uniform { max: 128 },
            algo,
            rate,
            seed: 7,
            deadline: 0.0,
            retries: 0,
        }
    }

    fn cfg2() -> ServeConfig {
        ServeConfig {
            tenants: vec![
                tenant("a", 40.0, AlgoKind::Tuna { radix: 4 }),
                tenant("b", 25.0, AlgoKind::SpreadOut),
            ],
            profile: MachineProfile::test_flat(),
            seconds: 0.5,
            pace: 0,
            seed: 11,
            plan_cache_cap: 64,
        }
    }

    #[test]
    fn tenant_measurement_reports_plan_cache_counters() {
        let cfg = cfg2();
        let (demands, counters) = measure_tenants_counters(&cfg).unwrap();
        assert_eq!(demands.len(), 2);
        // One compile per tenant handle, no lookups, nothing evicted
        // under a generous bound.
        assert_eq!(counters.misses, 2);
        assert_eq!(counters.hits, 0);
        assert_eq!(counters.evictions, 0);
        assert_eq!(counters.capacity, 64);
        // The thin wrapper returns the same demands.
        let plain = measure_tenants(&cfg).unwrap();
        assert!(demands.iter().zip(&plain).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn two_simultaneous_calls_share_capacity() {
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let (lat, drain) = simulate_calls(2, &calls, &[1.0, 1.0], 0);
        // Processor sharing: both run at rate 1/2, both finish at t = 2.
        assert_eq!(lat[0], vec![2.0]);
        assert_eq!(lat[1], vec![2.0]);
        assert_eq!(drain, 2.0);
    }

    #[test]
    fn pace_one_serializes_with_fifo_queueing() {
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let (lat, drain) = simulate_calls(2, &calls, &[1.0, 1.0], 1);
        // Admission control: the first call runs alone (finishes at 1),
        // the second waits in queue and finishes at 2.
        assert_eq!(lat[0], vec![1.0]);
        assert_eq!(lat[1], vec![2.0]);
        assert_eq!(drain, 2.0);
    }

    #[test]
    fn staggered_arrivals_interleave_correctly() {
        // Call A (demand 2) arrives at 0; call B (demand 1) at 1. From
        // t=1 they share: A has 1 unit left, B has 1; both finish at 3.
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 1.0 },
        ];
        let (lat, _) = simulate_calls(2, &calls, &[2.0, 1.0], 0);
        assert_eq!(lat[0], vec![3.0]);
        assert_eq!(lat[1], vec![2.0]);
    }

    #[test]
    fn percentiles_are_nearest_rank() {
        let s = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 95.0), 4.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // total_cmp sorts NaN last instead of panicking mid-report.
        let s = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert!(percentile(&s, 99.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn deadline_sheds_or_retries_an_unservable_call() {
        // Demand 1.0 against a 0.5 deadline: the call can never finish.
        let calls = [Call { tenant: 0, arrival: 0.0 }];
        let out = simulate_calls_resilient(1, &calls, &[1.0], 0, &[0.5], &[0]);
        assert!(out.latencies[0].is_empty());
        assert_eq!(out.timeouts[0], 1);
        assert_eq!(out.retries[0], 0);
        assert_eq!(out.shed[0], 1);
        // One retry: re-issued at 0.5 + 0.5*2^0 = 1.0, times out again
        // at 1.5, then shed — exponential backoff with no RNG.
        let out = simulate_calls_resilient(1, &calls, &[1.0], 0, &[0.5], &[1]);
        assert_eq!(out.timeouts[0], 2);
        assert_eq!(out.retries[0], 1);
        assert_eq!(out.shed[0], 1);
        assert_eq!(out.issued[0], 2);
        // A generous deadline changes nothing: completes at 1.0.
        let out = simulate_calls_resilient(1, &calls, &[1.0], 0, &[2.0], &[3]);
        assert_eq!(out.latencies[0], vec![1.0]);
        assert_eq!(out.timeouts[0], 0);
        assert_eq!(out.shed[0], 0);
    }

    #[test]
    fn timeout_frees_capacity_for_the_survivor() {
        // Both arrive at 0, demands 1.0 each, sharing at rate 1/2. Tenant
        // 0's 1.5 s deadline fires before the shared completion at 2.0;
        // tenant 1 then runs alone (progress 0.75) and finishes at 1.75.
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let out = simulate_calls_resilient(2, &calls, &[1.0, 1.0], 0, &[1.5, 0.0], &[0, 0]);
        assert!(out.latencies[0].is_empty());
        assert_eq!(out.shed[0], 1);
        assert_eq!(out.latencies[1], vec![1.75]);
        assert_eq!(out.drain, 1.75);
    }

    #[test]
    fn queued_attempts_time_out_too() {
        // pace=1: the second call waits its whole deadline in the queue
        // and is cancelled without ever running.
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 0, arrival: 0.0 },
        ];
        let out = simulate_calls_resilient(1, &calls, &[1.0], 1, &[0.8], &[0]);
        assert!(out.latencies[0].is_empty());
        assert_eq!(out.timeouts[0], 2);
        assert_eq!(out.shed[0], 2);
    }

    #[test]
    fn retry_succeeds_after_the_burst_clears() {
        // Tenant 0: one huge call (demand 2.0, no deadline). Tenant 1:
        // demand 0.5 under a 0.75 s deadline with 2 retries. Shared
        // capacity makes attempts 1 and 2 miss; by the third attempt
        // (t = 3.75) the engine is idle and the call lands at 4.25.
        let calls = [
            Call { tenant: 0, arrival: 0.0 },
            Call { tenant: 1, arrival: 0.0 },
        ];
        let out =
            simulate_calls_resilient(2, &calls, &[2.0, 0.5], 0, &[0.0, 0.75], &[0, 2]);
        assert_eq!(out.latencies[0], vec![2.75]);
        assert_eq!(out.latencies[1], vec![4.25]);
        assert_eq!(out.timeouts[1], 2);
        assert_eq!(out.retries[1], 2);
        assert_eq!(out.shed[1], 0);
        assert_eq!(out.drain, 4.25);
    }

    #[test]
    fn resilient_loop_without_deadlines_matches_legacy_bitwise() {
        let cfg = cfg2();
        let calls = poisson_calls(&cfg);
        let demands = [3.0e-4, 5.0e-4];
        let (legacy, drain) = simulate_calls(2, &calls, &demands, 2);
        let out = simulate_calls_resilient(2, &calls, &demands, 2, &[0.0, 0.0], &[0, 0]);
        assert_eq!(out.drain.to_bits(), drain.to_bits());
        for (a, b) in legacy.iter().zip(&out.latencies) {
            assert_eq!(a.len(), b.len());
            assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn serve_reports_goodput_and_degradation_counters() {
        // No deadlines: the legacy loop runs, goodput is exactly 1.
        let cfg = cfg2();
        let r = serve(&cfg).unwrap();
        for t in &r.tenants {
            assert_eq!(t.goodput, 1.0);
            assert_eq!(t.timeouts + t.retries + t.shed, 0);
        }
        // An impossible deadline sheds everything, deterministically.
        let mut strict = cfg2();
        strict.tenants[0].deadline = 1e-9;
        strict.tenants[0].retries = 1;
        let r1 = serve(&strict).unwrap();
        let r2 = serve(&strict).unwrap();
        assert_eq!(r1.tenants[0].goodput, 0.0);
        assert!(r1.tenants[0].shed > 0);
        assert_eq!(r1.tenants[0].timeouts, 2 * r1.tenants[0].shed, "1 retry per call");
        assert_eq!(r1.tenants[0].calls, 0);
        assert!(r1.tenants[1].goodput > 0.0);
        assert_eq!(r1.tenants[0].shed, r2.tenants[0].shed);
        assert_eq!(r1.tenants[1].p99.to_bits(), r2.tenants[1].p99.to_bits());
        // Bad degradation configs are typed errors.
        let mut bad = cfg2();
        bad.tenants[0].deadline = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = cfg2();
        bad.tenants[0].deadline = f64::NAN;
        assert!(bad.validate().is_err());
        let mut bad = cfg2();
        bad.tenants[0].retries = 3;
        assert!(bad.validate().is_err(), "retries without a deadline");
        let mut bad = cfg2();
        bad.profile.mem_bw = 0.0;
        assert!(bad.validate().is_err(), "poisoned profile");
    }

    #[test]
    fn poisson_streams_are_per_tenant_and_deterministic() {
        let cfg = cfg2();
        let a = poisson_calls(&cfg);
        let b = poisson_calls(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.arrival.to_bits() == y.arrival.to_bits() && x.tenant == y.tenant));
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(a.iter().all(|c| c.arrival < cfg.seconds));
        // Dropping a tenant leaves the survivor's stream untouched.
        let solo = ServeConfig { tenants: vec![cfg.tenants[0].clone()], ..cfg.clone() };
        let sa = poisson_calls(&solo);
        let first: Vec<u64> = a
            .iter()
            .filter(|c| c.tenant == 0)
            .map(|c| c.arrival.to_bits())
            .collect();
        let solo_bits: Vec<u64> = sa.iter().map(|c| c.arrival.to_bits()).collect();
        assert_eq!(first, solo_bits);
    }

    #[test]
    fn serve_end_to_end_is_deterministic_and_reports_percentiles() {
        let cfg = cfg2();
        let r1 = serve(&cfg).unwrap();
        let r2 = serve(&cfg).unwrap();
        assert_eq!(r1.total_calls, r2.total_calls);
        assert!(r1.total_calls > 0);
        assert!(r1.offered_load > 0.0);
        for (a, b) in r1.tenants.iter().zip(&r2.tenants) {
            assert_eq!(a.p50.to_bits(), b.p50.to_bits());
            assert_eq!(a.p95.to_bits(), b.p95.to_bits());
            assert_eq!(a.p99.to_bits(), b.p99.to_bits());
            assert!(a.p50 <= a.p95 && a.p95 <= a.p99, "{}: percentile order", a.name);
            // Latency can never beat the bare demand (tolerance: the
            // completion-minus-arrival subtraction rounds at ~1 ulp of
            // the arrival clock).
            assert!(a.p50 >= a.demand * (1.0 - 1e-9), "{} p50 < demand", a.name);
        }
        assert!(r1.drain > 0.0);
    }

    #[test]
    fn balanced_tenants_serve_through_their_handles() {
        // The persistent-only composition is a legal tenant algo: the
        // serving engine runs everything through PersistentColl.
        let cfg = ServeConfig {
            tenants: vec![tenant(
                "bal",
                30.0,
                AlgoKind::Hier { local: LocalAlgo::Balanced, global: GlobalAlgo::Linear },
            )],
            ..cfg2()
        };
        let r = serve(&cfg).unwrap();
        assert!(r.tenants[0].calls > 0);
        assert!(r.tenants[0].demand > 0.0);
    }

    #[test]
    fn tighter_pace_never_reduces_queueing_below_zero_and_validates() {
        let cfg = cfg2();
        let demands = measure_tenants(&cfg).unwrap();
        let free = simulate(&cfg, &demands);
        let paced = simulate(&ServeConfig { pace: 1, ..cfg.clone() }, &demands);
        // Same arrivals either way; the knob only changes scheduling.
        assert_eq!(free.total_calls, paced.total_calls);
        // Bad configs are typed errors.
        assert!(ServeConfig { tenants: vec![], ..cfg.clone() }.validate().is_err());
        assert!(ServeConfig { seconds: 0.0, ..cfg.clone() }.validate().is_err());
        let mut bad = cfg;
        bad.tenants[0].rate = 0.0;
        assert!(bad.validate().is_err());
    }
}
