//! Run configuration and `key=value` parsing for the CLI.

use std::sync::Arc;

use crate::algos::tuning::TuningTable;
use crate::algos::ExecMode;
use crate::comm::FaultSpec;
use crate::error::{Result, TunaError};
use crate::model::MachineProfile;
use crate::workload::Dist;

/// Configuration of a single experiment point.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Total ranks.
    pub p: usize,
    /// Ranks per node (the paper's Q; both machines use 32).
    pub q: usize,
    pub profile: MachineProfile,
    pub dist: Dist,
    pub seed: u64,
    /// Repetitions (paper: >= 20); seeds vary per iteration.
    pub iters: usize,
    /// Move and validate real payload bytes (engine only).
    pub real_payloads: bool,
    /// Engine rank budget for linear (O(P²)-message) algorithms.
    pub engine_limit_linear: usize,
    /// Engine rank budget for logarithmic algorithms.
    pub engine_limit_log: usize,
    /// Rank budget for plan/replay execution of logarithmic algorithms
    /// on *dense* workloads (linear families are additionally capped —
    /// their dense plans hold O(P²) ops). Plan compilation streams row
    /// views (O(P·K) working memory, never the P×P matrix), so the
    /// default covers P = 8192 comfortably.
    pub engine_limit_replay: usize,
    /// Rank budget for plan/replay execution of structurally *sparse*
    /// workloads (`dist=sparse:nnz=K`), every family included: sparse
    /// plans hold O(nnz) ops and the replay loop shards across workers,
    /// so exact bit-identical replay extends to P ≥ 64k by default.
    pub engine_limit_replay_sparse: usize,
    /// Execution mode for exact-fidelity points: threaded oracle,
    /// plan/replay, or auto (replay phantom, thread real).
    pub mode: ExecMode,
    /// Measure through a persistent handle (`persistent=true`): freeze
    /// the workload at `seed`, build one
    /// [`crate::comm::PersistentColl`] before the iteration loop, and
    /// `start` it per iteration — so plan compilation, payload arenas and
    /// transposes are paid once, not per iter. The default (one-shot)
    /// varies the seed per iteration like the paper's repetitions.
    pub persistent: bool,
    /// Worker-shard count for the replay executor (`replay-shards=N`);
    /// `None` (`replay-shards=auto`, the default) sizes from P and the
    /// host. Purely a wallclock knob — results are bit-identical for
    /// every value.
    pub replay_shards: Option<usize>,
    /// Persisted tuning table attached to every engine this config
    /// creates, consulted by `tuna:auto` (loaded by the CLI from
    /// `artifacts/tuning/`; not a `key=value` field).
    pub tuning: Option<Arc<TuningTable>>,
    /// Deterministic fault injection (`faults=<spec>`, see
    /// [`crate::comm::FaultSpec`]). The empty spec (the default) is
    /// provably zero-perturbation; non-empty specs perturb both
    /// executors identically (threaded ↔ replay stays bit-identical).
    pub faults: FaultSpec,
    /// Segmented execution (`segments=K`): split the collective into K
    /// chunk plans over [`crate::workload::segment_counts`] and run the
    /// stitched schedule. `1` (the default) is the ordinary unsegmented
    /// path. Phantom-only. Blocks smaller than K bytes simply occupy
    /// fewer than K segments — the byte split is exact (floor
    /// partition), dense workloads keep the zero-byte shares as
    /// structural sends, sparse workloads drop them.
    pub segments: usize,
    /// Pipelined stitch (`overlap=true`): segment i's compute runs while
    /// segment i−1's final round is in flight, so hiding is measured on
    /// the virtual clock (`exposed_comm`/`hidden_comm`). Requires
    /// `segments >= 2`; the default (`false`) is the blocking stitch.
    pub overlap: bool,
    /// Per-segment compute cost in seconds (`compute=secs`), charged by
    /// the overlap driver ahead of each segment on every rank — the
    /// constant-cost stand-in for an application's per-slab work.
    /// Requires `segments >= 2`.
    pub compute: f64,
    /// Plan-compile worker threads (`compile-threads=N`); `None`
    /// (`compile-threads=auto`, the default) sizes from P and the host
    /// ([`crate::comm::Engine::compile_threads_for`]). Purely a
    /// compile-wallclock knob — the compiled plan is bit-identical for
    /// every value.
    pub compile_threads: Option<usize>,
    /// Print plan-IR statistics after the run (`plan-stats=true`): total
    /// ops, distinct interned programs, arena bytes and the interned /
    /// legacy byte ratio. Replay-path only (threaded runs never compile
    /// a plan).
    pub plan_stats: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            p: 64,
            q: 8,
            profile: MachineProfile::fugaku(),
            dist: Dist::Uniform { max: 1024 },
            seed: 0xC0FFEE,
            iters: 5,
            real_payloads: false,
            engine_limit_linear: 512,
            engine_limit_log: 2048,
            engine_limit_replay: 8192,
            engine_limit_replay_sparse: 65536,
            mode: ExecMode::Auto,
            persistent: false,
            replay_shards: None,
            tuning: None,
            faults: FaultSpec::default(),
            segments: 1,
            overlap: false,
            compute: 0.0,
            compile_threads: None,
            plan_stats: false,
        }
    }
}

impl RunConfig {
    /// Parse `key=value` arguments: `p=128 q=16 profile=polaris
    /// dist=uniform:1024 seed=7 iters=20 real=true limit-linear=256
    /// limit-log=1024 limit-replay=8192 limit-replay-sparse=65536
    /// mode=replay replay-shards=4 persistent=true`. Unknown keys are
    /// errors (typos should not pass silently).
    pub fn parse_args(args: &[String]) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        for arg in args {
            let (k, v) = arg
                .split_once('=')
                .ok_or_else(|| TunaError::config(format!("expected key=value, got `{arg}`")))?;
            match k {
                "p" => cfg.p = parse_num(k, v)?,
                "q" => cfg.q = parse_num(k, v)?,
                "seed" => cfg.seed = parse_num(k, v)? as u64,
                "iters" => cfg.iters = parse_num(k, v)?,
                "real" => {
                    cfg.real_payloads = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for {k}: `{v}`")))?
                }
                "persistent" => {
                    cfg.persistent = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for {k}: `{v}`")))?
                }
                "limit-linear" => cfg.engine_limit_linear = parse_num(k, v)?,
                "limit-log" => cfg.engine_limit_log = parse_num(k, v)?,
                "limit-replay" => cfg.engine_limit_replay = parse_num(k, v)?,
                "limit-replay-sparse" => cfg.engine_limit_replay_sparse = parse_num(k, v)?,
                "replay-shards" => {
                    cfg.replay_shards = if v == "auto" {
                        None
                    } else {
                        let n = parse_num(k, v)?;
                        if n == 0 {
                            return Err(TunaError::config(
                                "replay-shards must be >= 1 (or `auto`)",
                            ));
                        }
                        Some(n)
                    }
                }
                "compile-threads" => {
                    cfg.compile_threads = if v == "auto" {
                        None
                    } else {
                        let n = parse_num(k, v)?;
                        if n == 0 {
                            return Err(TunaError::config(
                                "compile-threads must be >= 1 (or `auto`)",
                            ));
                        }
                        Some(n)
                    }
                }
                "plan-stats" => {
                    cfg.plan_stats = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for {k}: `{v}`")))?
                }
                "mode" => {
                    cfg.mode = ExecMode::parse(v).ok_or_else(|| {
                        TunaError::config(format!(
                            "unknown mode `{v}` (try auto, threaded, replay)"
                        ))
                    })?
                }
                "profile" => {
                    cfg.profile = MachineProfile::by_name(v).ok_or_else(|| {
                        TunaError::config(format!(
                            "unknown profile `{v}` (try polaris, fugaku, test-flat)"
                        ))
                    })?
                }
                "dist" => {
                    cfg.dist = Dist::parse(v).ok_or_else(|| {
                        TunaError::config(format!(
                            "unknown dist `{v}` (try uniform:1024, normal, powerlaw, const:64, fft-n1, fft-n2, sparse:nnz=16)"
                        ))
                    })?
                }
                "faults" => cfg.faults = FaultSpec::parse(v)?,
                "segments" => cfg.segments = parse_num(k, v)?,
                "overlap" => {
                    cfg.overlap = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for {k}: `{v}`")))?
                }
                "compute" => {
                    cfg.compute = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad number for {k}: `{v}`")))?
                }
                _ => {
                    return Err(TunaError::config(format!("unknown config key `{k}`")));
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.p < 2 {
            return Err(TunaError::config("need at least 2 ranks"));
        }
        // Topology shape errors (q = 0, q ∤ p) surface here as typed
        // config errors — the same check every engine construction path
        // goes through (`Topology::try_new`), so they can never reach a
        // rank-thread panic.
        crate::comm::Topology::try_new(self.p, self.q)?;
        if self.iters == 0 {
            return Err(TunaError::config("iters must be >= 1"));
        }
        if self.mode == ExecMode::Replay && self.real_payloads {
            return Err(TunaError::config(
                "mode=replay is phantom-only (real payloads need the threaded oracle); \
                 set real=false or mode=threaded",
            ));
        }
        if self.segments == 0 {
            return Err(TunaError::config(
                "segments must be >= 1 (segments=1 is the unsegmented run)",
            ));
        }
        if self.overlap && self.segments < 2 {
            return Err(TunaError::config(
                "overlap=true requires segments >= 2 (nothing to pipeline with one segment)",
            ));
        }
        if self.compute != 0.0 && self.segments < 2 {
            return Err(TunaError::config(
                "compute= requires segments >= 2 (per-segment cost needs segments)",
            ));
        }
        if !self.compute.is_finite() || self.compute < 0.0 {
            return Err(TunaError::config(
                "compute must be a finite number of seconds >= 0",
            ));
        }
        if self.segments > 1 && self.real_payloads {
            return Err(TunaError::config(
                "segments are phantom-only (plans model byte ranges, never payload bytes); \
                 set real=false",
            ));
        }
        if self.segments > 1 && self.persistent {
            return Err(TunaError::config(
                "persistent=true does not compose with segments yet: a handle freezes one \
                 plan, the segmented driver stitches per call",
            ));
        }
        // Machine parameters must be sane before any engine is built
        // from them — a NaN latency silently poisons every makespan.
        self.profile.validate()?;
        // Fault targets must exist on this topology.
        self.faults.check(self.p, self.q)?;
        Ok(())
    }
}

/// Configuration of a selector run (`tuna select`): the experiment point
/// plus selection-specific knobs.
#[derive(Clone, Debug)]
pub struct SelectConfig {
    pub run: RunConfig,
    /// How many model-ranked candidates to refine with engine
    /// measurements.
    pub shortlist: usize,
    /// Whether to refine at all (pure model ranking when false).
    pub refine: bool,
    /// Stress the refinement stage under skew: additionally measure each
    /// shortlisted candidate on a heavy-tailed companion of the workload
    /// ([`Dist::skewed_companion`]) and score it by the worse of the two,
    /// so the selected algorithm is robust to skewed distributions.
    pub skewed_refine: bool,
    /// Stress the refinement stage under faults (`faulted=<spec>`):
    /// additionally measure each shortlisted candidate with the given
    /// fault spec injected and score it by the worse of the healthy and
    /// (rescaled) faulted measurements, mirroring `skewed_refine` — so
    /// the selected algorithm degrades gracefully on sick machines.
    pub faulted_refine: Option<FaultSpec>,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig {
            run: RunConfig::default(),
            shortlist: 6,
            refine: true,
            skewed_refine: false,
            faulted_refine: None,
        }
    }
}

impl SelectConfig {
    /// Parse `key=value` arguments: selector keys (`shortlist=N`,
    /// `refine=true|false`, `skewed=true|false`, `faulted=<spec>`) are
    /// consumed here, everything else is delegated to
    /// [`RunConfig::parse_args`].
    pub fn parse_args(args: &[String]) -> Result<SelectConfig> {
        let mut cfg = SelectConfig::default();
        let mut rest: Vec<String> = Vec::new();
        for arg in args {
            match arg.split_once('=') {
                Some(("shortlist", v)) => cfg.shortlist = parse_num("shortlist", v)?,
                Some(("refine", v)) => {
                    cfg.refine = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for refine: `{v}`")))?
                }
                Some(("skewed", v)) => {
                    cfg.skewed_refine = v
                        .parse()
                        .map_err(|_| TunaError::config(format!("bad bool for skewed: `{v}`")))?
                }
                Some(("faulted", v)) => cfg.faulted_refine = Some(FaultSpec::parse(v)?),
                _ => rest.push(arg.clone()),
            }
        }
        cfg.run = RunConfig::parse_args(&rest)?;
        if let Some(spec) = &cfg.faulted_refine {
            spec.check(cfg.run.p, cfg.run.q)?;
        }
        Ok(cfg)
    }
}

fn parse_num(key: &str, v: &str) -> Result<usize> {
    v.parse()
        .map_err(|_| TunaError::config(format!("bad number for {key}: `{v}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_full_config() {
        let cfg = RunConfig::parse_args(&args(
            "p=128 q=16 profile=polaris dist=uniform:2048 seed=7 iters=20 real=true",
        ))
        .unwrap();
        assert_eq!(cfg.p, 128);
        assert_eq!(cfg.q, 16);
        assert_eq!(cfg.profile.name, "polaris");
        assert_eq!(cfg.dist, Dist::Uniform { max: 2048 });
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.iters, 20);
        assert!(cfg.real_payloads);
    }

    #[test]
    fn parse_persistent() {
        assert!(!RunConfig::default().persistent);
        assert!(RunConfig::parse_args(&args("p=64 q=8 persistent=true")).unwrap().persistent);
        assert!(!RunConfig::parse_args(&args("p=64 q=8 persistent=false")).unwrap().persistent);
        assert!(RunConfig::parse_args(&args("persistent=maybe")).is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(RunConfig::parse_args(&args("px=128")).is_err());
    }

    #[test]
    fn parse_mode_and_replay_limit() {
        let cfg = RunConfig::parse_args(&args(
            "p=64 q=8 mode=replay limit-replay=16384 limit-replay-sparse=65536",
        ))
        .unwrap();
        assert_eq!(cfg.mode, ExecMode::Replay);
        assert_eq!(cfg.engine_limit_replay, 16384);
        assert_eq!(cfg.engine_limit_replay_sparse, 65536);
        // Mode-aware defaults: dense log plans stream (8192), sparse
        // plans scale with nnz and shard across workers (65536).
        assert_eq!(RunConfig::default().engine_limit_replay, 8192);
        assert_eq!(RunConfig::default().engine_limit_replay_sparse, 65536);
        assert_eq!(RunConfig::default().mode, ExecMode::Auto);
        assert!(RunConfig::parse_args(&args("mode=turbo")).is_err());
        // Replay never materializes payload bytes: the combination with
        // real payloads is a contradiction, not a silent downgrade.
        assert!(RunConfig::parse_args(&args("mode=replay real=true")).is_err());
        assert!(RunConfig::parse_args(&args("mode=auto real=true")).is_ok());
    }

    #[test]
    fn parse_replay_shards() {
        assert_eq!(RunConfig::default().replay_shards, None, "default is auto");
        let cfg = RunConfig::parse_args(&args("p=64 q=8 replay-shards=4")).unwrap();
        assert_eq!(cfg.replay_shards, Some(4));
        let cfg = RunConfig::parse_args(&args("p=64 q=8 replay-shards=auto")).unwrap();
        assert_eq!(cfg.replay_shards, None);
        assert!(RunConfig::parse_args(&args("replay-shards=0")).is_err());
        assert!(RunConfig::parse_args(&args("replay-shards=lots")).is_err());
    }

    #[test]
    fn parse_compile_threads_and_plan_stats() {
        let d = RunConfig::default();
        assert_eq!(d.compile_threads, None, "default is auto");
        assert!(!d.plan_stats);
        let cfg =
            RunConfig::parse_args(&args("p=64 q=8 compile-threads=4 plan-stats=true")).unwrap();
        assert_eq!(cfg.compile_threads, Some(4));
        assert!(cfg.plan_stats);
        let cfg = RunConfig::parse_args(&args("p=64 q=8 compile-threads=auto")).unwrap();
        assert_eq!(cfg.compile_threads, None);
        assert!(RunConfig::parse_args(&args("compile-threads=0")).is_err());
        assert!(RunConfig::parse_args(&args("compile-threads=many")).is_err());
        assert!(RunConfig::parse_args(&args("plan-stats=maybe")).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::parse_args(&args("p=abc")).is_err());
        assert!(RunConfig::parse_args(&args("profile=summit")).is_err());
        assert!(RunConfig::parse_args(&args("dist=zipf")).is_err());
        assert!(RunConfig::parse_args(&args("p")).is_err());
    }

    #[test]
    fn rejects_inconsistent_topology() {
        assert!(RunConfig::parse_args(&args("p=10 q=4")).is_err());
        assert!(RunConfig::parse_args(&args("p=1 q=1")).is_err());
        assert!(RunConfig::parse_args(&args("iters=0")).is_err());
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_sparse_dist() {
        let cfg = RunConfig::parse_args(&args("p=64 q=8 dist=sparse:nnz=16")).unwrap();
        assert_eq!(cfg.dist, Dist::Sparse { nnz: 16, max: 1024 });
        let cfg = RunConfig::parse_args(&args("p=64 q=8 dist=sparse:nnz=4,max=256")).unwrap();
        assert_eq!(cfg.dist, Dist::Sparse { nnz: 4, max: 256 });
        assert!(RunConfig::parse_args(&args("dist=sparse")).is_err());
    }

    #[test]
    fn parse_faults() {
        assert!(RunConfig::default().faults.is_empty(), "default is healthy");
        let cfg = RunConfig::parse_args(&args(
            "p=64 q=8 faults=straggler:rank=7,slow=4/jitter:sigma=0.1,seed=3",
        ))
        .unwrap();
        assert_eq!(cfg.faults.spec(), "straggler:rank=7,slow=4/jitter:sigma=0.1,seed=3");
        // Malformed specs and out-of-range targets fail loudly.
        assert!(RunConfig::parse_args(&args("faults=straggler:rank=7")).is_err());
        assert!(RunConfig::parse_args(&args("p=8 q=2 faults=straggler:rank=8,slow=2")).is_err());
        assert!(RunConfig::parse_args(&args("p=8 q=2 faults=link:node=0-4,bw=0.5")).is_err());
        assert!(RunConfig::parse_args(&args("p=8 q=2 faults=outage:node=4,until=1")).is_err());
    }

    #[test]
    fn parse_segments_and_overlap() {
        let d = RunConfig::default();
        assert_eq!((d.segments, d.overlap, d.compute), (1, false, 0.0));
        let cfg =
            RunConfig::parse_args(&args("p=64 q=8 segments=4 overlap=true compute=1e-4")).unwrap();
        assert_eq!(cfg.segments, 4);
        assert!(cfg.overlap);
        assert!((cfg.compute - 1e-4).abs() < 1e-18);
        // Each bad combination is a typed error naming the problem.
        let err = |s: &str| RunConfig::parse_args(&args(s)).unwrap_err().to_string();
        assert!(err("p=64 q=8 segments=0").contains("segments must be >= 1"));
        assert!(err("p=64 q=8 overlap=true").contains("requires segments >= 2"));
        assert!(err("p=64 q=8 segments=1 overlap=true").contains("requires segments >= 2"));
        assert!(err("p=64 q=8 compute=1e-4").contains("requires segments >= 2"));
        assert!(err("p=64 q=8 segments=4 compute=-1").contains("finite number of seconds"));
        assert!(err("p=64 q=8 segments=4 real=true").contains("phantom-only"));
        assert!(err("p=64 q=8 segments=4 persistent=true").contains("persistent"));
        assert!(err("p=64 q=8 overlap=maybe").contains("bad bool for overlap"));
        assert!(err("p=64 q=8 segments=two").contains("bad number for segments"));
    }

    #[test]
    fn select_config_splits_its_keys() {
        let cfg = SelectConfig::parse_args(&args(
            "p=64 q=8 shortlist=3 refine=false skewed=true seed=9",
        ))
        .unwrap();
        assert_eq!(cfg.shortlist, 3);
        assert!(!cfg.refine);
        assert!(cfg.skewed_refine);
        assert_eq!(cfg.run.p, 64);
        assert_eq!(cfg.run.seed, 9);
        assert!(!SelectConfig::parse_args(&args("p=64 q=8")).unwrap().skewed_refine);
        // Run-config typos still fail loudly through the delegation.
        assert!(SelectConfig::parse_args(&args("shortlist=3 px=1")).is_err());
        assert!(SelectConfig::parse_args(&args("refine=maybe")).is_err());
        assert!(SelectConfig::parse_args(&args("skewed=maybe")).is_err());
    }

    #[test]
    fn select_config_parses_faulted_refine() {
        assert!(SelectConfig::default().faulted_refine.is_none());
        let cfg = SelectConfig::parse_args(&args("p=64 q=8 faulted=straggler:rank=3,slow=8"))
            .unwrap();
        assert_eq!(
            cfg.faulted_refine.as_ref().map(|s| s.spec()).as_deref(),
            Some("straggler:rank=3,slow=8")
        );
        assert!(cfg.run.faults.is_empty(), "faulted= stresses refinement, not the base run");
        // The stress spec is range-checked against the run topology too.
        assert!(SelectConfig::parse_args(&args("p=8 q=2 faulted=straggler:rank=99,slow=2")).is_err());
        assert!(SelectConfig::parse_args(&args("faulted=bogus")).is_err());
    }
}
