//! Graph mining: transitive closure / path finding (§VI-B).
//!
//! The classic semi-naive fixed point over a distributed relation store:
//! `paths(x,y) :- edge(x,y)`; `paths(x,z) :- delta(x,y), edge(y,z)` until
//! no new tuples appear. `edge` is hash-partitioned by its *first* column
//! (the join key), `paths`/`delta` by the *second*; every iteration's new
//! tuples are shuffled to their owners with a non-uniform all-to-all —
//! the MPI_Alltoallv call our algorithms substitute for (the paper runs
//! >5,800 such iterations on its SuiteSparse graph).

use std::collections::{HashMap, HashSet};

use crate::algos::AlgoKind;
use crate::comm::{Block, DataBuf, Engine, RankCtx};
use crate::error::Result;
use crate::workload::graph::Graph;

/// Result of a distributed transitive-closure run.
#[derive(Clone, Debug)]
pub struct TcReport {
    /// |TC(G)|: number of reachable (x, y) pairs, x != y paths included
    /// as discovered.
    pub paths: u64,
    /// Fixed-point iterations executed.
    pub iterations: usize,
    /// Simulated communication + compute time (max over ranks).
    pub makespan: f64,
    /// Simulated time spent inside all-to-all exchanges only.
    pub comm_time: f64,
    /// Host wallclock for the whole run.
    pub wall: f64,
}

/// Compute the transitive closure sequentially (oracle for validation).
pub fn sequential_tc(g: &Graph) -> u64 {
    let mut adj: HashMap<u32, Vec<u32>> = HashMap::new();
    for &(a, b) in &g.edges {
        adj.entry(a).or_default().push(b);
    }
    let mut total = 0u64;
    for start in 0..g.n as u32 {
        let mut seen: HashSet<u32> = HashSet::new();
        let mut stack = vec![start];
        while let Some(v) = stack.pop() {
            if let Some(nexts) = adj.get(&v) {
                for &w in nexts {
                    if seen.insert(w) {
                        stack.push(w);
                    }
                }
            }
        }
        total += seen.len() as u64;
    }
    total
}

fn encode(tuples: &[(u32, u32)]) -> DataBuf {
    let mut bytes = Vec::with_capacity(tuples.len() * 8);
    for &(a, b) in tuples {
        bytes.extend_from_slice(&a.to_le_bytes());
        bytes.extend_from_slice(&b.to_le_bytes());
    }
    // Written once here; every hop to the owner rank moves views.
    DataBuf::from_vec(bytes)
}

fn decode(buf: &DataBuf) -> Vec<(u32, u32)> {
    // Borrowed in place for the (usual) contiguous rope; materialized
    // only if an algorithm handed us a fragmented aggregate.
    let bytes = buf.to_contiguous();
    let bytes: &[u8] = bytes.as_ref();
    assert!(bytes.len() % 8 == 0, "tuple payload misaligned");
    bytes
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
            )
        })
        .collect()
}

/// Shuffle per-destination tuple buckets through the chosen all-to-all
/// algorithm; returns all tuples owned by this rank.
fn shuffle(
    ctx: &mut RankCtx,
    kind: &AlgoKind,
    mut buckets: Vec<Vec<(u32, u32)>>,
) -> Vec<(u32, u32)> {
    let me = ctx.rank();
    let blocks: Vec<Block> = buckets
        .drain(..)
        .enumerate()
        .map(|(d, tuples)| Block::new(me, d, encode(&tuples)))
        .collect();
    let (recv, _) = kind.dispatch(ctx, blocks);
    let mut out = Vec::new();
    for b in &recv {
        out.extend(decode(&b.data));
    }
    out
}

/// Run distributed transitive closure of `g` on `engine` using `kind` for
/// every shuffle. Validates against [`sequential_tc`] when `validate`.
pub fn run_tc(engine: &Engine, kind: &AlgoKind, g: &Graph, validate: bool) -> Result<TcReport> {
    run_tc_inner(engine, kind, g, validate).map(|(rep, _, _)| rep)
}

/// [`run_tc`], additionally returning the run's aggregate shuffle byte
/// matrix (`matrix[src][dst]` over every exchange of the fixed point)
/// and per-rank host seconds spent in join/dedup compute — the inputs
/// the segmented overlap twin replays.
fn run_tc_inner(
    engine: &Engine,
    kind: &AlgoKind,
    g: &Graph,
    validate: bool,
) -> Result<(TcReport, Vec<Vec<u64>>, Vec<f64>)> {
    let p = engine.topo.p();
    kind.check(p, engine.topo.q())?;
    let wall0 = std::time::Instant::now();
    let g_edges = g.edges.clone();
    let kind = *kind;

    let res = engine.run(move |ctx| {
        let me = ctx.rank();
        let p = ctx.size();
        let own = |v: u32| (v as usize) % p;
        let mut comm_time = 0.0f64;
        // Aggregate per-destination bytes across every shuffle, and the
        // host compute charged to the clock — the overlap twin's inputs.
        let mut sent = vec![0u64; p];
        let mut compute_secs = 0.0f64;
        fn tally(sent: &mut [u64], buckets: &[Vec<(u32, u32)>]) {
            for (d, b) in buckets.iter().enumerate() {
                sent[d] += (b.len() * 8) as u64;
            }
        }

        // Initial distribution: striped ownership of the edge list, then
        // two shuffles to the join/store partitions (real startup comm).
        let my_edges: Vec<(u32, u32)> = g_edges
            .iter()
            .copied()
            .enumerate()
            .filter(|(i, _)| i % p == me)
            .map(|(_, e)| e)
            .collect();

        let mut to_join: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        let mut to_store: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        for &(a, b) in &my_edges {
            to_join[own(a)].push((a, b));
            to_store[own(b)].push((a, b));
        }
        tally(&mut sent, &to_join);
        tally(&mut sent, &to_store);
        let t0 = ctx.now();
        let join_edges = shuffle(ctx, &kind, to_join);
        let stored = shuffle(ctx, &kind, to_store);
        comm_time += ctx.now() - t0;

        // edge index by source vertex (join key).
        let mut edge_by_src: HashMap<u32, Vec<u32>> = HashMap::new();
        for (a, b) in join_edges {
            edge_by_src.entry(a).or_default().push(b);
        }
        // paths / delta, partitioned by destination vertex.
        let mut paths: HashSet<(u32, u32)> = stored.iter().copied().collect();
        let mut delta: Vec<(u32, u32)> = paths.iter().copied().collect();

        let mut iterations = 0usize;
        loop {
            iterations += 1;
            // Join: delta(x, y) ⋈ edge(y, z) — but delta is partitioned by
            // y's owner only after a shuffle of delta to the join
            // partition.
            let mut delta_to_join: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
            for &(x, y) in &delta {
                delta_to_join[own(y)].push((x, y));
            }
            tally(&mut sent, &delta_to_join);
            let t = ctx.now();
            let delta_joinside = shuffle(ctx, &kind, delta_to_join);
            comm_time += ctx.now() - t;

            let wall_join = std::time::Instant::now();
            let mut new_buckets: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
            for (x, y) in delta_joinside {
                if let Some(zs) = edge_by_src.get(&y) {
                    for &z in zs {
                        // Note: (x, x) tuples are kept — a vertex on a
                        // cycle genuinely reaches itself in TC.
                        new_buckets[own(z)].push((x, z));
                    }
                }
            }
            // Charge the real join work to the virtual clock too, so the
            // simulated total reflects compute + comm.
            let join_secs = wall_join.elapsed().as_secs_f64();
            compute_secs += join_secs;
            ctx.compute(join_secs);

            tally(&mut sent, &new_buckets);
            let t = ctx.now();
            let arrivals = shuffle(ctx, &kind, new_buckets);
            comm_time += ctx.now() - t;

            let wall_dedup = std::time::Instant::now();
            delta = arrivals
                .into_iter()
                .filter(|tup| paths.insert(*tup))
                .collect();
            let dedup_secs = wall_dedup.elapsed().as_secs_f64();
            compute_secs += dedup_secs;
            ctx.compute(dedup_secs);

            let fresh = ctx.allreduce_sum(delta.len() as u64);
            if fresh == 0 {
                break;
            }
        }
        (paths.len() as u64, iterations, comm_time, sent, compute_secs)
    });

    let paths: u64 = res.ranks.iter().map(|r| r.value.0).sum();
    let iterations = res.ranks.iter().map(|r| r.value.1).max().unwrap_or(0);
    let comm_time = res
        .ranks
        .iter()
        .map(|r| r.value.2)
        .fold(0.0f64, f64::max);
    let matrix: Vec<Vec<u64>> = res.ranks.iter().map(|r| r.value.3.clone()).collect();
    let compute_secs: Vec<f64> = res.ranks.iter().map(|r| r.value.4).collect();

    if validate {
        let expect = sequential_tc(g);
        if paths != expect {
            return Err(crate::TunaError::validation(format!(
                "TC size mismatch: distributed {paths} vs sequential {expect}"
            )));
        }
    }

    Ok((
        TcReport {
            paths,
            iterations,
            makespan: res.makespan,
            comm_time,
            wall: wall0.elapsed().as_secs_f64(),
        },
        matrix,
        compute_secs,
    ))
}

/// Timing twin of [`run_tc`] under segmented overlap: blocking vs
/// pipelined accounting of the mining run's aggregate shuffle traffic.
#[derive(Clone, Debug)]
pub struct TcOverlapReport {
    /// The validated blocking run the twin is derived from.
    pub base: TcReport,
    /// Segment count K of the phantom timing runs.
    pub segments: usize,
    /// Makespan with join compute serialized before each exchange
    /// segment (overlap=false).
    pub blocking_makespan: f64,
    /// Makespan with segment-i join work interleaved into
    /// segment-(i−1)'s exchange (overlap=true).
    pub pipelined_makespan: f64,
    /// Comm seconds program order stalled on, blocking run.
    pub exposed_blocking: f64,
    /// Same, pipelined run.
    pub exposed_pipelined: f64,
    /// Comm seconds hidden behind host progress, pipelined run.
    pub hidden_pipelined: f64,
}

/// Run the validated transitive closure once, then re-run its aggregate
/// shuffle traffic as one segmented phantom collective, twice — blocking
/// and pipelined — charging each rank's measured join/dedup seconds in K
/// per-segment slices. The counts matrix is the run's own: `matrix[src]
/// [dst]` sums the tuple bytes `src` shipped to `dst` over every
/// exchange of the fixed point, so the twin times exactly the traffic
/// the mining run moved.
pub fn run_tc_overlap(
    engine: &Engine,
    kind: &AlgoKind,
    g: &Graph,
    validate: bool,
    segments: usize,
) -> Result<TcOverlapReport> {
    use crate::algos::{run_alltoallv_segmented, SegmentCompute};
    use crate::workload::BlockSizes;
    if segments == 0 {
        return Err(crate::TunaError::config(
            "segments must be >= 1 (segments=1 is the unsegmented run)",
        ));
    }
    let (base, matrix, compute_secs) = run_tc_inner(engine, kind, g, validate)?;
    let sizes = BlockSizes::from_dense(matrix);
    let per_segment = move |rank: usize, _segment: usize| compute_secs[rank] / segments as f64;
    let compute = SegmentCompute::PerRank(&per_segment);
    let blocking = run_alltoallv_segmented(engine, kind, &sizes, segments, false, &compute)?;
    let pipelined = run_alltoallv_segmented(engine, kind, &sizes, segments, true, &compute)?;
    Ok(TcOverlapReport {
        base,
        segments,
        blocking_makespan: blocking.makespan,
        pipelined_makespan: pipelined.makespan,
        exposed_blocking: blocking.counters.exposed_comm,
        exposed_pipelined: pipelined.counters.exposed_comm,
        hidden_pipelined: pipelined.counters.hidden_comm,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::model::MachineProfile;

    fn engine(p: usize, q: usize) -> Engine {
        Engine::new(MachineProfile::test_flat(), Topology::new(p, q))
    }

    #[test]
    fn sequential_oracle_on_known_graphs() {
        // Chain of n: TC has n(n-1)/2 pairs.
        assert_eq!(sequential_tc(&Graph::chain(5)), 10);
        assert_eq!(sequential_tc(&Graph::chain(10)), 45);
        // Depth-2 binary tree (7 nodes): each vertex reaches its subtree.
        // Internal: root reaches 6, two mid reach 2 each => 6+2+2 = 10.
        assert_eq!(sequential_tc(&Graph::binary_tree(2)), 10);
    }

    #[test]
    fn distributed_matches_sequential_chain() {
        let g = Graph::chain(24);
        let rep = run_tc(&engine(4, 2), &AlgoKind::Tuna { radix: 2 }, &g, true).unwrap();
        assert_eq!(rep.paths, 24 * 23 / 2);
        assert!(rep.iterations >= 4, "semi-naive doubles path length per iter");
        assert!(rep.comm_time > 0.0);
        assert!(rep.makespan >= rep.comm_time);
    }

    #[test]
    fn distributed_matches_sequential_scale_free() {
        let g = Graph::scale_free(60, 2, 3);
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(2, 1),
        ] {
            let rep = run_tc(&engine(8, 4), &kind, &g, true).unwrap();
            assert!(rep.paths > 0, "{kind:?}");
        }
    }

    #[test]
    fn pipelined_tc_twin_hides_join_compute() {
        let g = Graph::chain(24);
        let rep = run_tc_overlap(&engine(4, 2), &AlgoKind::Tuna { radix: 2 }, &g, true, 4).unwrap();
        assert_eq!(rep.base.paths, 24 * 23 / 2);
        // The twin moved real traffic with real measured compute: the
        // pipeline must hide some of the exchange the blocking schedule
        // exposes, never at a makespan cost.
        assert!(rep.exposed_blocking > 0.0);
        assert!(
            rep.exposed_pipelined < rep.exposed_blocking,
            "pipeline hid nothing: exposed {} vs blocking {}",
            rep.exposed_pipelined,
            rep.exposed_blocking
        );
        assert!(rep.hidden_pipelined > 0.0);
        assert!(rep.pipelined_makespan <= rep.blocking_makespan);
        // segments=0 is a typed config error, not a panic.
        let e = run_tc_overlap(&engine(4, 2), &AlgoKind::Tuna { radix: 2 }, &g, false, 0)
            .unwrap_err()
            .to_string();
        assert!(e.contains("segments"), "{e}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let tuples = vec![(1u32, 2u32), (70000, 3), (0, 0)];
        assert_eq!(decode(&encode(&tuples)), tuples);
        assert_eq!(decode(&encode(&[])), vec![]);
    }

    #[test]
    fn works_on_single_node_and_flat_topologies() {
        let g = Graph::binary_tree(3);
        let expect = sequential_tc(&g);
        let a = run_tc(&engine(4, 4), &AlgoKind::Pairwise, &g, false).unwrap();
        let b = run_tc(&engine(4, 1), &AlgoKind::Scattered { block_count: 2 }, &g, false).unwrap();
        assert_eq!(a.paths, expect);
        assert_eq!(b.paths, expect);
    }
}
