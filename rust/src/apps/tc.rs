//! Graph mining: transitive closure / path finding (§VI-B).
//!
//! The classic semi-naive fixed point over a distributed relation store:
//! `paths(x,y) :- edge(x,y)`; `paths(x,z) :- delta(x,y), edge(y,z)` until
//! no new tuples appear. `edge` is hash-partitioned by its *first* column
//! (the join key), `paths`/`delta` by the *second*; every iteration's new
//! tuples are shuffled to their owners with a non-uniform all-to-all —
//! the MPI_Alltoallv call our algorithms substitute for (the paper runs
//! >5,800 such iterations on its SuiteSparse graph).

use std::collections::{HashMap, HashSet};

use crate::algos::AlgoKind;
use crate::comm::{Block, DataBuf, Engine, RankCtx};
use crate::error::Result;
use crate::workload::graph::Graph;

/// Result of a distributed transitive-closure run.
#[derive(Clone, Debug)]
pub struct TcReport {
    /// |TC(G)|: number of reachable (x, y) pairs, x != y paths included
    /// as discovered.
    pub paths: u64,
    /// Fixed-point iterations executed.
    pub iterations: usize,
    /// Simulated communication + compute time (max over ranks).
    pub makespan: f64,
    /// Simulated time spent inside all-to-all exchanges only.
    pub comm_time: f64,
    /// Host wallclock for the whole run.
    pub wall: f64,
}

/// Compute the transitive closure sequentially (oracle for validation).
pub fn sequential_tc(g: &Graph) -> u64 {
    let mut adj: HashMap<u32, Vec<u32>> = HashMap::new();
    for &(a, b) in &g.edges {
        adj.entry(a).or_default().push(b);
    }
    let mut total = 0u64;
    for start in 0..g.n as u32 {
        let mut seen: HashSet<u32> = HashSet::new();
        let mut stack = vec![start];
        while let Some(v) = stack.pop() {
            if let Some(nexts) = adj.get(&v) {
                for &w in nexts {
                    if seen.insert(w) {
                        stack.push(w);
                    }
                }
            }
        }
        total += seen.len() as u64;
    }
    total
}

fn encode(tuples: &[(u32, u32)]) -> DataBuf {
    let mut bytes = Vec::with_capacity(tuples.len() * 8);
    for &(a, b) in tuples {
        bytes.extend_from_slice(&a.to_le_bytes());
        bytes.extend_from_slice(&b.to_le_bytes());
    }
    // Written once here; every hop to the owner rank moves views.
    DataBuf::from_vec(bytes)
}

fn decode(buf: &DataBuf) -> Vec<(u32, u32)> {
    // Borrowed in place for the (usual) contiguous rope; materialized
    // only if an algorithm handed us a fragmented aggregate.
    let bytes = buf.to_contiguous();
    let bytes: &[u8] = bytes.as_ref();
    assert!(bytes.len() % 8 == 0, "tuple payload misaligned");
    bytes
        .chunks_exact(8)
        .map(|c| {
            (
                u32::from_le_bytes([c[0], c[1], c[2], c[3]]),
                u32::from_le_bytes([c[4], c[5], c[6], c[7]]),
            )
        })
        .collect()
}

/// Shuffle per-destination tuple buckets through the chosen all-to-all
/// algorithm; returns all tuples owned by this rank.
fn shuffle(
    ctx: &mut RankCtx,
    kind: &AlgoKind,
    mut buckets: Vec<Vec<(u32, u32)>>,
) -> Vec<(u32, u32)> {
    let me = ctx.rank();
    let blocks: Vec<Block> = buckets
        .drain(..)
        .enumerate()
        .map(|(d, tuples)| Block::new(me, d, encode(&tuples)))
        .collect();
    let (recv, _) = kind.dispatch(ctx, blocks);
    let mut out = Vec::new();
    for b in &recv {
        out.extend(decode(&b.data));
    }
    out
}

/// Run distributed transitive closure of `g` on `engine` using `kind` for
/// every shuffle. Validates against [`sequential_tc`] when `validate`.
pub fn run_tc(engine: &Engine, kind: &AlgoKind, g: &Graph, validate: bool) -> Result<TcReport> {
    let p = engine.topo.p();
    kind.check(p, engine.topo.q())?;
    let wall0 = std::time::Instant::now();
    let g_edges = g.edges.clone();
    let kind = *kind;

    let res = engine.run(move |ctx| {
        let me = ctx.rank();
        let p = ctx.size();
        let own = |v: u32| (v as usize) % p;
        let mut comm_time = 0.0f64;

        // Initial distribution: striped ownership of the edge list, then
        // two shuffles to the join/store partitions (real startup comm).
        let my_edges: Vec<(u32, u32)> = g_edges
            .iter()
            .copied()
            .enumerate()
            .filter(|(i, _)| i % p == me)
            .map(|(_, e)| e)
            .collect();

        let mut to_join: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        let mut to_store: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
        for &(a, b) in &my_edges {
            to_join[own(a)].push((a, b));
            to_store[own(b)].push((a, b));
        }
        let t0 = ctx.now();
        let join_edges = shuffle(ctx, &kind, to_join);
        let stored = shuffle(ctx, &kind, to_store);
        comm_time += ctx.now() - t0;

        // edge index by source vertex (join key).
        let mut edge_by_src: HashMap<u32, Vec<u32>> = HashMap::new();
        for (a, b) in join_edges {
            edge_by_src.entry(a).or_default().push(b);
        }
        // paths / delta, partitioned by destination vertex.
        let mut paths: HashSet<(u32, u32)> = stored.iter().copied().collect();
        let mut delta: Vec<(u32, u32)> = paths.iter().copied().collect();

        let mut iterations = 0usize;
        loop {
            iterations += 1;
            // Join: delta(x, y) ⋈ edge(y, z) — but delta is partitioned by
            // y's owner only after a shuffle of delta to the join
            // partition.
            let mut delta_to_join: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
            for &(x, y) in &delta {
                delta_to_join[own(y)].push((x, y));
            }
            let t = ctx.now();
            let delta_joinside = shuffle(ctx, &kind, delta_to_join);
            comm_time += ctx.now() - t;

            let wall_join = std::time::Instant::now();
            let mut new_buckets: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p];
            for (x, y) in delta_joinside {
                if let Some(zs) = edge_by_src.get(&y) {
                    for &z in zs {
                        // Note: (x, x) tuples are kept — a vertex on a
                        // cycle genuinely reaches itself in TC.
                        new_buckets[own(z)].push((x, z));
                    }
                }
            }
            // Charge the real join work to the virtual clock too, so the
            // simulated total reflects compute + comm.
            ctx.compute(wall_join.elapsed().as_secs_f64());

            let t = ctx.now();
            let arrivals = shuffle(ctx, &kind, new_buckets);
            comm_time += ctx.now() - t;

            let wall_dedup = std::time::Instant::now();
            delta = arrivals
                .into_iter()
                .filter(|tup| paths.insert(*tup))
                .collect();
            ctx.compute(wall_dedup.elapsed().as_secs_f64());

            let fresh = ctx.allreduce_sum(delta.len() as u64);
            if fresh == 0 {
                break;
            }
        }
        (paths.len() as u64, iterations, comm_time)
    });

    let paths: u64 = res.ranks.iter().map(|r| r.value.0).sum();
    let iterations = res.ranks.iter().map(|r| r.value.1).max().unwrap_or(0);
    let comm_time = res
        .ranks
        .iter()
        .map(|r| r.value.2)
        .fold(0.0f64, f64::max);

    if validate {
        let expect = sequential_tc(g);
        if paths != expect {
            return Err(crate::TunaError::validation(format!(
                "TC size mismatch: distributed {paths} vs sequential {expect}"
            )));
        }
    }

    Ok(TcReport {
        paths,
        iterations,
        makespan: res.makespan,
        comm_time,
        wall: wall0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::model::MachineProfile;

    fn engine(p: usize, q: usize) -> Engine {
        Engine::new(MachineProfile::test_flat(), Topology::new(p, q))
    }

    #[test]
    fn sequential_oracle_on_known_graphs() {
        // Chain of n: TC has n(n-1)/2 pairs.
        assert_eq!(sequential_tc(&Graph::chain(5)), 10);
        assert_eq!(sequential_tc(&Graph::chain(10)), 45);
        // Depth-2 binary tree (7 nodes): each vertex reaches its subtree.
        // Internal: root reaches 6, two mid reach 2 each => 6+2+2 = 10.
        assert_eq!(sequential_tc(&Graph::binary_tree(2)), 10);
    }

    #[test]
    fn distributed_matches_sequential_chain() {
        let g = Graph::chain(24);
        let rep = run_tc(&engine(4, 2), &AlgoKind::Tuna { radix: 2 }, &g, true).unwrap();
        assert_eq!(rep.paths, 24 * 23 / 2);
        assert!(rep.iterations >= 4, "semi-naive doubles path length per iter");
        assert!(rep.comm_time > 0.0);
        assert!(rep.makespan >= rep.comm_time);
    }

    #[test]
    fn distributed_matches_sequential_scale_free() {
        let g = Graph::scale_free(60, 2, 3);
        for kind in [
            AlgoKind::SpreadOut,
            AlgoKind::Tuna { radix: 4 },
            AlgoKind::hier_coalesced(2, 1),
        ] {
            let rep = run_tc(&engine(8, 4), &kind, &g, true).unwrap();
            assert!(rep.paths > 0, "{kind:?}");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let tuples = vec![(1u32, 2u32), (70000, 3), (0, 0)];
        assert_eq!(decode(&encode(&tuples)), tuples);
        assert_eq!(decode(&encode(&[])), vec![]);
    }

    #[test]
    fn works_on_single_node_and_flat_topologies() {
        let g = Graph::binary_tree(3);
        let expect = sequential_tc(&g);
        let a = run_tc(&engine(4, 4), &AlgoKind::Pairwise, &g, false).unwrap();
        let b = run_tc(&engine(4, 1), &AlgoKind::Scattered { block_count: 2 }, &g, false).unwrap();
        assert_eq!(a.paths, expect);
        assert_eq!(b.paths, expect);
    }
}
