//! The paper's applications (§VI), each using the all-to-all algorithms
//! through the same block interface MPI_Alltoallv would provide:
//!
//! * [`fft`] — distributed 4-step FFT whose transpose is an all-to-allv
//!   and whose local stages execute AOT-compiled Pallas kernels via PJRT;
//! * [`tc`] — semi-naive transitive closure (path finding) with
//!   hash-partitioned relations shuffled every fixed-point iteration.

pub mod fft;
pub mod tc;
